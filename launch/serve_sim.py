"""Launch a continuous-batching simulation service under Poisson traffic.

Stands up a :class:`repro.runtime.SimServer`, streams procedurally
generated scenes at it with exponential inter-arrival gaps (the
open-loop traffic model serving systems are sized against), and reports
sustained scenes/s, tick latency percentiles, and slab-cache accounting.

Run:  PYTHONPATH=src python launch/serve_sim.py --slots 8 --scenes 32
      PYTHONPATH=src python launch/serve_sim.py --cache-dtype int8 --rate 0.5

See ``docs/serving.md`` for the slot lifecycle and isolation argument,
``benchmarks/serve_bench.py`` for the registered benchmark variant.
"""
import argparse
import logging
import time

import jax

from repro import obs
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.runtime.sim_server import SceneRequest, SimServer, poisson_drive
from repro.scenarios import ScenarioConfig
from repro.scenarios.registry import generate_mixed


def build(args):
    scen = ScenarioConfig(num_map=args.num_map, num_agents=args.num_agents,
                          num_steps=args.num_steps)
    head_dim = args.d_model // args.heads
    if args.encoding == "se2_fourier":
        head_dim = -(-head_dim // 6) * 6      # encoding needs 6 | head_dim
    cfg = AgentSimConfig(d_model=args.d_model, num_layers=args.layers,
                         num_heads=args.heads, head_dim=head_dim,
                         d_ff=4 * args.d_model,
                         num_actions=scen.num_actions,
                         encoding=args.encoding)
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(args.seed))
    return scen, model, params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--scenes", type=int, default=32)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="mean Poisson arrivals per service tick")
    ap.add_argument("--t-hist", type=int, default=4)
    ap.add_argument("--num-map", type=int, default=32)
    ap.add_argument("--num-agents", type=int, default=8)
    ap.add_argument("--num-steps", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--heads", type=int, default=4)
    ap.add_argument("--encoding", default="se2_fourier")
    ap.add_argument("--cache-dtype", default=None,
                    help="float32 / bfloat16 / int8 (default: model dtype)")
    ap.add_argument("--decode-impl", default=None,
                    help="auto / flash_decode / xla / ref (default: model)")
    ap.add_argument("--drain-lag", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry-out", default=None, metavar="PATH",
                    help="write the Chrome/Perfetto trace (spans + final "
                         "registry snapshot) to PATH after the drive; "
                         "render it with python -m repro.launch.obs_report")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="also dump the registry in Prometheus text "
                         "exposition format")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the drive into "
                         "DIR (TensorBoard/Perfetto-loadable; the "
                         "sim_server named_scopes label the XLA ops)")
    ap.add_argument("--postmortem-out", default=None, metavar="PATH",
                    help="dump a SimServer flight-recorder bundle (per-"
                         "slot phase/cursor table + registry tail) to "
                         "PATH after the drive; render with "
                         "python -m repro.launch.obs_report --postmortem")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(message)s")
    log = logging.getLogger("serve_sim")

    reg = obs.Registry()
    scen, model, params = build(args)
    srv = SimServer(model, params, scen, num_slots=args.slots,
                    cache_dtype=args.cache_dtype,
                    decode_impl=args.decode_impl, drain_lag=args.drain_lag,
                    registry=reg)
    scenes = generate_mixed(args.seed, 0, args.scenes, scen)
    reqs = [SceneRequest(uid=i, tensors=s, t_hist=args.t_hist,
                         seed=args.seed, scene_id=i)
            for i, s in enumerate(scenes)]

    log.info("serving %d scenes over %d slots (slab %d rows/slot, "
             "cache_dtype=%s, decode=%s, rate=%.2f/tick)",
             len(reqs), args.slots, srv.max_len,
             args.cache_dtype or "model", args.decode_impl or "model",
             args.rate)
    if args.profile_dir:
        jax.profiler.start_trace(args.profile_dir)
    t0 = time.perf_counter()
    out = poisson_drive(srv, reqs, rate=args.rate, seed=args.seed,
                        warmup_ticks=1)
    wall = time.perf_counter() - t0
    if args.profile_dir:
        jax.profiler.stop_trace()
        log.info("jax profiler trace written under %s", args.profile_dir)
    hist = out["latency"]                 # post-compile working ticks
    stats = srv.stats()
    assert len(srv.done) == len(reqs), "requests lost"
    log.info("drained %d/%d scenes in %d ticks, %.2fs wall "
             "(%.1f scenes/s sustained)", len(srv.done), len(reqs),
             srv.ticks, wall, len(reqs) / max(hist.sum, 1e-9))
    log.info("tick latency (post-compile): p50 %.2f ms  p99 %.2f ms",
             1e3 * hist.percentile(50), 1e3 * hist.percentile(99))
    log.info("slab: %.1f MiB for %d x %d rows; peak occupancy is live "
             "rows / slab rows per tick", stats["slab_mib"],
             args.slots, srv.max_len)
    log.info("compilations: tick=%d admit=%d (must both be 1)",
             int(stats["tick_compilations"]),
             int(stats["admit_compilations"]))
    if args.telemetry_out:
        obs.write_chrome_trace(reg, args.telemetry_out)
        log.info("telemetry trace: %s (load in Perfetto, or render with "
                 "python -m repro.launch.obs_report %s)",
                 args.telemetry_out, args.telemetry_out)
    if args.prom_out:
        with open(args.prom_out, "w") as f:
            f.write(obs.prometheus_text(reg))
        log.info("prometheus exposition: %s", args.prom_out)
    if args.postmortem_out:
        log.info("flight-recorder bundle: %s",
                 srv.dump_postmortem(args.postmortem_out, reason="manual"))


if __name__ == "__main__":
    main()
