"""Multi-device distribution tests.

These run in SUBPROCESSES with ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` so the main test process (and every other test) keeps seeing one
CPU device, per the dry-run isolation rule.
"""
import json
import os
import subprocess
import sys
import textwrap


REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    prelude = "import json, jax, jax.numpy as jnp\n"
    out = subprocess.run([sys.executable, "-c", prelude + textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    last = out.stdout.strip().splitlines()[-1]
    return json.loads(last)


def test_sharded_train_step_matches_single_device():
    """pjit train step on a 4x2 mesh == single-device step, bit-for-bit-ish."""
    res = run_with_devices("""
        import numpy as np
        from repro.configs.base import ModelConfig
        from repro.nn import module as nnm
        from repro.nn.transformer import TransformerLM
        from repro.optim import adamw, chain, clip_by_global_norm
        from repro.runtime.steps import make_train_step
        from repro.distributed.sharding import (sharding_for_specs,
            derive_opt_shardings, use_mesh_rules, batch_sharding)

        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                          num_q_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128, head_dim=16, dtype="float32")
        model = TransformerLM(cfg)
        specs = model.specs()
        params = nnm.init_params(specs, jax.random.key(0))
        opt = chain(clip_by_global_norm(1.0), adamw(1e-2))
        opt_state = opt.init(params)
        step = make_train_step(cfg, opt, remat=False)
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, 128, (8, 32)), jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 128, (8, 32)), jnp.int32)}

        # single device reference
        p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with use_mesh_rules(mesh):
            psh = sharding_for_specs(specs, mesh)
            osh = derive_opt_shardings(specs, jax.eval_shape(opt.init, params),
                                       mesh)
            bsh = {k: batch_sharding(mesh, v.shape) for k, v in batch.items()}
            sp = jax.device_put(params, psh)
            so = jax.device_put(opt_state, osh)
            sb = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
            jstep = jax.jit(step, in_shardings=(psh, osh, bsh),
                            out_shardings=(psh, osh, None))
            p2, o2, m2 = jstep(sp, so, sb)

        dmax = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                         jnp.asarray(b).astype(jnp.float32))))
                   for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        print(json.dumps({"loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
                          "param_maxdiff": dmax}))
    """)
    assert abs(res["loss1"] - res["loss2"]) < 1e-4
    assert res["param_maxdiff"] < 1e-3


def test_pipeline_parallel_matches_sequential():
    """4-stage GPipe schedule == running all layers sequentially."""
    res = run_with_devices("""
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.pipeline import (PipelineConfig,
                                                make_pipelined_fn)

        P_STAGES, LAYERS, M, MB, D = 4, 8, 4, 4, 32
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.normal(size=(LAYERS, D, D)) * 0.2,
                                   jnp.float32),
                  "b": jnp.asarray(rng.normal(size=(LAYERS, D)) * 0.1,
                                   jnp.float32)}
        x = jnp.asarray(rng.normal(size=(M * MB, D)), jnp.float32)

        def layer(w, b, h):
            return jnp.tanh(h @ w + b)

        def seq_apply(params, x):
            def body(h, wb):
                return layer(wb[0], wb[1], h), None
            h, _ = jax.lax.scan(body, x, (params["w"], params["b"]))
            return h

        def stage_fn(stage_params, h):
            def body(h, wb):
                return layer(wb[0], wb[1], h), None
            h, _ = jax.lax.scan(body, h, (stage_params["w"],
                                          stage_params["b"]))
            return h

        mesh = jax.make_mesh((4, 2), ("pipe", "model"))
        cfg = PipelineConfig(num_stages=P_STAGES, num_microbatches=M)
        piped = make_pipelined_fn(stage_fn, mesh, cfg)
        want = seq_apply(params, x)
        got = piped(params, x)
        err = float(jnp.max(jnp.abs(want - got)))
        print(json.dumps({"err": err,
                          "bubble": cfg.bubble_fraction}))
    """)
    assert res["err"] < 1e-5
    assert abs(res["bubble"] - 3 / 7) < 1e-9


def test_compressed_dp_step_tracks_uncompressed():
    """int8+EF cross-pod reduction converges like the f32 baseline."""
    res = run_with_devices("""
        import numpy as np
        from repro.distributed.dp_compress import make_compressed_dp_step
        from repro.optim import sgd

        mesh = jax.make_mesh((2, 4), ("pod", "data"))
        rng = np.random.default_rng(0)
        w_true = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
        X = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
        y = X @ w_true

        def loss_fn(params, batch):
            xb, yb = batch
            pred = xb @ params["w"]
            return jnp.mean((pred - yb) ** 2)

        opt = sgd(0.05)

        def train(compress):
            step = make_compressed_dp_step(loss_fn, opt, mesh,
                                           compress=compress)
            params = {"w": jnp.zeros(16)}
            state = opt.init(params)
            residual = {"w": jnp.zeros(16)}
            losses = []
            for i in range(60):
                params, state, residual, loss = step(params, state, residual,
                                                     (X, y))
                losses.append(float(loss))
            return losses

        lc = train(True)
        lu = train(False)
        print(json.dumps({"final_compressed": lc[-1],
                          "final_uncompressed": lu[-1]}))
    """)
    assert res["final_compressed"] < 1e-2
    assert res["final_uncompressed"] < 1e-2
    assert res["final_compressed"] < res["final_uncompressed"] * 10 + 1e-3


def test_fleet_rollout_sharded_matches_single_device():
    """Scene-sharded fleet eval == single-device engine, BIT-identical.

    The fleet contract (docs/distributed.md): device placement must never
    leak into results — per-slot PRNG keys and validity masks are computed
    on the host from slot identity alone, so the shard_mapped tick is pure
    partitioning. Checked on a ("pod", "data") = (2, 2) mesh, with a slot
    count that doesn't divide the fleet (rounds up with dead lanes) and a
    scene count that forces multiple chunks.
    """
    res = run_with_devices("""
        import numpy as np
        from repro.configs import get_sim_arch
        from repro.launch.mesh import make_fleet_mesh
        from repro.nn import module as nnm
        from repro.nn.agent_sim import AgentSimModel
        from repro.runtime.evaluation import EvalConfig, evaluate_scenes
        from repro.runtime.rollout import RolloutEngine
        from repro.scenarios import registry

        arch = get_sim_arch("sim-se2-fourier").reduced().reduced(
            num_map=12, num_agents=4, num_steps=8)
        scen = arch.scenario_config()
        model = AgentSimModel(arch.agent_sim_config())
        params = nnm.init_params(model.specs(), jax.random.key(0))
        fams = registry.names()
        scenes = [registry.generate_scene(fams[i % len(fams)], 5, i, scen)
                  for i in range(10)]
        cfg = EvalConfig(t_hist=4, n_samples=2, seed=3)

        ref = RolloutEngine(model, params, scen, num_slots=8)
        mesh = make_fleet_mesh(4, pods=2)
        # num_slots=6 does not divide the 4-way fleet: rounds up to 8
        fleet = RolloutEngine(model, params, scen, num_slots=6, mesh=mesh)

        f1 = ref.run([s.tensors for s in scenes], t_hist=4, n_samples=2,
                     seed=3)
        f2 = fleet.run([s.tensors for s in scenes], t_hist=4, n_samples=2,
                       seed=3)
        t1 = evaluate_scenes(ref, scenes, cfg)
        t2 = evaluate_scenes(fleet, scenes, cfg)
        flat = lambda t: {f"{f}/{m}": v for f, row in sorted(t.items())
                          for m, v in sorted(row.items())}
        print(json.dumps({
            "bit_identical": bool(np.array_equal(f1, f2)),
            "rounded_slots": fleet.num_slots,
            "tables_equal": flat(t1) == flat(t2),
            "overall_min_ade": t2["overall"]["min_ade"],
        }))
    """, n=4)
    assert res["bit_identical"], res
    assert res["tables_equal"], res
    assert res["rounded_slots"] == 8
    assert res["overall_min_ade"] == res["overall_min_ade"]  # finite


def test_fleet_mesh_rejects_non_fleet_axes():
    """RolloutEngine only shards scene lanes: a mesh carrying a model axis
    must be rejected loudly, not silently replicate the cache."""
    res = run_with_devices("""
        from repro.configs import get_sim_arch
        from repro.nn import module as nnm
        from repro.nn.agent_sim import AgentSimModel
        from repro.runtime.rollout import RolloutEngine

        arch = get_sim_arch("sim-se2-fourier").reduced().reduced(
            num_map=12, num_agents=4, num_steps=8)
        model = AgentSimModel(arch.agent_sim_config())
        params = nnm.init_params(model.specs(), jax.random.key(0))
        try:
            RolloutEngine(model, params, arch.scenario_config(),
                          num_slots=8,
                          mesh=jax.make_mesh((2, 2), ("data", "model")))
            err = ""
        except ValueError as e:
            err = str(e)
        print(json.dumps({"err": err}))
    """, n=4)
    assert "model" in res["err"], res


def test_elastic_restore_across_mesh_shapes(tmp_path):
    """Checkpoint saved on a 4x2 mesh restores onto 2x4 and 8x1 meshes."""
    res = run_with_devices(f"""
        import numpy as np
        from repro.checkpoint import CheckpointManager
        from repro.configs.base import ModelConfig
        from repro.nn import module as nnm
        from repro.nn.transformer import TransformerLM
        from repro.distributed.sharding import (sharding_for_specs,
                                                use_mesh_rules)

        cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                          num_q_heads=4, num_kv_heads=2, d_ff=128,
                          vocab_size=128, head_dim=16, dtype="float32")
        model = TransformerLM(cfg)
        specs = model.specs()
        mgr = CheckpointManager({json.dumps(str(tmp_path))}, async_save=False)

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        psh_a = sharding_for_specs(specs, mesh_a)
        params = jax.device_put(nnm.init_params(specs, jax.random.key(0)),
                                psh_a)
        mgr.save(1, {{"params": params}}, extra={{"step": 1}})

        diffs = []
        for shape in ((2, 4), (8, 1)):
            mesh_b = jax.make_mesh(shape, ("data", "model"))
            psh_b = sharding_for_specs(specs, mesh_b)
            tree, _ = mgr.restore(1, shardings={{"params": psh_b}})
            diffs.append(max(float(jnp.max(jnp.abs(
                jnp.asarray(a) - jnp.asarray(b))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(tree["params"]))))
        print(json.dumps({{"maxdiff": max(diffs)}}))
    """)
    assert res["maxdiff"] == 0.0
