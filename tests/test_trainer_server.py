"""End-to-end trainer (fault tolerance) and serving-loop tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.data import synthetic_lm
from repro.data.pipeline import ShardedIterator
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.nn.transformer import TransformerLM
from repro.optim import adamw, chain, clip_by_global_norm
from repro.runtime.steps import make_train_step
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.server import Request, Server
from repro.scenarios import ScenarioConfig
from repro.training.data import make_batch_fn
from repro.training.steps import make_sim_train_step

CFG = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                  num_q_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                  head_dim=16, dtype="float32")
DATA_CFG = synthetic_lm.LMDataConfig(vocab_size=128, seq_len=32)


def make_everything(tmp_path, total_steps=20, seed=0):
    model = TransformerLM(CFG)
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    opt = chain(clip_by_global_norm(1.0), adamw(3e-3))
    step = jax.jit(make_train_step(CFG, opt, remat=False))
    data = ShardedIterator(
        lambda s, i, b: synthetic_lm.generate_batch(s, i, b, DATA_CFG),
        batch_size=8, seed=0)
    tr = Trainer(step, params, opt.init(params), data, str(tmp_path),
                 TrainerConfig(total_steps=total_steps, ckpt_every=5,
                               log_every=100))
    return tr


def test_training_reduces_loss(tmp_path):
    tr = make_everything(tmp_path / "a", total_steps=30)
    out = tr.run()
    assert out["status"] == "done"
    first = np.mean(tr.history[:5])
    last = np.mean(tr.history[-5:])
    assert last < first - 0.1, (first, last)


def test_checkpoint_restart_bit_exact(tmp_path):
    # run 1: full 20 steps
    tr_full = make_everything(tmp_path / "full", total_steps=20)
    tr_full.run()
    full_hist = list(tr_full.history)
    # run 2: crash after 10 (simulated via total_steps=10), then resume to 20
    tr_a = make_everything(tmp_path / "resume", total_steps=10)
    tr_a.run()
    tr_b = make_everything(tmp_path / "resume", total_steps=20)
    assert tr_b.restore_if_available()
    assert tr_b.step == 10
    tr_b.run()
    np.testing.assert_allclose(full_hist[10:], tr_b.history, rtol=1e-5)
    # params identical too
    for a, b in zip(jax.tree.leaves(tr_full.params),
                    jax.tree.leaves(tr_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    tr_full.data.close(); tr_a.data.close(); tr_b.data.close()


def test_preemption_checkpoint_and_resume(tmp_path):
    calls = {"n": 0}

    def stop_after_7():
        calls["n"] += 1
        return calls["n"] > 7

    tr = make_everything(tmp_path / "p", total_steps=50)
    tr.should_stop = stop_after_7
    out = tr.run()
    assert out["status"] == "preempted"
    tr2 = make_everything(tmp_path / "p", total_steps=9)
    assert tr2.restore_if_available()
    assert tr2.step == out["step"]
    out2 = tr2.run()
    assert out2["status"] == "done"
    tr.data.close(); tr2.data.close()


def test_nan_guard_skips_bad_batches(tmp_path):
    tr = make_everything(tmp_path / "n", total_steps=10)
    inner = tr.step_fn
    bad_steps = {3, 4}
    counter = {"i": 0}

    def flaky(params, opt_state, batch):
        p, o, m = inner(params, opt_state, batch)
        if counter["i"] in bad_steps:
            m = dict(m); m["loss"] = jnp.asarray(float("nan"))
        counter["i"] += 1
        return p, o, m

    tr.step_fn = flaky
    out = tr.run()
    assert out["status"] == "done"
    assert tr.nan_guard.total_skipped == 2
    assert len(tr.history) == 10 - 2
    tr.data.close()


SIM_SCEN = ScenarioConfig(num_map=8, num_agents=3, num_steps=6)


def make_sim_everything(tmp_path, total_steps=20, seed=0):
    """The agent-sim analogue of make_everything: same Trainer, the BC
    train step + scenario-family expert stream instead of the LM pair."""
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=SIM_SCEN.num_actions,
                         encoding="se2_fourier", attn_impl="ref")
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    opt = chain(clip_by_global_norm(1.0), adamw(3e-3))
    step = jax.jit(make_sim_train_step(model, opt))
    data = ShardedIterator(make_batch_fn(SIM_SCEN), batch_size=2, seed=0)
    tr = Trainer(step, params, opt.init(params), data, str(tmp_path),
                 TrainerConfig(total_steps=total_steps, ckpt_every=5,
                               log_every=100))
    return tr


def test_sim_checkpoint_restart_bit_exact(tmp_path):
    """Kill-and-resume on the agent-sim BC step: identical loss history
    (=> identical data order) and identical final params."""
    tr_full = make_sim_everything(tmp_path / "full", total_steps=20)
    tr_full.run()
    full_hist = list(tr_full.history)
    # crash after 10 (simulated via total_steps=10), then resume to 20
    tr_a = make_sim_everything(tmp_path / "resume", total_steps=10)
    tr_a.run()
    tr_b = make_sim_everything(tmp_path / "resume", total_steps=20)
    assert tr_b.restore_if_available()
    assert tr_b.step == 10
    assert tr_b.data.cursor == 10    # data cursor rides the checkpoint
    tr_b.run()
    np.testing.assert_allclose(full_hist[10:], tr_b.history, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(tr_full.params),
                    jax.tree.leaves(tr_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    tr_full.data.close(); tr_a.data.close(); tr_b.data.close()


def test_sim_trainer_periodic_eval_hook(tmp_path):
    """The eval hook fires on cadence and must not perturb training: a run
    with an eval_cb produces the same loss history as one without."""
    calls = []
    tr = make_sim_everything(tmp_path / "a", total_steps=10)
    tr.config = TrainerConfig(total_steps=10, ckpt_every=100, log_every=100,
                              eval_every=4)
    tr.eval_cb = lambda step, params: calls.append(step)
    tr.run()
    assert calls == [4, 8]
    ref = make_sim_everything(tmp_path / "b", total_steps=10)
    ref.run()
    np.testing.assert_allclose(tr.history, ref.history, rtol=1e-6)
    tr.data.close(); ref.data.close()


def test_server_continuous_batching():
    model = TransformerLM(CFG)
    params = nnm.init_params(model.specs(), jax.random.key(1))
    srv = Server(model, params, num_slots=4, max_len=64)
    rng = np.random.default_rng(0)
    for uid in range(7):   # more requests than slots
        srv.submit(Request(uid=uid,
                           prompt=rng.integers(1, 100, rng.integers(2, 6)),
                           max_new_tokens=5))
    done = srv.run_until_drained()
    assert sorted(done) == list(range(7))
    for r in done.values():
        assert len(r.generated) == 5
        assert all(0 <= t < CFG.padded_vocab for t in r.generated)


def test_server_int8_slot_reuse_matches_solo():
    """The int8 KV cache path (PR 5) through the serving loop: more
    requests than slots, every request's greedy tokens must match its own
    solo decode in a fresh int8 server — slot recycling under
    quantize-on-write included."""
    model = TransformerLM(CFG)
    params = nnm.init_params(model.specs(), jax.random.key(4))
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, 100, rng.integers(2, 7)) for _ in range(5)]

    refs = {}
    for uid, p in enumerate(prompts):
        solo = Server(model, params, num_slots=1, max_len=64,
                      cache_dtype="int8")
        solo.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
        refs[uid] = solo.run_until_drained()[uid].generated

    srv = Server(model, params, num_slots=2, max_len=64, cache_dtype="int8")
    for uid, p in enumerate(prompts):          # 5 requests over 2 slots
        srv.submit(Request(uid=uid, prompt=p, max_new_tokens=6))
    done = srv.run_until_drained()
    assert sorted(done) == list(range(5))
    for uid in done:
        assert done[uid].generated == refs[uid], uid


def test_server_int8_eos_retirement():
    """eos retirement under int8: learn the greedy continuation, declare
    its third token the eos, and check the server stops there (and that
    the early-freed slot serves the next request correctly)."""
    model = TransformerLM(CFG)
    params = nnm.init_params(model.specs(), jax.random.key(6))
    prompt = np.asarray([9, 33, 71], np.int32)
    probe = Server(model, params, num_slots=1, max_len=64,
                   cache_dtype="int8")
    probe.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    ref = probe.run_until_drained()[0].generated
    eos = ref[2]
    assert eos not in ref[:2], "degenerate continuation; pick another seed"

    srv = Server(model, params, num_slots=1, max_len=64, eos_id=eos,
                 cache_dtype="int8")
    srv.submit(Request(uid=0, prompt=prompt, max_new_tokens=8))
    srv.submit(Request(uid=1, prompt=prompt, max_new_tokens=2))
    done = srv.run_until_drained()
    assert done[0].generated == ref[:3]        # retired AT the eos token
    assert done[1].generated == ref[:2]        # recycled slot, same prefix


@pytest.mark.parametrize("cache_dtype", ["float32", "int8"])
def test_server_cursor_restart_masks_stale_rows(cache_dtype):
    """Cursor-restart isolation, shared contract with the sim-side suite
    (tests/test_sim_server.py): after a long request retires, its rows
    stay in the cache — scribble them (and everything else beyond each
    slot's cursor) with adversarial garbage via the common helper, then
    demand the next request's tokens match a fresh server bit-for-bit."""
    from serving_utils import scribble_stale_rows

    model = TransformerLM(CFG)
    params = nnm.init_params(model.specs(), jax.random.key(7))
    rng = np.random.default_rng(8)
    victim = rng.integers(1, 100, 5)

    fresh = Server(model, params, num_slots=1, max_len=64,
                   cache_dtype=cache_dtype)
    fresh.submit(Request(uid=0, prompt=victim, max_new_tokens=6))
    ref = fresh.run_until_drained()[0].generated

    srv = Server(model, params, num_slots=1, max_len=64,
                 cache_dtype=cache_dtype)
    srv.submit(Request(uid=9, prompt=rng.integers(1, 100, 20),
                       max_new_tokens=30))     # long predecessor
    srv.run_until_drained()
    assert srv.slots[0].request is None
    srv.cache = scribble_stale_rows(srv.cache, np.zeros(1, np.int32),
                                    srv.max_len, seed=2)
    srv.submit(Request(uid=0, prompt=victim, max_new_tokens=6))
    got = srv.run_until_drained()[0].generated
    assert got == ref


def test_server_matches_sequential_decode():
    """Continuous batching must produce the same greedy tokens as a lone
    sequential decode of the same prompt (per-slot cursor correctness)."""
    model = TransformerLM(CFG)
    params = nnm.init_params(model.specs(), jax.random.key(2))
    prompt = np.asarray([5, 17, 42], np.int32)

    # reference: single-request server
    solo = Server(model, params, num_slots=1, max_len=64)
    solo.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    ref = solo.run_until_drained()[0].generated

    # same request admitted alongside three noisy neighbors
    srv = Server(model, params, num_slots=4, max_len=64)
    rng = np.random.default_rng(3)
    srv.submit(Request(uid=10, prompt=rng.integers(1, 100, 7),
                       max_new_tokens=9))
    srv.submit(Request(uid=0, prompt=prompt, max_new_tokens=6))
    srv.submit(Request(uid=11, prompt=rng.integers(1, 100, 2),
                       max_new_tokens=3))
    srv.submit(Request(uid=12, prompt=rng.integers(1, 100, 4),
                       max_new_tokens=12))
    got = srv.run_until_drained()[0].generated
    assert got == ref
