"""Unit + property tests for SE(2) group operations, plus the end-to-end
model property the group structure buys: globally re-posing a scene leaves
SE(2)-relative rollout action distributions unchanged."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep; see requirements-dev.txt
from hypothesis import assume, given, settings, strategies as st

from repro.core import se2

jax.config.update("jax_enable_x64", False)


def rand_pose(rng, shape=()):
    xy = rng.uniform(-5, 5, size=shape + (2,))
    th = rng.uniform(-np.pi, np.pi, size=shape + (1,))
    return jnp.asarray(np.concatenate([xy, th], axis=-1), dtype=jnp.float32)


def test_identity_compose():
    rng = np.random.default_rng(0)
    p = rand_pose(rng, (7,))
    e = se2.identity((7,))
    np.testing.assert_allclose(se2.compose(e, p), p, atol=1e-6)
    np.testing.assert_allclose(se2.compose(p, e), p, atol=1e-6)


def test_inverse():
    rng = np.random.default_rng(1)
    p = rand_pose(rng, (7,))
    e = se2.compose(se2.inverse(p), p)
    np.testing.assert_allclose(np.asarray(e), 0.0, atol=1e-5)
    e2 = se2.compose(p, se2.inverse(p))
    np.testing.assert_allclose(np.asarray(e2), 0.0, atol=1e-5)


def test_matrix_homomorphism():
    rng = np.random.default_rng(2)
    p1, p2 = rand_pose(rng, (5,)), rand_pose(rng, (5,))
    m12 = se2.matrix(se2.compose(p1, p2))
    np.testing.assert_allclose(
        np.asarray(m12), np.asarray(se2.matrix(p1) @ se2.matrix(p2)), atol=1e-5)


def test_from_matrix_roundtrip():
    rng = np.random.default_rng(3)
    p = rand_pose(rng, (9,))
    np.testing.assert_allclose(
        np.asarray(se2.from_matrix(se2.matrix(p))), np.asarray(p), atol=1e-5)


def test_relative_matches_matrix():
    rng = np.random.default_rng(4)
    pn, pm = rand_pose(rng, (4,)), rand_pose(rng, (4,))
    rel = se2.relative(pn, pm)
    expect = se2.from_matrix(
        jnp.linalg.inv(se2.matrix(pn)) @ se2.matrix(pm))
    np.testing.assert_allclose(np.asarray(rel), np.asarray(expect), atol=1e-4)


def test_relative_left_invariance():
    rng = np.random.default_rng(5)
    pn, pm, z = rand_pose(rng, (6,)), rand_pose(rng, (6,)), rand_pose(rng)
    rel = se2.relative(pn, pm)
    rel_z = se2.relative(se2.compose(z, pn), se2.compose(z, pm))
    np.testing.assert_allclose(np.asarray(rel), np.asarray(rel_z), atol=1e-4)


def test_transform_points():
    p = jnp.asarray([1.0, 2.0, np.pi / 2], dtype=jnp.float32)
    pts = jnp.asarray([[1.0, 0.0]], dtype=jnp.float32)
    out = se2.transform_points(p, pts)
    np.testing.assert_allclose(np.asarray(out), [[1.0, 3.0]], atol=1e-5)


finite_floats = st.floats(min_value=-4.0, max_value=4.0, allow_nan=False,
                          width=32)


@settings(max_examples=50, deadline=None)
@given(x1=finite_floats, y1=finite_floats, t1=finite_floats,
       x2=finite_floats, y2=finite_floats, t2=finite_floats,
       x3=finite_floats, y3=finite_floats, t3=finite_floats)
def test_associativity(x1, y1, t1, x2, y2, t2, x3, y3, t3):
    a = jnp.asarray([x1, y1, t1], dtype=jnp.float32)
    b = jnp.asarray([x2, y2, t2], dtype=jnp.float32)
    c = jnp.asarray([x3, y3, t3], dtype=jnp.float32)
    lhs = se2.compose(se2.compose(a, b), c)
    rhs = se2.compose(a, se2.compose(b, c))
    # angles compare on the circle
    np.testing.assert_allclose(np.asarray(lhs[:2]), np.asarray(rhs[:2]),
                               atol=1e-4)
    dth = float(se2.wrap_angle(lhs[2] - rhs[2]))
    assert abs(dth) < 1e-4


# ---------------------------------------------------------------------------
# Global SE(2) invariance of rollout action distributions.
#
# Applying one rigid transform z to EVERY pose in a scene leaves all
# relative poses p_n^{-1} p_m unchanged, so an SE(2)-relative model's
# action logits — and hence what a closed-loop rollout samples — must not
# move (up to the Fourier truncation / f32 error). The "absolute" baseline
# reads raw poses through a learned embedding and must move measurably.
# ---------------------------------------------------------------------------

def _sim_setup(encoding):
    from repro.data import scenarios
    from repro.nn import module as nnm
    from repro.nn.agent_sim import AgentSimConfig, AgentSimModel

    scen = scenarios.ScenarioConfig(num_map=4, num_agents=2, num_steps=3)
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=scen.num_actions,
                         encoding=encoding, fourier_terms=18,
                         attn_impl="ref")
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(1))
    batch = {k: jnp.asarray(v)
             for k, v in scenarios.generate_batch(3, 0, 1, scen).items()}
    return model, params, batch


_SIM_CACHE = {}


def _action_dists(encoding, z):
    """Softmax action distributions of the last sim step — what a rollout
    samples from — after re-posing the whole scene by z."""
    if encoding not in _SIM_CACHE:
        _SIM_CACHE[encoding] = _sim_setup(encoding)
    model, params, batch = _SIM_CACHE[encoding]
    b = dict(batch)
    b["map_pose"] = se2.compose(z, batch["map_pose"])
    b["agent_pose"] = se2.compose(z, batch["agent_pose"])
    logits, _ = model(params, b)
    return np.asarray(jax.nn.softmax(logits[:, -1].astype(jnp.float32), -1))


transl = st.floats(min_value=-4.0, max_value=4.0, allow_nan=False, width=32)
angle = st.floats(min_value=-np.pi, max_value=np.pi, allow_nan=False,
                  width=32)


@settings(max_examples=8, deadline=None)
@given(zx=transl, zy=transl, zth=angle)
def test_rollout_action_dists_invariant_relative_encodings(zx, zy, zth):
    z = jnp.asarray([zx, zy, zth], jnp.float32)
    e = jnp.zeros(3, jnp.float32)
    # se2_repr is exact (f32 roundoff only); se2_fourier carries the
    # truncation error of the F=18 basis on top.
    for encoding, tol in (("se2_repr", 5e-4), ("se2_fourier", 5e-3)):
        base = _action_dists(encoding, e)
        moved = _action_dists(encoding, z)
        np.testing.assert_allclose(moved, base, atol=tol,
                                   err_msg=encoding)


@settings(max_examples=8, deadline=None)
@given(zx=transl, zy=transl, zth=angle)
def test_rollout_action_dists_absolute_not_invariant(zx, zy, zth):
    assume(abs(zx) + abs(zy) > 1.0 or abs(zth) > 0.5)
    z = jnp.asarray([zx, zy, zth], jnp.float32)
    base = _action_dists("absolute", jnp.zeros(3, jnp.float32))
    moved = _action_dists("absolute", z)
    assert np.max(np.abs(moved - base)) > 1e-4
