"""Per-architecture smoke tests: reduced same-family configs on CPU.

For each of the 10 assigned architectures: instantiate the reduced config,
run one forward pass, one optimizer (train) step, and one decode step where
the family has one; assert output shapes and the absence of NaNs. The FULL
configs are exercised only through the AOT dry-run (no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.nn import module as nnm
from repro.nn.transformer import build_model
from repro.optim import adamw, chain, clip_by_global_norm
from repro.runtime.steps import (input_specs, make_serve_step,
                                 make_train_step)

B, S = 2, 32


def small_batch(cfg, rng):
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              jnp.int32),
    }
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_frames, cfg.d_model)),
            cfg.compute_dtype)
    if cfg.vision_prefix:
        batch["prefix"] = jnp.asarray(
            rng.normal(size=(B, cfg.vision_prefix, cfg.d_model)),
            cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    rng = np.random.default_rng(0)
    model = build_model(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(0))
    batch = small_batch(cfg, rng)

    # forward
    if cfg.enc_dec:
        logits, aux, _ = model(params, batch["frames"], batch["tokens"])
    elif cfg.vision_prefix:
        logits, aux, _ = model(params, batch["tokens"],
                               prefix_embeds=batch["prefix"])
        assert logits.shape[1] == cfg.vision_prefix + S
        logits = logits[:, cfg.vision_prefix:]
    else:
        logits, aux, _ = model(params, batch["tokens"])
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch

    # one optimizer step moves the loss
    opt = chain(clip_by_global_norm(1.0), adamw(1e-3))
    step = jax.jit(make_train_step(cfg, opt, remat=False))
    opt_state = opt.init(params)
    p1, o1, m1 = step(params, opt_state, batch)
    p2, o2, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m1["loss"])), arch
    assert np.isfinite(float(m2["loss"])), arch
    assert float(m2["loss"]) < float(m1["loss"]) + 0.5, arch  # not diverging
    # params actually changed
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_step(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    rng = np.random.default_rng(1)
    model = build_model(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(1))
    serve = jax.jit(make_serve_step(cfg))
    cache = model.init_cache(B, 16, cfg.compute_dtype)
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    kwargs = {}
    if cfg.enc_dec:
        kwargs["enc_out"] = model.encode(
            params, jnp.asarray(rng.normal(size=(B, cfg.encoder_frames,
                                                 cfg.d_model)),
                                cfg.compute_dtype))
    logits, cache = serve(params, cache, tok, jnp.int32(0), **kwargs)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    logits2, cache = serve(params, cache, tok, jnp.int32(1), **kwargs)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all()), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_decode_matches_prefill(arch):
    """Token-by-token decode must reproduce the teacher-forced logits."""
    cfg = get_config(arch).reduced(dtype="float32")
    if cfg.enc_dec:
        pytest.skip("enc-dec covered separately")
    rng = np.random.default_rng(2)
    model = build_model(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(2))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 8)), jnp.int32)
    full, _, _ = model(params, toks, remat=False)
    cache = model.init_cache(B, 16, jnp.float32)
    outs = []
    for i in range(8):
        lg, _, cache = model(params, toks[:, i:i + 1], cache=cache,
                             cache_index=i, remat=False)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=2e-3, rtol=2e-2)


def test_full_config_param_counts():
    """Full (non-reduced) configs must hit the published scale."""
    expected = {
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "kimi-k2-1t-a32b": (0.95e12, 1.15e12),
        "gemma2-27b": (26e9, 30e9),
        "stablelm-3b": (2.5e9, 3.6e9),
        "phi4-mini-3.8b": (3.3e9, 4.4e9),
        "granite-20b": (19e9, 22e9),
        "internvl2-26b": (18e9, 22e9),   # LM backbone only (vision stubbed)
        "hymba-1.5b": (1.2e9, 2.0e9),
        "whisper-base": (6e7, 1.2e8),
        "rwkv6-7b": (6e9, 8.5e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        model = build_model(cfg)
        n = nnm.count_params(model.specs())
        assert lo <= n <= hi, f"{arch}: {n:.3e} not in [{lo:.1e}, {hi:.1e}]"


def test_input_specs_all_cells():
    """input_specs is defined for every (arch x shape) cell that applies."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.long_context_ok:
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs
            if shape.mode == "decode":
                assert "cache" in specs and "index" in specs
