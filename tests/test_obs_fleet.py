"""PR 9 observability suite: thread-safe instruments, compiled-cost
accounting, per-rank fleet trace merging, the flight recorder, and the
exporter/CLI robustness satellites.

Complements ``tests/test_obs.py`` (which pins the zero-sync contract:
obs-on/off bit-parity and zero extra compilations — both now running
through the ``CostAccounted`` AOT wrappers).
"""
import json
import os
import threading
import time

import numpy as np
import pytest

import jax

from repro import obs
from repro.launch import obs_merge, obs_report
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.runtime.rollout import RolloutEngine
from repro.runtime.sim_server import SceneRequest, SimServer
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.scenarios import ScenarioConfig
from repro.scenarios.registry import generate_mixed

SCEN = ScenarioConfig(num_map=8, num_agents=3, num_steps=6)
T_HIST = 3


def _model(seed=0):
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=SCEN.num_actions,
                         encoding="se2_fourier", attn_impl="ref")
    model = AgentSimModel(cfg)
    return model, nnm.init_params(model.specs(), jax.random.key(seed))


MODEL, PARAMS = _model()
SCENES = generate_mixed(4, 0, 11, SCEN)


# ---------------------------------------------------------------------------
# satellite: thread-safe instruments
# ---------------------------------------------------------------------------

def test_counter_hammer_no_lost_increments():
    reg = obs.Registry()
    n_threads, n_inc = 8, 5000

    def work():
        for _ in range(n_inc):
            # re-lookup every iteration: creation and mutation both race
            reg.counter("hammer.total").inc()
            reg.counter("hammer.labeled", t=threading.get_ident() % 4).inc()

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert reg.counter("hammer.total").value == n_threads * n_inc
    labeled = sum(c["value"] for c in reg.snapshot()["counters"]
                  if c["name"] == "hammer.labeled")
    assert labeled == n_threads * n_inc


def test_histogram_and_events_hammer():
    reg = obs.Registry()
    n_threads, n_rec = 6, 3000

    def work(i):
        for k in range(n_rec):
            reg.histogram("hammer.seconds").record(1.0)
            if k % 10 == 0:
                reg.event("hammer.tick", worker=i)

    ts = [threading.Thread(target=work, args=(i,))
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    h = reg.histogram("hammer.seconds")
    assert h.count == n_threads * n_rec
    assert h.sum == float(n_threads * n_rec)     # 1.0 increments stay exact
    assert sum(1 for e in reg.events()
               if e["name"] == "hammer.tick") == n_threads * (n_rec // 10)


# ---------------------------------------------------------------------------
# satellite: prometheus escaping + NaN omission
# ---------------------------------------------------------------------------

def test_prometheus_label_escaping_round_trip():
    reg = obs.Registry()
    evil = {'backslash': 'a\\b', 'quote': 'say "hi"', 'newline': 'x\ny'}
    for k, v in evil.items():
        reg.counter("adversarial", which=k, value_label=v).inc(2)
    text = obs.prometheus_text(reg)

    # every sample line must parse back to the original label value;
    # unescape tokenwise (order of str.replace passes would be ambiguous)
    import re

    def unescape(s):
        out, i = [], 0
        while i < len(s):
            if s[i] == "\\" and i + 1 < len(s):
                out.append({"n": "\n", '"': '"', "\\": "\\"}[s[i + 1]])
                i += 2
            else:
                out.append(s[i])
                i += 1
        return "".join(out)

    seen = {}
    for m in re.finditer(r'value_label="((?:[^"\\]|\\.)*)"', text):
        val = unescape(m.group(1))
        seen[val] = seen.get(val, 0) + 1
    assert set(seen) == set(evil.values()), (seen, text)


def test_prometheus_omits_nan_gauges():
    reg = obs.Registry()
    reg.gauge("never_set", a="b")           # value stays NaN
    reg.gauge("was_set").set(1.5)
    text = obs.prometheus_text(reg)
    assert "never_set" not in text
    assert "was_set 1.5" in text
    assert "NaN" not in text


# ---------------------------------------------------------------------------
# compiled-cost accounting
# ---------------------------------------------------------------------------

def test_cost_accounted_wrapper_basics():
    reg = obs.Registry()
    f = obs.CostAccounted(jax.jit(lambda a, b: a @ b + 1.0), "toy.mm",
                          registry=reg, labels={"tier": "test"})
    a = np.ones((4, 4), np.float32)
    out1 = np.asarray(f(a, a))
    out2 = np.asarray(f(a, a))
    np.testing.assert_array_equal(out1, out2)
    np.testing.assert_array_equal(out1, a @ a + 1.0)
    assert f.num_compilations == 1 and f._cache_size() == 1
    assert f.cost["flops"] > 0 and f.cost["bytes_accessed"] > 0
    assert f.cost["compile_seconds"] > 0
    snap = reg.snapshot()
    got = {(g["name"], g["labels"].get("path"), g["labels"].get("tier"))
           for g in snap["gauges"]}
    assert ("cost.flops", "toy.mm", "test") in got
    assert ("cost.peak_bytes", "toy.mm", "test") in got
    [c] = [c for c in snap["counters"] if c["name"] == "cost.compilations"]
    assert c["value"] == 1
    assert any(e["name"] == "cost.compiled" for e in reg.events())


def test_cost_accounted_null_registry_still_computes():
    f = obs.CostAccounted(jax.jit(lambda x: x * 2), "toy.mul",
                          registry=obs.NULL)
    out = np.asarray(f(np.arange(4, dtype=np.float32)))
    np.testing.assert_array_equal(out, np.arange(4, dtype=np.float32) * 2)
    # analysis ran (the wrapper's own record), but nothing hit the registry
    assert f.cost is not None and f.num_compilations == 1
    assert not list(obs.NULL.instruments())


def test_engine_and_server_record_cost_gauges(tmp_path):
    reg = obs.Registry()
    eng = RolloutEngine(MODEL, PARAMS, SCEN, num_slots=4, registry=reg)
    eng.run(SCENES[:2], t_hist=T_HIST, n_samples=1, seed=0)
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2, registry=reg)
    srv.submit(SceneRequest(uid=0, tensors=SCENES[0], t_hist=T_HIST))
    srv.run_until_drained()
    paths = {g["labels"]["path"] for g in reg.snapshot()["gauges"]
             if g["name"] == "cost.flops"}
    assert {"rollout.prefill", "rollout.step",
            "sim_server.tick", "sim_server.admit"} <= paths

    # obs_report renders the roofline table from the written trace
    trace = tmp_path / "run.trace.jsonl"
    obs.write_chrome_trace(reg, str(trace))
    assert obs_report.main([str(trace)]) == 0
    snap = obs_report.snapshot_of(obs.read_chrome_trace(str(trace)))
    rows = obs_report.cost_rows(snap)
    assert {r[0] for r in rows} >= paths
    for r in rows:
        assert r[2] is not None and r[2] > 0        # flops column


# ---------------------------------------------------------------------------
# fleet: identity, per-rank traces, merge
# ---------------------------------------------------------------------------

def _two_rank_traces(tmp_path):
    regs = []
    for r in range(2):
        reg = obs.Registry()
        obs.fleet.stamp_identity(reg, rank=r, pod=r, data=0, world=2)
        t0 = time.perf_counter()
        reg.observe_span("rollout.step", t0, t0 + 0.010 * (r + 1))
        reg.counter("rollout.ticks").inc(5)
        regs.append(reg)
    regs[0].event("straggler.flagged", ranks="1", fleet_median_s=0.01,
                  factor=1.5)
    return [obs.fleet.write_rank_trace(reg, str(tmp_path),
                                       process_name="test")
            for reg in regs]


def test_fleet_merge_tracks_overlays_snapshot(tmp_path):
    paths = _two_rank_traces(tmp_path)
    assert [os.path.basename(p) for p in paths] == \
        ["rank00000.trace.jsonl", "rank00001.trace.jsonl"]
    out = str(tmp_path / "merged.trace.jsonl")
    summary = obs.fleet.merge_traces(paths, out)
    assert summary["ranks"] == [0, 1]
    assert summary["straggler_overlays"] == 1

    events = obs.read_chrome_trace(out)
    metas = [e for e in events if e.get("ph") == "M"
             and e["name"] == "process_name"]
    assert len(metas) == 2
    assert {m["args"]["name"].split(" (")[0] for m in metas} \
        == {"rank 0", "rank 1"}
    # pid remapped to the rank; overlay lands on the flagged rank's track
    [ov] = [e for e in events if e["name"] == "straggler.straggling"]
    assert ov["pid"] == 1 and ov["args"]["flagged_by_rank"] == 0
    # epoch alignment keeps every span ts non-negative
    assert all(e["ts"] >= 0 for e in events if e.get("ph") == "X")
    # merged snapshot: every instrument labeled with its rank
    snap = obs_report.snapshot_of(events)
    ranks = {c["labels"]["rank"] for c in snap["counters"]
             if c["name"] == "rollout.ticks"}
    assert ranks == {"0", "1"}
    # per-rank span rows in the rendered report
    rows = obs_report.span_rows(events)
    assert any(r[0].startswith("rank 0") for r in rows)
    assert any(r[0].startswith("rank 1") for r in rows)


def test_obs_merge_cli(tmp_path, capsys):
    _two_rank_traces(tmp_path)
    assert obs_merge.main([str(tmp_path)]) == 0
    assert "merged 2 rank trace(s)" in capsys.readouterr().out
    assert os.path.exists(tmp_path / "merged.trace.jsonl")
    assert obs_report.main([str(tmp_path / "merged.trace.jsonl")]) == 0


def test_obs_merge_cli_rejects_bad_inputs(tmp_path, capsys):
    bad = tmp_path / "rank00000.trace.jsonl"
    bad.write_text("{ not json")
    assert obs_merge.main([str(tmp_path)]) == 2
    err = capsys.readouterr().err
    assert err.startswith("error:") and err.count("\n") == 1
    assert obs_merge.main([str(tmp_path / "missing_dir_xyz")]) == 2


def test_merge_rejects_duplicate_ranks(tmp_path):
    reg = obs.Registry()
    obs.fleet.stamp_identity(reg, rank=0)
    p1 = obs.fleet.write_rank_trace(reg, str(tmp_path / "a"))
    p2 = obs.fleet.write_rank_trace(reg, str(tmp_path / "b"))
    with pytest.raises(obs.fleet.MergeError, match="duplicate"):
        obs.fleet.merge_traces([p1, p2], str(tmp_path / "m.jsonl"))


# ---------------------------------------------------------------------------
# satellite: obs_report robustness
# ---------------------------------------------------------------------------

def _one_line_error(capsys):
    err = capsys.readouterr().err
    assert err.startswith("error:") and err.count("\n") == 1, err


def test_obs_report_missing_file(capsys):
    assert obs_report.main(["/nonexistent/x.trace.jsonl"]) == 2
    _one_line_error(capsys)


def test_obs_report_empty_file(tmp_path, capsys):
    p = tmp_path / "empty.trace.jsonl"
    p.write_text("")
    assert obs_report.main([str(p)]) == 2
    _one_line_error(capsys)


def test_obs_report_garbage_file(tmp_path, capsys):
    p = tmp_path / "garbage.trace.jsonl"
    p.write_text("[\n{this is not json\n")
    assert obs_report.main([str(p)]) == 2
    _one_line_error(capsys)


def test_obs_report_truncated_no_snapshot(tmp_path, capsys):
    # a trace cut off mid-write: events parse, but the final snapshot
    # event never made it out
    reg = obs.Registry()
    reg.observe_span("x", 0.0, 0.001)
    full = tmp_path / "full.trace.jsonl"
    obs.write_chrome_trace(reg, str(full))
    lines = full.read_text().splitlines()
    trunc = tmp_path / "trunc.trace.jsonl"
    trunc.write_text("\n".join(lines[:-2]) + "\n")
    assert obs_report.main([str(trunc)]) == 2
    _one_line_error(capsys)


def test_obs_report_postmortem_rejects_non_bundle(tmp_path, capsys):
    p = tmp_path / "not_bundle.json"
    p.write_text(json.dumps({"kind": "something_else"}))
    assert obs_report.main(["--postmortem", str(p)]) == 2
    _one_line_error(capsys)


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_sim_server_dump_postmortem_mid_flight(tmp_path):
    reg = obs.Registry()
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2, registry=reg)
    for uid, sc in enumerate(SCENES[:3]):
        srv.submit(SceneRequest(uid=uid, tensors=sc, t_hist=T_HIST))
    for _ in range(2):
        srv.tick()
    path = srv.dump_postmortem(str(tmp_path / "pm.json"), reason="drill",
                               note="mid-flight")
    with open(path) as f:
        b = json.load(f)
    assert b["kind"] == "repro.flight_recorder"
    assert b["reason"] == "drill" and b["context"]["note"] == "mid-flight"
    slots = b["state"]["sim_server"]["slots"]
    assert len(slots) == 2
    busy = [s for s in slots if s["phase"] != "idle"]
    assert busy and all("cursor_rows" in s and "scene_id" in s
                        for s in busy)
    assert b["state"]["sim_server"]["queued_uids"] == [2]
    assert b["snapshot"]["counters"]      # registry rode along
    assert b["events"]                    # trace tail rode along
    # the bundle renders
    assert obs_report.main(["--postmortem", path]) == 0


def test_trainer_nan_halt_dumps_flight_bundle(tmp_path):
    reg = obs.Registry()
    flight = obs.FlightRecorder(reg, out_path=str(tmp_path / "pm.json"))

    calls = {"n": 0}

    def step_fn(params, opt_state, batch):
        calls["n"] += 1
        loss = float("nan") if calls["n"] > 2 else 1.0 / calls["n"]
        return params, opt_state, {"loss": loss}

    class _Data:
        def __next__(self):
            return {"x": np.zeros(1)}
        def state_dict(self):
            return {}
        def load_state_dict(self, s):
            pass
        def close(self):
            pass

    tr = Trainer(step_fn, {"w": np.zeros(1)}, {}, _Data(),
                 str(tmp_path / "ckpt"),
                 TrainerConfig(total_steps=50, max_consecutive_nans=3),
                 registry=reg, flight=flight)
    with pytest.raises(FloatingPointError):
        tr.run()
    with open(tmp_path / "pm.json") as f:
        b = json.load(f)
    assert b["reason"] == "nan_halt"
    st = b["state"]["trainer"]
    assert st["nan_consecutive"] == 3
    assert st["loss_tail"] == [1.0, 0.5]      # finite steps before the run
    assert any(e["name"] == "trainer.halt" for e in b["events"])
    assert obs_report.main(["--postmortem", str(tmp_path / "pm.json")]) == 0


def test_trainer_preemption_dumps_flight_bundle(tmp_path):
    reg = obs.Registry()
    flight = obs.FlightRecorder(reg, out_path=str(tmp_path / "pm.json"))

    class _Data:
        def __next__(self):
            return {}
        def state_dict(self):
            return {}
        def load_state_dict(self, s):
            pass
        def close(self):
            pass

    tr = Trainer(lambda p, o, b: (p, o, {"loss": 1.0}), {"w": np.zeros(1)},
                 {}, _Data(), str(tmp_path / "ckpt"),
                 TrainerConfig(total_steps=50),
                 should_stop=lambda: True, registry=reg, flight=flight)
    out = tr.run()
    assert out["status"] == "preempted"
    with open(tmp_path / "pm.json") as f:
        assert json.load(f)["reason"] == "preempted"


def test_flight_provider_errors_do_not_kill_dump(tmp_path):
    fr = obs.FlightRecorder(obs.Registry(),
                            out_path=str(tmp_path / "pm.json"))
    fr.add_provider("broken", lambda: 1 / 0)
    fr.add_provider("fine", lambda: {"ok": True})
    path = fr.dump(reason="drill")
    with open(path) as f:
        b = json.load(f)
    assert "ZeroDivisionError" in b["state"]["broken"]["error"]
    assert b["state"]["fine"]["ok"] is True
