"""Tests for optim / checkpoint / data / monitor substrates."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep; see requirements-dev.txt

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import ShardedIterator
from repro.data import scenarios, synthetic_lm
from repro.optim import (adafactor, adamw, chain, clip_by_global_norm,
                         warmup_cosine)
from repro.optim.transforms import apply_updates
from repro.optim.compression import (ErrorFeedbackCompressor,
                                     compress_gradients,
                                     decompress_gradients)
from repro.runtime.monitor import NaNGuard, StragglerPolicy


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def quad_problem():
    target = {"a": jnp.asarray([1.0, -2.0, 3.0]),
              "b": {"v": jnp.full((4, 4), 0.5)}}   # "v" key on purpose
    params = jax.tree.map(jnp.zeros_like, target)

    def loss(p):
        return sum(jnp.sum((x - t) ** 2) for x, t in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    return params, loss


@pytest.mark.parametrize("make_opt", [
    lambda: adamw(0.1),
    lambda: adafactor(0.5, min_dim_size_to_factor=2),
    lambda: chain(clip_by_global_norm(1.0), adamw(0.1)),
])
def test_optimizers_converge(make_opt):
    params, loss = quad_problem()
    opt = make_opt()
    state = opt.init(params)
    grad_fn = jax.jit(jax.grad(loss))
    l0 = float(loss(params))
    for _ in range(200):
        g = grad_fn(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.05 * l0


def test_adafactor_state_is_factored():
    params = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((16,))}
    opt = adafactor(1e-2)
    state = opt.init(params)
    assert set(state["v"]["w"]) == {"vr", "vc"}
    assert state["v"]["w"]["vr"].shape == (256,)
    assert state["v"]["w"]["vc"].shape == (512,)
    assert set(state["v"]["b"]) == {"v"}
    # factored state is ~1000x smaller than an adam second moment
    full = 256 * 512
    fact = 256 + 512
    assert fact * 100 < full


def test_warmup_cosine_schedule():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(s(jnp.asarray(100))) <= 0.12
    assert float(s(jnp.asarray(55))) < float(s(jnp.asarray(20)))


def test_grad_clip():
    opt = clip_by_global_norm(1.0)
    g = {"x": jnp.full((10,), 100.0)}
    upd, _ = opt.update(g, opt.init(g), g)
    norm = float(jnp.linalg.norm(upd["x"]))
    assert abs(norm - 1.0) < 1e-5


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_compression_roundtrip():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    comp = compress_gradients(g)
    assert comp["w"]["q"].dtype == jnp.int8
    back = decompress_gradients(comp)
    err = float(jnp.max(jnp.abs(back["w"] - g["w"])))
    assert err <= float(comp["w"]["scale"]) * 0.51 + 1e-6


def test_error_feedback_compressor_is_unbiased_over_time():
    """Sum of transmitted grads + final residual == sum of true grads."""
    rng = np.random.default_rng(1)
    c = ErrorFeedbackCompressor(k_frac=0.1)
    params = {"w": jnp.zeros((32, 32))}
    residual = c.init(params)
    total_sent = jnp.zeros((32, 32))
    total_true = jnp.zeros((32, 32))
    for i in range(5):
        g = {"w": jnp.asarray(rng.normal(size=(32, 32)), jnp.float32)}
        sent, residual = c.compress(g, residual)
        total_sent = total_sent + sent["w"]
        total_true = total_true + g["w"]
    np.testing.assert_allclose(np.asarray(total_sent + residual["w"]),
                               np.asarray(total_true), atol=1e-5)
    # and it actually sparsifies
    nz = float(jnp.mean((sent["w"] != 0).astype(jnp.float32)))
    assert nz < 0.2


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
            "opt": ({"step": jnp.asarray(3)},)}
    mgr.save(7, tree, extra={"step": 7, "data": {"cursor": 11, "seed": 0}})
    assert mgr.latest_step() == 7
    got, extra = mgr.restore()
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.arange(6.0).reshape(2, 3))
    assert isinstance(got["opt"], tuple)
    assert extra["data"]["cursor"] == 11


def test_checkpoint_keep_k_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.asarray(float(s))}, extra={"step": s})
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]
    got, _ = mgr.restore()
    assert float(got["x"]) == 4.0


def test_checkpoint_async_and_atomic(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, {"x": jnp.ones((128, 128))}, extra={"step": 1})
    mgr.wait()
    # no tmp dirs left behind
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
    got, _ = mgr.restore(1)
    assert got["x"].shape == (128, 128)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_lm_data_deterministic():
    cfg = synthetic_lm.LMDataConfig(vocab_size=64, seq_len=16)
    a = synthetic_lm.generate_batch(0, 100, 4, cfg)
    b = synthetic_lm.generate_batch(0, 100, 4, cfg)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = synthetic_lm.generate_batch(0, 104, 4, cfg)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_sharded_iterator_checkpoint_resume():
    cfg = synthetic_lm.LMDataConfig(vocab_size=64, seq_len=8)
    mk = lambda seed, idx, bs: synthetic_lm.generate_batch(seed, idx, bs, cfg)
    it = ShardedIterator(mk, batch_size=2, seed=3)
    for _ in range(5):     # advance past the checkpoint point
        next(it)
    state = it.state_dict()
    more = [next(it) for _ in range(3)]
    it.close()
    # resume from checkpoint reproduces the same stream
    it2 = ShardedIterator(mk, batch_size=2, seed=3)
    it2.load_state_dict(state)
    more2 = [next(it2) for _ in range(3)]
    it2.close()
    for x, y in zip(more, more2):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])


def test_sharded_iterator_disjoint_hosts():
    cfg = synthetic_lm.LMDataConfig(vocab_size=64, seq_len=8)
    mk = lambda seed, idx, bs: synthetic_lm.generate_batch(seed, idx, bs, cfg)
    seen = set()
    for rank in range(3):
        it = ShardedIterator(mk, batch_size=2, seed=0, host_rank=rank, world=3)
        for _ in range(4):
            b = next(it)
            seen.add(b["tokens"].tobytes())
        it.close()
    assert len(seen) == 12  # no overlap across hosts


def test_scenarios_shapes_and_actions():
    cfg = scenarios.ScenarioConfig(num_map=16, num_agents=4, num_steps=8)
    s = scenarios.generate_scene(0, 0, cfg)
    assert s["map_pose"].shape == (16, 3)
    assert s["agent_pose"].shape == (8, 4, 3)
    assert s["actions"].shape == (8, 4)
    assert s["actions"].min() >= 0 and s["actions"].max() < cfg.num_actions
    # labels round-trip through kinematics: replaying quantized actions from
    # the recorded poses reproduces the next poses
    accel, yaw = scenarios.decode_action(cfg, s["actions"][0])
    speed = s["agent_feats"][0, :, 0] * 10.0
    nxt, _ = scenarios.step_kinematics(s["agent_pose"][0], speed, accel, yaw)
    np.testing.assert_allclose(nxt[:, :2], s["agent_pose"][1, :, :2], atol=1e-3)


# ---------------------------------------------------------------------------
# monitors
# ---------------------------------------------------------------------------

def test_nan_guard():
    g = NaNGuard(max_consecutive=3)
    assert g.check(1.0) == "ok"
    assert g.check(float("nan")) == "skip"
    assert g.check(float("inf")) == "skip"
    assert g.check(float("nan")) == "halt"
    assert g.check(1.0) == "ok"
    assert g.total_skipped == 3


def test_straggler_policy():
    p = StragglerPolicy(straggler_factor=1.5, min_samples=10)
    medians = {0: 1.0, 1: 1.05, 2: 0.98, 3: 2.5}
    warm = {r: 10 for r in medians}
    assert p.evaluate(medians, warm) == [3]
    assert p.evaluate({0: 1.0, 1: 1.1}, {0: 10, 1: 10}) == []
