"""Tests for the group encodings and relative attention (Alg. 1 vs Alg. 2)."""
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # optional dev dep; see requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import attention, encodings, se2


def rand_qkv(rng, n, m, d, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(n, d)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(m, d)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(m, d)), dtype=dtype)
    return q, k, v


def rand_se2(rng, n, radius=3.0):
    xy = rng.uniform(-radius, radius, size=(n, 2))
    th = rng.uniform(-np.pi, np.pi, size=(n, 1))
    return jnp.asarray(np.concatenate([xy, th], -1), dtype=jnp.float32)


ENCS = {
    "rope1d": lambda: encodings.Rope1D(head_dim=32),
    "rope2d": lambda: encodings.Rope2D(head_dim=32, max_freq=0.5),
    "se2_repr": lambda: encodings.SE2Repr(head_dim=30),
    "se2_fourier": lambda: encodings.SE2Fourier(head_dim=30, num_terms=20),
}


def poses_for(enc, rng, n):
    if enc.pose_dim == 1:
        return jnp.asarray(rng.uniform(0, 64, size=(n, 1)), dtype=jnp.float32)
    if enc.pose_dim == 2:
        return jnp.asarray(rng.uniform(-4, 4, size=(n, 2)), dtype=jnp.float32)
    return rand_se2(rng, n)


@pytest.mark.parametrize("name", sorted(ENCS))
def test_linear_matches_quadratic(name):
    """Algorithm 2 == Algorithm 1 (to Fourier tolerance for se2_fourier)."""
    enc = ENCS[name]()
    rng = np.random.default_rng(0)
    n, m = 9, 13
    q, k, v = rand_qkv(rng, n, m, enc.head_dim)
    pq, pk = poses_for(enc, rng, n), poses_for(enc, rng, m)
    out_lin = attention.relative_attention_linear(enc, q, k, v, pq, pk)
    out_quad = attention.relative_attention_quadratic(enc, q, k, v, pq, pk)
    tol = 5e-3 if name == "se2_fourier" else 2e-5
    np.testing.assert_allclose(np.asarray(out_lin), np.asarray(out_quad),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("name", sorted(ENCS))
def test_fold_scale_equivalent(name):
    """Paper-verbatim Alg. 2 scaling (c/d)^{1/4} == explicit 1/sqrt(d)."""
    enc = ENCS[name]()
    rng = np.random.default_rng(1)
    q, k, v = rand_qkv(rng, 6, 8, enc.head_dim)
    pq, pk = poses_for(enc, rng, 6), poses_for(enc, rng, 8)
    a = attention.relative_attention_linear(enc, q, k, v, pq, pk,
                                            fold_scale=False)
    b = attention.relative_attention_linear(enc, q, k, v, pq, pk,
                                            fold_scale=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@pytest.mark.parametrize("name", sorted(ENCS))
def test_masking(name):
    """Masked-out keys must not influence the output."""
    enc = ENCS[name]()
    rng = np.random.default_rng(2)
    n, m = 5, 11
    q, k, v = rand_qkv(rng, n, m, enc.head_dim)
    pq, pk = poses_for(enc, rng, n), poses_for(enc, rng, m)
    mask = jnp.asarray(rng.uniform(size=(n, m)) > 0.3)
    mask = mask.at[:, 0].set(True)   # keep at least one key per query
    mask = mask.at[:, 8:].set(False)  # keys >= 8 are masked for all queries
    out = attention.relative_attention_linear(enc, q, k, v, pq, pk, mask=mask)
    # perturb fully-masked-out keys/values; output must not change
    noise = jnp.asarray(rng.normal(size=k.shape), dtype=k.dtype) * 10
    keep = mask.any(axis=0)[:, None]
    k2 = jnp.where(keep, k, k + noise)
    v2 = jnp.where(keep, v, v + noise)
    out2 = attention.relative_attention_linear(enc, q, k2, v2, pq, pk, mask=mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-5)


@pytest.mark.parametrize("name,tol", [
    ("rope1d", 1e-4), ("rope2d", 1e-4), ("se2_repr", 1e-4),
    ("se2_fourier", 2e-2),
])
def test_invariance(name, tol):
    """Output invariant to a global transform of all poses (paper Eq. 2).

    rope/se2_repr are exactly invariant; se2_fourier is invariant up to the
    Fourier truncation error, provided transformed positions stay within the
    magnitude budget the basis size was chosen for.
    """
    enc = ENCS[name]()
    rng = np.random.default_rng(3)
    n, m = 8, 12
    q, k, v = rand_qkv(rng, n, m, enc.head_dim)
    if enc.pose_dim == 3:
        pq, pk = rand_se2(rng, n, radius=2.0), rand_se2(rng, m, radius=2.0)
        z = jnp.asarray([1.0, -0.5, 0.8], dtype=jnp.float32)
    elif enc.pose_dim == 2:
        pq = jnp.asarray(rng.uniform(-3, 3, (n, 2)), dtype=jnp.float32)
        pk = jnp.asarray(rng.uniform(-3, 3, (m, 2)), dtype=jnp.float32)
        z = jnp.asarray([11.0, -7.0], dtype=jnp.float32)
    else:
        pq = jnp.asarray(rng.uniform(0, 32, (n, 1)), dtype=jnp.float32)
        pk = jnp.asarray(rng.uniform(0, 32, (m, 1)), dtype=jnp.float32)
        z = jnp.asarray([100.0], dtype=jnp.float32)
    gap = attention.invariance_gap(enc, q, k, v, pq, pk, z)
    assert float(gap) < tol, float(gap)


def test_rope1d_matches_classic_rope():
    """Our Rope1D must equal the standard rotate-half RoPE formulation."""
    enc = encodings.Rope1D(head_dim=16)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(5, 16)), dtype=jnp.float32)
    pos = jnp.asarray(np.arange(5.0)[:, None], dtype=jnp.float32)
    got = enc.transform_q(x, pos)
    # classic: split halves, rotate
    freqs = encodings.rope_frequencies(8)
    ang = np.arange(5.0)[:, None] * freqs[None, :]
    cos, sin = np.cos(ang), np.sin(ang)
    x0, x1 = np.asarray(x[:, :8]), np.asarray(x[:, 8:])
    expect = np.concatenate([x0 * cos - x1 * sin, x0 * sin + x1 * cos], -1)
    np.testing.assert_allclose(np.asarray(got), expect, atol=1e-5)


def test_se2_fourier_expanded_dim():
    enc = encodings.SE2Fourier(head_dim=12, num_terms=7)
    assert enc.num_blocks == 2
    assert enc.expanded_dim == 2 * (4 * 7 + 2)
    rng = np.random.default_rng(5)
    q, k, v = rand_qkv(rng, 3, 4, 12)
    pq, pk = rand_se2(rng, 3), rand_se2(rng, 4)
    assert enc.transform_q(q, pq).shape == (3, enc.expanded_dim)
    assert enc.transform_k(k, pk).shape == (4, enc.expanded_dim)
    o = attention.relative_attention_linear(enc, q, k, v, pq, pk)
    assert o.shape == (3, 12)


def test_se2_fourier_score_matches_target():
    """q~^T k~ must approximate q^T diag[rho(x_r), rho(y_r), rho(t_r)] k."""
    enc = encodings.SE2Fourier(head_dim=6, num_terms=24, min_scale=1.0,
                               max_scale=1.0)
    rng = np.random.default_rng(6)
    q, k, _ = rand_qkv(rng, 16, 16, 6)
    pq, pk = rand_se2(rng, 16, radius=3.0), rand_se2(rng, 16, radius=3.0)
    qt, kt = enc.transform_q(q, pq), enc.transform_k(k, pk)
    scores = np.asarray(qt @ kt.T)
    rel = se2.relative(pq[:, None, :], pk[None, :, :])
    phik = enc.apply_phi(rel, jnp.broadcast_to(k[None, :, :], (16, 16, 6)))
    target = np.asarray(jnp.einsum("nd,nmd->nm", q, phik))
    np.testing.assert_allclose(scores, target, atol=2e-3)


def test_batched_heads_broadcast():
    """Encodings must broadcast over (batch, heads, seq, dim)."""
    enc = encodings.SE2Fourier(head_dim=12, num_terms=8)
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 3, 5, 12)), dtype=jnp.float32)
    pose = jnp.asarray(
        np.concatenate([rng.uniform(-2, 2, (2, 1, 5, 2)),
                        rng.uniform(-3, 3, (2, 1, 5, 1))], -1),
        dtype=jnp.float32)
    pose = jnp.broadcast_to(pose, (2, 3, 5, 3))
    out = enc.transform_q(q, pose)
    assert out.shape == (2, 3, 5, enc.expanded_dim)
    # row 0 computed standalone must match
    single = enc.transform_q(q[0, 0], pose[0, 0])
    np.testing.assert_allclose(np.asarray(out[0, 0]), np.asarray(single),
                               atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000),
       radius=st.floats(0.1, 3.5),
       num_terms=st.integers(18, 30))
def test_property_linear_equals_quadratic_se2(seed, radius, num_terms):
    """Property: Alg. 2 tracks Alg. 1 within tolerance across random scenes."""
    enc = encodings.SE2Fourier(head_dim=12, num_terms=num_terms,
                               min_scale=0.5, max_scale=1.0)
    rng = np.random.default_rng(seed)
    q, k, v = rand_qkv(rng, 6, 7, 12)
    pq, pk = rand_se2(rng, 6, radius), rand_se2(rng, 7, radius)
    a = attention.relative_attention_linear(enc, q, k, v, pq, pk)
    b = attention.relative_attention_quadratic(enc, q, k, v, pq, pk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)


def test_adaptive_basis_cuts_expanded_dim_within_error_budget():
    """Beyond-paper scale-adaptive truncation (see benchmarks/adaptive_basis)."""
    uni = encodings.SE2Fourier(head_dim=24, num_terms=18, min_scale=0.25,
                               max_scale=1.0)
    ada = encodings.SE2Fourier(head_dim=24, num_terms=18, min_scale=0.25,
                               max_scale=1.0, adaptive_terms=True,
                               min_terms=6)
    assert ada.expanded_dim < 0.78 * uni.expanded_dim
    rng = np.random.default_rng(0)
    q, k, v = rand_qkv(rng, 8, 10, 24)
    pq, pk = rand_se2(rng, 8, 3.0), rand_se2(rng, 10, 3.0)
    a = attention.relative_attention_linear(ada, q, k, v, pq, pk)
    b = attention.relative_attention_quadratic(ada, q, k, v, pq, pk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-2)
