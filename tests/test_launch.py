"""Tests for launch-layer pure logic: roofline parsing, report, input specs."""
import pytest

from repro.configs import ARCH_NAMES, SHAPES, get_config
from repro.launch.mesh import HW
from repro.launch.roofline import (CollectiveStats, model_flops_for,
                                   parse_collectives, roofline_terms)

HLO_SAMPLE = """
HloModule jit_step
  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%x), replica_groups=[16,16]<=[256], to_apply=%add
  %all-gather.2 = bf16[256,512]{1,0} all-gather(%y), replica_groups={{0,1,2,3}}, dimensions={0}
  %reduce-scatter.3 = f32[64]{0} reduce-scatter(%z), replica_groups=[32,8]<=[256], dimensions={0}
  %all-to-all.4 = bf16[8,8]{1,0} all-to-all(%w), replica_groups=[16,16]<=[256]
  %collective-permute.5 = f32[32]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %not-a-collective = f32[9999,9999]{1,0} add(%a, %b)
"""


def test_parse_collectives_kinds_and_ring_factors():
    st = parse_collectives(HLO_SAMPLE)
    assert st.count == 5
    # all-reduce: 2*(15/16)*16*128*4
    ar = 2 * 15 / 16 * 16 * 128 * 4
    assert abs(st.by_kind["all-reduce"] - ar) < 1e-6
    # all-gather group=4: (3/4)*256*512*2
    ag = 3 / 4 * 256 * 512 * 2
    assert abs(st.by_kind["all-gather"] - ag) < 1e-6
    # reduce-scatter group=8: 7 * 64 * 4
    assert abs(st.by_kind["reduce-scatter"] - 7 * 64 * 4) < 1e-6
    assert "collective-permute" in st.by_kind
    # f32 split: ar + rs + permute are f32
    assert st.f32_bytes > 0
    assert st.bf16_corrected < st.per_chip_bytes


def test_roofline_terms_dominance():
    coll = CollectiveStats(per_chip_bytes=50e9, f32_bytes=0.0)
    t = roofline_terms(1e12, 1e11, coll, 256, HW)
    assert t["dominant"] == "collective"
    assert abs(t["collective_s"] - 1.0) < 1e-6        # 50GB / 50GB/s
    assert abs(t["compute_s"] - 1e12 / HW["peak_flops_bf16"]) < 1e-9
    t2 = roofline_terms(1e15, 1e9, CollectiveStats(), 256, HW)
    assert t2["dominant"] == "compute"


def test_bf16_correction_halves_f32_share():
    coll = CollectiveStats(per_chip_bytes=100.0, f32_bytes=60.0)
    assert coll.bf16_corrected == 70.0


def test_model_flops_train_vs_decode():
    cfg = get_config("stablelm-3b")
    tr = model_flops_for(cfg, SHAPES["train_4k"])
    de = model_flops_for(cfg, SHAPES["decode_32k"])
    # train: 6*N*B*S; decode: 2*N*B*1
    assert tr / de == pytest.approx(
        (6 * 256 * 4096) / (2 * 128), rel=1e-6)


def test_model_flops_moe_uses_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    f = model_flops_for(cfg, SHAPES["train_4k"])
    # active ~32B of 1.03T params
    tokens = 256 * 4096
    n_active = f / (6 * tokens)
    assert 25e9 < n_active < 45e9, n_active


def test_depth_variant_scan_iters_consistent():
    """depth_variant(i).scan_iters() must be linear in i for every arch —
    the precondition of the dry-run extrapolation."""
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        s2 = cfg.depth_variant(2).scan_iters()
        s4 = cfg.depth_variant(4).scan_iters()
        s3 = cfg.depth_variant(3).scan_iters()
        assert s4 - s3 == s3 - s2 != 0, arch
        assert cfg.scan_iters() >= s4, arch


def test_reduced_configs_are_small():
    for arch in ARCH_NAMES:
        cfg = get_config(arch).reduced()
        from repro.nn import module as nnm
        from repro.nn.transformer import build_model
        n = nnm.count_params(build_model(cfg).specs())
        assert n < 5e6, (arch, n)
