"""Incremental-decode parity: cached rollout equals the full forward.

Two levels:

  * ops-level — the ``kv_length`` cursor-masked decode path of
    ``repro.kernels.ops.attention`` reproduces the matching rows of the
    full-sequence forward across the feature matrix {causal positions,
    block-causal times, segment ids, GQA} and every impl (ref / chunked /
    flash-in-interpret-mode).
  * model-level — ``AgentSimModel.prefill`` + repeated ``step`` over the
    per-layer transformed-K/V cache reproduces ``__call__``'s logits for
    all four Table-I encodings, in f32 (tight tol) and bf16 (loose tol).
    This is the soundness proof of SE(2)-invariant K/V caching: cached
    ``phi_k``-transformed rows are never re-projected (docs/rollout.md).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import scenarios
from repro.kernels import ops, ref
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# ops-level: decode rows == full-forward rows
# ---------------------------------------------------------------------------

def _qkv(rng, b, hq, hkv, s, d, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    return q, k, v


DECODE_CASES = {
    # positions-as-times exercises plain causal decode in every impl
    # (flash has no q_offset; explicit times subsume it)
    "causal": dict(times="iota", segments=False, hkv="mha"),
    "block_causal_times": dict(times="blocky", segments=False, hkv="mha"),
    "segments": dict(times="blocky", segments=True, hkv="mha"),
    "gqa": dict(times="iota", segments=False, hkv="gqa"),
    "gqa_segments_times": dict(times="blocky", segments=True, hkv="gqa"),
}


@pytest.mark.parametrize("impl", ["ref", "chunked", "flash"])
@pytest.mark.parametrize("case", sorted(DECODE_CASES))
def test_ops_decode_matches_full(case, impl):
    spec = DECODE_CASES[case]
    rng = np.random.default_rng(sorted(DECODE_CASES).index(case))
    b, s, d, n = 2, 48, 16, 3
    hq, hkv = (4, 2) if spec["hkv"] == "gqa" else (2, 2)
    q, k, v = _qkv(rng, b, hq, hkv, s, d)
    if spec["times"] == "iota":
        times = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    else:
        times = jnp.asarray(np.sort(rng.integers(0, 6, size=(b, s)), -1),
                            jnp.int32)
    seg = (jnp.asarray(rng.integers(0, 2, size=(b, s)), jnp.int32)
           if spec["segments"] else None)
    kw = dict(causal=True, q_times=times, k_times=times,
              q_segment_ids=seg, k_segment_ids=seg)
    extra = dict(interpret=True, block_q=16, block_k=16) \
        if impl == "flash" else {}
    if impl == "flash":
        full = ops.flash_attention(q, k, v, **kw, **extra)
    else:
        full = ops.attention(q, k, v, impl=impl, **kw)

    # decode: the last n tokens as queries over the "cache" (all keys),
    # with per-row cursors — row 0 decodes with a shorter cache to prove
    # the cursor masks, row 1 with the full one.
    kvl = jnp.asarray([s - 1, s], jnp.int32)
    dq = q[:, :, s - n:]
    dkw = dict(causal=True, q_times=times[:, s - n:], k_times=times,
               q_segment_ids=None if seg is None else seg[:, s - n:],
               k_segment_ids=seg, kv_length=kvl)
    if impl == "flash":
        got = ops.flash_attention(dq, k, v, **dkw, **extra)
    else:
        got = ops.attention(dq, k, v, impl=impl, **dkw)

    # row 1 (full cursor) must equal the full forward's suffix rows
    np.testing.assert_allclose(np.asarray(got[1]),
                               np.asarray(full[1, :, s - n:]),
                               atol=2e-5, rtol=2e-4, err_msg=case)
    # row 0 (cursor s-1) must equal a forward over the truncated cache
    want0 = (ops.flash_attention(dq[:1], k[:1, :, :s - 1], v[:1, :, :s - 1],
                                 causal=True, q_times=times[:1, s - n:],
                                 k_times=times[:1, :s - 1],
                                 q_segment_ids=None if seg is None
                                 else seg[:1, s - n:],
                                 k_segment_ids=None if seg is None
                                 else seg[:1, :s - 1], **extra)
             if impl == "flash" else
             ops.attention(dq[:1], k[:1, :, :s - 1], v[:1, :, :s - 1],
                           impl=impl, causal=True,
                           q_times=times[:1, s - n:],
                           k_times=times[:1, :s - 1],
                           q_segment_ids=None if seg is None
                           else seg[:1, s - n:],
                           k_segment_ids=None if seg is None
                           else seg[:1, :s - 1]))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want0[0]),
                               atol=2e-5, rtol=2e-4,
                               err_msg=f"{case} cursor row")


def test_ops_decode_q_offset_equivalence():
    """kv_length decode == the ref/chunked q_offset decode convention."""
    rng = np.random.default_rng(42)
    q, k, v = _qkv(rng, 1, 2, 2, 64, 16)
    dq = q[:, :, 60:]
    want = ref.mha_reference(dq, k, v, causal=True, q_offset=60)
    times = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (1, 64))
    got = ops.attention(dq, k, v, impl="chunked", causal=True,
                        q_times=times[:, 60:], k_times=times,
                        kv_length=jnp.asarray([64], jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# model-level: prefill + step == __call__ for all four encodings
# ---------------------------------------------------------------------------

SCEN = scenarios.ScenarioConfig(num_map=4, num_agents=2, num_steps=4)
ENCODINGS = ["absolute", "rope2d", "se2_repr", "se2_fourier"]


def _tiny_model(encoding, dtype="float32"):
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=SCEN.num_actions,
                         encoding=encoding, fourier_terms=8,
                         attn_impl="ref", dtype=dtype)
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(0))
    return cfg, model, params


def _batch(with_invalid=False):
    b = {k: jnp.asarray(v)
         for k, v in scenarios.generate_batch(0, 0, 2, SCEN).items()}
    if with_invalid:
        valid = np.asarray(b["agent_valid"]).copy()
        valid[0, 2:, -1] = False          # one agent drops out mid-scene
        b["agent_valid"] = jnp.asarray(valid)
    return b


@pytest.mark.parametrize("encoding", ENCODINGS)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_cached_decode_matches_full_forward(encoding, dtype):
    cfg, model, params = _tiny_model(encoding, dtype)
    batch = _batch()
    full, _ = model(params, batch)                   # (B, T, A, K)
    tol = (dict(atol=2e-4, rtol=2e-3) if dtype == "float32"
           else dict(atol=8e-2, rtol=8e-2))

    t_hist = 2
    hist = dict(batch)
    for key in ("agent_feats", "agent_pose", "agent_valid"):
        hist[key] = batch[key][:, :t_hist]
    b = batch["map_feats"].shape[0]
    max_len = SCEN.num_map + SCEN.num_steps * SCEN.num_agents
    cache = model.init_cache(b, max_len)
    got, cache = model.prefill(params, cache, hist)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full[:, :t_hist], np.float32),
                               err_msg=f"{encoding} prefill", **tol)
    for t in range(t_hist, SCEN.num_steps):
        lt, cache = model.step(params, cache, batch["agent_feats"][:, t],
                               batch["agent_pose"][:, t],
                               batch["agent_valid"][:, t],
                               jnp.full((b,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lt, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   err_msg=f"{encoding} step {t}", **tol)
    assert int(cache["cursor"][0]) == SCEN.num_map + SCEN.num_steps * \
        SCEN.num_agents


@pytest.mark.parametrize("encoding", ["se2_fourier", "absolute"])
def test_cached_decode_invalid_agents(encoding):
    """Segment masking composes: dropped-out agents don't poison parity of
    the tokens that remain valid."""
    cfg, model, params = _tiny_model(encoding)
    batch = _batch(with_invalid=True)
    full, _ = model(params, batch)
    valid = np.asarray(batch["agent_valid"])

    b = batch["map_feats"].shape[0]
    max_len = SCEN.num_map + SCEN.num_steps * SCEN.num_agents
    cache = model.init_cache(b, max_len)
    hist = dict(batch)
    for key in ("agent_feats", "agent_pose", "agent_valid"):
        hist[key] = batch[key][:, :1]
    got, cache = model.prefill(params, cache, hist)
    diffs = [np.abs(np.asarray(got[:, 0], np.float32)
                    - np.asarray(full[:, 0], np.float32))[valid[:, 0]]]
    for t in range(1, SCEN.num_steps):
        lt, cache = model.step(params, cache, batch["agent_feats"][:, t],
                               batch["agent_pose"][:, t],
                               batch["agent_valid"][:, t],
                               jnp.full((b,), t, jnp.int32))
        diffs.append(np.abs(np.asarray(lt, np.float32)
                            - np.asarray(full[:, t], np.float32))[valid[:, t]])
    assert max(d.max() for d in diffs if d.size) < 2e-4


def test_engine_kinematics_matches_scenario_generator():
    """The engine's jnp unicycle integrator must track the numpy one in
    scenarios.py bit-for-bit-ish: if someone retunes the clamp or the
    integration scheme in one place, this is the test that names it."""
    from repro.runtime.rollout import step_kinematics as jnp_kin

    rng = np.random.default_rng(99)
    pose = rng.normal(scale=20.0, size=(32, 3)).astype(np.float32)
    speed = np.abs(rng.normal(scale=12.0, size=(32,))).astype(np.float32)
    accel = rng.normal(scale=3.0, size=(32,)).astype(np.float32)
    yaw = rng.normal(scale=0.5, size=(32,)).astype(np.float32)
    p_np, s_np = scenarios.step_kinematics(pose, speed, accel, yaw)
    p_j, s_j = jnp_kin(jnp.asarray(pose), jnp.asarray(speed),
                       jnp.asarray(accel), jnp.asarray(yaw))
    np.testing.assert_allclose(np.asarray(p_j), p_np, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_j), s_np, atol=1e-6)


def test_per_slot_cursor_decode():
    """Slots at different cursors decode correctly in ONE batched call —
    the RolloutEngine / continuous-batching shape: a (B,) cursor vector,
    per-slot scatter, per-slot step times."""
    cfg, model, params = _tiny_model("se2_fourier")
    batch = _batch()
    full, _ = model(params, batch)
    b = batch["map_feats"].shape[0]
    max_len = SCEN.num_map + SCEN.num_steps * SCEN.num_agents

    # slot 0 prefills 1 history step, slot 1 prefills 2: cursors diverge
    caches = []
    for t0 in (1, 2):
        hist = dict(batch)
        for key in ("agent_feats", "agent_pose", "agent_valid"):
            hist[key] = batch[key][:, :t0]
        cache = model.init_cache(b, max_len)
        _, cache = model.prefill(params, cache, hist)
        caches.append(cache)

    def pick(leaf_a, leaf_b):
        axis = 1 if leaf_a.ndim >= 5 else 0      # (L, B, ...) vs (B, ...)
        take = lambda leaf, i: jax.lax.slice_in_dim(leaf, i, i + 1, axis=axis)
        return jnp.concatenate([take(leaf_a, 0), take(leaf_b, 1)], axis=axis)

    merged = jax.tree.map(pick, caches[0], caches[1])
    assert int(merged["cursor"][0]) != int(merged["cursor"][1])

    # one batched step: slot 0 consumes its t=1 tokens, slot 1 its t=2
    # tokens; each row lands at its own cursor with its own time
    t_vec = jnp.asarray([1, 2], jnp.int32)
    gather_t = lambda arr: jnp.stack([arr[0, 1], arr[1, 2]])
    lt, merged = model.step(params, merged,
                            gather_t(batch["agent_feats"]),
                            gather_t(batch["agent_pose"]),
                            gather_t(batch["agent_valid"]), t_vec)
    np.testing.assert_allclose(np.asarray(lt[0], np.float32),
                               np.asarray(full[0, 1], np.float32),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(lt[1], np.float32),
                               np.asarray(full[1, 2], np.float32),
                               atol=2e-4, rtol=2e-3)
