"""Incremental-decode parity: cached rollout equals the full forward.

Three levels:

  * ops-level — the ``kv_length`` cursor-masked decode path of
    ``repro.kernels.ops.attention`` reproduces the matching rows of the
    full-sequence forward across the feature matrix {causal positions,
    block-causal times, segment ids, GQA} and every impl (ref / chunked /
    flash-in-interpret-mode).
  * decode-kernel — the split-K ragged decode paths
    (``ops.decode_attention``: the Pallas kernel in interpret mode, its
    cursor-bounded XLA twin, and the generic-kernel fallback) agree with
    the O(S^2) oracle across cursors {0, 1, block-1, block, full, ragged
    per-row}, GQA, segments, times, split counts, and cache dtypes
    f32 / bf16 / int8-with-scales. The f32/bf16/int8 *parity* tolerances
    are tight (all paths consume identical cache values; only summation
    order differs); the int8 *quantization drift* against an unquantized
    cache is asserted separately at its documented ~1% level.
  * model-level — ``AgentSimModel.prefill`` + repeated ``step`` over the
    per-layer transformed-K/V cache reproduces ``__call__``'s logits for
    all four Table-I encodings, in f32 (tight tol), bf16 (loose tol),
    and with an int8-quantized cache (documented quantization tol) under
    every decode impl; and int8-cache closed-loop rollout metrics match
    the f32 cache within documented tolerance.
    This is the soundness proof of SE(2)-invariant K/V caching: cached
    ``phi_k``-transformed rows are never re-projected (docs/rollout.md).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import scenarios
from repro.kernels import ops, ref
from repro.kernels.flash_decode import dequantize_kv, quantize_kv
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# ops-level: decode rows == full-forward rows
# ---------------------------------------------------------------------------

def _qkv(rng, b, hq, hkv, s, d, dtype=jnp.float32):
    q = jnp.asarray(rng.normal(size=(b, hq, s, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), dtype)
    return q, k, v


DECODE_CASES = {
    # positions-as-times exercises plain causal decode in every impl
    # (flash has no q_offset; explicit times subsume it)
    "causal": dict(times="iota", segments=False, hkv="mha"),
    "block_causal_times": dict(times="blocky", segments=False, hkv="mha"),
    "segments": dict(times="blocky", segments=True, hkv="mha"),
    "gqa": dict(times="iota", segments=False, hkv="gqa"),
    "gqa_segments_times": dict(times="blocky", segments=True, hkv="gqa"),
}


@pytest.mark.parametrize("impl", ["ref", "chunked", "flash"])
@pytest.mark.parametrize("case", sorted(DECODE_CASES))
def test_ops_decode_matches_full(case, impl):
    spec = DECODE_CASES[case]
    rng = np.random.default_rng(sorted(DECODE_CASES).index(case))
    b, s, d, n = 2, 48, 16, 3
    hq, hkv = (4, 2) if spec["hkv"] == "gqa" else (2, 2)
    q, k, v = _qkv(rng, b, hq, hkv, s, d)
    if spec["times"] == "iota":
        times = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    else:
        times = jnp.asarray(np.sort(rng.integers(0, 6, size=(b, s)), -1),
                            jnp.int32)
    seg = (jnp.asarray(rng.integers(0, 2, size=(b, s)), jnp.int32)
           if spec["segments"] else None)
    kw = dict(causal=True, q_times=times, k_times=times,
              q_segment_ids=seg, k_segment_ids=seg)
    extra = dict(interpret=True, block_q=16, block_k=16) \
        if impl == "flash" else {}
    if impl == "flash":
        full = ops.flash_attention(q, k, v, **kw, **extra)
    else:
        full = ops.attention(q, k, v, impl=impl, **kw)

    # decode: the last n tokens as queries over the "cache" (all keys),
    # with per-row cursors — row 0 decodes with a shorter cache to prove
    # the cursor masks, row 1 with the full one.
    kvl = jnp.asarray([s - 1, s], jnp.int32)
    dq = q[:, :, s - n:]
    dkw = dict(causal=True, q_times=times[:, s - n:], k_times=times,
               q_segment_ids=None if seg is None else seg[:, s - n:],
               k_segment_ids=seg, kv_length=kvl)
    if impl == "flash":
        got = ops.flash_attention(dq, k, v, **dkw, **extra)
    else:
        got = ops.attention(dq, k, v, impl=impl, **dkw)

    # row 1 (full cursor) must equal the full forward's suffix rows
    np.testing.assert_allclose(np.asarray(got[1]),
                               np.asarray(full[1, :, s - n:]),
                               atol=2e-5, rtol=2e-4, err_msg=case)
    # row 0 (cursor s-1) must equal a forward over the truncated cache
    want0 = (ops.flash_attention(dq[:1], k[:1, :, :s - 1], v[:1, :, :s - 1],
                                 causal=True, q_times=times[:1, s - n:],
                                 k_times=times[:1, :s - 1],
                                 q_segment_ids=None if seg is None
                                 else seg[:1, s - n:],
                                 k_segment_ids=None if seg is None
                                 else seg[:1, :s - 1], **extra)
             if impl == "flash" else
             ops.attention(dq[:1], k[:1, :, :s - 1], v[:1, :, :s - 1],
                           impl=impl, causal=True,
                           q_times=times[:1, s - n:],
                           k_times=times[:1, :s - 1],
                           q_segment_ids=None if seg is None
                           else seg[:1, s - n:],
                           k_segment_ids=None if seg is None
                           else seg[:1, :s - 1]))
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want0[0]),
                               atol=2e-5, rtol=2e-4,
                               err_msg=f"{case} cursor row")


def test_ops_decode_q_offset_equivalence():
    """kv_length decode == the ref/chunked q_offset decode convention."""
    rng = np.random.default_rng(42)
    q, k, v = _qkv(rng, 1, 2, 2, 64, 16)
    dq = q[:, :, 60:]
    want = ref.mha_reference(dq, k, v, causal=True, q_offset=60)
    times = jnp.broadcast_to(jnp.arange(64, dtype=jnp.int32), (1, 64))
    got = ops.attention(dq, k, v, impl="chunked", causal=True,
                        q_times=times[:, 60:], k_times=times,
                        kv_length=jnp.asarray([64], jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# decode kernel: split-K ragged decode vs the O(S^2) oracle
# ---------------------------------------------------------------------------

DECODE_BLOCK = 16          # kernel key-block size used by the parity matrix

DECODE_FEATS = {
    "plain": dict(times=False, segments=False, hkv="mha"),
    "times": dict(times=True, segments=False, hkv="mha"),
    "seg_times": dict(times=True, segments=True, hkv="mha"),
    "gqa": dict(times=False, segments=False, hkv="gqa"),
    "gqa_seg_times": dict(times=True, segments=True, hkv="gqa"),
}

# cursor cases from the issue: zero, one, block-1, block, full, and a
# ragged per-row vector straddling a block boundary
DECODE_CURSORS = {
    "zero": lambda b, s: np.zeros(b, np.int32),
    "one": lambda b, s: np.ones(b, np.int32),
    "block_minus_1": lambda b, s: np.full(b, DECODE_BLOCK - 1, np.int32),
    "block": lambda b, s: np.full(b, DECODE_BLOCK, np.int32),
    "full": lambda b, s: np.full(b, s, np.int32),
    "ragged": lambda b, s: np.asarray(
        [s - 7, DECODE_BLOCK + 1][:b] * (b // 2 + 1), np.int32)[:b],
}

#: parity tolerance per cache dtype. Every impl consumes the *same*
#: cache values (bf16 rows / int8 rows + scales are dequantized to
#: identical f32 on all paths), so f32 / int8 stay at f32-summation-
#: order tightness. bf16 is looser for one reason only: the generic
#: fallback rounds its *output* to the cache dtype (``mha_reference``
#: returns v.dtype) while the decode kernels emit q.dtype f32 — one
#: bf16 output rounding, <= 2^-8 relative. The quantization error
#: itself is asserted separately (test_flash_decode_int8_drift).
DECODE_TOL = {"float32": dict(atol=2e-5, rtol=2e-4),
              "bfloat16": dict(atol=8e-3, rtol=8e-3),
              "int8": dict(atol=2e-4, rtol=2e-3)}


def _decode_case(feat, cache_dtype, seed):
    rng = np.random.default_rng(seed)
    b, s, sq, d = 2, 48, 4, 12
    hq, hkv = (4, 2) if DECODE_FEATS[feat]["hkv"] == "gqa" else (2, 2)
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
    kw = {}
    if DECODE_FEATS[feat]["times"]:
        kt = jnp.asarray(np.sort(rng.integers(0, 6, size=(b, s)), -1),
                         jnp.int32)
        kw["q_times"] = jnp.full((b, sq), 6, jnp.int32)  # appended last
        kw["k_times"] = kt
    if DECODE_FEATS[feat]["segments"]:
        kw["q_segment_ids"] = jnp.asarray(
            rng.integers(0, 2, size=(b, sq)), jnp.int32)
        kw["k_segment_ids"] = jnp.asarray(
            rng.integers(0, 2, size=(b, s)), jnp.int32)
    k_scale = v_scale = None
    if cache_dtype == "int8":
        k, k_scale = quantize_kv(k)
        v, v_scale = quantize_kv(v)
        k_oracle = dequantize_kv(k, k_scale)
        v_oracle = dequantize_kv(v, v_scale)
    elif cache_dtype == "bfloat16":
        k = k.astype(jnp.bfloat16)
        v = v.astype(jnp.bfloat16)
        k_oracle, v_oracle = k.astype(jnp.float32), v.astype(jnp.float32)
    else:
        k_oracle, v_oracle = k, v
    return q, k, v, k_scale, v_scale, k_oracle, v_oracle, kw


@pytest.mark.parametrize("cache_dtype", sorted(DECODE_TOL))
@pytest.mark.parametrize("feat", sorted(DECODE_FEATS))
@pytest.mark.parametrize("cursor", sorted(DECODE_CURSORS))
def test_decode_kernel_parity_matrix(cursor, feat, cache_dtype):
    """flash_decode (interpret) == ragged XLA == generic fallback ==
    O(S^2) oracle, across the cursor x feature x cache-dtype matrix."""
    seed = (sorted(DECODE_CURSORS).index(cursor) * 31
            + sorted(DECODE_FEATS).index(feat))
    q, k, v, k_scale, v_scale, k_oracle, v_oracle, kw = _decode_case(
        feat, cache_dtype, seed)
    b, s = k.shape[0], k.shape[2]
    kvl = jnp.asarray(DECODE_CURSORS[cursor](b, s))
    want = np.asarray(ref.mha_reference(
        q, k_oracle, v_oracle, causal="q_times" in kw,
        kv_length=kvl, **kw), np.float32)

    common = dict(kv_length=kvl, k_scale=k_scale, v_scale=v_scale, **kw)
    got = {
        "flash_decode": ops.decode_attention(
            q, k, v, impl="flash_decode", block_k=DECODE_BLOCK,
            num_splits=2, interpret=True, **common),
        "xla": ops.decode_attention(q, k, v, impl="xla",
                                    block_k=DECODE_BLOCK, **common),
        "ref": ops.decode_attention(q, k, v, impl="ref", **common),
    }
    for name, g in got.items():
        np.testing.assert_allclose(
            np.asarray(g, np.float32), want, **DECODE_TOL[cache_dtype],
            err_msg=f"{name} {cursor}/{feat}/{cache_dtype}")


@pytest.mark.parametrize("num_splits", [1, 2, 3, 5])
def test_flash_decode_split_counts(num_splits):
    """The split-K reduction is invariant to the split count (including
    counts that do not divide the block count, and a single split)."""
    q, k, v, _, _, _, _, kw = _decode_case("gqa_seg_times", "float32", 7)
    kvl = jnp.asarray([41, 17], jnp.int32)
    want = ops.decode_attention(q, k, v, impl="ref", kv_length=kvl, **kw)
    got = ops.decode_attention(q, k, v, impl="flash_decode", kv_length=kvl,
                               block_k=8, num_splits=num_splits,
                               interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_flash_decode_int8_drift():
    """int8 cache vs unquantized f32 cache: the documented quantization
    error budget. Per-row symmetric int8 rounds each K/V entry within
    absmax/254 (~0.4% of the row scale); through the softmax that stays
    well under 5e-2 absolute on O(1)-magnitude attention outputs."""
    q, k, v, _, _, _, _, kw = _decode_case("seg_times", "float32", 11)
    kvl = jnp.asarray([48, 33], jnp.int32)
    want = ops.decode_attention(q, k, v, impl="xla", kv_length=kvl, **kw)
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    got = ops.decode_attention(q, kq, vq, impl="xla", kv_length=kvl,
                               k_scale=ks, v_scale=vs, **kw)
    drift = float(jnp.max(jnp.abs(got - want)))
    assert 0 < drift < 5e-2, drift


# ---------------------------------------------------------------------------
# model-level: prefill + step == __call__ for all four encodings
# ---------------------------------------------------------------------------

SCEN = scenarios.ScenarioConfig(num_map=4, num_agents=2, num_steps=4)
ENCODINGS = ["absolute", "rope2d", "se2_repr", "se2_fourier"]


def _tiny_model(encoding, dtype="float32"):
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=SCEN.num_actions,
                         encoding=encoding, fourier_terms=8,
                         attn_impl="ref", dtype=dtype)
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(0))
    return cfg, model, params


def _batch(with_invalid=False):
    b = {k: jnp.asarray(v)
         for k, v in scenarios.generate_batch(0, 0, 2, SCEN).items()}
    if with_invalid:
        valid = np.asarray(b["agent_valid"]).copy()
        valid[0, 2:, -1] = False          # one agent drops out mid-scene
        b["agent_valid"] = jnp.asarray(valid)
    return b


@pytest.mark.parametrize("encoding", ENCODINGS)
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_cached_decode_matches_full_forward(encoding, dtype):
    cfg, model, params = _tiny_model(encoding, dtype)
    batch = _batch()
    full, _ = model(params, batch)                   # (B, T, A, K)
    tol = (dict(atol=2e-4, rtol=2e-3) if dtype == "float32"
           else dict(atol=8e-2, rtol=8e-2))

    t_hist = 2
    hist = dict(batch)
    for key in ("agent_feats", "agent_pose", "agent_valid"):
        hist[key] = batch[key][:, :t_hist]
    b = batch["map_feats"].shape[0]
    max_len = SCEN.num_map + SCEN.num_steps * SCEN.num_agents
    cache = model.init_cache(b, max_len)
    got, cache = model.prefill(params, cache, hist)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full[:, :t_hist], np.float32),
                               err_msg=f"{encoding} prefill", **tol)
    for t in range(t_hist, SCEN.num_steps):
        lt, cache = model.step(params, cache, batch["agent_feats"][:, t],
                               batch["agent_pose"][:, t],
                               batch["agent_valid"][:, t],
                               jnp.full((b,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lt, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   err_msg=f"{encoding} step {t}", **tol)
    assert int(cache["cursor"][0]) == SCEN.num_map + SCEN.num_steps * \
        SCEN.num_agents


@pytest.mark.parametrize("encoding", ["se2_fourier", "absolute"])
def test_cached_decode_invalid_agents(encoding):
    """Segment masking composes: dropped-out agents don't poison parity of
    the tokens that remain valid."""
    cfg, model, params = _tiny_model(encoding)
    batch = _batch(with_invalid=True)
    full, _ = model(params, batch)
    valid = np.asarray(batch["agent_valid"])

    b = batch["map_feats"].shape[0]
    max_len = SCEN.num_map + SCEN.num_steps * SCEN.num_agents
    cache = model.init_cache(b, max_len)
    hist = dict(batch)
    for key in ("agent_feats", "agent_pose", "agent_valid"):
        hist[key] = batch[key][:, :1]
    got, cache = model.prefill(params, cache, hist)
    diffs = [np.abs(np.asarray(got[:, 0], np.float32)
                    - np.asarray(full[:, 0], np.float32))[valid[:, 0]]]
    for t in range(1, SCEN.num_steps):
        lt, cache = model.step(params, cache, batch["agent_feats"][:, t],
                               batch["agent_pose"][:, t],
                               batch["agent_valid"][:, t],
                               jnp.full((b,), t, jnp.int32))
        diffs.append(np.abs(np.asarray(lt, np.float32)
                            - np.asarray(full[:, t], np.float32))[valid[:, t]])
    assert max(d.max() for d in diffs if d.size) < 2e-4


def test_engine_kinematics_matches_scenario_generator():
    """The engine's jnp unicycle integrator must track the numpy one in
    scenarios.py bit-for-bit-ish: if someone retunes the clamp or the
    integration scheme in one place, this is the test that names it."""
    from repro.runtime.rollout import step_kinematics as jnp_kin

    rng = np.random.default_rng(99)
    pose = rng.normal(scale=20.0, size=(32, 3)).astype(np.float32)
    speed = np.abs(rng.normal(scale=12.0, size=(32,))).astype(np.float32)
    accel = rng.normal(scale=3.0, size=(32,)).astype(np.float32)
    yaw = rng.normal(scale=0.5, size=(32,)).astype(np.float32)
    p_np, s_np = scenarios.step_kinematics(pose, speed, accel, yaw)
    p_j, s_j = jnp_kin(jnp.asarray(pose), jnp.asarray(speed),
                       jnp.asarray(accel), jnp.asarray(yaw))
    np.testing.assert_allclose(np.asarray(p_j), p_np, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_j), s_np, atol=1e-6)


def test_per_slot_cursor_decode():
    """Slots at different cursors decode correctly in ONE batched call —
    the RolloutEngine / continuous-batching shape: a (B,) cursor vector,
    per-slot scatter, per-slot step times."""
    cfg, model, params = _tiny_model("se2_fourier")
    batch = _batch()
    full, _ = model(params, batch)
    b = batch["map_feats"].shape[0]
    max_len = SCEN.num_map + SCEN.num_steps * SCEN.num_agents

    # slot 0 prefills 1 history step, slot 1 prefills 2: cursors diverge
    caches = []
    for t0 in (1, 2):
        hist = dict(batch)
        for key in ("agent_feats", "agent_pose", "agent_valid"):
            hist[key] = batch[key][:, :t0]
        cache = model.init_cache(b, max_len)
        _, cache = model.prefill(params, cache, hist)
        caches.append(cache)

    def pick(leaf_a, leaf_b):
        axis = 1 if leaf_a.ndim >= 5 else 0      # (L, B, ...) vs (B, ...)
        take = lambda leaf, i: jax.lax.slice_in_dim(leaf, i, i + 1, axis=axis)
        return jnp.concatenate([take(leaf_a, 0), take(leaf_b, 1)], axis=axis)

    merged = jax.tree.map(pick, caches[0], caches[1])
    assert int(merged["cursor"][0]) != int(merged["cursor"][1])

    # one batched step: slot 0 consumes its t=1 tokens, slot 1 its t=2
    # tokens; each row lands at its own cursor with its own time
    t_vec = jnp.asarray([1, 2], jnp.int32)
    gather_t = lambda arr: jnp.stack([arr[0, 1], arr[1, 2]])
    lt, merged = model.step(params, merged,
                            gather_t(batch["agent_feats"]),
                            gather_t(batch["agent_pose"]),
                            gather_t(batch["agent_valid"]), t_vec)
    np.testing.assert_allclose(np.asarray(lt[0], np.float32),
                               np.asarray(full[0, 1], np.float32),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(lt[1], np.float32),
                               np.asarray(full[1, 2], np.float32),
                               atol=2e-4, rtol=2e-3)


# ---------------------------------------------------------------------------
# model-level: quantized caches and the ragged decode impls
# ---------------------------------------------------------------------------

#: model-level tolerance for an int8 K/V cache vs the unquantized full
#: forward. The cached phi_k-transformed rows are quantized per (head,
#: token) to int8 (round-off <= absmax/254 per row); at the tiny test
#: scale that perturbs action logits by ~2e-2, so 8e-2 gives 4x headroom
#: while still catching a mis-scaled row outright (which shifts logits
#: by O(1)).
INT8_MODEL_TOL = dict(atol=8e-2, rtol=8e-2)


@pytest.mark.parametrize("impl", ["ref", "xla", "flash_decode"])
@pytest.mark.parametrize("encoding", ["se2_fourier", "absolute"])
def test_cached_decode_int8_cache(encoding, impl):
    """prefill + step over an int8-quantized cache tracks the unquantized
    full forward within the documented tolerance — identically under the
    oracle fallback, the ragged XLA path, and the Pallas split-K kernel
    (interpret mode): every new flag combination keeps ref as oracle."""
    cfg, model, params = _tiny_model(encoding)
    batch = _batch()
    full, _ = model(params, batch)
    b = batch["map_feats"].shape[0]
    max_len = SCEN.num_map + SCEN.num_steps * SCEN.num_agents
    cache = model.init_cache(b, max_len, dtype="int8")
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache

    t_hist = 2
    hist = dict(batch)
    for key in ("agent_feats", "agent_pose", "agent_valid"):
        hist[key] = batch[key][:, :t_hist]
    got, cache = model.prefill(params, cache, hist, impl=impl)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(full[:, :t_hist], np.float32),
                               err_msg=f"{encoding}/{impl} prefill",
                               **INT8_MODEL_TOL)
    for t in range(t_hist, SCEN.num_steps):
        lt, cache = model.step(params, cache, batch["agent_feats"][:, t],
                               batch["agent_pose"][:, t],
                               batch["agent_valid"][:, t],
                               jnp.full((b,), t, jnp.int32), impl=impl)
        np.testing.assert_allclose(np.asarray(lt, np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   err_msg=f"{encoding}/{impl} step {t}",
                                   **INT8_MODEL_TOL)


@pytest.mark.parametrize("encoding", ["se2_fourier", "rope2d"])
def test_cached_decode_ragged_impls_match_oracle_exactly(encoding):
    """With an f32 cache the ragged decode impls must match the oracle
    ("ref") decode path to f32-roundoff on the logits: same mask, same
    cache rows, only the online-softmax evaluation order differs."""
    cfg, model, params = _tiny_model(encoding)
    batch = _batch(with_invalid=True)
    b = batch["map_feats"].shape[0]
    max_len = SCEN.num_map + SCEN.num_steps * SCEN.num_agents

    def roll(impl):
        cache = model.init_cache(b, max_len)
        hist = dict(batch)
        for key in ("agent_feats", "agent_pose", "agent_valid"):
            hist[key] = batch[key][:, :1]
        logits, cache = model.prefill(params, cache, hist, impl=impl)
        outs = [logits]
        for t in range(1, SCEN.num_steps):
            lt, cache = model.step(params, cache,
                                   batch["agent_feats"][:, t],
                                   batch["agent_pose"][:, t],
                                   batch["agent_valid"][:, t],
                                   jnp.full((b,), t, jnp.int32), impl=impl)
            outs.append(lt)
        return np.concatenate([np.asarray(o, np.float32).reshape(b, -1)
                               for o in outs], axis=1)

    want = roll("ref")
    # invalid-agent rows are compared too: every impl forces fully-masked
    # attention rows to zero, so their logits are well-defined and equal
    for impl in ("xla", "flash_decode"):
        got = roll(impl)
        np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-3,
                                   err_msg=impl)


def test_lm_attention_int8_cache_decode():
    """The generic LM ``Attention`` cache also supports int8 storage
    (quantize-on-write, scales beside K/V): greedy decode logits over an
    int8 cache track the f32 cache within the quantization tolerance."""
    from repro.nn.attention import Attention

    attn = Attention(d_model=32, num_q_heads=4, num_kv_heads=2, head_dim=8,
                     causal=True)
    params = nnm.init_params(attn.specs(), jax.random.key(3))
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 6, 32)), jnp.float32)
    pose = jnp.broadcast_to(jnp.arange(6, dtype=jnp.float32), (2, 6))

    outs = {}
    for dtype in ("float32", "int8"):
        cache = attn.init_cache(2, 8, dtype=dtype)
        assert ("k_scale" in cache) == (dtype == "int8")
        step_outs = []
        for t in range(6):
            y, cache = attn(params, x[:, t:t + 1], pose[:, t:t + 1],
                            cache=cache, cache_index=t)
            step_outs.append(np.asarray(y, np.float32))
        outs[dtype] = np.concatenate(step_outs, axis=1)
    np.testing.assert_allclose(outs["int8"], outs["float32"],
                               atol=8e-2, rtol=8e-2)
    assert np.abs(outs["int8"] - outs["float32"]).max() > 0, \
        "int8 cache produced bit-identical outputs — quantization inert?"


def test_int8_cache_rollout_metrics_match_f32():
    """Closed-loop acceptance: int8-cache rollout metrics (minADE / miss
    / collision) match the f32 cache within documented tolerance.

    Same engine, same per-(scene, sample) key stream; the int8 cache
    perturbs logits by ~1e-2, which can flip an occasional categorical
    draw — so trajectories may diverge on a few (scene, sample, step)
    triples while the *metrics* stay close. Tolerances: minADE within
    25% relative (or 0.5 m absolute); miss/collision rates within 0.25
    absolute. The run is deterministic, so this is a regression pin, not
    a flaky statistical test.
    """
    from repro.runtime.evaluation import EvalConfig, scene_metrics
    from repro.runtime.rollout import RolloutEngine
    from repro.scenarios import registry

    scen = scenarios.ScenarioConfig(num_map=8, num_agents=4, num_steps=16)
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=scen.num_actions,
                         encoding="se2_fourier", fourier_terms=8,
                         attn_impl="ref")
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(0))
    scenes = [registry.generate_scene("highway", 123, i, scen)
              for i in range(2)]
    eval_cfg = EvalConfig(t_hist=4, n_samples=2, seed=0)

    def metrics(cache_dtype):
        eng = RolloutEngine(model, params, scen, num_slots=4,
                            cache_dtype=cache_dtype, decode_impl="xla")
        futures = eng.run([s.tensors for s in scenes],
                          t_hist=eval_cfg.t_hist,
                          n_samples=eval_cfg.n_samples, seed=eval_cfg.seed)
        rows = [scene_metrics(scen, eval_cfg, s, futures[i])
                for i, s in enumerate(scenes)]
        return {m: float(np.nanmean([r[m] for r in rows]))
                for m in ("min_ade", "miss_rate", "collision_rate")}

    m32 = metrics(None)
    m8 = metrics("int8")
    assert abs(m8["min_ade"] - m32["min_ade"]) <= \
        max(0.5, 0.25 * m32["min_ade"]), (m32, m8)
    for key in ("miss_rate", "collision_rate"):
        assert abs(m8[key] - m32[key]) <= 0.25, (key, m32, m8)
