"""Fleet-monitor regression tests: the PR-7 fixes.

Each test pins a bug that would have silently defanged the monitors on a
real fleet: a ``min_samples`` gate that never gated, a fleet median that
the straggler itself defined in 2-host fleets, and a timer that raised
(or double-counted) when ``stop`` ran without a matching ``start``.
"""
import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import ShardedIterator
from repro.runtime.monitor import NaNGuard, StepTimer, StragglerPolicy
from repro.runtime.trainer import Trainer, TrainerConfig


# ---------------------------------------------------------------------------
# StragglerPolicy
# ---------------------------------------------------------------------------

def test_min_samples_gates_cold_ranks():
    # a rank's median rests on 1 noisy step -> it must neither be flagged
    # nor drag the fleet baseline around (the old guard was len(vals) < 1,
    # i.e. dead for any non-empty fleet)
    p = StragglerPolicy(straggler_factor=1.5, min_samples=10)
    medians = {0: 1.0, 1: 1.0, 2: 5.0}
    cold = {0: 10, 1: 10, 2: 3}
    assert p.evaluate(medians, cold) == []
    warm = {0: 10, 1: 10, 2: 10}
    assert p.evaluate(medians, warm) == [2]


def test_min_samples_gates_whole_fleet_without_counts():
    # no per-rank counts -> the fleet itself must carry min_samples finite
    # medians before any flag is raised
    p = StragglerPolicy(straggler_factor=1.5, min_samples=4)
    assert p.evaluate({0: 1.0, 1: 9.0}) == []
    assert p.evaluate({0: 1.0, 1: 1.0, 2: 1.0, 3: 9.0}) == [3]


def test_two_rank_straggler_is_flaggable():
    # upper-middle median made the slow rank its own baseline: in a 2-host
    # fleet a 2x straggler was structurally unflaggable
    p = StragglerPolicy(straggler_factor=1.5, min_samples=2)
    warm = {0: 100, 1: 100}
    assert p.evaluate({0: 1.0, 1: 2.0}, warm) == [1]
    assert p.evaluate({0: 1.0, 1: 1.2}, warm) == []


def test_straggler_even_fleet_lower_median():
    p = StragglerPolicy(straggler_factor=1.5, min_samples=1)
    warm = {r: 10 for r in range(4)}
    # two healthy + two slow: baseline stays at the healthy rank
    assert sorted(p.evaluate({0: 1.0, 1: 1.0, 2: 3.0, 3: 4.0}, warm)) \
        == [2, 3]
    # non-finite medians (rank not yet reporting) are excluded, not fatal
    assert p.evaluate({0: 1.0, 1: float("nan"), 2: 2.5}, warm) == [2]


def test_evaluate_timers_derives_counts():
    p = StragglerPolicy(straggler_factor=1.5, min_samples=3)
    fast, slow, cold = StepTimer(), StepTimer(), StepTimer()
    for t, dts in ((fast, [0.1] * 5), (slow, [0.5] * 5), (cold, [0.5])):
        for dt in dts:
            t.times.append(dt)
    assert p.evaluate_timers({0: fast, 1: slow, 2: cold}) == [1]


# ---------------------------------------------------------------------------
# StepTimer
# ---------------------------------------------------------------------------

def test_step_timer_even_window_lower_median():
    # even window: the LOWER middle, matching _lower_median — the upper
    # pick reported a systematically pessimistic median to the same
    # StragglerPolicy that builds lower-median fleet baselines
    from repro.runtime.monitor import _lower_median
    t = StepTimer(window=4)
    for dt in (3.0, 1.0, 4.0, 2.0):
        t.times.append(dt)
    assert t.median == 2.0
    assert t.median == _lower_median(sorted(t.times))
    t.times.append(5.0)                  # window rolls: [1,4,2,5] -> 2.0
    assert t.median == 2.0
    t.times.append(6.0)                  # [4,2,5,6] -> 4.0
    assert t.median == 4.0


def test_step_timer_stop_without_start_is_nan():
    t = StepTimer()
    assert math.isnan(t.stop())          # no TypeError on None - float
    assert t.count == 0
    t.start()
    assert t.stop() >= 0.0
    assert t.count == 1
    # double-stop: the interval must not be counted twice
    assert math.isnan(t.stop())
    assert t.count == 1
    assert math.isfinite(t.median)


# ---------------------------------------------------------------------------
# NaNGuard, unit and through the Trainer
# ---------------------------------------------------------------------------

def test_nan_guard_recovers_between_runs():
    g = NaNGuard(max_consecutive=3)
    seq = [1.0, float("nan"), float("inf"), 2.0, float("nan")]
    assert [g.check(x) for x in seq] == ["ok", "skip", "skip", "ok", "skip"]
    assert g.total_skipped == 3
    assert g.consecutive == 1


def test_trainer_halts_on_consecutive_nans(tmp_path):
    # systematic divergence: the Trainer must checkpoint and raise, not
    # spin through the full budget skipping every step
    def nan_step(params, opt_state, batch):
        return params, opt_state, {"loss": jnp.float32(float("nan"))}

    data = ShardedIterator(
        lambda seed, idx, b: {"x": np.zeros((b, 1), np.float32)},
        batch_size=2, seed=0)
    tr = Trainer(nan_step, {"w": jnp.zeros(2)}, {}, data, str(tmp_path),
                 TrainerConfig(total_steps=50, ckpt_every=100,
                               log_every=100, max_consecutive_nans=4))
    with pytest.raises(FloatingPointError):
        tr.run()
    assert tr.nan_guard.consecutive == 4
    assert tr.step < 50
    data.close()
