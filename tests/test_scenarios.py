"""Scenario-suite tests: registry, determinism, lane-graph topology,
variable-agent-count masking, mask-aware metrics, and the end-to-end
SE(2) property — globally re-posing any family's scene leaves closed-loop
evaluation metrics unchanged for relative encodings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.runtime.evaluation import EvalConfig, evaluate_scenes
from repro.runtime.rollout import RolloutEngine
from repro.scenarios import registry
from repro.scenarios.lane_graph import STEP

jax.config.update("jax_enable_x64", False)

CFG = scenarios.ScenarioConfig(num_map=16, num_agents=6, num_steps=10)
FAMILIES = registry.names()

TENSOR_KEYS = {"map_feats", "map_pose", "map_valid", "agent_feats",
               "agent_pose", "agent_valid", "actions", "behavior",
               "agent_type"}


# ---------------------------------------------------------------------------
# registry + determinism
# ---------------------------------------------------------------------------

def test_registry_discoverable():
    assert len(FAMILIES) >= 6
    for expected in ("freeform", "highway", "onramp_merge", "roundabout",
                     "signalized_intersection", "unprotected_left",
                     "pedestrian_crossing"):
        assert expected in FAMILIES
    with pytest.raises(KeyError):
        registry.get("no_such_family")


@pytest.mark.parametrize("family", FAMILIES)
def test_family_deterministic_from_cursor(family):
    a = registry.generate_scene(family, seed=3, index=11, cfg=CFG)
    b = registry.generate_scene(family, seed=3, index=11, cfg=CFG)
    assert set(a.tensors) == TENSOR_KEYS
    for k in a.tensors:
        np.testing.assert_array_equal(a.tensors[k], b.tensors[k],
                                      err_msg=f"{family}/{k}")
    c = registry.generate_scene(family, seed=3, index=12, cfg=CFG)
    assert any(not np.array_equal(a.tensors[k], c.tensors[k])
               for k in ("agent_pose", "map_pose")), \
        f"{family}: index does not vary the scene"


@pytest.mark.parametrize("family", FAMILIES)
def test_scene_shapes_and_masks(family):
    s = registry.generate_scene(family, seed=0, index=2, cfg=CFG)
    t, a, m = CFG.num_steps, CFG.num_agents, CFG.num_map
    tt = s.tensors
    assert tt["map_pose"].shape == (m, 3)
    assert tt["map_feats"].shape == (m, CFG.map_feat_dim)
    assert tt["agent_pose"].shape == (t, a, 3)
    assert tt["agent_feats"].shape == (t, a, CFG.agent_feat_dim)
    assert tt["agent_valid"].shape == (t, a)
    assert tt["actions"].shape == (t, a)
    assert tt["actions"].min() >= 0
    assert tt["actions"].max() < CFG.num_actions
    # valid-first packing, constant over time
    valid0 = tt["agent_valid"][0]
    n = int(valid0.sum())
    assert 1 <= n <= a
    assert valid0[:n].all() and not valid0[n:].any()
    np.testing.assert_array_equal(
        tt["agent_valid"], np.broadcast_to(valid0, (t, a)))
    # behavior labels only for valid agents
    assert (tt["behavior"][:n] >= 0).all()
    assert (tt["behavior"][n:] == -1).all() or n == a
    # speed feature convention: channel 0 is speed/10, consistent with the
    # pose deltas the rollout engine integrates
    assert tt["agent_feats"][..., 0].min() >= 0.0


def test_agent_counts_vary_across_indices():
    counts = {
        fam: {registry.generate_scene(fam, 0, i, CFG).num_valid_agents
              for i in range(8)}
        for fam in FAMILIES if fam != "freeform"}
    assert any(len(v) > 1 for v in counts.values()), counts


# ---------------------------------------------------------------------------
# lane-graph topology invariants
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", FAMILIES)
def test_lane_graph_topology(family):
    s = registry.generate_scene(family, seed=1, index=0, cfg=CFG)
    g = s.lane_graph
    assert g is not None and len(g.lanes) >= 1
    for a, succs in enumerate(g.successors):
        for b in succs:
            end, start = g.lanes[a].points[-1], g.lanes[b].points[0]
            gap = np.linalg.norm(end - start)
            assert gap <= STEP, \
                f"{family}: lane {a}->{b} endpoint gap {gap:.2f}m"
    # centerline points are on-road; a point far outside is not
    pts, _ = g.all_points()
    assert g.on_road(pts[:: max(1, len(pts) // 16)]).all()
    far = pts.max(axis=0) + 500.0
    assert not g.on_road(far).any()
    # route tracing follows successors and only ever extends the route
    rng = np.random.default_rng(0)
    route = g.trace_route(0, 100.0, rng)
    assert route[0] == 0
    for a, b in zip(route, route[1:]):
        assert b in g.successors[a]
    xy, hd = g.route_points(route)
    assert xy.shape[0] == hd.shape[0] >= len(g.lanes[0].points)


def test_map_tokens_cover_every_lane():
    """Token budget >= lane count => every lane owns at least one map
    token — its first centerline point is always sampled (left-turn arcs
    etc. must never be invisible to the model)."""
    s = registry.generate_scene("signalized_intersection", 0, 0, cfg=CFG)
    g = s.lane_graph
    assert CFG.num_map >= len(g.lanes)
    pose, _, valid = g.map_tokens(CFG.num_map, CFG.map_feat_dim)
    tok = pose[valid]
    for li, lane in enumerate(g.lanes):
        d = np.linalg.norm(tok[:, :2] - lane.points[0], axis=-1)
        assert d.min() < 1e-4, f"lane {li} has no token at its entry"


def test_offroad_query_ignores_crosswalks():
    """A vehicle standing on the crosswalk, away from the driving lanes,
    is off-road: the metric measures distance to kind='lane' only."""
    s = registry.generate_scene("pedestrian_crossing", 0, 0, cfg=CFG)
    g = s.lane_graph
    on_crosswalk = np.array([0.0, 6.0])       # mid-crosswalk, off both lanes
    assert g.distance(on_crosswalk) < 1.0
    assert g.distance(on_crosswalk, kinds=("lane",)) > 3.5
    assert not g.on_road(on_crosswalk, kinds=("lane",))


def test_spaced_starts_honors_min_gap():
    from repro.scenarios.policies import spaced_starts

    rng = np.random.default_rng(0)
    for n, lo, hi, gap in [(8, 10.0, 108.0, 18.0), (3, 0.0, 200.0, 10.0),
                           (5, 0.0, 12.0, 10.0)]:
        starts = spaced_starts(rng, n, lo, hi, min_gap=gap)
        assert 1 <= len(starts) <= n
        if len(starts) > 1:
            assert np.diff(starts).min() >= gap - 1e-4, (n, lo, hi, gap)


def test_map_tokens_masked_and_capped():
    s = registry.generate_scene("onramp_merge", seed=0, index=0, cfg=CFG)
    pose, feats, valid = s.lane_graph.map_tokens(CFG.num_map,
                                                 CFG.map_feat_dim)
    assert pose.shape == (CFG.num_map, 3)
    n = int(valid.sum())
    assert 0 < n <= CFG.num_map
    assert valid[:n].all() and not valid[n:].any()
    assert (pose[~valid] == 0).all()


# ---------------------------------------------------------------------------
# freeform back-compat shims
# ---------------------------------------------------------------------------

def test_freeform_shim_matches_registry():
    from repro.data import scenarios as data_scen

    legacy = data_scen.generate_scene(5, 9, CFG)
    fam = registry.generate_scene("freeform", 5, 9, CFG)
    for k in legacy:
        np.testing.assert_array_equal(legacy[k], fam.tensors[k], err_msg=k)
    batch = data_scen.generate_batch(5, 0, 3, CFG)
    assert batch["agent_pose"].shape == (3, CFG.num_steps, CFG.num_agents, 3)


def test_shared_kinematics_is_single_implementation():
    from repro.core import kinematics
    from repro.data import scenarios as data_scen
    from repro.runtime import rollout

    rng = np.random.default_rng(0)
    pose = rng.normal(size=(5, 3)).astype(np.float32)
    speed = np.abs(rng.normal(size=5)).astype(np.float32)
    p_np, s_np = data_scen.step_kinematics(pose, speed, 1.0, 0.1)
    p_j, s_j = rollout.step_kinematics(jnp.asarray(pose), jnp.asarray(speed),
                                       1.0, 0.1)
    p_c, s_c = kinematics.step_kinematics(pose, speed, 1.0, 0.1)
    np.testing.assert_allclose(np.asarray(p_j), p_np, atol=1e-6)
    np.testing.assert_array_equal(p_c, p_np)
    np.testing.assert_array_equal(s_c, s_np)


# ---------------------------------------------------------------------------
# variable agent counts through the model + engine
# ---------------------------------------------------------------------------

def _tiny_model(encoding="se2_fourier"):
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=CFG.num_actions,
                         encoding=encoding, fourier_terms=8, attn_impl="ref")
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(0))
    return model, params


def _scene_with_padding():
    """A scene whose valid agent count is strictly below the cap."""
    for idx in range(20):
        s = registry.generate_scene("onramp_merge", 2, idx, CFG)
        if 0 < s.num_valid_agents < CFG.num_agents:
            return s
    raise AssertionError("no padded scene found")


def test_padded_agents_do_not_change_valid_logits():
    """Physically removing the padding slots must not change any valid
    agent's logits — masking, not magic values, carries the variable
    agent count through attention."""
    model, params = _tiny_model()
    s = _scene_with_padding()
    n = s.num_valid_agents
    full = {k: jnp.asarray(v)[None] for k, v in s.tensors.items()}
    trimmed = dict(full)
    for k in ("agent_feats", "agent_pose", "agent_valid", "actions"):
        trimmed[k] = full[k][:, :, :n]
    lf, _ = model(params, full)
    lt, _ = model(params, trimmed)
    np.testing.assert_allclose(np.asarray(lf[:, :, :n], np.float32),
                               np.asarray(lt, np.float32),
                               atol=2e-4, rtol=2e-3)


def test_padded_agents_masked_through_prefill_step():
    """The cached decode path (prefill + per-step decode) agrees with the
    full forward on valid agents when padding slots ride along."""
    model, params = _tiny_model()
    s = _scene_with_padding()
    n = s.num_valid_agents
    batch = {k: jnp.asarray(v)[None] for k, v in s.tensors.items()}
    full, _ = model(params, batch)
    t_hist = 4
    cache = model.init_cache(1, CFG.num_map + CFG.num_steps * CFG.num_agents)
    hist = dict(batch)
    for k in ("agent_feats", "agent_pose", "agent_valid"):
        hist[k] = batch[k][:, :t_hist]
    got, cache = model.prefill(params, cache, hist)
    np.testing.assert_allclose(
        np.asarray(got[:, :, :n], np.float32),
        np.asarray(full[:, :t_hist, :n], np.float32), atol=2e-4, rtol=2e-3)
    for t in range(t_hist, CFG.num_steps):
        lt, cache = model.step(params, cache, batch["agent_feats"][:, t],
                               batch["agent_pose"][:, t],
                               batch["agent_valid"][:, t],
                               jnp.full((1,), t, jnp.int32))
        np.testing.assert_allclose(
            np.asarray(lt[:, :n], np.float32),
            np.asarray(full[:, t, :n], np.float32), atol=2e-4, rtol=2e-3)


def test_engine_freezes_invalid_agents():
    """RolloutEngine must not integrate padding slots: their 'poses' stay
    at the last history value for the whole rollout."""
    model, params = _tiny_model()
    s = _scene_with_padding()
    n = s.num_valid_agents
    t_hist = CFG.num_steps // 2
    engine = RolloutEngine(model, params, CFG, num_slots=2)
    fut = engine.run([s], t_hist=t_hist, n_samples=2, seed=0)
    last_hist = s.tensors["agent_pose"][t_hist - 1]
    for pad in range(n, CFG.num_agents):
        np.testing.assert_array_equal(
            fut[0, :, :, pad], np.broadcast_to(
                last_hist[pad], fut[0, :, :, pad].shape))
    # valid agents do move
    assert np.abs(fut[0, :, -1, :n, :2]
                  - last_hist[:n, :2]).max() > 1e-3


# ---------------------------------------------------------------------------
# mask-aware metrics
# ---------------------------------------------------------------------------

def test_rollout_metrics_exclude_invalid_agents():
    t, a, k = 6, 4, 3
    rng = np.random.default_rng(0)
    gt = rng.normal(size=(t, a, 3)).astype(np.float32)
    fut = np.repeat(gt[None], k, axis=0) + 0.1
    behavior = np.array([1, 1, 1, 1], np.int32)
    valid = np.ones((t, a), bool)
    valid[:, -1] = False
    fut_bad = fut.copy()
    fut_bad[:, :, -1, :2] += 1e6          # poison the padding slot
    clean = scenarios.rollout_metrics(CFG, gt, fut, behavior,
                                      agent_valid=valid)
    masked = scenarios.rollout_metrics(CFG, gt, fut_bad, behavior,
                                       agent_valid=valid)
    legacy = scenarios.rollout_metrics(CFG, gt, fut_bad, behavior)
    assert masked["straight"] == pytest.approx(clean["straight"])
    assert legacy["straight"] > 1e4       # the bug the mask fixes


def test_evaluation_metrics_shape():
    model, params = _tiny_model()
    scenes = [registry.generate_scene(f, 0, i, CFG)
              for f in ("highway", "pedestrian_crossing") for i in range(2)]
    engine = RolloutEngine(model, params, CFG, num_slots=4)
    res = evaluate_scenes(engine, scenes,
                          EvalConfig(t_hist=5, n_samples=2, seed=1))
    assert set(res) == {"highway", "pedestrian_crossing", "overall"}
    for fam, m in res.items():
        assert np.isfinite(m["min_ade"])
        assert 0.0 <= m["collision_rate"] <= 1.0
        assert m["kinematic_infeasibility_rate"] <= 1e-9
    assert res["overall"]["n_scenes"] == 4


# ---------------------------------------------------------------------------
# SE(2) property: re-posing a scene leaves closed-loop eval metrics alone
# ---------------------------------------------------------------------------

_ENGINE_CACHE = {}


def _eval_engine():
    if "e" not in _ENGINE_CACHE:
        model, params = _tiny_model("se2_repr")   # exact invariance
        _ENGINE_CACHE["e"] = RolloutEngine(model, params, CFG,
                                           num_slots=len(FAMILIES) * 2)
    return _ENGINE_CACHE["e"]


def _check_metrics_invariant(zx, zy, zth):
    """Re-posing every pose in a scene (map, agents, lane graph) by one
    rigid transform must leave every closed-loop eval metric of an
    SE(2)-relative model unchanged: the sampled action streams coincide
    (same per-(scene, sample) keys, invariant logits) and all metrics are
    functions of relative geometry only."""
    z = np.array([zx, zy, zth], np.float32)
    engine = _eval_engine()
    eval_cfg = EvalConfig(t_hist=CFG.num_steps // 2, n_samples=2, seed=5)
    scenes = [registry.generate_scene(f, 11, 0, CFG) for f in FAMILIES]
    moved = [scenarios.transform_scene(s, z) for s in scenes]
    base_m = evaluate_scenes(engine, scenes, eval_cfg)
    moved_m = evaluate_scenes(engine, moved, eval_cfg)
    for fam in base_m:
        for metric in ("min_ade", "miss_rate", "collision_rate",
                       "offroad_rate", "kinematic_infeasibility_rate"):
            b, m = base_m[fam][metric], moved_m[fam][metric]
            if np.isnan(b) and np.isnan(m):
                continue
            np.testing.assert_allclose(
                m, b, atol=0.1 if metric == "min_ade" else 0.15,
                err_msg=f"{fam}/{metric} moved under z={z}")


try:
    from hypothesis import given, settings, strategies as st

    transl = st.floats(min_value=-4.0, max_value=4.0, allow_nan=False,
                       width=32)
    angle = st.floats(min_value=-np.pi, max_value=np.pi, allow_nan=False,
                      width=32)

    @settings(max_examples=3, deadline=None, derandomize=True)
    @given(zx=transl, zy=transl, zth=angle)
    def test_eval_metrics_se2_invariant_all_families(zx, zy, zth):
        _check_metrics_invariant(zx, zy, zth)

except ImportError:            # hypothesis is an optional dev dep:
    @pytest.mark.parametrize(  # fall back to fixed transforms
        "zx,zy,zth",
        [(0.0, 0.0, np.pi / 2), (3.0, -2.0, 0.7), (-4.0, 3.5, -2.9)])
    def test_eval_metrics_se2_invariant_all_families(zx, zy, zth):
        _check_metrics_invariant(zx, zy, zth)
