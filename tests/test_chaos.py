"""Fault-injection / self-healing tests (the chaos layer).

Covers the contracts ``docs/robustness.md`` promises and
``repro.launch.chaos`` drills end-to-end:
  * FaultPlan determinism (seeded schedules, recorded firings);
  * checkpoint corruption matrix — truncated ``arrays.npz``, missing
    manifest, bit-flipped array (CRC mismatch), interrupted ``.tmp`` —
    each falls back to the previous verified step with a reported
    reason;
  * async-save IO failures: bounded retry + backoff, daemon-thread
    errors surfaced at ``wait()`` / the next ``save()``;
  * data-worker failures: bounded retries then ``DataWorkerError`` on
    the consumer thread (never a hang, never a silent respawn loop),
    cursor un-advanced so a fixed cause resumes exactly;
  * NaN-poisoned serving slots: quarantined with a reason while every
    healthy lane stays bit-identical to a fault-free run;
  * NaN-halt checkpoints: tagged ``halt_reason`` and refused on blind
    resume without ``force``.
"""
import importlib.util
import math
import os
import time

import numpy as np
import pytest

import jax

from repro import chaos
from repro.checkpoint import CheckpointManager, CheckpointWriteError
from repro.data.pipeline import DataWorkerError, ShardedIterator
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.runtime.sim_server import SceneRequest, SimServer
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.scenarios import ScenarioConfig
from repro.scenarios.registry import generate_mixed
from tests.serving_utils import assert_bit_identical

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError):
        chaos.Fault("not_a_kind", at=0)
    with pytest.raises(ValueError):
        chaos.Fault("delay_tick", at=-1)
    with pytest.raises(ValueError):
        chaos.Fault("delay_tick", at=0, count=0)


def test_fault_plan_covers_and_records():
    plan = chaos.FaultPlan([chaos.Fault("delay_tick", at=3, count=2)])
    clock = chaos.Clock()
    hits = [plan.fires("delay_tick", clock.next()) is not None
            for _ in range(6)]
    assert hits == [False, False, False, True, True, False]
    assert plan.fired_counts() == {"delay_tick": 2}
    assert [f["clock"] for f in plan.fired] == [3, 4]


def test_fault_plan_rng_deterministic():
    a = chaos.FaultPlan(seed=7).rng(1).integers(0, 1 << 30, 8)
    b = chaos.FaultPlan(seed=7).rng(1).integers(0, 1 << 30, 8)
    c = chaos.FaultPlan(seed=8).rng(1).integers(0, 1 << 30, 8)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, c)


# ---------------------------------------------------------------------------
# checkpoint integrity: corruption matrix -> verified fallback restore
# ---------------------------------------------------------------------------

def _tree(step):
    rng = np.random.default_rng(step)
    return {"w": rng.standard_normal((4, 5)).astype(np.float32),
            "b": np.full(3, step, np.float32)}


def _save_two(d):
    mgr = CheckpointManager(str(d), async_save=False)
    mgr.save(1, _tree(1), extra={"step": 1})
    mgr.save(2, _tree(2), extra={"step": 2})
    return mgr


CORRUPTIONS = ["truncate_checkpoint_npz", "bitflip_checkpoint_array",
               "drop_checkpoint_manifest"]


@pytest.mark.parametrize("mode", CORRUPTIONS)
def test_corrupt_latest_falls_back_with_reason(tmp_path, mode):
    _save_two(tmp_path)
    detail = chaos.corrupt_checkpoint(str(tmp_path), mode)
    assert detail["step"] == 2
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.verify(2) is not None        # corruption is detectable
    assert mgr.verify(1) is None
    tree, extra = mgr.restore(fallback=True)
    assert int(extra["step"]) == 1
    for k, v in _tree(1).items():
        assert_bit_identical(tree[k], v, f"fallback restore {k}")
    rep = mgr.last_restore_report
    assert rep["step"] == 1
    assert [s["step"] for s in rep["skipped"]] == [2]
    assert rep["skipped"][0]["reason"]      # human-readable cause


def test_every_step_corrupt_raises_listing_reasons(tmp_path):
    # all checkpoints bad is NOT a fresh start: restarting from scratch
    # silently would be the worst possible "recovery"
    _save_two(tmp_path)
    chaos.corrupt_checkpoint(str(tmp_path), "truncate_checkpoint_npz", step=2)
    chaos.corrupt_checkpoint(str(tmp_path), "drop_checkpoint_manifest",
                             step=1)
    with pytest.raises(IOError, match="no checkpoint passed"):
        CheckpointManager(str(tmp_path)).restore(fallback=True)


def test_empty_directory_restores_nothing(tmp_path):
    tree, extra = CheckpointManager(str(tmp_path)).restore(fallback=True)
    assert tree is None and extra is None


def test_explicit_strict_restore_raises_on_corruption(tmp_path):
    _save_two(tmp_path)
    chaos.corrupt_checkpoint(str(tmp_path), "bitflip_checkpoint_array")
    with pytest.raises(IOError):
        CheckpointManager(str(tmp_path)).restore(2)


def test_interrupted_tmp_is_invisible_and_swept(tmp_path):
    _save_two(tmp_path)
    detail = chaos.corrupt_checkpoint(str(tmp_path), "stale_checkpoint_tmp")
    assert os.path.isdir(detail["dir"])
    # a half-written .tmp never shows up as a restorable step...
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.available_steps() == [1, 2]
    assert mgr.latest_step() == 2
    # ...and manager startup swept the debris
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    tree, extra = mgr.restore(fallback=True)
    assert int(extra["step"]) == 2


def test_legacy_manifest_without_crc_still_restores(tmp_path):
    import json
    _save_two(tmp_path)
    # simulate a pre-CRC checkpoint: strip the crc32 block from step 2
    man = os.path.join(str(tmp_path), "step_0000000002", "manifest.json")
    with open(man) as f:
        m = json.load(f)
    del m["crc32"]
    with open(man, "w") as f:
        json.dump(m, f)
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.verify(2) is None            # structural checks only
    _, extra = mgr.restore(fallback=True)
    assert int(extra["step"]) == 2


def test_resave_same_step_keeps_readers_consistent(tmp_path):
    # the rename-aside swap: re-saving an existing step must never leave
    # a window where the step vanishes or half-deleted dirs are listed
    mgr = CheckpointManager(str(tmp_path), async_save=False, keep=2)
    for step in (1, 2, 2, 3, 4):            # includes a same-step re-save
        mgr.save(step, _tree(step), extra={"step": step})
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
    assert mgr.available_steps() == [3, 4]  # keep=2 GC'd the rest
    for s in (3, 4):
        assert mgr.verify(s) is None


# ---------------------------------------------------------------------------
# async save: bounded retry, surfaced daemon-thread errors
# ---------------------------------------------------------------------------

def test_async_save_transient_io_retries_to_success(tmp_path):
    plan = chaos.FaultPlan([chaos.Fault("fail_async_save_io", at=0, count=2)])
    mgr = CheckpointManager(str(tmp_path), save_retries=2, retry_backoff=0.01,
                            io_hook=chaos.checkpoint_io_hook(plan))
    mgr.save(5, _tree(5), extra={"step": 5})
    mgr.wait()                              # retries absorbed the failures
    assert plan.fired_counts()["fail_async_save_io"] == 2
    assert mgr.verify(5) is None
    tree, _ = mgr.restore(5)
    assert_bit_identical(tree["w"], _tree(5)["w"], "post-retry restore")


def test_async_save_persistent_io_surfaces_at_wait(tmp_path):
    plan = chaos.FaultPlan(
        [chaos.Fault("fail_async_save_io", at=0, count=10 ** 6)])
    mgr = CheckpointManager(str(tmp_path), save_retries=1, retry_backoff=0.01,
                            io_hook=chaos.checkpoint_io_hook(plan))
    mgr.save(1, _tree(1))
    with pytest.raises(CheckpointWriteError):
        mgr.wait()
    assert mgr.latest_step() is None        # nothing half-published
    # the error is one-shot: after surfacing, the manager keeps working
    mgr.io_hook = None
    mgr.save(2, _tree(2), extra={"step": 2})
    mgr.wait()
    assert mgr.verify(2) is None


def test_async_save_error_surfaces_on_next_save(tmp_path):
    plan = chaos.FaultPlan(
        [chaos.Fault("fail_async_save_io", at=0, count=10 ** 6)])
    mgr = CheckpointManager(str(tmp_path), save_retries=0, retry_backoff=0.01,
                            io_hook=chaos.checkpoint_io_hook(plan))
    mgr.save(1, _tree(1))
    time.sleep(0.2)                         # let the daemon thread fail
    with pytest.raises(CheckpointWriteError):
        mgr.save(2, _tree(2))               # surfaced here, not lost


# ---------------------------------------------------------------------------
# data pipeline: worker failures propagate, bounded, resumable
# ---------------------------------------------------------------------------

def _batch_fn(seed, index, batch):
    rng = np.random.default_rng(seed + index)
    return {"x": rng.standard_normal((batch, 3)).astype(np.float32)}


def test_dead_worker_raises_bounded_not_hang():
    plan = chaos.FaultPlan(
        [chaos.Fault("kill_data_worker", at=0, count=10 ** 6)])
    it = ShardedIterator(chaos.flaky_make_batch(_batch_fn, plan),
                         batch_size=2, worker_retries=2, retry_backoff=0.01)
    t0 = time.perf_counter()
    with pytest.raises(DataWorkerError, match="after 3 attempts"):
        next(it)
    assert time.perf_counter() - t0 < 30.0
    assert plan.fired_counts()["kill_data_worker"] == 3
    assert it.cursor == 0                   # NOT advanced past the failure
    it.close()


def test_worker_transient_failure_stream_unchanged():
    clean_it = ShardedIterator(_batch_fn, batch_size=2)
    clean = [next(clean_it) for _ in range(3)]
    clean_it.close()
    plan = chaos.FaultPlan([chaos.Fault("kill_data_worker", at=1, count=2)])
    it = ShardedIterator(chaos.flaky_make_batch(_batch_fn, plan),
                         batch_size=2, worker_retries=2, retry_backoff=0.01)
    got = [next(it) for _ in range(3)]
    it.close()
    assert plan.fired_counts()["kill_data_worker"] == 2
    for i, (g, c) in enumerate(zip(got, clean)):
        assert_bit_identical(g["x"], c["x"], f"batch {i} after retries")


def test_worker_error_then_fixed_resumes_at_same_cursor():
    # one hard failure burns the whole retry budget; once the cause is
    # gone, the next __next__ resumes from the SAME cursor
    plan = chaos.FaultPlan([chaos.Fault("kill_data_worker", at=0, count=3)])
    it = ShardedIterator(chaos.flaky_make_batch(_batch_fn, plan),
                         batch_size=2, worker_retries=2, retry_backoff=0.01)
    with pytest.raises(DataWorkerError):
        next(it)
    assert it.cursor == 0
    got = next(it)                          # respawned from cursor 0
    it.close()
    assert_bit_identical(got["x"], _batch_fn(0, 0, 2)["x"],
                         "post-fix resume batch")
    assert it.cursor == 1


def test_worker_checkpoint_state_survives_error():
    plan = chaos.FaultPlan([chaos.Fault("kill_data_worker", at=2, count=10)])
    it = ShardedIterator(chaos.flaky_make_batch(_batch_fn, plan),
                         batch_size=2, worker_retries=0, retry_backoff=0.01)
    next(it), next(it)
    state = it.state_dict()
    with pytest.raises(DataWorkerError):
        next(it)
    assert it.state_dict() == state         # error did not corrupt cursor
    it.close()


# ---------------------------------------------------------------------------
# serving: NaN-poisoned slot -> quarantine, healthy lanes bit-identical
# ---------------------------------------------------------------------------

SCEN = ScenarioConfig(num_map=8, num_agents=3, num_steps=6)
T_HIST = 3


def _model(seed=0):
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=SCEN.num_actions,
                         encoding="se2_fourier", attn_impl="ref")
    model = AgentSimModel(cfg)
    return model, nnm.init_params(model.specs(), jax.random.key(seed))


MODEL, PARAMS = _model()


def _serve(poison_tick=None, poison_slot=0):
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2)
    for i, scene in enumerate(generate_mixed(5, 0, 3, SCEN)):
        srv.submit(SceneRequest(uid=i, tensors=scene, t_hist=T_HIST,
                                seed=11, scene_id=i))
    tick = 0
    while srv.queue or any(s.req for s in srv.slots):
        if tick == poison_tick:
            chaos.poison_server_slot(srv, poison_slot)
        srv.tick()
        tick += 1
        assert tick < 1000
    srv.flush()
    return srv


def test_quarantine_marks_victim_and_counts():
    srv = _serve(poison_tick=4)
    victim = srv.done[0]
    assert victim.status == "failed"
    assert victim.reason == "nonfinite_pose"
    assert srv.quarantined == 1
    assert srv.stats()["quarantined"] == 1.0
    # quarantine emits an event + counter for the fleet monitors
    # (srv.obs is the process-default registry, shared across tests:
    # assert presence/monotonicity, not exact totals)
    assert srv.obs.counter("sim_server.quarantined").value >= 1
    kinds = [e["name"] for e in srv.obs.events()]
    assert "sim_server.quarantine" in kinds


def test_quarantine_healthy_lanes_bit_identical():
    ref = _serve(poison_tick=None)
    assert all(r.status == "ok" for r in ref.done.values())
    srv = _serve(poison_tick=4)
    healthy = [u for u, r in srv.done.items() if r.status == "ok"]
    assert sorted(healthy) == [1, 2]        # everyone but the victim
    for uid in healthy:
        assert_bit_identical(srv.done[uid].future, ref.done[uid].future,
                             f"lane {uid} poses under quarantine")
        assert_bit_identical(srv.done[uid].actions, ref.done[uid].actions,
                             f"lane {uid} actions under quarantine")


def test_quarantined_slot_serves_next_tenant_bit_exact():
    ref = _serve(poison_tick=None)
    srv = _serve(poison_tick=3)             # poison uid 0 early in rollout
    assert srv.done[0].status == "failed"
    # a new lane through the recycled server reproduces the fault-free
    # result for the same request
    scene = generate_mixed(5, 0, 3, SCEN)[2]
    srv2_uid = 7
    srv.submit(SceneRequest(uid=srv2_uid, tensors=scene, t_hist=T_HIST,
                            seed=11, scene_id=2))
    srv.run_until_drained()
    assert srv.done[srv2_uid].status == "ok"
    assert_bit_identical(srv.done[srv2_uid].future, ref.done[2].future,
                         "recycled-slot tenant poses")


def test_serve_scenes_raises_on_quarantine():
    from repro.runtime.sim_server import serve_scenes
    # serve_scenes stacks futures; a quarantined lane must surface as an
    # error, never silently as a zero-filled row in the stack
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2)
    orig_tick, calls = srv.tick, {"n": 0}

    def poisoning_tick():
        if calls["n"] == 4:
            chaos.poison_server_slot(srv, 0)
        calls["n"] += 1
        return orig_tick()

    srv.tick = poisoning_tick
    with pytest.raises(RuntimeError, match="quarantined"):
        serve_scenes(srv, generate_mixed(5, 0, 2, SCEN), t_hist=T_HIST,
                     n_samples=1, seed=11)


# ---------------------------------------------------------------------------
# trainer: NaN-halt checkpoints are tagged and refuse blind resume
# ---------------------------------------------------------------------------

class _ListData:
    """Minimal checkpointable data source for trainer-contract tests."""

    def __init__(self):
        self.cursor = 0

    def __next__(self):
        self.cursor += 1
        return {"x": np.zeros(2, np.float32)}

    def state_dict(self):
        return {"cursor": self.cursor}

    def load_state_dict(self, s):
        self.cursor = int(s["cursor"])

    def close(self):
        pass


def _nan_step(params, opt_state, batch):
    return params, opt_state, {"loss": math.nan}


def test_nan_halt_tags_checkpoint_and_refuses_blind_resume(tmp_path):
    tr = Trainer(_nan_step, {"w": np.zeros(2, np.float32)}, {}, _ListData(),
                 str(tmp_path), TrainerConfig(total_steps=10, ckpt_every=100,
                                              max_consecutive_nans=2))
    with pytest.raises(FloatingPointError):
        tr.run()
    # the halt checkpoint exists and is tagged
    mgr = CheckpointManager(str(tmp_path))
    _, extra = mgr.restore(fallback=True)
    assert extra["halt_reason"] == "nan"
    # a blind relaunch refuses...
    tr2 = Trainer(_nan_step, {"w": np.zeros(2, np.float32)}, {}, _ListData(),
                  str(tmp_path), TrainerConfig(total_steps=10))
    with pytest.raises(RuntimeError, match="--force"):
        tr2.restore_if_available()
    # ...and force=True acknowledges and proceeds
    tr3 = Trainer(_nan_step, {"w": np.zeros(2, np.float32)}, {}, _ListData(),
                  str(tmp_path), TrainerConfig(total_steps=10))
    assert tr3.restore_if_available(force=True)


def test_clean_checkpoint_resumes_without_force(tmp_path):
    def ok_step(params, opt_state, batch):
        return params, opt_state, {"loss": 0.5}

    tr = Trainer(ok_step, {"w": np.zeros(2, np.float32)}, {}, _ListData(),
                 str(tmp_path), TrainerConfig(total_steps=4, ckpt_every=2))
    tr.run()
    tr2 = Trainer(ok_step, {"w": np.zeros(2, np.float32)}, {}, _ListData(),
                  str(tmp_path), TrainerConfig(total_steps=4))
    assert tr2.restore_if_available()       # no force needed
    assert tr2.step == 4


def test_trainer_fallback_counts_skipped_steps(tmp_path):
    def ok_step(params, opt_state, batch):
        return params, opt_state, {"loss": 0.5}

    tr = Trainer(ok_step, {"w": np.zeros(2, np.float32)}, {}, _ListData(),
                 str(tmp_path), TrainerConfig(total_steps=4, ckpt_every=2))
    tr.run()
    chaos.corrupt_checkpoint(str(tmp_path), "truncate_checkpoint_npz")
    tr2 = Trainer(ok_step, {"w": np.zeros(2, np.float32)}, {}, _ListData(),
                  str(tmp_path), TrainerConfig(total_steps=4))
    assert tr2.restore_if_available()
    assert tr2.step == 2                    # fell back past the corrupt 4
    assert tr2.obs.counter("trainer.ckpt_fallback").value >= 1


# ---------------------------------------------------------------------------
# bench schema: the committed BENCH_chaos.json is pinned
# ---------------------------------------------------------------------------

def _load_bench_schema():
    spec = importlib.util.spec_from_file_location(
        "bench_schema", os.path.join(ROOT, "benchmarks", "bench_schema.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_chaos_bench_schema_catches_regressions():
    bs = _load_bench_schema()
    good = {
        "kind": "chaos_drill", "seed": 0, "wall_s": 10.0, "n_scenarios": 5,
        "all_passed": True,
        "scenarios": {
            name: {"passed": True, "wall_s": 1.0, "bundle": f"{name}.json"}
            for name in bs.CHAOS_SCENARIOS}}
    good["scenarios"]["nan_slot_quarantine"].update({
        dt: {"healthy_bit_identical": True, "recycle_bit_identical": True}
        for dt in ("float32", "int8")})
    c = bs._Check("BENCH_chaos.json")
    bs.check_chaos(good, c)
    assert c.problems == []
    # a drill that shrank or failed must not pass the schema
    bad = {**good, "scenarios": dict(good["scenarios"]), "all_passed": False}
    del bad["scenarios"]["dead_worker"]
    c2 = bs._Check("BENCH_chaos.json")
    bs.check_chaos(bad, c2)
    assert any("all_passed" in p for p in c2.problems)
    assert any("dead_worker" in p for p in c2.problems)


def test_committed_chaos_record_passes_schema():
    bs = _load_bench_schema()
    path = os.path.join(ROOT, "BENCH_chaos.json")
    assert os.path.exists(path), "BENCH_chaos.json must be committed"
    assert bs.check_file(path) == []
