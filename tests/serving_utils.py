"""Shared helpers for the slot-isolation suites (LM Server + SimServer).

Both serving loops make the same promise: a slot is recycled by resetting
its cursor, the predecessor's rows are left in place, and every decode
masks key positions >= kv_length — so stale rows are *unreachable*, not
merely unlikely to matter. ``scribble_stale_rows`` weaponizes that
promise: it overwrites every row at or beyond each slot's cursor with
adversarial garbage (huge K/V values, int8 extremes, "valid"-looking
integer metadata such as segment ids), and the tests then require
bit-identical outputs. If any masked row ever leaks into attention, the
garbage makes it loud.
"""
import jax
import numpy as np


def scribble_stale_rows(cache, cursors, max_len: int, seed: int = 0):
    """Overwrite rows >= cursor of every per-row cache leaf with garbage.

    ``cache``: any pytree whose per-row leaves carry exactly one axis of
    size ``max_len`` (the LM per-block ``{k, v[, *_scale]}`` dicts and
    the sim layer-stacked slab both qualify); leaves without such an
    axis (e.g. cursor vectors) pass through untouched. ``cursors``: per
    slot, the count of rows legitimately written — everything at or past
    it is fair game. Garbage by dtype: int8 gets full-range values,
    other ints get 1 (a plausible time / a *valid-looking* segment id —
    strictly nastier than the -1 "masked" sentinel fresh caches use),
    floats get huge noise with NaN sprinkled in — ``0 * NaN`` is NaN,
    so a masked row's weight being zero is NOT enough; the decode
    kernels must zero unreachable *values* too (they do — that contract
    is what keeps a NaN-poisoned quarantined lane's debris harmless,
    see ``docs/robustness.md``). Test sizes must keep ``max_len`` and
    the slot count distinct from every other axis length.
    """
    rng = np.random.default_rng(seed)
    n = len(cursors)
    cur = np.asarray(cursors)

    def leaf(x):
        shape = x.shape
        if shape.count(max_len) != 1:
            assert max_len not in shape, f"ambiguous row axis in {shape}"
            return x
        row_ax = shape.index(max_len)
        batch_ax = [i for i, s in enumerate(shape)
                    if s == n and i != row_ax]
        assert batch_ax, f"no slot axis of size {n} in {shape}"
        rows = np.arange(max_len).reshape(
            [-1 if i == row_ax else 1 for i in range(len(shape))])
        cur_b = cur.reshape(
            [-1 if i == batch_ax[0] else 1 for i in range(len(shape))])
        stale = rows >= cur_b
        x_np = np.asarray(x)
        if x_np.dtype == np.int8:
            junk = rng.integers(-128, 128, shape).astype(np.int8)
        elif np.issubdtype(x_np.dtype, np.integer):
            junk = np.ones(shape, x_np.dtype)
        else:
            junk = (rng.standard_normal(shape) * 100.0).astype(x_np.dtype)
            junk[rng.random(shape) < 0.25] = np.nan
        return np.where(stale, junk, x_np)

    return jax.tree.map(leaf, cache)


def assert_bit_identical(got, want, label: str):
    got, want = np.asarray(got), np.asarray(want)
    same = np.array_equal(got, want)
    if not same:
        bad = np.flatnonzero((got != want).ravel())
        raise AssertionError(
            f"{label}: {bad.size}/{got.size} elements differ "
            f"(first at flat index {bad[0]}; "
            f"max |diff| {np.abs(got.astype(np.float64) - want.astype(np.float64)).max()})")
