"""Tests for the Fourier quadrature machinery (paper Sec. III-B, Fig. 3/4)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fourier


def test_basis_values():
    z = jnp.asarray([0.0, np.pi / 2], dtype=jnp.float32)
    b = np.asarray(fourier.eval_basis(z, 5))
    # g = [1, sin z, cos z, sin 2z, cos 2z]
    np.testing.assert_allclose(b[0], [1, 0, 1, 0, 1], atol=1e-6)
    np.testing.assert_allclose(b[1], [1, 1, 0, 0, -1], atol=1e-6)


def test_quadrature_exact_for_bandlimited():
    """A function already in the basis span must be recovered exactly."""
    F = 8
    nodes = fourier.quadrature_nodes(F)
    target_coeffs = np.zeros(F, dtype=np.float32)
    target_coeffs[0] = 0.3
    target_coeffs[3] = -1.2   # sin(2z)
    target_coeffs[6] = 0.7    # cos(3z)
    basis_at_nodes = np.asarray(fourier.eval_basis(nodes, F))
    samples = jnp.asarray(basis_at_nodes @ target_coeffs)
    got = np.asarray(fourier.fourier_coefficients(samples, F))
    np.testing.assert_allclose(got, target_coeffs, atol=1e-5)


@pytest.mark.parametrize("radius,num_terms,tol", [
    (2.0, 12, 2e-3),
    (4.0, 18, 2e-3),
    (8.0, 28, 2e-3),
])
def test_approx_error_matches_paper_fig3(radius, num_terms, tol):
    """Paper Fig. 3: with F = 12/18/28 the error at radius 2/4/8 is ~1e-3."""
    rng = np.random.default_rng(0)
    ang = rng.uniform(0, 2 * np.pi, size=256)
    x = jnp.asarray(radius * np.cos(ang), dtype=jnp.float32)
    y = jnp.asarray(radius * np.sin(ang), dtype=jnp.float32)
    theta = jnp.asarray(rng.uniform(0, 2 * np.pi, size=256), dtype=jnp.float32)
    for which in ("x", "y"):
        cos_a, sin_a = fourier.approx_cos_sin(x, y, theta, num_terms, which)
        if which == "x":
            u = x * jnp.cos(theta) + y * jnp.sin(theta)
        else:
            u = -x * jnp.sin(theta) + y * jnp.cos(theta)
        err = np.maximum(np.abs(np.asarray(cos_a - jnp.cos(u))),
                         np.abs(np.asarray(sin_a - jnp.sin(u))))
        assert float(err.mean()) < tol, (which, float(err.mean()))


def test_error_decreases_with_terms():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.uniform(-4, 4, 64), dtype=jnp.float32)
    y = jnp.asarray(rng.uniform(-4, 4, 64), dtype=jnp.float32)
    theta = jnp.asarray(rng.uniform(0, 2 * np.pi, 64), dtype=jnp.float32)
    u = x * jnp.cos(theta) + y * jnp.sin(theta)
    errs = []
    for F in (6, 12, 18, 24):
        cos_a, _ = fourier.approx_cos_sin(x, y, theta, F, "x")
        errs.append(float(jnp.mean(jnp.abs(cos_a - jnp.cos(u)))))
    assert errs[0] > errs[1] > errs[2] > errs[3]
    assert errs[3] < 1e-4
