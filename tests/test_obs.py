"""Telemetry-layer suite: the zero-sync contract, end to end.

The claim under test: turning telemetry on changes NOTHING about the
computation — actions, poses, and metrics are bit-identical with the
registry enabled vs ``obs.NULL``, and no component compiles even one
extra program. Plus the instruments themselves: the log-bucket
histogram's percentile error bound, the Chrome-trace / Prometheus
exporters, the NaN-guard surfacing through the Trainer, straggler
decisions landing as events, and the committed bench records passing
the schema checker.
"""
import importlib.util
import json
import math
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs
from repro.data.pipeline import ShardedIterator
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.runtime.monitor import StragglerPolicy
from repro.runtime.rollout import RolloutEngine
from repro.runtime.sim_server import SceneRequest, SimServer, poisson_drive
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.scenarios import ScenarioConfig
from repro.scenarios.registry import generate_mixed

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

SCEN = ScenarioConfig(num_map=8, num_agents=3, num_steps=6)
T_HIST = 3


def _model(seed=0):
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=SCEN.num_actions,
                         encoding="se2_fourier", attn_impl="ref")
    model = AgentSimModel(cfg)
    return model, nnm.init_params(model.specs(), jax.random.key(seed))


MODEL, PARAMS = _model()
SCENES = generate_mixed(5, 0, 4, SCEN)


# ---------------------------------------------------------------------------
# Histogram: the shared percentile sketch
# ---------------------------------------------------------------------------

def _nearest_rank(sorted_vals, q):
    return sorted_vals[max(1, math.ceil(q / 100.0 * len(sorted_vals))) - 1]


def test_histogram_percentile_error_bound():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-6.0, sigma=1.5, size=2000)
    h = obs.Histogram("t")
    for v in samples:
        h.record(v)
    exact = np.sort(samples)
    for q in (1, 25, 50, 90, 99, 99.9):
        got, want = h.percentile(q), _nearest_rank(exact, q)
        assert abs(got / want - 1) <= h.max_rel_error + 1e-12, (q, got, want)
    assert h.count == 2000
    np.testing.assert_allclose(h.sum, samples.sum(), rtol=1e-9)
    assert h.min == samples.min() and h.max == samples.max()


def test_histogram_extremes_are_exact():
    h = obs.Histogram()
    for v in (0.5, 1.0, 3.0):
        h.record(v)
    assert h.percentile(0) == 0.5          # clamped to observed min
    assert h.percentile(100) == 3.0        # clamped to observed max


def test_histogram_zero_and_negative_underflow():
    h = obs.Histogram()
    for v in (-1.0, 0.0, 0.0, 1.0):
        h.record(v)
    assert h.count == 4 and h.zero_count == 3
    assert h.percentile(50) <= 0.0         # rank falls in the underflow
    assert h.percentile(100) == 1.0
    h2 = obs.Histogram()
    assert math.isnan(h2.percentile(50))   # empty -> NaN, not a crash
    h2.record(float("nan"))                # NaN samples are dropped
    assert h2.count == 0


def test_poisson_drive_returns_shared_histogram():
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2, registry=obs.NULL)
    reqs = [SceneRequest(uid=i, tensors=s, t_hist=T_HIST)
            for i, s in enumerate(SCENES)]
    out = poisson_drive(srv, reqs, rate=0.5, seed=1, warmup_ticks=2)
    assert isinstance(out["latency"], obs.Histogram)
    assert out["ticks"] > 2
    # warmup ticks are excluded from the sketch but counted in "ticks"
    assert out["latency"].count == out["ticks"] - 2
    assert out["latency"].percentile(50) > 0


# ---------------------------------------------------------------------------
# Registry mechanics
# ---------------------------------------------------------------------------

def test_registry_instrument_identity_and_labels():
    r = obs.Registry()
    assert r.counter("c") is r.counter("c")
    assert r.counter("c", k=1) is not r.counter("c", k=2)
    r.counter("c", k=1).inc(3)
    snap = r.snapshot()
    by = {(c["name"], tuple(sorted(c["labels"].items())))
          for c in snap["counters"]}
    assert ("c", (("k", "1"),)) in by     # label values stringify


def test_null_registry_records_nothing():
    n0 = len(obs.NULL.events())
    with obs.NULL.span("x"):
        pass
    obs.NULL.counter("c").inc()
    obs.NULL.gauge("g").set(1)
    obs.NULL.histogram("h").record(1.0)
    obs.NULL.event("e")
    assert len(obs.NULL.events()) == n0
    assert obs.NULL.snapshot()["counters"] == []


def test_span_records_histogram_and_event():
    r = obs.Registry()
    with r.span("work", phase="a"):
        pass
    h = r.histogram("work.seconds", phase="a")
    assert h.count == 1
    (ev,) = [e for e in r.events() if e["ph"] == "X"]
    assert ev["name"] == "work" and ev["dur"] >= 0
    assert ev["args"] == {"phase": "a"}
    # observe_span: same shape, caller-measured interval
    r.observe_span("work", 0.0, 1.0, phase="a")
    assert h.count == 2


def test_trace_ring_drops_oldest_half_at_capacity():
    r = obs.Registry(trace_capacity=10)
    for i in range(12):
        r.event("e", i=i)
    assert r.dropped_events == 5
    assert len(r.events()) <= 10
    assert r.events()[-1]["args"]["i"] == 11    # newest survive


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def _busy_registry():
    r = obs.Registry()
    r.counter("reqs", route="a").inc(3)
    r.gauge("occ").set(0.5)
    for v in (1e-3, 2e-3, 4e-3):
        r.histogram("lat.seconds").record(v)
    with r.span("tick"):
        pass
    r.event("evict", uid=7)
    return r


def test_chrome_trace_roundtrip(tmp_path):
    r = _busy_registry()
    path = str(tmp_path / "t.trace.jsonl")
    obs.write_chrome_trace(r, path)
    with open(path) as f:
        whole = json.load(f)               # valid JSON array for Perfetto
    again = obs.read_chrome_trace(path)
    assert whole == again
    for ev in whole:
        assert "name" in ev and "ph" in ev
    names = [e["name"] for e in whole]
    assert names[0] == "process_name"       # metadata first
    assert names[-1] == obs.SNAPSHOT_EVENT  # snapshot last
    snap = whole[-1]["args"]["snapshot"]
    assert any(c["name"] == "reqs" for c in snap["counters"])
    assert any(h["name"] == "lat.seconds" for h in snap["histograms"])


def test_prometheus_text_exposition():
    text = obs.prometheus_text(_busy_registry())
    assert 'reqs_total{route="a"} 3.0' in text
    assert "occ 0.5" in text
    assert "lat_seconds_count 3" in text
    # classic histogram: cumulative buckets ending at +Inf == count
    le_lines = [ln for ln in text.splitlines()
                if ln.startswith("lat_seconds_bucket")]
    counts = [float(ln.rsplit(" ", 1)[1]) for ln in le_lines]
    assert counts == sorted(counts), "bucket series must be cumulative"
    assert 'le="+Inf"' in le_lines[-1] and counts[-1] == 3


# ---------------------------------------------------------------------------
# No-perturbation: obs on/off bit-parity + zero extra compilations
# ---------------------------------------------------------------------------

MATRIX = [("float32", "xla"), ("int8", "ref")]


@pytest.mark.parametrize("cache_dtype,impl", MATRIX,
                         ids=[f"{d}-{i}" for d, i in MATRIX])
def test_sim_server_obs_on_off_bit_identical(cache_dtype, impl):
    results = {}
    for name, reg in (("on", obs.Registry()), ("off", obs.NULL)):
        srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2,
                        cache_dtype=cache_dtype, decode_impl=impl,
                        registry=reg)
        reqs = [SceneRequest(uid=i, tensors=s, t_hist=T_HIST, seed=9)
                for i, s in enumerate(SCENES)]
        poisson_drive(srv, reqs, rate=0.7, seed=3)
        # retrace guard: telemetry must not add even one compilation
        assert srv.tick_traces == 1, f"{name}: tick retraced"
        assert srv.admit_traces == 1, f"{name}: admit retraced"
        results[name] = srv.done
    assert results["on"].keys() == results["off"].keys()
    for uid in results["on"]:
        a, b = results["on"][uid], results["off"][uid]
        np.testing.assert_array_equal(a.actions, b.actions)
        np.testing.assert_array_equal(a.future, b.future)


@pytest.mark.parametrize("cache_dtype,impl", MATRIX,
                         ids=[f"{d}-{i}" for d, i in MATRIX])
def test_rollout_engine_obs_on_off_bit_identical(cache_dtype, impl):
    outs = {}
    for name, reg in (("on", obs.Registry()), ("off", obs.NULL)):
        eng = RolloutEngine(MODEL, PARAMS, SCEN, num_slots=4,
                            cache_dtype=cache_dtype, decode_impl=impl,
                            registry=reg)
        fut = eng.run(SCENES, t_hist=T_HIST, n_samples=1, seed=9)
        # zero extra compilations: one program per jitted entry point
        assert eng._prefill._cache_size() == 1, f"{name}: prefill retraced"
        assert eng._step._cache_size() == 1, f"{name}: step retraced"
        outs[name] = (fut, eng.last_actions)
    np.testing.assert_array_equal(outs["on"][0], outs["off"][0])
    np.testing.assert_array_equal(outs["on"][1], outs["off"][1])


def test_sim_server_telemetry_content():
    reg = obs.Registry()
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2, registry=reg)
    reqs = [SceneRequest(uid=i, tensors=s, t_hist=T_HIST)
            for i, s in enumerate(SCENES)]
    poisson_drive(srv, reqs, rate=0.7, seed=3)
    srv.evict(999)                         # miss: no event
    stats = srv.stats()
    assert reg.counter("sim_server.ticks").value == stats["ticks"]
    assert reg.counter("sim_server.admitted").value == stats["admitted"]
    assert reg.counter("sim_server.tick_traces").value == 1
    assert reg.histogram("sim_server.queue_wait.seconds").count \
        == len(SCENES)
    assert reg.histogram("sim_server.first_action.seconds").count \
        == len(SCENES)
    # per-tick gauges end drained: nothing resident, nothing queued
    assert reg.gauge("sim_server.occupancy").value == 0.0
    assert reg.gauge("sim_server.resident").value == 0.0
    assert reg.gauge("sim_server.slab_bytes").value > 0
    tick_spans = [e for e in reg.events()
                  if e.get("ph") == "X" and e["name"] == "sim_server.tick"]
    assert len(tick_spans) == int(stats["ticks"])


# ---------------------------------------------------------------------------
# Trainer: NaN-guard surfacing + step spans
# ---------------------------------------------------------------------------

def _nanny_step(nan_steps):
    calls = {"n": 0}

    def step(params, opt_state, batch):
        loss = (jnp.float32(float("nan")) if calls["n"] in nan_steps
                else jnp.float32(1.0 / (1 + calls["n"])))
        calls["n"] += 1
        return params, opt_state, {"loss": loss}

    return step


def test_trainer_surfaces_nan_skips(tmp_path):
    data = ShardedIterator(
        lambda seed, idx, b: {"x": np.zeros((b, 1), np.float32)},
        batch_size=2, seed=0)
    reg = obs.Registry()
    payloads = []
    tr = Trainer(_nanny_step({1, 5}), {"w": jnp.zeros(2)}, {}, data,
                 str(tmp_path),
                 TrainerConfig(total_steps=8, ckpt_every=100, log_every=2,
                               max_consecutive_nans=4),
                 metrics_cb=lambda s, m: payloads.append((s, m)),
                 registry=reg)
    out = tr.run()
    data.close()
    assert out["status"] == "done"
    # run summary carries the skip count (satellite: silent discards ban)
    assert out["nan_skipped"] == 2
    assert reg.counter("trainer.nan_skipped").value == 2
    # every metrics payload reports the counts
    assert payloads and all("nan_skipped_total" in m and
                            "nan_consecutive" in m for _, m in payloads)
    assert payloads[-1][1]["nan_skipped_total"] == 2
    # 8 total steps dispatched (6 applied + 2 skipped), each under a span
    assert reg.histogram("trainer.step.seconds").count == 8
    assert reg.histogram("trainer.checkpoint.seconds").count >= 1


def test_trainer_halt_emits_event(tmp_path):
    data = ShardedIterator(
        lambda seed, idx, b: {"x": np.zeros((b, 1), np.float32)},
        batch_size=2, seed=0)
    reg = obs.Registry()
    tr = Trainer(_nanny_step(set(range(99))), {"w": jnp.zeros(2)}, {}, data,
                 str(tmp_path),
                 TrainerConfig(total_steps=50, ckpt_every=100, log_every=100,
                               max_consecutive_nans=3),
                 registry=reg)
    with pytest.raises(FloatingPointError):
        tr.run()
    data.close()
    halts = [e for e in reg.events() if e["name"] == "trainer.halt"]
    assert len(halts) == 1 and halts[0]["args"]["consecutive"] == 3


def test_straggler_policy_exports_decision():
    reg = obs.Registry()
    p = StragglerPolicy(straggler_factor=1.5, min_samples=2, registry=reg)
    warm = {0: 10, 1: 10}
    assert p.evaluate({0: 1.0, 1: 4.0}, warm) == [1]
    assert reg.gauge("straggler.rank_median_s", rank=1).value == 4.0
    assert reg.gauge("straggler.rank_samples", rank=0).value == 10
    assert reg.counter("straggler.flag_decisions").value == 1
    (ev,) = [e for e in reg.events() if e["name"] == "straggler.flagged"]
    assert ev["args"]["ranks"] == "1"
    assert ev["args"]["fleet_median_s"] == 1.0
    # a no-flag evaluation updates gauges but emits no event
    assert p.evaluate({0: 1.0, 1: 1.1}, warm) == []
    assert reg.counter("straggler.flag_decisions").value == 1


# ---------------------------------------------------------------------------
# obs_report CLI + bench schema
# ---------------------------------------------------------------------------

def test_obs_report_renders_trace(tmp_path, capsys):
    from repro.launch import obs_report
    reg = obs.Registry()
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2, registry=reg)
    reqs = [SceneRequest(uid=i, tensors=s, t_hist=T_HIST)
            for i, s in enumerate(SCENES)]
    poisson_drive(srv, reqs, rate=0.7, seed=3)
    path = str(tmp_path / "run.trace.jsonl")
    obs.write_chrome_trace(reg, path)

    assert obs_report.main([path]) == 0
    text = capsys.readouterr().out
    for needle in ("== spans", "== compilations", "== gauges",
                   "sim_server.tick", "sim_server.admit_traces",
                   "sim_server.occupancy"):
        assert needle in text, f"report missing {needle!r}"

    assert obs_report.main([path, "--json"]) == 0
    agg = json.loads(capsys.readouterr().out)
    assert agg["spans"]["sim_server.tick"]["count"] == srv.ticks
    assert agg["snapshot"]["counters"]


def test_obs_report_renders_committed_sample(capsys):
    from repro.launch import obs_report
    sample = os.path.join(ROOT, "docs", "samples", "obs_sample.trace.jsonl")
    assert os.path.exists(sample), "committed sample trace missing"
    assert obs_report.main([sample]) == 0
    text = capsys.readouterr().out
    assert "sim_server.tick" in text and "== histograms" in text


def _load_bench_schema():
    spec = importlib.util.spec_from_file_location(
        "bench_schema", os.path.join(ROOT, "benchmarks", "bench_schema.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_committed_bench_records_pass_schema():
    bs = _load_bench_schema()
    import glob
    records = sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json")))
    assert records, "no committed bench records found"
    problems = [p for path in records for p in bs.check_file(path)]
    assert problems == []


def test_bench_schema_catches_broken_record(tmp_path):
    bs = _load_bench_schema()
    with open(os.path.join(ROOT, "BENCH_serve.json")) as f:
        rec = json.load(f)
    row = next(iter(rec["slot_counts"].values()))
    del row["tick_p50_ms"]
    row["tick_p99_ms"] = float("nan")
    bad = tmp_path / "BENCH_serve_broken.json"
    bad.write_text(json.dumps(rec).replace("NaN", "null"))
    # null p99 -> type problem; missing p50 -> missing-key problem
    problems = bs.check_file(str(bad))
    assert any("tick_p50_ms" in p for p in problems)
    assert any("tick_p99_ms" in p for p in problems)
