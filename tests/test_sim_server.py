"""Isolation & invariance suite for the continuous-batching sim server.

The contract under test: a scene served by a churning ``SimServer`` —
recycled slots, co-resident strangers, adversarially scribbled stale
cache rows, arbitrary arrival schedules — produces **bit-identical**
per-step actions, poses, and metrics to the same scene run alone in a
fresh ``RolloutEngine``. Not "close": identical. Anything weaker would
mean slot state leaks across admissions.
"""
import numpy as np
import pytest

import jax

from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.runtime.evaluation import METRICS, EvalConfig, scene_metrics
from repro.runtime.rollout import RolloutEngine
from repro.runtime.sim_server import (SceneRequest, SimServer, poisson_drive,
                                      serve_scenes)
from repro.scenarios import ScenarioConfig
from repro.scenarios.registry import generate_mixed, generate_scene

from serving_utils import assert_bit_identical, scribble_stale_rows

SCEN = ScenarioConfig(num_map=8, num_agents=3, num_steps=6)
T_HIST = 3
MATRIX = [("float32", "xla"), ("float32", "ref"),
          ("int8", "xla"), ("int8", "ref")]


def _model(seed=0):
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=SCEN.num_actions,
                         encoding="se2_fourier", attn_impl="ref")
    model = AgentSimModel(cfg)
    return model, nnm.init_params(model.specs(), jax.random.key(seed))


MODEL, PARAMS = _model()


def _solo_reference(scene, cache_dtype, impl, seed=9):
    """The scene run alone, fresh engine, one slot: the ground truth every
    server schedule must reproduce bit-for-bit."""
    eng = RolloutEngine(MODEL, PARAMS, SCEN, num_slots=1,
                        cache_dtype=cache_dtype, decode_impl=impl)
    fut = eng.run([scene], t_hist=T_HIST, n_samples=1, seed=seed)
    return fut[0, 0], eng.last_actions[0, 0]      # (Tf, A, 3), (Tf, A)


@pytest.mark.parametrize("cache_dtype,impl", MATRIX,
                         ids=[f"{d}-{i}" for d, i in MATRIX])
def test_recycled_slot_bit_identical_to_solo(cache_dtype, impl):
    """The full churn gauntlet, one pass per {dtype} x {decode impl}:

    1. fill both slots with evictee scenes of different families and a
       *different* (shorter) horizon;
    2. evict one MID-PREFILL, let the other retire at its horizon;
    3. scribble every stale row of the shared slab with garbage;
    4. admit the victim into a recycled slot alongside fresh noisy
       neighbors and demand bit-identical actions, poses, and metrics
       vs the fresh solo engine."""
    victim = generate_scene("signalized_intersection", 40, 0, SCEN)
    ref_fut, ref_acts = _solo_reference(victim, cache_dtype, impl)

    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2,
                    cache_dtype=cache_dtype, decode_impl=impl)
    evictees = generate_mixed(7, 100, 2, SCEN)
    srv.submit(SceneRequest(uid=100, tensors=evictees[0], t_hist=2,
                            t_total=4, seed=1, scene_id=50))
    srv.submit(SceneRequest(uid=101, tensors=evictees[1], t_hist=2,
                            t_total=4, seed=1, scene_id=51))
    srv.tick()                                    # both slots mid-prefill
    assert srv.evict(101)                         # mid-prefill eviction
    for _ in range(4):                            # uid=100 retires (t_total)
        srv.tick()
    assert all(s.req is None for s in srv.slots)
    assert srv.admitted == 2 and srv.evicted == 1

    # every slot cursor is stale now: poison the whole slab beyond 0
    srv.flush()
    srv.cache = scribble_stale_rows(
        srv.cache, np.zeros(2, np.int32), srv.max_len, seed=3)

    # victim + a noisy neighbor into the recycled slots
    srv.submit(SceneRequest(uid=0, tensors=victim, t_hist=T_HIST,
                            seed=9, scene_id=0, sample_id=0))
    srv.submit(SceneRequest(uid=1, tensors=evictees[0], t_hist=2,
                            seed=2, scene_id=77))
    done = srv.run_until_drained()
    assert sorted(done) == [0, 1, 100]    # 100 finished pre-churn; 101 evicted

    assert_bit_identical(done[0].actions, ref_acts,
                         f"actions ({cache_dtype}/{impl})")
    assert_bit_identical(done[0].future, ref_fut,
                         f"poses ({cache_dtype}/{impl})")
    ecfg = EvalConfig(t_hist=T_HIST, n_samples=1)
    m_ref = scene_metrics(SCEN, ecfg, victim, ref_fut[None])
    m_srv = scene_metrics(SCEN, ecfg, victim, done[0].future[None])
    for k in METRICS:
        assert (m_srv[k] == m_ref[k]
                or (np.isnan(m_srv[k]) and np.isnan(m_ref[k]))), \
            (k, m_srv[k], m_ref[k])


def test_mid_prefill_eviction_frees_slot_for_identical_successor():
    """A successor admitted into a slot whose predecessor died mid-prefill
    must match the fresh solo run — the half-written prefill rows are
    beyond the reset cursor and unreachable."""
    victim = generate_scene("onramp_merge", 41, 0, SCEN)
    ref_fut, ref_acts = _solo_reference(victim, "float32", "ref")

    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=1,
                    cache_dtype="float32", decode_impl="ref")
    srv.submit(SceneRequest(uid=5, tensors=generate_scene("highway", 1, 0,
                                                          SCEN),
                            t_hist=4, seed=3, scene_id=5))
    srv.tick(); srv.tick()                        # 2 of 4 prefill ticks in
    assert srv.slots[0].req.uid == 5
    assert srv.evict(5)
    srv.submit(SceneRequest(uid=0, tensors=victim, t_hist=T_HIST,
                            seed=9, scene_id=0))
    done = srv.run_until_drained()
    assert sorted(done) == [0]
    assert_bit_identical(done[0].actions, ref_acts, "actions after evict")
    assert_bit_identical(done[0].future, ref_fut, "poses after evict")


def test_retrace_guard_one_compile_across_recycle_generations():
    """Admit/evict churn over >= 3 full slot-recycle generations must hit
    the jit cache every time: exactly one tick trace, one admit trace.
    A shape leaking into the hot loop (host int vs traced value, dtype
    drift on recycled state) fails here instead of silently recompiling."""
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2, cache_dtype="float32",
                    decode_impl="ref")
    scenes = generate_mixed(5, 0, 8, SCEN)        # 8 scenes / 2 slots = 4 gens
    for i, s in enumerate(scenes):
        srv.submit(SceneRequest(uid=i, tensors=s, t_hist=2 + (i % 3),
                                t_total=4 + (i % 3), seed=i, scene_id=i))
    # sprinkle evictions into the churn as well
    ticks = 0
    while srv.queue or any(s.req for s in srv.slots):
        srv.tick()
        ticks += 1
        if ticks == 3:
            assert srv.evict(srv.slots[0].req.uid)
    srv.flush()
    assert srv.admitted == 8 and srv.evicted == 1
    assert srv.tick_traces == 1, "tick recompiled under churn"
    assert srv.admit_traces == 1, "admission recompiled under churn"


def test_serve_scenes_matches_engine_batch():
    """Engine-shaped entry: futures bit-match RolloutEngine.run across the
    whole (scene, sample) grid even when slots << lanes."""
    scenes = generate_mixed(11, 0, 3, SCEN)
    eng = RolloutEngine(MODEL, PARAMS, SCEN, num_slots=3,
                        cache_dtype="float32", decode_impl="ref")
    ref = eng.run(scenes, t_hist=T_HIST, n_samples=2, seed=13)
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=2,
                    cache_dtype="float32", decode_impl="ref")
    got = serve_scenes(srv, scenes, t_hist=T_HIST, n_samples=2, seed=13)
    assert_bit_identical(got, ref, "serve_scenes futures")


# -- schedule invariance ------------------------------------------------------

N_PROP_SCENES = 3


def _scene_set():
    return generate_mixed(21, 0, N_PROP_SCENES, SCEN)


def _per_scene_metrics(scenes, futures_by_sid):
    ecfg = EvalConfig(t_hist=T_HIST, n_samples=1)
    return [scene_metrics(SCEN, ecfg, s, futures_by_sid[i][None])
            for i, s in enumerate(scenes)]


def _check_schedule_invariant(order_seed, rate, num_slots):
    """Any admission schedule of the same scene set — permuted arrival
    order, Poisson gaps, any slot count — yields the same per-scene
    futures and therefore the same per-scene metrics, bit-for-bit."""
    scenes = _scene_set()
    eng = RolloutEngine(MODEL, PARAMS, SCEN, num_slots=2,
                        cache_dtype="float32", decode_impl="ref")
    ref = eng.run(scenes, t_hist=T_HIST, n_samples=1, seed=17)
    ref_by_sid = {i: ref[i, 0] for i in range(len(scenes))}

    order = np.random.default_rng(order_seed).permutation(len(scenes))
    srv = SimServer(MODEL, PARAMS, SCEN, num_slots=num_slots,
                    cache_dtype="float32", decode_impl="ref")
    reqs = [SceneRequest(uid=int(sid), tensors=scenes[sid], t_hist=T_HIST,
                         seed=17, scene_id=int(sid)) for sid in order]
    poisson_drive(srv, reqs, rate=rate, seed=order_seed)
    assert sorted(srv.done) == list(range(len(scenes)))
    got_by_sid = {sid: srv.done[sid].future for sid in srv.done}
    for sid in ref_by_sid:
        assert_bit_identical(
            got_by_sid[sid], ref_by_sid[sid],
            f"scene {sid} under schedule (order_seed={order_seed}, "
            f"rate={rate}, slots={num_slots})")
    for m_ref, m_got in zip(_per_scene_metrics(scenes, ref_by_sid),
                            _per_scene_metrics(scenes, got_by_sid)):
        for k in METRICS:
            assert (m_got[k] == m_ref[k]
                    or (np.isnan(m_got[k]) and np.isnan(m_ref[k]))), \
                (k, m_got[k], m_ref[k])


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=4, deadline=None, derandomize=True)
    @given(order_seed=st.integers(0, 2 ** 16),
           rate=st.floats(0.2, 3.0, allow_nan=False, width=32),
           num_slots=st.integers(1, 3))
    def test_metrics_invariant_to_arrival_schedule(order_seed, rate,
                                                   num_slots):
        _check_schedule_invariant(order_seed, rate, num_slots)

except ImportError:            # hypothesis is an optional dev dep:
    @pytest.mark.parametrize(  # fall back to fixed schedules
        "order_seed,rate,num_slots",
        [(0, 1.0, 2), (7, 0.3, 1), (123, 2.5, 3)])
    def test_metrics_invariant_to_arrival_schedule(order_seed, rate,
                                                   num_slots):
        _check_schedule_invariant(order_seed, rate, num_slots)
