"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles.

Sweeps shapes/dtypes and asserts allclose against ``repro.kernels.ref`` —
the contract demanded for every Pallas kernel in this repo.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encodings
from repro.kernels import ops, ref
from repro.kernels.se2_project import se2_fourier_project


def rand_qkv(rng, b, hq, hkv, sq, sk, d, dv=None, dtype=jnp.float32):
    dv = dv or d
    q = jnp.asarray(rng.normal(size=(b, hq, sq, d)), dtype=dtype)
    k = jnp.asarray(rng.normal(size=(b, hkv, sk, d)), dtype=dtype)
    v = jnp.asarray(rng.normal(size=(b, hkv, sk, dv)), dtype=dtype)
    return q, k, v


def tol_for(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-4)


SHAPE_SWEEP = [
    # b, hq, hkv, sq, sk, d, dv, block
    (1, 1, 1, 32, 32, 32, 32, 16),
    (2, 4, 4, 64, 64, 64, 64, 32),
    (1, 4, 2, 48, 80, 32, 32, 16),     # GQA + ragged (padding path)
    (2, 8, 1, 33, 65, 16, 16, 16),     # MQA + unaligned seq lens
    (1, 2, 2, 64, 64, 24, 40, 32),     # dv != d, unaligned head dims
]


@pytest.mark.parametrize("shape", SHAPE_SWEEP)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref(shape, dtype):
    b, hq, hkv, sq, sk, d, dv, blk = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q, k, v = rand_qkv(rng, b, hq, hkv, sq, sk, d, dv, dtype)
    got = ops.flash_attention(q, k, v, block_q=blk, block_k=blk,
                              interpret=True)
    want = ref.mha_reference(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol_for(dtype))


@pytest.mark.parametrize("causal,window,softcap", [
    (True, None, None),
    (False, 24, None),
    (True, 16, None),
    (False, None, 30.0),
    (True, None, 50.0),
])
def test_flash_mask_variants(causal, window, softcap):
    rng = np.random.default_rng(0)
    q, k, v = rand_qkv(rng, 2, 4, 2, 64, 64, 32)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=16, block_k=16,
                              interpret=True)
    want = ref.mha_reference(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_flash_segment_ids():
    rng = np.random.default_rng(1)
    b, sq = 2, 64
    q, k, v = rand_qkv(rng, b, 2, 2, sq, sq, 32)
    seg = jnp.asarray(rng.integers(0, 3, size=(b, sq)), jnp.int32)
    got = ops.flash_attention(q, k, v, q_segment_ids=seg, k_segment_ids=seg,
                              block_q=16, block_k=16, interpret=True)
    want = ref.mha_reference(q, k, v, q_segment_ids=seg, k_segment_ids=seg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_flash_gradients_match_ref():
    """Default (Pallas) backward vs autodiff through the O(S^2) oracle."""
    rng = np.random.default_rng(2)
    q, k, v = rand_qkv(rng, 1, 2, 1, 32, 48, 16)

    def loss_flash(q, k, v):
        o = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                                interpret=True)
        return jnp.sum(o * jnp.cos(jnp.arange(o.size).reshape(o.shape) * 0.1))

    def loss_ref(q, k, v):
        o = ref.mha_reference(q, k, v, causal=True)
        return jnp.sum(o * jnp.cos(jnp.arange(o.size).reshape(o.shape) * 0.1))

    g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-4, rtol=1e-3)


def test_flash_gradients_gqa_softcap():
    rng = np.random.default_rng(3)
    q, k, v = rand_qkv(rng, 1, 4, 2, 32, 32, 16)

    def mk(fn):
        def loss(q, k, v):
            o = fn(q, k, v)
            return jnp.sum(o ** 2)
        return loss

    flash = mk(lambda q, k, v: ops.flash_attention(
        q, k, v, softcap=20.0, block_q=16, block_k=16, interpret=True))
    oracle = mk(lambda q, k, v: ref.mha_reference(q, k, v, softcap=20.0))
    g1 = jax.grad(flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(oracle, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# Pallas backward kernels: parity against the reference gradients and the
# blocked-XLA recurrence across the full feature matrix.
# ---------------------------------------------------------------------------

def _flash_grads(q, k, v, g, bwd_impl, *, block=16, **kwargs):
    def loss(q, k, v):
        o = ops.flash_attention(q, k, v, block_q=block, block_k=block,
                                interpret=True, bwd_impl=bwd_impl, **kwargs)
        return jnp.sum(o.astype(jnp.float32) * g.astype(jnp.float32))
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


GRAD_CASES = {
    "plain": dict(),
    "causal": dict(causal=True),
    "window": dict(window=24),
    "causal_window": dict(causal=True, window=16),
    "softcap": dict(softcap=20.0),
    "causal_softcap": dict(causal=True, softcap=30.0),
}


@pytest.mark.parametrize("case", sorted(GRAD_CASES))
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_bwd_pallas_feature_matrix(case, dtype):
    """Pallas backward vs reference gradients vs the XLA-recurrence backward."""
    kwargs = GRAD_CASES[case]
    # str hash is randomized per process; seed deterministically instead.
    rng = np.random.default_rng(sorted(GRAD_CASES).index(case))
    q, k, v = rand_qkv(rng, 2, 4, 2, 64, 64, 32, dtype=dtype)   # GQA
    g = jnp.asarray(rng.normal(size=(2, 4, 64, 32)), dtype)
    got = _flash_grads(q, k, v, g, "pallas", **kwargs)
    want = ref.mha_grads_reference(q, k, v, g, **kwargs)
    xla = _flash_grads(q, k, v, g, "xla", **kwargs)
    # bf16: both sides quantize their outputs to bf16, so the envelope is a
    # bf16 ulp of the gradient magnitude (sums over 64 keys), not 1e-2 alone.
    tol = dict(atol=1e-2, rtol=4e-2) if dtype == jnp.bfloat16 else dict(
        atol=1e-5, rtol=1e-3)
    for name, a, w, x in zip("dq dk dv".split(), got, want, xla):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(w, np.float32),
                                   err_msg=f"{name} pallas-vs-ref", **tol)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(x, np.float32),
                                   err_msg=f"{name} pallas-vs-xla", **tol)


@pytest.mark.parametrize("shape", SHAPE_SWEEP)
def test_flash_bwd_pallas_shape_sweep(shape):
    """Backward parity at every forward sweep shape (padding, GQA, dv != d)."""
    b, hq, hkv, sq, sk, d, dv, blk = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q, k, v = rand_qkv(rng, b, hq, hkv, sq, sk, d, dv)
    g = jnp.asarray(rng.normal(size=(b, hq, sq, dv)), jnp.float32)
    got = _flash_grads(q, k, v, g, "pallas", block=blk, causal=True)
    want = ref.mha_grads_reference(q, k, v, g, causal=True)
    for name, a, w in zip("dq dk dv".split(), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   atol=1e-5, rtol=1e-3,
                                   err_msg=f"{name} @ {shape}")


def test_flash_bwd_pallas_segment_ids():
    rng = np.random.default_rng(11)
    b, s = 2, 64
    q, k, v = rand_qkv(rng, b, 2, 2, s, s, 32)
    g = jnp.asarray(rng.normal(size=(b, 2, s, 32)), jnp.float32)
    seg = jnp.asarray(rng.integers(0, 3, size=(b, s)), jnp.int32)
    kw = dict(q_segment_ids=seg, k_segment_ids=seg)
    got = _flash_grads(q, k, v, g, "pallas", **kw)
    want = ref.mha_grads_reference(q, k, v, g, **kw)
    for name, a, w in zip("dq dk dv".split(), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   atol=1e-5, rtol=1e-3, err_msg=name)


def test_flash_bwd_pallas_times():
    """Block-causal over explicit per-token times (agent-sim scenes)."""
    rng = np.random.default_rng(12)
    b, s = 2, 64
    q, k, v = rand_qkv(rng, b, 2, 2, s, s, 32)
    g = jnp.asarray(rng.normal(size=(b, 2, s, 32)), jnp.float32)
    times = jnp.asarray(np.sort(rng.integers(0, 8, size=(b, s)), axis=-1),
                        jnp.int32)
    kw = dict(causal=True, q_times=times, k_times=times)
    got = _flash_grads(q, k, v, g, "pallas", **kw)
    want = ref.mha_grads_reference(q, k, v, g, **kw)
    for name, a, w in zip("dq dk dv".split(), got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   atol=1e-5, rtol=1e-3, err_msg=name)


def test_flash_fwd_lse_matches_reference():
    """The forward kernel's saved LSE rows equal the O(S^2) logsumexp."""
    from repro.kernels import flash_attention as fa
    rng = np.random.default_rng(13)
    q, k, v = rand_qkv(rng, 2, 4, 2, 64, 64, 32)
    _, lse = fa.flash_attention_fwd(q, k, v, causal=True, block_q=16,
                                    block_k=16, interpret=True,
                                    return_lse=True)
    want = ref.lse_reference(q, k, causal=True)
    np.testing.assert_allclose(np.asarray(lse), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_flash_bwd_default_dispatches_pallas(monkeypatch):
    """jax.grad through ops.flash_attention runs the Pallas backward by
    default: poison the XLA fallback and check gradients still flow."""
    monkeypatch.setattr(ops, "_bwd_chunked",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            AssertionError("XLA backward should not run")))
    # Pin the default so an ambient REPRO_FLASH_BWD override cannot skew
    # what this test checks (that bwd_impl=None resolves to Pallas).
    monkeypatch.setattr(ops, "DEFAULT_BWD_IMPL", "pallas")
    rng = np.random.default_rng(14)
    q, k, v = rand_qkv(rng, 1, 2, 2, 32, 32, 16)
    g = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), jnp.float32)
    got = _flash_grads(q, k, v, g, None, causal=True)
    want = ref.mha_grads_reference(q, k, v, g, causal=True)
    for a, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(w),
                                   atol=1e-5, rtol=1e-3)


@pytest.mark.parametrize("causal,window", [(False, None), (True, None),
                                           (True, 32)])
def test_chunked_matches_ref(causal, window):
    rng = np.random.default_rng(4)
    q, k, v = rand_qkv(rng, 2, 4, 2, 96, 96, 32)
    got = ref.mha_chunked(q, k, v, causal=causal, window=window,
                          chunk_size=32)
    want = ref.mha_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_chunked_q_offset_decode():
    """Decode semantics: queries are a suffix of the key sequence."""
    rng = np.random.default_rng(5)
    q, k, v = rand_qkv(rng, 1, 2, 2, 4, 64, 32)
    got = ref.mha_chunked(q, k, v, causal=True, q_offset=60, chunk_size=16)
    want = ref.mha_reference(q, k, v, causal=True, q_offset=60)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# Small-q decode path: q_len << block_q (the incremental rollout shape),
# cursor-based masking via kv_length, and the _pad_all padding edge cases.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sq", [1, 2, 5])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_small_q_decode_matches_ref(sq, dtype):
    """Tiny query counts over a long K/V cache with per-row cursors (GQA).

    Exercises ``_pad_all``'s q_len < block_q path: the auto-shrunk decode
    block is 16 rows, so every sq here gets zero-padded query rows that
    must be sliced off without contaminating live rows.
    """
    rng = np.random.default_rng(100 + sq)
    q, k, v = rand_qkv(rng, 2, 4, 2, sq, 96, 16, dtype=dtype)
    kvl = jnp.asarray([70, 96], jnp.int32)
    got = ops.flash_attention(q, k, v, kv_length=kvl, block_k=32,
                              interpret=True)
    want = ref.mha_reference(q, k, v, kv_length=kvl)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol_for(dtype))


def test_flash_small_q_unaligned_kv():
    """Both _pad_all branches at once: q_len < block_q AND sk % block_k != 0
    (padded key rows must stay masked behind segment id -1)."""
    rng = np.random.default_rng(9)
    q, k, v = rand_qkv(rng, 2, 2, 1, 3, 65, 24, 40)     # MQA + dv != d
    kvl = jnp.asarray([50, 65], jnp.int32)
    got = ops.flash_attention(q, k, v, kv_length=kvl, block_q=32, block_k=32,
                              interpret=True)
    want = ref.mha_reference(q, k, v, kv_length=kvl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_pad_all_q_lt_block_q_direct():
    """_pad_all with q_len < block_q, driven through the padded forward at
    an explicit 32-row block (bypasses the auto-shrink)."""
    rng = np.random.default_rng(10)
    q, k, v = rand_qkv(rng, 1, 2, 2, 5, 64, 16)
    out, lse = ops._flash_fwd_padded(q, k, v, None, None, None, None,
                                     causal=False, window=None, softcap=None,
                                     scale=None, block_q=32, block_k=32,
                                     interpret=True)
    want = ref.mha_reference(q, k, v)
    assert out.shape == (1, 2, 5, 16) and lse.shape == (1, 2, 5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(lse),
                               np.asarray(ref.lse_reference(q, k)),
                               atol=1e-5, rtol=1e-5)


def test_flash_small_q_times_block_causal_decode():
    """The agent-sim decode shape: new tokens at one sim step attending a
    block-causal times cache plus segment ids plus cursor masking."""
    rng = np.random.default_rng(11)
    b, sk, n = 2, 64, 4
    q, k, v = rand_qkv(rng, b, 2, 2, n, sk, 16)
    k_times = jnp.asarray(np.sort(rng.integers(0, 8, size=(b, sk)), -1),
                          jnp.int32)
    q_times = jnp.full((b, n), 5, jnp.int32)
    seg = jnp.asarray(rng.integers(0, 2, size=(b, sk)), jnp.int32)
    qseg = jnp.zeros((b, n), jnp.int32)
    kvl = jnp.asarray([40, 64], jnp.int32)
    kw = dict(causal=True, q_times=q_times, k_times=k_times,
              q_segment_ids=qseg, k_segment_ids=seg, kv_length=kvl)
    got = ops.flash_attention(q, k, v, block_k=16, interpret=True, **kw)
    want = ref.mha_reference(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


def test_decode_block_q_auto_shrink():
    assert ops._decode_block_q(1, 128) == 16
    assert ops._decode_block_q(5, 128) == 16
    assert ops._decode_block_q(17, 128) == 32
    assert ops._decode_block_q(128, 128) == 128
    assert ops._decode_block_q(64, 16) == 16        # never grows the block


def test_flash_kv_length_one_sided_segment_ids():
    """kv_length must survive a caller passing only ONE segment-id side
    (regression: the fold used to leave q_seg None — which disables the
    kernel's segment mask entirely — or clobber a provided q_seg)."""
    rng = np.random.default_rng(13)
    b, sq, sk = 2, 4, 64
    q, k, v = rand_qkv(rng, b, 2, 2, sq, sk, 16)
    kvl = jnp.asarray([40, 64], jnp.int32)
    kseg = jnp.asarray(rng.integers(0, 2, size=(b, sk)), jnp.int32)
    got = ops.flash_attention(q, k, v, k_segment_ids=kseg, kv_length=kvl,
                              block_k=16, interpret=True)
    want = ref.mha_reference(q, k, v, q_segment_ids=jnp.zeros((b, sq),
                                                              jnp.int32),
                             k_segment_ids=kseg, kv_length=kvl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4, err_msg="k-side only")
    qseg = jnp.asarray(rng.integers(-1, 1, size=(b, sq)), jnp.int32)
    got = ops.flash_attention(q, k, v, q_segment_ids=qseg, kv_length=kvl,
                              block_k=16, interpret=True)
    want = ref.mha_reference(q, k, v, q_segment_ids=qseg,
                             k_segment_ids=jnp.zeros((b, sk), jnp.int32),
                             kv_length=kvl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-4, err_msg="q-side only")


@pytest.mark.parametrize("impl", ["ref", "chunked"])
def test_kv_length_scalar_and_vector(impl):
    """Scalar cursors behave like broadcast vectors in the XLA impls."""
    rng = np.random.default_rng(12)
    q, k, v = rand_qkv(rng, 2, 2, 2, 4, 48, 16)
    a = ops.attention(q, k, v, impl=impl, kv_length=33, chunk_size=16)
    b_ = ops.attention(q, k, v, impl=impl,
                       kv_length=jnp.asarray([33, 33], jnp.int32),
                       chunk_size=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)
    want = ref.mha_reference(q, k[:, :, :33], v[:, :, :33])
    np.testing.assert_allclose(np.asarray(a), np.asarray(want),
                               atol=2e-5, rtol=2e-4)


# ---------------------------------------------------------------------------
# SE(2) Fourier projection kernel vs the encodings oracle.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("head_dim,num_terms,tokens,block_t", [
    (6, 8, 16, 8),
    (12, 18, 100, 32),     # unaligned token count (padding path)
    (24, 12, 64, 64),
])
@pytest.mark.parametrize("mode", ["q", "k"])
def test_se2_project_matches_oracle(head_dim, num_terms, tokens, block_t, mode):
    enc = encodings.SE2Fourier(head_dim=head_dim, num_terms=num_terms)
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(tokens, head_dim)), dtype=jnp.float32)
    pose = jnp.asarray(
        np.concatenate([rng.uniform(-3, 3, (tokens, 2)),
                        rng.uniform(-np.pi, np.pi, (tokens, 1))], -1),
        dtype=jnp.float32)
    got = se2_fourier_project(x, pose, enc, mode, block_t=block_t,
                              interpret=True)
    want = enc.transform_q(x, pose) if mode == "q" else enc.transform_k(x, pose)
    assert got.shape == want.shape == (tokens, enc.expanded_dim)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_se2_project_dtypes(dtype):
    enc = encodings.SE2Fourier(head_dim=12, num_terms=10)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.normal(size=(32, 12)), dtype=dtype)
    pose = jnp.asarray(rng.uniform(-2, 2, (32, 3)), dtype=jnp.float32)
    got = se2_fourier_project(x, pose, enc, "k", block_t=16, interpret=True)
    want = enc.transform_k(x, pose)
    assert got.dtype == dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_flash_then_se2_project_end_to_end():
    """Alg. 2 with both Pallas kernels == quadratic oracle (Alg. 1)."""
    from repro.core import attention as core_attn
    enc = encodings.SE2Fourier(head_dim=12, num_terms=20)
    rng = np.random.default_rng(8)
    n = 32
    q = jnp.asarray(rng.normal(size=(n, 12)), dtype=jnp.float32)
    k = jnp.asarray(rng.normal(size=(n, 12)), dtype=jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, 12)), dtype=jnp.float32)
    pose = jnp.asarray(
        np.concatenate([rng.uniform(-2, 2, (n, 2)),
                        rng.uniform(-np.pi, np.pi, (n, 1))], -1),
        dtype=jnp.float32)
    qt = se2_fourier_project(q, pose, enc, "q", block_t=16, interpret=True)
    kt = se2_fourier_project(k, pose, enc, "k", block_t=16, interpret=True)
    vt = se2_fourier_project(v, pose, enc, "k", block_t=16, interpret=True)
    ot = ops.flash_attention(qt[None, None], kt[None, None], vt[None, None],
                             scale=1.0 / np.sqrt(12), block_q=16, block_k=16,
                             interpret=True)[0, 0]
    out = enc.untransform_out(ot, pose)
    want = core_attn.relative_attention_quadratic(enc, q, k, v, pose, pose)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=5e-3, rtol=5e-3)
