"""Training-subsystem tests: expert-demonstration data contract, BC train
step, sim-arch registry, and the SE(2) *training* invariance property —
globally re-posing a scene leaves the behavior-cloning loss unchanged for
relative encodings and measurably changed for the ``absolute`` baseline
(the trained comparison's premise, property-tested before any training).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import scenarios
from repro.configs import SIM_ARCH_NAMES, get_sim_arch
from repro.data.pipeline import ShardedIterator
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel, action_nll
from repro.optim import adamw, chain, clip_by_global_norm
from repro.training.data import (TRAIN_KEYS, holdout_batches, make_batch_fn,
                                 make_sim_batch)
from repro.training.steps import (make_sim_eval_step, make_sim_train_step,
                                  open_loop_metrics, sim_input_specs)

SCEN = scenarios.ScenarioConfig(num_map=12, num_agents=4, num_steps=8)


def _tiny_model(encoding="se2_fourier", seed=0):
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=SCEN.num_actions,
                         encoding=encoding, fourier_terms=12,
                         attn_impl="ref")
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    return model, params


def _device_batch(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


# ---------------------------------------------------------------------------
# data contract
# ---------------------------------------------------------------------------

def test_sim_batch_shapes_keys_and_determinism():
    a = make_sim_batch(3, 16, 4, SCEN)
    b = make_sim_batch(3, 16, 4, SCEN)
    assert set(a) == set(TRAIN_KEYS)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    c = make_sim_batch(3, 20, 4, SCEN)
    assert any(not np.array_equal(a[k], c[k])
               for k in ("agent_pose", "map_pose"))
    # shapes match the abstract specs the dry-run lowers
    specs = sim_input_specs(SCEN, 4)
    for k, v in a.items():
        assert specs[k].shape == v.shape, k
    # action labels live in the model vocabulary
    assert a["actions"].dtype == np.int32
    assert a["actions"].min() >= 0
    assert a["actions"].max() < SCEN.num_actions


def test_sim_batch_mixes_families():
    """Consecutive indices cycle the registered families: within one batch
    spanning len(families) indices, at least two map layouts differ in
    their valid-token counts or geometry."""
    n_fam = len(scenarios.registry.names())
    b = make_sim_batch(0, 0, n_fam, SCEN)
    pose = b["map_pose"].reshape(n_fam, -1)
    assert len({arr.tobytes() for arr in pose}) > 1


def test_sharded_iterator_resume_preserves_data_order():
    it = ShardedIterator(make_batch_fn(SCEN), batch_size=2, seed=5)
    for _ in range(3):
        next(it)
    state = it.state_dict()
    expect = [next(it) for _ in range(2)]
    it.close()
    it2 = ShardedIterator(make_batch_fn(SCEN), batch_size=2, seed=5)
    it2.load_state_dict(state)
    got = [next(it2) for _ in range(2)]
    it2.close()
    for e, g in zip(expect, got):
        for k in e:
            np.testing.assert_array_equal(e[k], g[k], err_msg=k)
    assert state["batch_size"] == 2 and state["world"] == 1


def test_holdout_disjoint_from_training_stream():
    train = make_sim_batch(0, 0, 2, SCEN)
    held = holdout_batches(SCEN, 2, 1, seed=0)[0]
    assert not np.array_equal(train["agent_pose"], held["agent_pose"])


# ---------------------------------------------------------------------------
# train / eval steps
# ---------------------------------------------------------------------------

def test_train_step_reduces_loss_and_reports_metrics():
    model, params = _tiny_model()
    opt = chain(clip_by_global_norm(1.0), adamw(3e-3))
    step = jax.jit(make_sim_train_step(model, opt))
    opt_state = opt.init(params)
    mk = make_batch_fn(SCEN)
    losses = []
    for i in range(12):
        batch = _device_batch(mk(0, i * 2, 2))
        params, opt_state, m = step(params, opt_state, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
        assert np.isfinite(float(m["grad_norm"]))
        assert 0.0 <= float(m["accuracy"]) <= 1.0
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.1, losses


def test_eval_step_matches_action_nll():
    model, params = _tiny_model()
    batch = _device_batch(make_sim_batch(1, 0, 2, SCEN))
    out = jax.jit(make_sim_eval_step(model))(params, batch)
    logits, _ = model(params, batch)
    direct = action_nll(logits, batch["actions"], batch["agent_valid"])
    np.testing.assert_allclose(float(out["nll"]), float(direct), rtol=1e-6)
    m = open_loop_metrics(model, params, [make_sim_batch(1, 0, 2, SCEN)])
    np.testing.assert_allclose(m["nll"], float(direct), rtol=1e-6)


def test_loss_masks_padding_agents():
    """Poisoning an invalid agent's action labels must not move the loss
    (the mask is what makes variable-agent-count batches trainable)."""
    model, params = _tiny_model()
    batch = make_sim_batch(2, 0, 2, SCEN)
    # ensure there is at least one padding slot to poison
    batch["agent_valid"] = batch["agent_valid"].copy()
    batch["agent_valid"][:, :, -1] = False
    bad = {k: v.copy() for k, v in batch.items()}
    bad["actions"][:, :, -1] = SCEN.num_actions - 1
    eval_fn = jax.jit(make_sim_eval_step(model))
    a = float(eval_fn(params, _device_batch(batch))["nll"])
    b = float(eval_fn(params, _device_batch(bad))["nll"])
    assert a == pytest.approx(b, abs=1e-6)


# ---------------------------------------------------------------------------
# sim-arch registry
# ---------------------------------------------------------------------------

def test_sim_arch_registry():
    assert set(SIM_ARCH_NAMES) == {"sim-absolute", "sim-rope2d",
                                   "sim-se2-repr", "sim-se2-fourier"}
    with pytest.raises(KeyError):
        get_sim_arch("sim-nope")
    for name in SIM_ARCH_NAMES:
        arch = get_sim_arch(name)
        cfg = arch.agent_sim_config()
        scen = arch.scenario_config()
        assert cfg.num_actions == scen.num_actions
        small = arch.reduced()
        n = nnm.count_params(AgentSimModel(small.agent_sim_config()).specs())
        assert n < 1e6, (name, n)


def test_sim_arch_reduced_trains_one_step():
    arch = get_sim_arch("sim-se2-repr").reduced(num_map=8, num_agents=3,
                                                num_steps=6)
    model = AgentSimModel(arch.agent_sim_config())
    params = nnm.init_params(model.specs(), jax.random.key(0))
    opt = chain(clip_by_global_norm(1.0), adamw(1e-3))
    step = jax.jit(make_sim_train_step(model, opt))
    batch = _device_batch(make_sim_batch(0, 0, 2, arch.scenario_config()))
    p1, _, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    delta = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta > 0


# ---------------------------------------------------------------------------
# SE(2) property: re-posing a scene leaves the TRAINING loss unchanged for
# relative encodings (and changed for the absolute baseline)
# ---------------------------------------------------------------------------

_LOSS_CACHE = {}


def _training_loss(encoding, z):
    """BC loss of a fixed random model on one batch re-posed by z."""
    if encoding not in _LOSS_CACHE:
        model, params = _tiny_model(encoding, seed=7)
        batch = _device_batch(make_sim_batch(11, 0, 2, SCEN))
        eval_fn = jax.jit(make_sim_eval_step(model))
        _LOSS_CACHE[encoding] = (batch, eval_fn, params)
    batch, eval_fn, params = _LOSS_CACHE[encoding]
    moved = dict(batch)
    moved["map_pose"] = jnp.asarray(
        scenarios.transform_poses(z, np.asarray(batch["map_pose"])))
    moved["agent_pose"] = jnp.asarray(
        scenarios.transform_poses(z, np.asarray(batch["agent_pose"])))
    return float(eval_fn(params, moved)["nll"])


def _check_training_invariance(zx, zy, zth):
    z = np.array([zx, zy, zth], np.float32)
    e = np.zeros(3, np.float32)
    # se2_repr is exact (f32 roundoff); se2_fourier adds truncation error
    for encoding, tol in (("se2_repr", 1e-3), ("se2_fourier", 5e-3)):
        base = _training_loss(encoding, e)
        moved = _training_loss(encoding, z)
        assert abs(moved - base) < tol, (encoding, base, moved, z)
    if abs(zx) + abs(zy) > 1.0 or abs(zth) > 0.5:
        base = _training_loss("absolute", e)
        moved = _training_loss("absolute", z)
        assert abs(moved - base) > 1e-4, \
            f"absolute loss suspiciously invariant under z={z}"


try:
    from hypothesis import given, settings, strategies as st

    transl = st.floats(min_value=-4.0, max_value=4.0, allow_nan=False,
                       width=32)
    angle = st.floats(min_value=-np.pi, max_value=np.pi, allow_nan=False,
                      width=32)

    @settings(max_examples=5, deadline=None, derandomize=True)
    @given(zx=transl, zy=transl, zth=angle)
    def test_training_loss_se2_invariant(zx, zy, zth):
        _check_training_invariance(zx, zy, zth)

except ImportError:            # hypothesis is an optional dev dep:
    @pytest.mark.parametrize(  # fall back to fixed transforms
        "zx,zy,zth",
        [(0.0, 0.0, np.pi / 2), (3.0, -2.0, 0.7), (-4.0, 3.5, -2.9)])
    def test_training_loss_se2_invariant(zx, zy, zth):
        _check_training_invariance(zx, zy, zth)


def test_rope2d_training_loss_translation_invariant():
    """rope2d is the translation-only row of Table I: invariant to shifts,
    NOT to rotations — both directions checked so the registry's claims
    stay honest."""
    shift = _training_loss("rope2d", np.array([5.0, -3.0, 0.0], np.float32))
    base = _training_loss("rope2d", np.zeros(3, np.float32))
    assert abs(shift - base) < 1e-3, (base, shift)
    rot = _training_loss("rope2d", np.array([0.0, 0.0, 1.2], np.float32))
    assert abs(rot - base) > 1e-4, (base, rot)
