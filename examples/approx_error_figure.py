"""Reproduce paper Fig. 3 (approximation error vs radius / basis size) as an
ASCII table + CSV on stdout.

Run:  PYTHONPATH=src:. python examples/approx_error_figure.py
"""
import sys

sys.path.insert(0, ".")


from benchmarks.approx_error import BF16_EPS, FP16_EPS, spectral_error

RADII = (1.0, 2.0, 4.0, 8.0)
BASES = (8, 12, 18, 28)


def main():
    print("spectral-norm approximation error "
          "|| phi(rel) - phi_q phi_k ||_2 (mean over 512 samples)")
    print(f"{'radius':>8} | " + " | ".join(f"F={f:<3d}" for f in BASES))
    print("-" * (10 + 11 * len(BASES)))
    rows = []
    for r in RADII:
        vals = [spectral_error(r, f, n_samples=256)["mean"] for f in BASES]
        rows.append((r, vals))
        print(f"{r:8.1f} | " + " | ".join(f"{v:8.1e}" for v in vals))
    print(f"\nreference: fp16 eps = {FP16_EPS:.1e}, bf16 eps = {BF16_EPS:.1e}")
    print("paper's operating points: (r=2, F=12), (r=4, F=18), (r=8, F=28) "
          "all ~1e-3  [Fig. 3]")
    print("\ncsv:")
    print("radius," + ",".join(f"F{f}" for f in BASES))
    for r, vals in rows:
        print(f"{r}," + ",".join(f"{v:.3e}" for v in vals))


if __name__ == "__main__":
    main()
