"""Quickstart: linear-memory SE(2)-invariant attention in 60 lines.

Demonstrates the paper's core result end to end:
  1. build an SE(2) Fourier encoding,
  2. run Algorithm 2 (linear memory) and the Algorithm 1 oracle,
  3. show they agree, and that the output is invariant to re-expressing
     every pose in a different global frame.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import se2
from repro.core.attention import (relative_attention_linear,
                                  relative_attention_quadratic)
from repro.core.encodings import SE2Fourier

rng = np.random.default_rng(0)
N, HEAD_DIM = 32, 24

# a scene: 32 tokens with features and SE(2) poses (x, y, heading)
q = jnp.asarray(rng.normal(size=(N, HEAD_DIM)), jnp.float32)
k = jnp.asarray(rng.normal(size=(N, HEAD_DIM)), jnp.float32)
v = jnp.asarray(rng.normal(size=(N, HEAD_DIM)), jnp.float32)
poses = jnp.asarray(
    np.concatenate([rng.uniform(-3, 3, (N, 2)),            # positions <= |4|
                    rng.uniform(-np.pi, np.pi, (N, 1))], -1), jnp.float32)

enc = SE2Fourier(head_dim=HEAD_DIM, num_terms=18)   # F=18: err ~1e-3 @ r<=4
print(f"encoding: head_dim={enc.head_dim} -> expanded c={enc.expanded_dim} "
      f"({enc.num_blocks} blocks x (4F+2))")

# --- Algorithm 2 (linear memory) vs Algorithm 1 (quadratic oracle) --------
out_linear = relative_attention_linear(enc, q, k, v, poses, poses)
out_quad = relative_attention_quadratic(enc, q, k, v, poses, poses)
err = float(jnp.max(jnp.abs(out_linear - out_quad)))
print(f"linear vs quadratic max |diff|: {err:.2e}   (Fourier truncation)")
assert err < 5e-3

# --- SE(2) invariance: re-express all poses in a shifted+rotated frame ----
z = jnp.asarray([1.5, -0.7, 2.1], jnp.float32)       # arbitrary new frame
poses_z = se2.compose(jnp.broadcast_to(z, poses.shape), poses)
out_z = relative_attention_linear(enc, q, k, v, poses_z, poses_z)
gap = float(jnp.max(jnp.abs(out_linear - out_z)))
print(f"invariance gap under global transform: {gap:.2e}")
assert gap < 2e-2

# --- and the memory point: no (N, N) tensor was ever built ---------------
print(f"largest intermediate in Alg 2: ({N}, {enc.expanded_dim}) "
      f"— linear in N. Alg 1 builds ({N}, {N}, {HEAD_DIM}).")
print("OK")
