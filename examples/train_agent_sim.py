"""End-to-end driver: train an agent-simulation model with SE(2) Fourier
attention on procedurally generated driving scenes.

This is the paper's task (Sec. IV-B) at CPU-runnable scale by default
(--preset small trains a ~1.1M-param model for 300 steps in a few minutes);
``--preset 100m`` is the ~100M-parameter configuration for a real
accelerator. Uses the full production substrate: sharded data pipeline,
fault-tolerant trainer with checkpointing, NaN guard, step-time monitor.

Run:  PYTHONPATH=src python examples/train_agent_sim.py --steps 300
"""
import argparse
import logging

import jax
import jax.numpy as jnp

from repro.data import scenarios
from repro.data.pipeline import ShardedIterator
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel, action_nll
from repro.optim import adamw, chain, clip_by_global_norm, warmup_cosine
from repro.optim.transforms import apply_updates
from repro.runtime.trainer import Trainer, TrainerConfig

log = logging.getLogger("train_agent_sim")

PRESETS = {
    # ~1.1M params; a few minutes of CPU
    "small": dict(d_model=96, num_layers=3, num_heads=4, head_dim=24,
                  d_ff=384),
    # ~100M params; the paper-scale example driver for real hardware
    "100m": dict(d_model=768, num_layers=12, num_heads=12, head_dim=24,
                 d_ff=3072),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="small", choices=sorted(PRESETS))
    ap.add_argument("--encoding", default="se2_fourier",
                    choices=["absolute", "rope2d", "se2_repr", "se2_fourier"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_agent_sim")
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    scen = scenarios.ScenarioConfig(num_map=24, num_agents=8, num_steps=12)
    cfg = AgentSimConfig(num_actions=scen.num_actions,
                         encoding=args.encoding, fourier_terms=12,
                         **PRESETS[args.preset])
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(0))
    n = nnm.count_params(model.specs())
    log.info("encoding=%s params=%.2fM", args.encoding, n / 1e6)

    opt = chain(clip_by_global_norm(1.0),
                adamw(warmup_cosine(args.lr, 20, args.steps)))

    @jax.jit
    def step(params, opt_state, batch):
        def loss_fn(p):
            logits, _ = model(p, batch)
            return action_nll(logits, batch["actions"], batch["agent_valid"])
        loss, grads = jax.value_and_grad(loss_fn)(params)
        upd, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state, {"loss": loss}

    def mk(seed, idx, bs):
        b = scenarios.generate_batch(seed, idx, bs, scen)
        return {k: jnp.asarray(v) for k, v in b.items()}

    data = ShardedIterator(mk, batch_size=args.batch, seed=0)
    trainer = Trainer(step, params, opt.init(params), data, args.ckpt_dir,
                      TrainerConfig(total_steps=args.steps, ckpt_every=100,
                                    log_every=20),
                      metrics_cb=lambda s, m: log.info(
                          "step %d nll %.4f (%.2fs/step)", s, m["loss"],
                          m["sec_per_step"]))
    trainer.restore_if_available()
    out = trainer.run()
    log.info("done: %s; first-20 nll %.3f -> last-20 nll %.3f", out,
             sum(trainer.history[:20]) / 20,
             sum(trainer.history[-20:]) / 20)
    data.close()


if __name__ == "__main__":
    main()
