"""Serve a small LM with continuous batching (per-slot cache cursors).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch phi4-mini-3.8b
(uses the reduced same-family config so it runs on CPU; drop --reduced on
real hardware).
"""
import argparse
import logging
import time

import numpy as np

import jax

from repro.configs import get_config
from repro.nn import module as nnm
from repro.nn.transformer import build_model
from repro.runtime.server import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b")
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real accelerator)")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)
    log = logging.getLogger("serve_lm")

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced(dtype="float32")
    model = build_model(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(0))
    srv = Server(model, params, num_slots=args.slots, max_len=128)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        srv.submit(Request(uid=uid,
                           prompt=rng.integers(1, cfg.vocab_size,
                                               rng.integers(3, 10)),
                           max_new_tokens=int(rng.integers(4, 12)),
                           temperature=0.7))
    done = srv.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.generated) for r in done.values())
    log.info("%d requests, %d tokens, %.2fs (%.1f tok/s), %d ticks",
             len(done), toks, dt, toks / dt, srv.ticks)
    for uid in sorted(done):
        r = done[uid]
        log.info("req %d: prompt=%s -> %s", uid, list(r.prompt), r.generated)


if __name__ == "__main__":
    main()
