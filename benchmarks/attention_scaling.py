"""The paper's central systems claim: linear vs quadratic memory scaling.

Compares Algorithm 1 (explicit pairwise phi(p_rel)) against Algorithm 2
(factorized, standard SDPA inside) for SE(2) Fourier attention:

  * peak temp memory of the jitted computation (from compiled
    ``memory_analysis`` — an analytic, device-independent measure), and
  * wall time per call on this host (CPU; relative scaling is the signal).

Algorithm 1 memory grows O(N^2) (it materializes (N, N, d) phi-transformed
keys); Algorithm 2 grows O(N). The crossover makes 32k-token scenes
feasible — the paper's enabling observation.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import attention, encodings
from repro.kernels import ref as kref


def _linear_sdpa(q, k, v, mask=None, scale=None):
    """Linear-memory SDPA (chunked online softmax) — the FlashAttention
    stand-in Algorithm 2 routes through (on TPU: the Pallas kernel)."""
    assert mask is None
    out = kref.mha_chunked(q[None, None], k[None, None], v[None, None],
                           scale=scale, chunk_size=128)
    return out[0, 0]


def measure(n_tokens: int, linear: bool, head_dim: int = 12,
            num_terms: int = 8):
    enc = encodings.SE2Fourier(head_dim=head_dim, num_terms=num_terms)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(n_tokens, head_dim)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n_tokens, head_dim)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n_tokens, head_dim)), jnp.float32)
    pose = jnp.asarray(
        np.concatenate([rng.uniform(-3, 3, (n_tokens, 2)),
                        rng.uniform(-np.pi, np.pi, (n_tokens, 1))], -1),
        jnp.float32)

    if linear:
        fn = lambda q, k, v, p: attention.relative_attention_linear(
            enc, q, k, v, p, p, sdpa_fn=_linear_sdpa)
    else:
        fn = lambda q, k, v, p: attention.relative_attention_quadratic(
            enc, q, k, v, p, p)
    jitted = jax.jit(fn)
    lowered = jitted.lower(q, k, v, pose)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    temp = getattr(mem, "temp_size_in_bytes", 0)
    out = jitted(q, k, v, pose)
    out.block_until_ready()
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        jitted(q, k, v, pose).block_until_ready()
    dt = (time.perf_counter() - t0) / reps
    return temp, dt


def run(report):
    sizes = [64, 128, 256, 512, 1024]
    quad_mem, lin_mem = {}, {}
    for n in sizes:
        tq, dq = measure(n, linear=False)
        tl, dl = measure(n, linear=True)
        quad_mem[n], lin_mem[n] = tq, tl
        report(f"attn_scaling/quadratic_n{n}", dq * 1e6,
               f"temp_bytes={tq}")
        report(f"attn_scaling/linear_n{n}", dl * 1e6,
               f"temp_bytes={tl}")
    # scaling-exponent check over the last doubling
    q_ratio = quad_mem[1024] / max(quad_mem[256], 1)
    l_ratio = lin_mem[1024] / max(lin_mem[256], 1)
    report("attn_scaling/quad_mem_ratio_4x_tokens", q_ratio,
           "expect ~16 (O(N^2))")
    report("attn_scaling/linear_mem_ratio_4x_tokens", l_ratio,
           "expect ~4 (O(N))")
    assert q_ratio > 8.0, q_ratio
    assert l_ratio < 8.0, l_ratio


if __name__ == "__main__":
    run(lambda name, val, extra="": print(f"{name},{val},{extra}"))
