"""Training-throughput benchmark for the agent-sim BC trainer.

Measures steps/s of the jitted sharded train step (device work) and the
host-side expert-demonstration generation cost separately — the two
numbers that size a data-loader fleet — plus the loss trajectory, and
writes the machine-readable record to ``BENCH_train.json`` so successive
PRs accumulate a bench trajectory.

``--smoke`` is the CI variant: few steps, asserts the step is finite,
training moves the loss down from init, and throughput is nonzero.

Run:  PYTHONPATH=src python benchmarks/train_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_sim_arch
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimModel
from repro.optim import adamw, chain, clip_by_global_norm
from repro.training.data import make_batch_fn
from repro.training.steps import loss_summary, make_sim_train_step

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "..", "BENCH_train.json")


def run(report, *, arch="sim-se2-fourier", steps=80, warmup=5, batch=8,
        lr=3e-3, seed=0, n_unique_batches=32, smoke=False, out=None):
    """Time the train step over a cycled pool of pre-generated batches.

    Pre-generating decouples device steps/s from host scene generation
    (measured separately as ``datagen_s_per_batch``); cycling a pool keeps
    the loss trajectory meaningful without paying generation per step.
    """
    if steps < 1:
        raise ValueError("train_bench needs steps >= 1")
    warmup = min(warmup, steps - 1)   # guarantee the timed window exists
    sim = get_sim_arch(arch).reduced()
    cfg = sim.agent_sim_config()
    scen = sim.scenario_config()
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    opt = chain(clip_by_global_norm(1.0), adamw(lr))
    opt_state = opt.init(params)
    step = jax.jit(make_sim_train_step(model, opt))
    mk = make_batch_fn(scen)

    n_unique = min(steps, n_unique_batches)
    t0 = time.time()
    pool = [{k: jnp.asarray(v) for k, v in mk(seed, i * batch, batch).items()}
            for i in range(n_unique)]
    datagen_s = (time.time() - t0) / n_unique

    losses = []
    t_start = None
    for i in range(steps):
        if i == warmup:
            jax.block_until_ready(params)
            t_start = time.time()
        params, opt_state, m = step(params, opt_state, pool[i % n_unique])
        losses.append(float(m["loss"]))
    jax.block_until_ready(params)
    elapsed = time.time() - t_start
    timed_steps = steps - warmup      # >= 1 by the warmup clamp above
    steps_per_s = timed_steps / max(elapsed, 1e-9)

    rec = {
        "arch": sim.name, "encoding": sim.encoding,
        "steps": steps, "batch": batch,
        "n_params": nnm.count_params(model.specs()),
        "tokens_per_scene": scen.num_map + scen.num_steps * scen.num_agents,
        "steps_per_s": steps_per_s,
        "sec_per_step": 1.0 / steps_per_s,
        "datagen_s_per_batch": datagen_s,
        **loss_summary(losses),
        "accuracy_last": float(m["accuracy"]),
        "loss_trajectory": losses[:: max(1, len(losses) // 50)],
    }
    report("train_bench/steps_per_s", f"{steps_per_s:.2f}",
           f"batch={batch} params={rec['n_params']}")
    report("train_bench/datagen_s_per_batch", f"{datagen_s:.3f}")
    report("train_bench/loss_first", f"{rec['loss_first']:.4f}")
    report("train_bench/loss_last", f"{rec['loss_last']:.4f}",
           f"acc={rec['accuracy_last']:.3f}")

    out_path = os.path.abspath(out or DEFAULT_OUT)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    report("train_bench/out", out_path)

    if smoke:
        assert all(np.isfinite(losses)), "non-finite training loss"
        assert rec["loss_last"] < rec["loss_first"], \
            f"loss did not decrease: {rec['loss_first']} -> {rec['loss_last']}"
        assert steps_per_s > 0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: few steps + health assertions")
    ap.add_argument("--arch", default="sim-se2-fourier")
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default=None,
                    help=f"JSON output path (default {DEFAULT_OUT})")
    args = ap.parse_args()
    report = lambda name, val, extra="": print(f"{name},{val},{extra}",
                                               flush=True)
    if args.smoke:
        run(report, arch=args.arch, steps=30, warmup=3, batch=4,
            n_unique_batches=8, smoke=True, out=args.out)
    else:
        run(report, arch=args.arch, steps=args.steps, batch=args.batch,
            out=args.out)


if __name__ == "__main__":
    main()
