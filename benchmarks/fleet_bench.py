"""Fleet rollout benchmark: scenes/s vs device count + real-budget Table I.

Two phases, both over the scene-sharded fleet path
(``RolloutEngine(mesh=...)`` shard_mapping its tick over ``("pod",
"data")`` — see ``docs/distributed.md``):

* **scaling curve** — one mixed-family scene workload rolled out at
  every requested device count (device 1 = the unsharded single-device
  engine). Each count reports scenes/s, and every sharded run's futures
  must be BIT-IDENTICAL to the single-device reference — the curve is
  only meaningful if sharding is free of placement effects. On a forced
  CPU mesh (``--xla_force_host_platform_device_count``) the devices are
  virtual and share the host's physical cores, so the curve measures
  dispatch/partitioning overhead rather than real parallel speedup; the
  record carries ``physical_cpus`` so readers can tell. On a real pod
  the same code measures the actual scaling.

* **Table I at a real budget** (``--table1``, on by default for the full
  run) — the PR 4 invariant-vs-absolute comparison executed through the
  production fleet path: training goes through the shard_mapped
  compressed-DP step (int8 + error-feedback cross-pod psum carrying the
  gradient traffic on the "pod" axis), and the closed-loop scoring runs
  10k+ mixed-family scenes through the scene-sharded engine. Output:
  per-family metric tables per encoding plus the paper's headline
  relative-vs-absolute NLL comparison.

Writes the rich record to ``BENCH_fleet.json`` (repo root) and prints
``name,value,notes`` CSV rows like every other benchmark.

Run:  PYTHONPATH=src python benchmarks/fleet_bench.py [--smoke] [--no-table1]

The script forces its own ``--xla_force_host_platform_device_count``
(before first jax init) when launched as __main__; through
``benchmarks/run.py`` it runs in a subprocess for the same reason.
"""
from __future__ import annotations

import argparse
import json
import os
import time

HERE = os.path.dirname(os.path.abspath(__file__))
DEF_OUT = os.path.join(HERE, "..", "BENCH_fleet.json")

TABLE1_ENCODINGS = ("se2_fourier", "absolute")   # the acceptance pair


def _fleet_arch(smoke: bool):
    from repro.configs import get_sim_arch
    arch = get_sim_arch("sim-se2-fourier").reduced()
    if smoke:
        arch = arch.reduced(num_map=12, num_agents=4, num_steps=8)
    return arch


def _mixed_scenes(scen, n: int, seed: int = 7):
    """n mixed-family scenes, families interleaved deterministically."""
    from repro.scenarios import registry
    fams = registry.names()
    return [registry.generate_scene(fams[i % len(fams)], seed,
                                    i // len(fams), scen)
            for i in range(n)]


def _per_family_scenes(scen, per_family: int, seed: int):
    from repro.scenarios import registry
    return [registry.generate_scene(f, seed, i, scen)
            for f in registry.names() for i in range(per_family)]


def scaling_curve(report, *, arch, device_counts, n_scenes, n_samples,
                  slots_per_device, seed=0):
    """scenes/s per device count + bit-parity against the 1-device run."""
    import jax
    import numpy as np

    from repro import obs
    from repro.launch.mesh import make_fleet_mesh
    from repro.nn import module as nnm
    from repro.nn.agent_sim import AgentSimModel
    from repro.runtime.rollout import RolloutEngine

    scen = arch.scenario_config()
    model = AgentSimModel(arch.agent_sim_config())
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    t0 = time.time()
    scenes = [s.tensors for s in _mixed_scenes(scen, n_scenes)]
    report("fleet_bench/scene_gen_s", f"{time.time() - t0:.1f}",
           f"n={n_scenes}")
    t_hist = max(1, scen.num_steps // 2)

    curve, ref = [], None
    for d in device_counts:
        # d=1 is the plain single-device engine — the parity reference;
        # even d >= 2 splits a leading 2-wide "pod" axis off so the
        # cross-pod dimension of the spec is exercised, not just "data"
        mesh = (None if d == 1 else
                make_fleet_mesh(d, pods=2 if d % 2 == 0 else 1))
        reg = obs.Registry()
        eng = RolloutEngine(model, params, scen,
                            num_slots=slots_per_device * d, mesh=mesh,
                            registry=reg)
        t0 = time.time()
        eng.run(scenes[:2], t_hist=t_hist, n_samples=n_samples, seed=seed)
        compile_s = time.time() - t0
        warm_steps = reg.histogram("rollout.step.seconds").count
        t0 = time.time()
        fut = eng.run(scenes, t_hist=t_hist, n_samples=n_samples, seed=seed)
        dt = time.time() - t0
        parity = bool(ref is None or np.array_equal(ref, fut))
        ref = fut if ref is None else ref
        mesh_shape = "1" if mesh is None else "x".join(
            str(mesh.shape[a]) for a in ("pod", "data"))
        step_hist = reg.histogram("rollout.step.seconds")
        row = {"devices": d, "mesh": mesh_shape,
               "num_slots": eng.num_slots,
               "scenes_per_s": n_scenes / dt, "lanes": n_scenes * n_samples,
               "run_s": dt, "compile_s": compile_s,
               # registry-derived: per-tick p50 over both runs (the
               # warm-up run's steps are a small, post-compile minority)
               "step_p50_ms": 1e3 * step_hist.percentile(50),
               "steps_timed": step_hist.count - warm_steps,
               "cache_mib": reg.gauge("rollout.cache_bytes").value / 2 ** 20,
               "bit_identical_to_single_device": parity}
        curve.append(row)
        report(f"fleet_bench/curve/d{d}/scenes_per_s",
               f"{row['scenes_per_s']:.2f}",
               f"mesh={mesh_shape} slots={eng.num_slots} parity={parity}")
        assert parity, (
            f"sharded rollout at {d} devices diverged from the "
            f"single-device reference — placement leaked into results")
    return curve


def fleet_telemetry(report, *, arch, ranks, out_dir, n_scenes, n_samples,
                    slots_per_rank, seed=0, smoke=False):
    """Per-rank trace aggregation demo: one registry per rank, each rank
    rolling out its own scene shard (the per-host split of a data-
    parallel fleet, run sequentially in this one process), merged into a
    single Perfetto timeline by ``repro.obs.fleet.merge_traces``.

    The last rank gets a deliberate per-step slowdown injected (a host
    sleep of 3x rank 0's measured step median — a failure drill, clearly
    not a claim about real hardware) so the whole chain fires on honest
    wall-clock: per-rank medians -> ``StragglerPolicy`` flags the slow
    rank on rank 0's registry -> ``obs_merge`` overlays the flag on the
    straggler's own track in the merged trace.
    """
    import jax

    from repro import obs
    from repro.nn import module as nnm
    from repro.nn.agent_sim import AgentSimModel
    from repro.runtime.monitor import StragglerPolicy
    from repro.runtime.rollout import RolloutEngine

    scen = arch.scenario_config()
    model = AgentSimModel(arch.agent_sim_config())
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    scenes = [s.tensors for s in _mixed_scenes(scen, n_scenes)]
    t_hist = max(1, scen.num_steps // 2)
    pods = 2 if ranks % 2 == 0 and ranks > 1 else 1
    per_pod = ranks // pods

    regs, medians, counts = [], {}, {}
    straggle_s = 0.0
    for r in range(ranks):
        reg = obs.Registry()
        obs.fleet.stamp_identity(reg, rank=r, pod=r // per_pod,
                                 data=r % per_pod, world=ranks)
        eng = RolloutEngine(model, params, scen, num_slots=slots_per_rank,
                            registry=reg)
        if r == ranks - 1 and ranks > 1 and straggle_s > 0:
            inner = eng._step

            def slow_step(*a, _inner=inner, _s=straggle_s):
                time.sleep(_s)
                return _inner(*a)

            eng._step = slow_step
        shard = scenes[r::ranks]
        eng.run(shard, t_hist=t_hist, n_samples=n_samples, seed=seed)
        h = reg.histogram("rollout.step.seconds")
        medians[r], counts[r] = h.percentile(50), h.count
        regs.append(reg)
        if r == 0:
            straggle_s = 3.0 * max(medians[0], 1e-4)

    policy = StragglerPolicy(straggler_factor=1.5,
                             min_samples=min(10, min(counts.values())),
                             registry=regs[0])
    flagged = policy.evaluate(medians, counts)
    report("fleet_bench/telemetry/flagged",
           ",".join(map(str, flagged)) or "none",
           " ".join(f"r{r}={m * 1e3:.2f}ms" for r, m in medians.items()))

    paths = [obs.fleet.write_rank_trace(reg, out_dir,
                                        process_name="fleet_bench")
             for reg in regs]
    merged = obs.fleet.merge_traces(
        paths, os.path.join(out_dir, "merged.trace.jsonl"))
    report("fleet_bench/telemetry/merged", merged["out"],
           f"ranks={len(merged['ranks'])} events={merged['events']} "
           f"overlays={merged['straggler_overlays']}")
    if smoke:
        assert flagged == [ranks - 1], (
            f"straggler drill: expected rank {ranks - 1} flagged, "
            f"got {flagged} (medians {medians})")
        assert merged["straggler_overlays"] >= 1, merged
    return {"ranks": ranks, "flagged": flagged,
            "step_p50_ms": {str(r): 1e3 * m for r, m in medians.items()},
            "injected_straggle_ms": 1e3 * straggle_s,
            "per_rank_traces": paths, **merged}


def table1(report, *, arch, devices, n_samples, slots_per_device,
           steps, batch, encodings, scenes_per_family, seed=0):
    """The invariant-vs-absolute comparison on the production fleet path."""
    from repro.launch.mesh import make_fleet_mesh
    from repro.training.comparison import format_table, run_comparison

    mesh = make_fleet_mesh(devices, pods=2 if devices % 2 == 0 else 1)
    n_scenes = scenes_per_family * 7   # 7 registered families
    report("fleet_bench/table1/budget",
           f"steps={steps}", f"batch={batch} eval_scenes={n_scenes} "
           f"samples={n_samples} devices={devices}")
    rows = run_comparison(
        arch, encodings, steps=steps, batch=batch, seed=seed,
        n_scenes_per_family=scenes_per_family, eval_samples=n_samples,
        mesh=mesh, dp_compress=True, eval_mesh=mesh,
        eval_num_slots=slots_per_device * devices,
        report=lambda n, v, extra="": report(f"fleet_bench/{n}", v, extra))
    for enc in encodings:
        for fam, m in sorted(rows[enc]["families"].items()):
            report(f"fleet_bench/table1/{enc}/{fam}/min_ade",
                   f"{m['min_ade']:.4f}",
                   f"miss={m['miss_rate']:.4f} "
                   f"collision={m['collision_rate']:.4f} "
                   f"offroad={m['offroad_rate']:.4f} "
                   f"scenes={m['n_scenes']:.0f} agents={m['n_agents']:.0f}")
    print(format_table(rows))
    return rows


def run(report, *, smoke=False, devices=4, device_counts=(1, 2, 4),
        n_scenes=256, n_samples=2, slots_per_device=64, with_table1=True,
        steps=250, batch=32, encodings=TABLE1_ENCODINGS,
        scenes_per_family=1432, seed=0, out=DEF_OUT, telemetry_dir=None):
    import jax
    import numpy as np

    if smoke:
        # trim the curve to the forced device count before validating it,
        # so e.g. --smoke --devices 2 runs the 1,2 prefix instead of
        # demanding the default 4-point curve
        device_counts = tuple(d for d in device_counts if d <= devices)
        n_scenes, slots_per_device = 16, 4
        steps, batch, scenes_per_family = 6, 8, 2
    if len(jax.devices()) < max(device_counts):
        raise RuntimeError(
            f"{len(jax.devices())} devices visible but the curve needs "
            f"{max(device_counts)}; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=... before jax init "
            f"(the __main__ entry point does this)")
    arch = _fleet_arch(smoke)
    record = {
        "benchmark": "fleet_bench", "smoke": smoke,
        "arch": {"encoding_curve": arch.encoding, "d_model": arch.d_model,
                 "num_layers": arch.num_layers, "num_map": arch.num_map,
                 "num_agents": arch.num_agents, "num_steps": arch.num_steps},
        "backend": jax.default_backend(),
        "forced_devices": len(jax.devices()),
        "physical_cpus": os.cpu_count(),
    }

    t0 = time.time()
    record["curve"] = scaling_curve(
        report, arch=arch, device_counts=device_counts, n_scenes=n_scenes,
        n_samples=n_samples, slots_per_device=slots_per_device, seed=seed)
    record["curve_elapsed_s"] = round(time.time() - t0, 1)

    if telemetry_dir:
        t0 = time.time()
        record["fleet_telemetry"] = fleet_telemetry(
            report, arch=arch, ranks=devices, out_dir=telemetry_dir,
            n_scenes=min(n_scenes, 8 if smoke else 32),
            n_samples=n_samples, slots_per_rank=min(slots_per_device, 8),
            seed=seed, smoke=smoke)
        record["fleet_telemetry"]["elapsed_s"] = round(time.time() - t0, 1)

    if with_table1:
        t0 = time.time()
        rows = table1(report, arch=arch, devices=devices,
                      n_samples=n_samples, slots_per_device=slots_per_device,
                      steps=steps, batch=batch, encodings=encodings,
                      scenes_per_family=scenes_per_family, seed=seed)
        record["table1"] = {
            "budget": {"steps": steps, "batch": batch,
                       "eval_scenes": scenes_per_family * 7,
                       "eval_samples": n_samples, "devices": devices,
                       "dp_compress": True},
            "rows": rows,
        }
        record["table1_elapsed_s"] = round(time.time() - t0, 1)
        if smoke:
            for enc in encodings:
                r = rows[enc]
                assert r["status"] == "done", (enc, r)
                assert np.isfinite(r["open_loop_nll"]), (enc, r)
                assert np.isfinite(r["closed_loop_min_ade"]), (enc, r)
                assert len(r["families"]) == 8, (enc, list(r["families"]))

    with open(out, "w") as f:
        json.dump(record, f, indent=2)
    report("fleet_bench/out", os.path.abspath(out))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run with structural assertions")
    ap.add_argument("--devices", type=int, default=4,
                    help="forced CPU device count (and the fleet size for "
                         "the Table-I phase)")
    ap.add_argument("--device-counts", default=None,
                    help="comma list for the scaling curve (default 1,2,4)")
    ap.add_argument("--scenes", type=int, default=256)
    ap.add_argument("--samples", type=int, default=2)
    ap.add_argument("--slots-per-device", type=int, default=64)
    ap.add_argument("--no-table1", action="store_true")
    ap.add_argument("--steps", type=int, default=250)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--encodings", default=",".join(TABLE1_ENCODINGS))
    ap.add_argument("--scenes-per-family", type=int, default=1432,
                    help="closed-loop eval scenes per family for Table I "
                         "(1432 x 7 families = 10024 scenes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--telemetry-dir", default=None, metavar="DIR",
                    help="also run the per-rank telemetry demo: one "
                         "registry per rank, rank*.trace.jsonl files + a "
                         "merged Perfetto timeline (with the straggler "
                         "drill flagged + overlaid) under DIR")
    args = ap.parse_args()

    # MUST precede first jax init: jax locks the device count.
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.devices}")

    counts = (tuple(int(x) for x in args.device_counts.split(","))
              if args.device_counts else (1, 2, 4))
    out = args.out or ("/tmp/BENCH_fleet_smoke.json" if args.smoke
                       else DEF_OUT)
    report = lambda name, val, extra="": print(f"{name},{val},{extra}",
                                               flush=True)
    run(report, smoke=args.smoke, devices=args.devices, device_counts=counts,
        n_scenes=args.scenes, n_samples=args.samples,
        slots_per_device=args.slots_per_device,
        with_table1=not args.no_table1, steps=args.steps, batch=args.batch,
        encodings=tuple(args.encodings.split(",")),
        scenes_per_family=args.scenes_per_family, seed=args.seed, out=out,
        telemetry_dir=args.telemetry_dir)


if __name__ == "__main__":
    main()
