"""Schema checker for the committed ``BENCH_*.json`` records.

The bench records at the repo root are the project's perf trajectory:
sessions compare against them and docs cite them, so a bench refactor
that silently renames ``tick_p50_ms`` or drops ``parity_vs_batch_eval``
corrupts the record for every future reader. This checker pins the
committed keys per bench — names, types, and basic sanity (finite,
positive where a latency/throughput, percentile ordering) — without
pulling in a JSON-schema dependency.

Run:  python benchmarks/bench_schema.py            # checks repo root
      python benchmarks/bench_schema.py FILE...    # specific records

Exit status 1 if any record is missing keys or carries insane values.
The CI ``obs-smoke`` job runs it, and ``tests/test_obs.py`` runs it on
the committed records plus freshly generated smoke records.
"""
from __future__ import annotations

import glob
import json
import math
import os
import sys
from typing import Any, Dict, List

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.abspath(os.path.join(HERE, ".."))

Num = (int, float)


class _Check:
    def __init__(self, path: str):
        self.path = path
        self.problems: List[str] = []

    def fail(self, msg: str):
        self.problems.append(f"{os.path.basename(self.path)}: {msg}")

    def require(self, d: Dict[str, Any], key: str, types, ctx: str = ""):
        where = f"{ctx}.{key}" if ctx else key
        if key not in d:
            self.fail(f"missing key {where}")
            return None
        v = d[key]
        if types is not None and not isinstance(v, types):
            self.fail(f"{where} has type {type(v).__name__}, "
                      f"wanted {types}")
            return None
        return v

    def finite(self, d: Dict[str, Any], key: str, ctx: str = "",
               positive: bool = False):
        v = self.require(d, key, Num, ctx)
        if v is None:
            return None
        where = f"{ctx}.{key}" if ctx else key
        if not math.isfinite(v):
            self.fail(f"{where} is not finite: {v}")
        elif positive and v <= 0:
            self.fail(f"{where} must be > 0, got {v}")
        return v


def check_serve(rec: Dict[str, Any], c: _Check):
    for k in ("encoding", "backend", "cache_dtype"):
        c.require(rec, k, str)
    for k in ("n_scenes", "num_map", "num_agents", "num_steps", "t_hist"):
        c.finite(rec, k, positive=True)
    slots = c.require(rec, "slot_counts", dict)
    for ns, row in (slots or {}).items():
        ctx = f"slot_counts[{ns}]"
        for k in ("ticks", "wall_s", "scenes_per_s", "tick_p50_ms",
                  "tick_p99_ms", "slab_mib", "slab_rows", "no_slab_mib",
                  "tick_p50_off_ms", "queue_wait_p50_ms",
                  "first_action_p50_ms"):
            c.finite(row, k, ctx, positive=True)
        c.finite(row, "telemetry_overhead_p50", ctx)    # may be negative
        p50, p99 = row.get("tick_p50_ms"), row.get("tick_p99_ms")
        if isinstance(p50, Num) and isinstance(p99, Num) and p99 < p50:
            c.fail(f"{ctx}: tick_p99_ms {p99} < tick_p50_ms {p50}")
        if row.get("parity_vs_batch_eval") is not True:
            c.fail(f"{ctx}: parity_vs_batch_eval is not true — the "
                   "committed record must come from an isolating run")


def check_rollout(rec: Dict[str, Any], c: _Check):
    c.require(rec, "encoding", str)
    for k in ("num_agents", "num_steps", "lanes", "live_len", "max_len"):
        c.finite(rec, k, positive=True)
    paths = c.require(rec, "paths", dict) or {}
    for need in ("generic_cached", "ragged_f32"):
        if need not in paths:
            c.fail(f"paths.{need} missing")
    for name, row in paths.items():
        c.finite(row, "steps_per_s", f"paths.{name}", positive=True)
        if "step_p50_ms" in row:        # registry-derived (newer records)
            c.finite(row, "step_p50_ms", f"paths.{name}", positive=True)
    c.finite(rec, "decode_speedup", positive=True)
    flat = c.require(rec, "flatness", dict)
    if flat:
        c.finite(flat, "max_rel_dev", "flatness")


def check_fleet(rec: Dict[str, Any], c: _Check):
    c.require(rec, "backend", str)
    curve = c.require(rec, "curve", list) or []
    if not curve:
        c.fail("curve is empty")
    for i, row in enumerate(curve):
        ctx = f"curve[{i}]"
        for k in ("devices", "num_slots", "scenes_per_s", "run_s"):
            c.finite(row, k, ctx, positive=True)
        if "step_p50_ms" in row:        # registry-derived (newer records)
            c.finite(row, "step_p50_ms", ctx, positive=True)
        if row.get("bit_identical_to_single_device") is not True:
            c.fail(f"{ctx}: sharded run not bit-identical to the "
                   "single-device reference")


def check_train(rec: Dict[str, Any], c: _Check):
    for k in ("arch", "encoding"):
        c.require(rec, k, str)
    for k in ("steps", "batch", "n_params", "steps_per_s", "sec_per_step"):
        c.finite(rec, k, positive=True)
    for k in ("loss_first", "loss_last"):
        c.finite(rec, k)


CHAOS_SCENARIOS = ("corrupt_ckpt_resume", "nan_slot_quarantine",
                   "dead_worker", "async_save_io", "delay_tick")


def check_chaos(rec: Dict[str, Any], c: _Check):
    if c.require(rec, "kind", str) not in (None, "chaos_drill"):
        c.fail(f"kind is {rec.get('kind')!r}, wanted 'chaos_drill'")
    c.finite(rec, "wall_s", positive=True)
    c.finite(rec, "n_scenarios", positive=True)
    if rec.get("all_passed") is not True:
        c.fail("all_passed is not true — the committed record must come "
               "from a fully passing drill run")
    scen = c.require(rec, "scenarios", dict) or {}
    for name in CHAOS_SCENARIOS:
        row = scen.get(name)
        if row is None:
            c.fail(f"scenarios.{name} missing — the drill suite shrank")
            continue
        if row.get("passed") is not True:
            c.fail(f"scenarios.{name}.passed is not true")
        c.finite(row, "wall_s", f"scenarios.{name}", positive=True)
        c.require(row, "bundle", str, f"scenarios.{name}")
    q = scen.get("nan_slot_quarantine") or {}
    for dtype in ("float32", "int8"):
        row = q.get(dtype)
        if not isinstance(row, dict):
            c.fail(f"scenarios.nan_slot_quarantine.{dtype} missing — "
                   "quarantine parity must cover both cache dtypes")
            continue
        for k in ("healthy_bit_identical", "recycle_bit_identical"):
            if row.get(k) is not True:
                c.fail(f"scenarios.nan_slot_quarantine.{dtype}.{k} "
                       "is not true")


CHECKERS = {
    "BENCH_serve.json": check_serve,
    "BENCH_rollout.json": check_rollout,
    "BENCH_fleet.json": check_fleet,
    "BENCH_train.json": check_train,
    "BENCH_chaos.json": check_chaos,
}


def match_checker(path: str):
    base = os.path.basename(path)
    for name, fn in CHECKERS.items():
        # smoke copies like BENCH_serve_smoke.json use the same schema
        if base.startswith(name[:-len(".json")]):
            return fn
    return None


def check_file(path: str) -> List[str]:
    c = _Check(path)
    fn = match_checker(path)
    if fn is None:
        c.fail("no schema registered for this bench record")
        return c.problems
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, ValueError) as e:
        c.fail(f"unreadable: {e}")
        return c.problems
    fn(rec, c)
    return c.problems


def main(argv=None) -> int:
    paths = (argv if argv else
             sorted(glob.glob(os.path.join(ROOT, "BENCH_*.json"))))
    if not paths:
        print("bench_schema: no BENCH_*.json records found", file=sys.stderr)
        return 1
    bad = 0
    for p in paths:
        problems = check_file(p)
        status = "FAIL" if problems else "ok"
        print(f"bench_schema: {os.path.basename(p)}: {status}")
        for msg in problems:
            print(f"  {msg}")
        bad += bool(problems)
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:] or None))
