"""Closed-loop rollout throughput: cached incremental decode vs recompute.

Benchmarks the inference-scaling claim behind the SE(2) K/V cache (see
``docs/rollout.md``): with the per-token ``phi_q``/``phi_k`` factorization,
a rollout step only pays attention of the A new agent tokens against the
cached scene — O(T) — while the naive closed-loop simulator re-runs the
full scene forward, O(T^2) per rollout.

Both paths are driven from the *same* per-(scene, sample) key stream
(``repro.runtime.rollout.rollout_keys``), so they sample from matching
distributions; the cached path's numerical parity with the recompute
forward is asserted separately in ``tests/test_decode.py``.

Default workload (the acceptance target): 16 agents x 64 steps, 8 lanes.
``--smoke`` shrinks everything for CI and asserts the cached path wins.

Run:  PYTHONPATH=src python benchmarks/rollout_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import scenarios
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.runtime.rollout import (RolloutEngine, rollout_keys,
                                   step_kinematics)


def build(scen: scenarios.ScenarioConfig, encoding="se2_fourier",
          d_model=64, layers=2, heads=4, seed=0):
    cfg = AgentSimConfig(d_model=d_model, num_layers=layers, num_heads=heads,
                         head_dim=24, d_ff=4 * d_model,
                         num_actions=scen.num_actions, encoding=encoding,
                         fourier_terms=12, pos_scale=0.05)
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    return cfg, model, params


class RecomputeRollout:
    """The O(T^2) baseline: full-scene forward at every rollout step.

    Static shapes (future rows ride along masked invalid), so it compiles
    exactly once — this is the *fair* version of the naive loop; the
    original one re-jitted at every step because the sequence grew.
    """

    def __init__(self, model, params, scen: scenarios.ScenarioConfig):
        self.model = model
        self.params = params
        self.scen = scen
        self._accel = jnp.asarray(scen.accel_values(), jnp.float32)
        self._yaw = jnp.asarray(scen.yaw_values(), jnp.float32)
        self._step = jax.jit(self._step_impl)
        self.ticks = 0

    def _step_impl(self, params, batch, pose, speed, feats_proto, keys, t):
        logits_all, _ = self.model(params, batch)          # (B, T, A, K)
        logits = jax.lax.dynamic_index_in_dim(
            logits_all, t - 1, axis=1, keepdims=False)     # step t-1 tokens
        keys_t = jax.vmap(jax.random.fold_in, in_axes=(0, None))(keys, t)
        acts = jax.vmap(jax.random.categorical)(
            keys_t, logits.astype(jnp.float32))
        ai, yi = jnp.divmod(acts, self.scen.yaw_bins)
        pose, speed = step_kinematics(pose, speed, self._accel[ai],
                                      self._yaw[yi])
        feats = feats_proto.at[..., 0].set(speed / 10.0)
        batch = dict(batch)
        batch["agent_pose"] = batch["agent_pose"].at[:, t].set(pose)
        batch["agent_feats"] = batch["agent_feats"].at[:, t].set(feats)
        batch["agent_valid"] = batch["agent_valid"].at[:, t].set(True)
        return batch, pose, speed, acts

    def run(self, scenes, *, t_hist: int, n_samples: int, seed: int = 0,
            t_total=None):
        scen = self.scen
        t_total = t_total or scen.num_steps
        n_scenes = len(scenes)
        keys = rollout_keys(seed, n_scenes, n_samples)
        rep = lambda x: np.repeat(np.stack(x), n_samples, axis=0)
        b = n_scenes * n_samples
        a = scen.num_agents
        agent_feats = np.zeros((b, t_total, a, scen.agent_feat_dim),
                               np.float32)
        agent_pose = np.zeros((b, t_total, a, 3), np.float32)
        agent_valid = np.zeros((b, t_total, a), bool)
        agent_feats[:, :t_hist] = rep([s["agent_feats"][:t_hist]
                                       for s in scenes])
        agent_pose[:, :t_hist] = rep([s["agent_pose"][:t_hist]
                                      for s in scenes])
        agent_valid[:, :t_hist] = True
        batch = {
            "map_feats": jnp.asarray(rep([s["map_feats"] for s in scenes])),
            "map_pose": jnp.asarray(rep([s["map_pose"] for s in scenes])),
            "map_valid": jnp.asarray(rep([s["map_valid"] for s in scenes])),
            "agent_feats": jnp.asarray(agent_feats),
            "agent_pose": jnp.asarray(agent_pose),
            "agent_valid": jnp.asarray(agent_valid),
        }
        pose = batch["agent_pose"][:, t_hist - 1]
        speed = batch["agent_feats"][:, t_hist - 1, :, 0] * 10.0
        feats_proto = batch["agent_feats"][:, t_hist - 1]
        out = []
        for t in range(t_hist, t_total):
            batch, pose, speed, _ = self._step(
                self.params, batch, pose, speed, feats_proto, keys,
                jnp.asarray(t, jnp.int32))
            self.ticks += 1
            out.append(pose)
        fut = np.asarray(jnp.stack(out, axis=1))
        return fut.reshape(n_scenes, n_samples, t_total - t_hist, a, 3)


def _score_bytes(b, h, sq, sk):
    """Analytic f32 attention-score footprint of one layer's (Sq, Sk)."""
    return 4 * b * h * sq * sk


def _timed(fn, *args, reps=1, **kwargs):
    """Best-of-``reps`` wall time after a compile/warm-up run (best-of
    absorbs GC pauses and CPU steal on shared CI runners)."""
    out = fn(*args, **kwargs)        # warm-up: compile + first run
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(report, *, num_agents=16, num_steps=64, num_map=16, n_scenes=4,
        n_samples=2, encoding="se2_fourier", seed=0, min_speedup=None,
        reps=1):
    scen = scenarios.ScenarioConfig(num_map=num_map, num_agents=num_agents,
                                    num_steps=num_steps)
    cfg, model, params = build(scen, encoding=encoding)
    scenes = [scenarios.generate_scene(777, i, scen) for i in range(n_scenes)]
    t_hist = max(1, num_steps // 8)
    lanes = n_scenes * n_samples
    n_fut = num_steps - t_hist
    s_max = num_map + num_steps * num_agents

    base = RecomputeRollout(model, params, scen)
    fut_base, dt_base = _timed(base.run, scenes, t_hist=t_hist,
                               n_samples=n_samples, seed=seed, reps=reps)
    eng = RolloutEngine(model, params, scen, num_slots=lanes)
    fut_cached, dt_cached = _timed(eng.run, scenes, t_hist=t_hist,
                                   n_samples=n_samples, seed=seed, reps=reps)
    assert np.isfinite(fut_cached).all() and np.isfinite(fut_base).all()

    sps_base = n_fut / dt_base
    sps_cached = n_fut / dt_cached
    speedup = sps_cached / sps_base
    ck, cv = model.attn.cache_dims
    cache_bytes = (cfg.num_layers * lanes * cfg.num_heads * s_max * (ck + cv)
                   * jnp.dtype(cfg.compute_dtype).itemsize)
    mem_base = _score_bytes(lanes, cfg.num_heads, s_max, s_max)
    mem_cached = _score_bytes(lanes, cfg.num_heads, num_agents, s_max)
    report(f"rollout/{encoding}/recompute_steps_per_s", f"{sps_base:.2f}",
           f"lanes={lanes} agents={num_agents} T={num_steps}")
    report(f"rollout/{encoding}/cached_steps_per_s", f"{sps_cached:.2f}",
           f"lanes={lanes} agents={num_agents} T={num_steps}")
    report(f"rollout/{encoding}/speedup", f"{speedup:.2f}")
    report(f"rollout/{encoding}/score_mem_recompute_mib",
           f"{mem_base / 2**20:.1f}", "per-layer (Smax,Smax) f32 scores")
    report(f"rollout/{encoding}/score_mem_cached_mib",
           f"{mem_cached / 2**20:.1f}", "per-layer (A,Smax) f32 scores")
    report(f"rollout/{encoding}/kv_cache_mib", f"{cache_bytes / 2**20:.1f}",
           f"c={ck} cv={cv} dtype={cfg.dtype}")
    if min_speedup is not None and speedup < min_speedup:
        raise AssertionError(
            f"cached rollout speedup {speedup:.2f}x < required "
            f"{min_speedup:.1f}x")
    return speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny scene, asserts cached path wins")
    ap.add_argument("--agents", type=int, default=16)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--scenes", type=int, default=4)
    ap.add_argument("--samples", type=int, default=2)
    ap.add_argument("--encoding", default="se2_fourier")
    ap.add_argument("--min-speedup", type=float, default=None,
                    help="fail unless cached/recompute exceeds this")
    args = ap.parse_args()
    report = lambda name, val, extra="": print(f"{name},{val},{extra}",
                                               flush=True)
    if args.smoke:
        # big enough that the O(T^2)-vs-O(T) asymptotics, not dispatch
        # noise, decide the winner (S_max = 264 tokens), small enough for CI
        run(report, num_agents=8, num_steps=32, num_map=8, n_scenes=2,
            n_samples=2, encoding=args.encoding, min_speedup=1.2, reps=3)
    else:
        run(report, num_agents=args.agents, num_steps=args.steps,
            n_scenes=args.scenes, n_samples=args.samples,
            encoding=args.encoding, min_speedup=args.min_speedup)


if __name__ == "__main__":
    main()
