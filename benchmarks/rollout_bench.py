"""Closed-loop rollout throughput: ragged decode kernel vs generic paths.

Benchmarks the decode hot path three ways (see ``docs/rollout.md`` and
``docs/kernels.md``):

  * **recompute** — the O(T^2) full-scene forward per step (optional;
    the PR-2 baseline, kept for trajectory context and the smoke
    assertion that caching wins at all).
  * **generic cached** — the pre-decode-kernel path: incremental decode
    through the generic attention with ``kv_length`` folded into the
    mask. Scans the *whole preallocated* ``max_len`` cache every tick,
    so tick time grows with the overallocation factor.
  * **ragged cached** — ``kops.decode_attention(impl="auto")``: the
    split-K ragged decode kernel on TPU, its cursor-bounded XLA twin on
    CPU. Tick cost is O(live prefix) — flat in ``max_len`` at fixed
    cursor — and the cache may be stored in bf16 or int8 (per-row
    scales, dequantized in-kernel).

The sweep crosses cache overallocation (fill fraction) x cache dtype,
asserts the ragged path's tick time is flat in ``max_len`` (the
regression guard for the O(max_len) generic behavior) and that it beats
the generic cached path by ``min_speedup``, and writes the
machine-readable record to ``BENCH_rollout.json``.

All paths consume the identical per-(scene, sample) key stream
(``repro.runtime.rollout.rollout_keys``); numerical parity of the decode
impls is pinned separately in ``tests/test_decode.py``.

Default workload (the acceptance target): 16 agents x 64 steps, 8 lanes,
cache overallocated 4x. ``--smoke`` shrinks everything for CI and keeps
the assertions (with CI-noise-tolerant margins).

Run:  PYTHONPATH=src python benchmarks/rollout_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.data import scenarios
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.runtime.rollout import (RolloutEngine, rollout_keys,
                                   step_kinematics)

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "..", "BENCH_rollout.json")


def build(scen: scenarios.ScenarioConfig, encoding="se2_fourier",
          d_model=64, layers=2, heads=4, seed=0):
    cfg = AgentSimConfig(d_model=d_model, num_layers=layers, num_heads=heads,
                         head_dim=24, d_ff=4 * d_model,
                         num_actions=scen.num_actions, encoding=encoding,
                         fourier_terms=12, pos_scale=0.05)
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    return cfg, model, params


class RecomputeRollout:
    """The O(T^2) baseline: full-scene forward at every rollout step.

    Static shapes (future rows ride along masked invalid), so it compiles
    exactly once — this is the *fair* version of the naive loop; the
    original one re-jitted at every step because the sequence grew.
    """

    def __init__(self, model, params, scen: scenarios.ScenarioConfig):
        self.model = model
        self.params = params
        self.scen = scen
        self._accel = jnp.asarray(scen.accel_values(), jnp.float32)
        self._yaw = jnp.asarray(scen.yaw_values(), jnp.float32)
        self._step = jax.jit(self._step_impl)
        self.ticks = 0

    def _step_impl(self, params, batch, pose, speed, feats_proto, keys, t):
        logits_all, _ = self.model(params, batch)          # (B, T, A, K)
        logits = jax.lax.dynamic_index_in_dim(
            logits_all, t - 1, axis=1, keepdims=False)     # step t-1 tokens
        keys_t = jax.vmap(jax.random.fold_in, in_axes=(0, None))(keys, t)
        acts = jax.vmap(jax.random.categorical)(
            keys_t, logits.astype(jnp.float32))
        ai, yi = jnp.divmod(acts, self.scen.yaw_bins)
        pose, speed = step_kinematics(pose, speed, self._accel[ai],
                                      self._yaw[yi])
        feats = feats_proto.at[..., 0].set(speed / 10.0)
        batch = dict(batch)
        batch["agent_pose"] = batch["agent_pose"].at[:, t].set(pose)
        batch["agent_feats"] = batch["agent_feats"].at[:, t].set(feats)
        batch["agent_valid"] = batch["agent_valid"].at[:, t].set(True)
        return batch, pose, speed, acts

    def run(self, scenes, *, t_hist: int, n_samples: int, seed: int = 0,
            t_total=None):
        scen = self.scen
        t_total = t_total or scen.num_steps
        n_scenes = len(scenes)
        keys = rollout_keys(seed, n_scenes, n_samples)
        rep = lambda x: np.repeat(np.stack(x), n_samples, axis=0)
        b = n_scenes * n_samples
        a = scen.num_agents
        agent_feats = np.zeros((b, t_total, a, scen.agent_feat_dim),
                               np.float32)
        agent_pose = np.zeros((b, t_total, a, 3), np.float32)
        agent_valid = np.zeros((b, t_total, a), bool)
        agent_feats[:, :t_hist] = rep([s["agent_feats"][:t_hist]
                                       for s in scenes])
        agent_pose[:, :t_hist] = rep([s["agent_pose"][:t_hist]
                                      for s in scenes])
        agent_valid[:, :t_hist] = True
        batch = {
            "map_feats": jnp.asarray(rep([s["map_feats"] for s in scenes])),
            "map_pose": jnp.asarray(rep([s["map_pose"] for s in scenes])),
            "map_valid": jnp.asarray(rep([s["map_valid"] for s in scenes])),
            "agent_feats": jnp.asarray(agent_feats),
            "agent_pose": jnp.asarray(agent_pose),
            "agent_valid": jnp.asarray(agent_valid),
        }
        pose = batch["agent_pose"][:, t_hist - 1]
        speed = batch["agent_feats"][:, t_hist - 1, :, 0] * 10.0
        feats_proto = batch["agent_feats"][:, t_hist - 1]
        out = []
        for t in range(t_hist, t_total):
            batch, pose, speed, _ = self._step(
                self.params, batch, pose, speed, feats_proto, keys,
                jnp.asarray(t, jnp.int32))
            self.ticks += 1
            out.append(pose)
        fut = np.asarray(jnp.stack(out, axis=1))
        return fut.reshape(n_scenes, n_samples, t_total - t_hist, a, 3)


def _cache_mib(engine) -> float:
    """Cache footprint from shapes only — no device allocation."""
    shapes = jax.eval_shape(engine.init_cache)
    return sum(v.size * v.dtype.itemsize for v in jax.tree.leaves(shapes)) \
        / 2 ** 20


def _timed(fn, *args, reps=1, **kwargs):
    """Best-of-``reps`` wall time after a compile/warm-up run (best-of
    absorbs GC pauses and CPU steal on shared CI runners)."""
    out = fn(*args, **kwargs)        # warm-up: compile + first run
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(report, *, num_agents=16, num_steps=64, num_map=16, n_scenes=4,
        n_samples=2, encoding="se2_fourier", seed=0, reps=1, overalloc=4,
        min_speedup=None, max_flat_dev=None, with_recompute=False,
        smoke=False, out=None):
    scen = scenarios.ScenarioConfig(num_map=num_map, num_agents=num_agents,
                                    num_steps=num_steps)
    cfg, model, params = build(scen, encoding=encoding)
    scenes = [scenarios.generate_scene(777, i, scen) for i in range(n_scenes)]
    t_hist = max(1, num_steps // 8)
    lanes = n_scenes * n_samples
    n_fut = num_steps - t_hist
    live_len = num_map + num_steps * num_agents
    max_len = overalloc * live_len
    rec = {"encoding": encoding, "num_agents": num_agents,
           "num_steps": num_steps, "num_map": num_map, "lanes": lanes,
           "t_hist": t_hist, "live_len": live_len, "overalloc": overalloc,
           "reps": reps, "backend": jax.default_backend(), "paths": {}}

    def bench_engine(decode_impl, cache_dtype, ml):
        # per-engine registry: the engine's own rollout.step spans give a
        # per-tick latency distribution the aggregate steps/s (best-of
        # wall over whole runs) can't — p50 lands in the record below
        reg = obs.Registry()
        eng = RolloutEngine(model, params, scen, num_slots=lanes, max_len=ml,
                            cache_dtype=cache_dtype, decode_impl=decode_impl,
                            registry=reg)
        fut, dt = _timed(eng.run, scenes, t_hist=t_hist, n_samples=n_samples,
                         seed=seed, reps=reps)
        assert np.isfinite(fut).all()
        # eng.max_len is the length actually allocated (the engine rounds
        # up to the decode kernel's 128-row block alignment)
        return fut, n_fut / dt, _cache_mib(eng), eng.max_len, reg

    def _step_p50_ms(reg):
        return 1e3 * reg.histogram("rollout.step.seconds").percentile(50)

    # -- the headline comparison at the overallocated cache size ----------
    fut_gen, sps_gen, mib_gen, alloc_len, reg_gen = \
        bench_engine(None, None, max_len)
    fut_new, sps_new, mib_new, _, reg_new = bench_engine("auto", None,
                                                         max_len)
    rec["max_len"] = alloc_len
    speedup = sps_new / sps_gen
    report(f"rollout/{encoding}/generic_cached_steps_per_s", f"{sps_gen:.2f}",
           f"kv_length-masked {cfg.attn_impl}; scans max_len={alloc_len}")
    report(f"rollout/{encoding}/ragged_cached_steps_per_s", f"{sps_new:.2f}",
           f"decode_attention auto; lanes={lanes} agents={num_agents}")
    report(f"rollout/{encoding}/decode_speedup", f"{speedup:.2f}",
           f"ragged vs generic at overalloc={overalloc}")
    rec["paths"]["generic_cached"] = {"steps_per_s": sps_gen,
                                      "cache_mib": mib_gen,
                                      "step_p50_ms": _step_p50_ms(reg_gen)}
    rec["paths"]["ragged_f32"] = {"steps_per_s": sps_new,
                                  "cache_mib": mib_new,
                                  "step_p50_ms": _step_p50_ms(reg_new)}
    rec["decode_speedup"] = speedup
    # the two paths compute the same attention up to f32 summation order;
    # logits-level parity is pinned in tests/test_decode.py — here just
    # record how far the sampled trajectories drift (0.0 unless a
    # roundoff-level logit difference flips a categorical draw)
    gen_drift = float(np.abs(fut_gen - fut_new).mean())
    report(f"rollout/{encoding}/ragged_vs_generic_traj_drift_m",
           f"{gen_drift:.4f}")
    rec["ragged_vs_generic_traj_drift_m"] = gen_drift

    # -- cache dtype sweep (accuracy-vs-memory table in docs/rollout.md) --
    for dtype in ("bfloat16", "int8"):
        fut_d, sps_d, mib_d, _, reg_d = bench_engine("auto", dtype, max_len)
        drift = float(np.abs(fut_d - fut_new).mean())
        report(f"rollout/{encoding}/ragged_{dtype}_steps_per_s",
               f"{sps_d:.2f}", f"cache={mib_d:.1f}MiB")
        report(f"rollout/{encoding}/ragged_{dtype}_traj_drift_m",
               f"{drift:.4f}", "mean |pose - f32-cache pose| over rollout")
        rec["paths"][f"ragged_{dtype}"] = {
            "steps_per_s": sps_d, "cache_mib": mib_d,
            "traj_drift_m": drift,
            "step_p50_ms": _step_p50_ms(reg_d)}

    # -- flatness in max_len at fixed cursor (the ragged-scan guarantee) --
    flat = {overalloc: (sps_new, alloc_len)}   # headline: already measured
    for m in sorted({1, 2, overalloc} - {overalloc}):
        _, sps_m, _, alloc_m, _ = bench_engine("auto", None, m * live_len)
        flat[m] = (sps_m, alloc_m)
    for m in sorted(flat):
        report(f"rollout/{encoding}/ragged_steps_per_s_overalloc{m}",
               f"{flat[m][0]:.2f}", f"max_len={flat[m][1]}")
    flat_sps = {m: v[0] for m, v in flat.items()}
    flat_dev = max(abs(s - flat_sps[1]) / flat_sps[1]
                   for s in flat_sps.values())
    report(f"rollout/{encoding}/ragged_flatness_dev", f"{flat_dev:.3f}",
           "max relative tick-rate deviation across overalloc sweep")
    rec["flatness"] = {"steps_per_s_by_overalloc": flat_sps,
                       "max_len_by_overalloc": {m: v[1]
                                                for m, v in flat.items()},
                       "max_rel_dev": flat_dev}

    # -- optional O(T^2) recompute baseline -------------------------------
    if with_recompute or smoke:
        base = RecomputeRollout(model, params, scen)
        fut_base, dt_base = _timed(base.run, scenes, t_hist=t_hist,
                                   n_samples=n_samples, seed=seed, reps=reps)
        assert np.isfinite(fut_base).all()
        sps_base = n_fut / dt_base
        report(f"rollout/{encoding}/recompute_steps_per_s", f"{sps_base:.2f}")
        report(f"rollout/{encoding}/cached_vs_recompute",
               f"{sps_new / sps_base:.2f}")
        rec["paths"]["recompute"] = {"steps_per_s": sps_base}
        if smoke and sps_new < 1.2 * sps_base:
            raise AssertionError(
                f"cached rollout ({sps_new:.2f} steps/s) did not beat "
                f"recompute ({sps_base:.2f} steps/s)")

    out_path = os.path.abspath(out or DEFAULT_OUT)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    report(f"rollout/{encoding}/out", out_path)

    if min_speedup is not None and speedup < min_speedup:
        raise AssertionError(
            f"ragged decode speedup {speedup:.2f}x < required "
            f"{min_speedup:.1f}x vs the generic cached path")
    if max_flat_dev is not None and flat_dev > max_flat_dev:
        raise AssertionError(
            f"ragged tick rate varied {flat_dev:.2f} across max_len at "
            f"fixed cursor (> {max_flat_dev:.2f}): decode is not O(live)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny scene, keeps all assertions")
    ap.add_argument("--agents", type=int, default=16)
    ap.add_argument("--steps", type=int, default=64)
    ap.add_argument("--scenes", type=int, default=4)
    ap.add_argument("--samples", type=int, default=2)
    ap.add_argument("--overalloc", type=int, default=4,
                    help="cache max_len as a multiple of the live length")
    ap.add_argument("--encoding", default="se2_fourier")
    ap.add_argument("--reps", type=int, default=2)
    ap.add_argument("--min-speedup", type=float, default=2.0,
                    help="fail unless ragged/generic exceeds this")
    ap.add_argument("--max-flat-dev", type=float, default=0.2,
                    help="max relative tick-rate deviation across max_len")
    ap.add_argument("--with-recompute", action="store_true",
                    help="also time the O(T^2) full-recompute baseline")
    ap.add_argument("--out", default=None,
                    help=f"JSON output path (default {DEFAULT_OUT})")
    args = ap.parse_args()
    report = lambda name, val, extra="": print(f"{name},{val},{extra}",
                                               flush=True)
    if args.smoke:
        # big enough that the O(max_len)-vs-O(cursor) asymptotics, not
        # dispatch noise, decide the winner; small enough for CI. Margins
        # are looser than the acceptance run: CI runners are noisy.
        # Smoke-sized records default to /tmp so they never clobber the
        # committed full-size BENCH_rollout.json perf-trajectory record.
        run(report, num_agents=8, num_steps=32, num_map=8, n_scenes=2,
            n_samples=2, encoding=args.encoding, overalloc=4, reps=3,
            min_speedup=1.2, max_flat_dev=0.5, smoke=True,
            out=args.out or "/tmp/BENCH_rollout_smoke.json")
    else:
        run(report, num_agents=args.agents, num_steps=args.steps,
            n_scenes=args.scenes, n_samples=args.samples,
            encoding=args.encoding, overalloc=args.overalloc, reps=args.reps,
            min_speedup=args.min_speedup, max_flat_dev=args.max_flat_dev,
            with_recompute=args.with_recompute, out=args.out)


if __name__ == "__main__":
    main()
