"""Beyond-paper optimization: scale-adaptive Fourier basis truncation.

The paper gives every feature block the same basis size F regardless of its
spatial scale a_b. But the approximated target ``cos(a_b * u(theta))`` has
Jacobi-Anger bandwidth ~ a_b * r_max, so the low-scale blocks are
over-resolved: a block at a_b = 0.25 needs ~1/4 the terms of the a_b = 1
block for the same error. Adaptive truncation (F_b = F * a_b / a_max,
floored) shrinks the expanded feature dim c = sum(4F_b + 2) — and with it
every q~/k~/v~ HBM byte and every attention-score MXU FLOP, which scale
linearly in c.

This benchmark measures, at the paper's operating point (F=18, scales
0.25..1, r<=4):
  * expanded dim (uniform vs adaptive) -> attention cost ratio,
  * worst-block spectral approximation error (must not regress),
  * end-to-end Alg.2-vs-Alg.1 attention deviation (must not regress).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import attention, encodings


def make_pair(head_dim=24, num_terms=18):
    uni = encodings.SE2Fourier(head_dim=head_dim, num_terms=num_terms,
                               min_scale=0.25, max_scale=1.0)
    ada = encodings.SE2Fourier(head_dim=head_dim, num_terms=num_terms,
                               min_scale=0.25, max_scale=1.0,
                               adaptive_terms=True, min_terms=6)
    return uni, ada


def e2e_error(enc, n=24, radius=3.5, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(n, enc.head_dim)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(n, enc.head_dim)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n, enc.head_dim)), jnp.float32)
    pq = jnp.asarray(np.concatenate(
        [rng.uniform(-radius, radius, (n, 2)),
         rng.uniform(-np.pi, np.pi, (n, 1))], -1), jnp.float32)
    pk = jnp.asarray(np.concatenate(
        [rng.uniform(-radius, radius, (n, 2)),
         rng.uniform(-np.pi, np.pi, (n, 1))], -1), jnp.float32)
    a = attention.relative_attention_linear(enc, q, k, v, pq, pk)
    b = attention.relative_attention_quadratic(enc, q, k, v, pq, pk)
    return float(jnp.max(jnp.abs(a - b)))


def run(report):
    uni, ada = make_pair()
    report("adaptive/uniform_expanded_dim", uni.expanded_dim,
           f"blocks F={uni.block_terms()}")
    report("adaptive/adaptive_expanded_dim", ada.expanded_dim,
           f"blocks F={ada.block_terms()}")
    ratio = ada.expanded_dim / uni.expanded_dim
    report("adaptive/attention_cost_ratio", round(ratio, 3),
           "q~k~ MXU flops + q~/k~/v~ bytes scale ~linearly in c")
    err_u = e2e_error(uni)
    err_a = e2e_error(ada)
    report("adaptive/e2e_err_uniform", err_u)
    report("adaptive/e2e_err_adaptive", err_a)
    # >= 25% attention-cost reduction with error still under bf16 epsilon
    # (the paper's own "approximation <= 16-bit noise" acceptance bar)
    assert ratio < 0.78, ratio
    assert err_a < 7.8e-3, err_a              # bf16 eps
    report("adaptive/error_still_below_bf16_eps", 1.0,
           f"{err_a:.2e} < 7.8e-3")


if __name__ == "__main__":
    run(lambda name, val, extra="": print(f"{name},{val},{extra}"))
