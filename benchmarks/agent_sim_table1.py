"""Paper Table I proxy: agent-simulation NLL + minADE by encoding.

Trains the same small scene transformer with the four relative-attention
mechanisms (absolute / rope2d / se2_repr / se2_fourier) on the synthetic
scenario stream, then rolls out 16 sampled futures per scene and reports
minADE split by ground-truth behavior (stationary / straight / turning).

CPU-sized by default (--steps 300, d_model 64); the config scales to the
paper's setup by flags. The expected qualitative result matches Table I:
relative encodings beat absolute positions, and se2_fourier is strongest
on turning scenes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import scenarios
from repro.nn import module as nnm
from repro.nn.agent_sim import (AgentSimConfig, AgentSimModel, action_nll)
from repro.optim import adamw, chain, clip_by_global_norm
from repro.optim.transforms import apply_updates

SCEN = scenarios.ScenarioConfig(num_map=16, num_agents=6, num_steps=12)


def make_batch(seed, idx, bs):
    b = scenarios.generate_batch(seed, idx, bs, SCEN)
    return {k: jnp.asarray(v) for k, v in b.items()}


def build(encoding: str, d_model=64, layers=2, heads=4, steps=300,
          batch=8, lr=3e-3, seed=0, fourier_terms=12):
    cfg = AgentSimConfig(d_model=d_model, num_layers=layers, num_heads=heads,
                         head_dim=24, d_ff=4 * d_model,
                         num_actions=SCEN.num_actions,
                         encoding=encoding, fourier_terms=fourier_terms,
                         pos_scale=0.05)
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    opt = chain(clip_by_global_norm(1.0), adamw(lr))
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        def loss_fn(p):
            logits, _ = model(p, batch)
            return action_nll(logits, batch["actions"], batch["agent_valid"])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        upd, opt_state2 = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state2, loss

    t0 = time.time()
    losses = []
    for i in range(steps):
        batch_i = make_batch(seed, i * batch, batch)
        params, opt_state, loss = step(params, opt_state, batch_i)
        losses.append(float(loss))
    train_time = time.time() - t0

    # eval NLL on held-out scenes
    eval_batches = [make_batch(10_000 + seed, i * batch, batch)
                    for i in range(4)]
    eval_fn = jax.jit(lambda p, b: action_nll(model(p, b)[0], b["actions"],
                                              b["agent_valid"]))
    nll = float(np.mean([float(eval_fn(params, b)) for b in eval_batches]))
    return cfg, model, params, nll, losses, train_time


def rollout_minade(cfg, model, params, n_scenes=8, n_samples=16, seed=123,
                   num_slots=32):
    """Sample futures from half-history via the cached rollout engine.

    Runs the incremental-decode :class:`repro.runtime.RolloutEngine` —
    O(T) attention per simulation step against the per-layer K/V cache
    instead of re-running the full scene forward (O(T^2)) at every step.

    Sampling is keyed per (scene, sample) (``rollout_keys``), not from one
    shared host RNG stream, so the reported metrics are bit-reproducible
    under any slot count, chunking, or parallel execution order.
    """
    from repro.runtime.rollout import RolloutEngine

    t_hist = SCEN.num_steps // 2
    scenes = [scenarios.generate_scene(777, si, SCEN)
              for si in range(n_scenes)]
    engine = RolloutEngine(model, params, SCEN,
                           num_slots=min(num_slots, n_scenes * n_samples))
    futures = engine.run(scenes, t_hist=t_hist, n_samples=n_samples,
                         seed=seed)                  # (S, K, T_fut, A, 3)
    per_cat = {"stationary": [], "straight": [], "turning": []}
    for si, scene in enumerate(scenes):
        m = scenarios.rollout_metrics(
            SCEN, scene["agent_pose"][t_hist:], futures[si],
            scene["behavior"],
            agent_valid=scene["agent_valid"][t_hist:])
        for k, v in m.items():
            if np.isfinite(v):
                per_cat[k].append(v)
    return {k: (float(np.mean(v)) if v else float("nan"))
            for k, v in per_cat.items()}


def run(report, steps=200, with_rollouts=False):
    results = {}
    for enc in ("absolute", "rope2d", "se2_repr", "se2_fourier"):
        cfg, model, params, nll, losses, tt = build(enc, steps=steps)
        results[enc] = (cfg, model, params, nll)
        report(f"table1/{enc}/nll", nll, f"train_s={tt:.1f}")
        if with_rollouts:
            m = rollout_minade(cfg, model, params)
            for cat, v in m.items():
                report(f"table1/{enc}/minade_{cat}", v)
    # qualitative Table-I ordering: relative encodings beat absolute
    rel_best = min(results[e][3] for e in ("rope2d", "se2_repr",
                                           "se2_fourier"))
    report("table1/relative_beats_absolute",
           float(rel_best <= results["absolute"][3] + 0.02))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--rollouts", action="store_true")
    args = ap.parse_args()
    run(lambda name, val, extra="": print(f"{name},{val},{extra}"),
        steps=args.steps, with_rollouts=args.rollouts)


if __name__ == "__main__":
    main()
