"""Paper Fig. 3: spectral-norm approximation error vs radius and basis size.

Samples key positions uniformly on circles of fixed radius and query
headings uniformly in [0, 2pi); reports mean / 2.5% / 97.5% of
``|| phi(p_rel) - phi_q(p_n) phi_k(p_m) ||_2`` in float32, plus the bf16/fp16
epsilon reference lines from the paper.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import encodings, se2

FP16_EPS = 2.0 ** -10
BF16_EPS = 2.0 ** -7


def spectral_error(radius: float, num_terms: int, n_samples: int = 512,
                   seed: int = 0):
    """Error of the single-block (head_dim=6, scale=1) encoding."""
    enc = encodings.SE2Fourier(head_dim=6, num_terms=num_terms,
                               min_scale=1.0, max_scale=1.0)
    rng = np.random.default_rng(seed)
    ang = rng.uniform(0, 2 * np.pi, n_samples)
    pk = np.stack([radius * np.cos(ang), radius * np.sin(ang),
                   rng.uniform(0, 2 * np.pi, n_samples)], -1).astype(np.float32)
    pq = np.zeros((n_samples, 3), np.float32)
    pq[:, 2] = rng.uniform(0, 2 * np.pi, n_samples)
    pq, pk = jnp.asarray(pq), jnp.asarray(pk)

    # build the 6x6 matrices column by column via the factorized transforms
    eye = jnp.eye(6, dtype=jnp.float32)
    # phi_q(p_n) phi_k(p_m): (6, c) x (c, 6) assembled from basis vectors
    qt = enc.transform_q(jnp.broadcast_to(eye[None], (n_samples, 6, 6)),
                         pq[:, None, :])        # (N, 6, c) rows of phi_q^T
    kt = enc.transform_k(jnp.broadcast_to(eye[None], (n_samples, 6, 6)),
                         pk[:, None, :])        # (N, 6, c) cols of phi_k
    approx = jnp.einsum("nic,njc->nij", qt, kt)  # (N, 6, 6) matrices
    rel = se2.relative(pq, pk)
    # apply_phi(e_j) returns phi's columns; transpose into matrices
    exact_cols = enc.apply_phi(rel[:, None, :],
                               jnp.broadcast_to(eye[None], (n_samples, 6, 6)))
    exact = jnp.swapaxes(exact_cols, 1, 2)
    diff = np.asarray(exact - approx)
    errs = np.linalg.norm(diff, ord=2, axis=(1, 2))
    return {"mean": float(errs.mean()),
            "p2_5": float(np.percentile(errs, 2.5)),
            "p97_5": float(np.percentile(errs, 97.5))}


def run(report):
    # paper's headline operating points first
    for radius, terms in ((2.0, 12), (4.0, 18), (8.0, 28)):
        r = spectral_error(radius, terms)
        report(f"fig3/radius{radius:g}_F{terms}", r["mean"],
               f"p97.5={r['p97_5']:.2e} bf16eps={BF16_EPS:.1e}")
        assert r["mean"] < 6e-3, (radius, terms, r)
    # error-vs-F sweep at radius 4 (paper Fig. 4 trend)
    for terms in (6, 10, 14, 18, 24, 32):
        r = spectral_error(4.0, terms)
        report(f"fig3/sweep_radius4_F{terms}", r["mean"],
               f"p97.5={r['p97_5']:.2e}")


if __name__ == "__main__":
    run(lambda name, val, extra="": print(f"{name},{val},{extra}"))
