"""Continuous-batching sim-serving throughput under Poisson arrivals.

Drives a :class:`repro.runtime.SimServer` with an open-loop Poisson
arrival stream (the traffic model serving systems are sized against — a
"heavy traffic from millions of users" proxy at bench scale) and
records, per slot count:

  * **sustained scenes/s** — drained scenes over post-compile wall time,
    admissions interleaving with mid-flight scenes the whole way;
  * **p50/p99 tick latency** — per-``tick()`` wall time (device dispatch
    + the pipelined drain of tick t-``drain_lag``'s outputs);
  * **slab accounting** — one shared ``(L, slots, H, slab, ·)`` cache,
    MiB and peak row occupancy, vs the sum of per-scene caches a
    no-slab design would allocate.

Every lane is keyed exactly like ``RolloutEngine.run`` lane (i, 0), so
the bench double-checks the isolation contract for free: per-scene
futures under Poisson churn must bit-match the engine's batch eval
(asserted in --smoke, where CI runs it; recorded always).

Writes ``BENCH_serve.json`` (repo root; --smoke writes to /tmp so CI
never clobbers the committed record).

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import jax
import numpy as np

from benchmarks.rollout_bench import build
from repro import obs
from repro.runtime.rollout import RolloutEngine
from repro.runtime.sim_server import SceneRequest, SimServer, poisson_drive
from repro.scenarios import ScenarioConfig
from repro.scenarios.registry import generate_mixed

DEFAULT_OUT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "..", "BENCH_serve.json")
WARM_TICKS = 2        # first ticks carry the tick + admit compilations


def _nearest_rank(sorted_vals, q):
    """The histogram's own rank definition on exact samples — so the
    sketch-vs-exact comparison isolates bucketing error alone."""
    n = len(sorted_vals)
    return sorted_vals[max(1, math.ceil(q / 100.0 * n)) - 1]


def _drive_one(model, params, scen, scenes, *, num_slots, rate, t_hist,
               cache_dtype, seed, registry):
    """One Poisson drive; per-tick latency comes from the shared
    ``repro.obs`` log-bucket histogram (``poisson_drive``'s return), not
    a hand-rolled list. When ``registry`` is enabled, the sketch's
    percentiles are cross-checked against the exact per-tick durations
    the registry's trace spans recorded."""
    srv = SimServer(model, params, scen, num_slots=num_slots,
                    cache_dtype=cache_dtype, registry=registry)
    reqs = [SceneRequest(uid=i, tensors=s, t_hist=t_hist, seed=seed,
                        scene_id=i) for i, s in enumerate(scenes)]
    t0 = time.perf_counter()
    drive = poisson_drive(srv, reqs, rate=rate, seed=seed,
                          warmup_ticks=WARM_TICKS)
    wall_total = time.perf_counter() - t0
    hist = drive["latency"]
    assert len(srv.done) == len(scenes), "requests lost under churn"
    stats = srv.stats()
    assert stats["tick_compilations"] == 1, "tick recompiled"
    assert stats["admit_compilations"] == 1, "admission recompiled"
    p50, p99 = hist.percentile(50), hist.percentile(99)
    if registry.enabled:
        # sketch-vs-exact: every working tick landed BOTH as an exact
        # span duration and as one sample of the registry's tick
        # histogram (same t0/t1), so the sketch's percentiles must agree
        # with nearest-rank on the exact durations to within its
        # documented bucket error — no measurement skew in the loop
        reg_hist = registry.histogram("sim_server.tick.seconds")
        exact = sorted(e["dur"] / 1e6 for e in registry.events()
                       if e.get("ph") == "X"
                       and e["name"] == "sim_server.tick")
        assert len(exact) == reg_hist.count, \
            "span stream / histogram diverged"
        for q in (50, 99):
            got, want = reg_hist.percentile(q), _nearest_rank(exact, q)
            tol = 2 * reg_hist.max_rel_error + 1e-9
            assert abs(got / want - 1) <= tol, (
                f"histogram p{q} {got:.6f}s vs exact {want:.6f}s: "
                f"off by more than the sketch's {tol:.3%} bound")
    return srv, {
        "num_slots": num_slots,
        "rate_per_tick": rate,
        "ticks": int(stats["ticks"]),
        "wall_s": wall_total,
        "scenes_per_s": len(scenes) / max(hist.sum, 1e-9),
        "tick_p50_ms": 1e3 * p50,
        "tick_p99_ms": 1e3 * p99,
        "slab_mib": stats["slab_mib"],
        "slab_rows": int(stats["slab_rows"]),
    }


def run(report, *, slot_counts=(4, 8), n_scenes=16, num_map=16,
        num_agents=8, num_steps=32, rate=1.0, encoding="se2_fourier",
        cache_dtype=None, seed=0, smoke=False, out=None,
        overhead_tol=0.03, overhead_reps=3):
    scen = ScenarioConfig(num_map=num_map, num_agents=num_agents,
                          num_steps=num_steps)
    _, model, params = build(scen, encoding=encoding)
    scenes = generate_mixed(seed, 0, n_scenes, scen)
    t_hist = max(1, num_steps // 8)
    rec = {"encoding": encoding, "n_scenes": n_scenes, "num_map": num_map,
           "num_agents": num_agents, "num_steps": num_steps,
           "t_hist": t_hist, "rate_per_tick": rate,
           "cache_dtype": str(cache_dtype), "backend": jax.default_backend(),
           "slot_counts": {}}

    # batch-eval reference: the same lanes, keyed identically, run
    # start-to-finish in lockstep by the engine
    eng = RolloutEngine(model, params, scen, num_slots=min(slot_counts),
                        cache_dtype=cache_dtype)
    ref = eng.run(scenes, t_hist=t_hist, n_samples=1, seed=seed)

    for ns in slot_counts:
        # telemetry-off reference: same workload against obs.NULL — the
        # zero-sync claim is a measured number, not a design note. Each
        # mode is driven best-of-N on p50: single drives on a shared
        # host carry hundreds of µs of scheduler/frequency noise, an
        # order of magnitude above the ~10 µs the instruments cost
        row_off = srv = row = reg = None
        for _ in range(overhead_reps):
            _, r = _drive_one(model, params, scen, scenes, num_slots=ns,
                              rate=rate, t_hist=t_hist,
                              cache_dtype=cache_dtype, seed=seed,
                              registry=obs.NULL)
            if row_off is None or r["tick_p50_ms"] < row_off["tick_p50_ms"]:
                row_off = r
        for _ in range(overhead_reps):
            g = obs.Registry()
            s, r = _drive_one(model, params, scen, scenes, num_slots=ns,
                              rate=rate, t_hist=t_hist,
                              cache_dtype=cache_dtype, seed=seed,
                              registry=g)
            if row is None or r["tick_p50_ms"] < row["tick_p50_ms"]:
                srv, row, reg = s, r, g
        got = np.stack([srv.done[i].future for i in range(n_scenes)])
        parity = bool(np.array_equal(got, ref[:, 0]))
        row["parity_vs_batch_eval"] = parity
        # what the slab saves: a no-slab design allocates one full-length
        # cache per admitted scene instead of num_slots resident ones
        row["no_slab_mib"] = row["slab_mib"] / ns * n_scenes
        row["tick_p50_off_ms"] = row_off["tick_p50_ms"]
        overhead = row["tick_p50_ms"] / row_off["tick_p50_ms"] - 1.0
        row["telemetry_overhead_p50"] = overhead
        row["queue_wait_p50_ms"] = 1e3 * reg.histogram(
            "sim_server.queue_wait.seconds").percentile(50)
        row["first_action_p50_ms"] = 1e3 * reg.histogram(
            "sim_server.first_action.seconds").percentile(50)
        rec["slot_counts"][ns] = row
        report(f"serve/{encoding}/slots{ns}/scenes_per_s",
               f"{row['scenes_per_s']:.2f}",
               f"poisson rate={rate}/tick, {n_scenes} scenes")
        report(f"serve/{encoding}/slots{ns}/tick_p50_ms",
               f"{row['tick_p50_ms']:.2f}")
        report(f"serve/{encoding}/slots{ns}/tick_p99_ms",
               f"{row['tick_p99_ms']:.2f}", "post-compile ticks")
        report(f"serve/{encoding}/slots{ns}/slab_mib",
               f"{row['slab_mib']:.1f}",
               f"vs {row['no_slab_mib']:.1f} MiB unshared")
        report(f"serve/{encoding}/slots{ns}/parity_vs_batch_eval",
               int(parity), "per-scene futures bit-match RolloutEngine")
        report(f"serve/{encoding}/slots{ns}/telemetry_overhead_p50",
               f"{overhead:.4f}",
               f"p50 on/off - 1; tolerance {overhead_tol:.2f}")
        assert overhead <= overhead_tol, (
            f"slots={ns}: telemetry added {overhead:.2%} to p50 tick "
            f"latency (> {overhead_tol:.0%}): instruments are not cheap "
            "enough for the hot loop")
        if smoke:
            assert row["scenes_per_s"] > 0, "no sustained throughput"
            assert np.isfinite(row["tick_p99_ms"]), "p99 not finite"
            assert parity, (
                f"slots={ns}: served futures diverged from batch eval — "
                "slot isolation broke under Poisson churn")

    out_path = os.path.abspath(out or DEFAULT_OUT)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=2)
    report(f"serve/{encoding}/out", out_path)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: tiny scenes, keeps all assertions")
    ap.add_argument("--slots", type=int, nargs="+", default=[4, 8])
    ap.add_argument("--scenes", type=int, default=16)
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--map", type=int, dest="num_map", default=16)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="mean Poisson arrivals per service tick")
    ap.add_argument("--encoding", default="se2_fourier")
    ap.add_argument("--cache-dtype", default=None)
    ap.add_argument("--out", default=None,
                    help=f"JSON output path (default {DEFAULT_OUT})")
    args = ap.parse_args()
    report = lambda name, val, extra="": print(f"{name},{val},{extra}",
                                               flush=True)
    if args.smoke:
        # small enough for CI, big enough that scenes outnumber slots and
        # every slot recycles; smoke records go to /tmp so they never
        # clobber the committed BENCH_serve.json perf-trajectory record
        # overhead tolerance is loose in smoke: two tiny drives moments
        # apart on a shared CI runner measure scheduler noise as much as
        # instrument cost; the 3% acceptance bound is the full run's
        run(report, slot_counts=(2, 4), n_scenes=8, num_map=8,
            num_agents=4, num_steps=12, rate=1.0, smoke=True,
            overhead_tol=0.50, overhead_reps=1,
            out=args.out or "/tmp/BENCH_serve_smoke.json")
    else:
        run(report, slot_counts=tuple(args.slots), n_scenes=args.scenes,
            num_map=args.num_map, num_agents=args.agents,
            num_steps=args.steps, rate=args.rate, encoding=args.encoding,
            cache_dtype=args.cache_dtype, out=args.out)


if __name__ == "__main__":
    main()
