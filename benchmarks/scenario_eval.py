"""Closed-loop per-family scenario evaluation (the paper's driving
workloads, scenario-diverse).

Trains a small agent-sim model on a mixed-family scenario stream (every
registered family interleaved deterministically), then rolls out sampled
futures closed-loop through the cached :class:`RolloutEngine` and reports
per-family minADE, miss rate, collision rate, off-road rate, and
kinematic-infeasibility rate — the evaluation surface GoRela-style
lane-graph benchmarks use, on our procedural families.

``--smoke`` skips training (metrics of an untrained model are still
well-defined; the run proves every family generates, batches, rolls out,
and scores end-to-end) and asserts structural health: all families
present, all metrics finite, rollouts kinematically feasible.

Run:  PYTHONPATH=src python benchmarks/scenario_eval.py [--smoke]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import scenarios
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel, action_nll
from repro.optim import adamw, chain, clip_by_global_norm
from repro.optim.transforms import apply_updates
from repro.runtime.evaluation import (EvalConfig, METRICS,
                                      evaluate_families)


def build(scen: scenarios.ScenarioConfig, encoding="se2_fourier",
          d_model=64, layers=2, heads=4, seed=0):
    cfg = AgentSimConfig(d_model=d_model, num_layers=layers, num_heads=heads,
                         head_dim=24, d_ff=4 * d_model,
                         num_actions=scen.num_actions, encoding=encoding,
                         fourier_terms=12, pos_scale=0.05)
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    return cfg, model, params


def train(model, params, scen, *, steps, batch, seed=0, lr=3e-3):
    """Short mixed-family training run (next-action NLL)."""
    import jax.numpy as jnp

    opt = chain(clip_by_global_norm(1.0), adamw(lr))
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, b):
        def loss_fn(p):
            logits, _ = model(p, b)
            return action_nll(logits, b["actions"], b["agent_valid"])
        loss, grads = jax.value_and_grad(loss_fn)(params)
        upd, opt_state2 = opt.update(grads, opt_state, params)
        return apply_updates(params, upd), opt_state2, loss

    loss = float("nan")
    for i in range(steps):
        b = scenarios.generate_mixed_batch(seed, i * batch, batch, scen)
        b = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, loss = step(params, opt_state, b)
        loss = float(loss)
    return params, loss


def run(report, *, train_steps=150, batch=8, encoding="se2_fourier",
        num_map=24, num_agents=8, num_steps=16, n_scenes_per_family=4,
        n_samples=4, seed=0, smoke=False):
    scen = scenarios.ScenarioConfig(num_map=num_map, num_agents=num_agents,
                                    num_steps=num_steps)
    cfg, model, params = build(scen, encoding=encoding, seed=seed)
    if train_steps:
        t0 = time.time()
        params, loss = train(model, params, scen, steps=train_steps,
                             batch=batch, seed=seed)
        report("scenario_eval/train_nll", f"{loss:.4f}",
               f"steps={train_steps} train_s={time.time() - t0:.1f}")
    eval_cfg = EvalConfig(t_hist=max(1, num_steps // 2),
                          n_samples=n_samples, seed=seed + 1)
    t0 = time.time()
    results = evaluate_families(model, params, scen, eval_cfg,
                                n_scenes_per_family=n_scenes_per_family)
    report("scenario_eval/eval_s", f"{time.time() - t0:.1f}",
           f"families={len(results) - 1} samples={n_samples}")
    for family, m in results.items():
        for metric in METRICS:
            report(f"scenario_eval/{family}/{metric}", f"{m[metric]:.4f}")
        report(f"scenario_eval/{family}/n_agents", f"{m['n_agents']:.0f}",
               f"scenes={m['n_scenes']:.0f}")
    if smoke:
        fams = set(scenarios.registry.names())
        missing = fams - set(results)
        assert not missing, f"families missing from eval: {missing}"
        assert len(fams) >= 6, "fewer than 6 registered families"
        for family in fams | {"overall"}:
            m = results[family]
            assert np.isfinite(m["min_ade"]), (family, m)
            assert m["kinematic_infeasibility_rate"] <= 1e-6, \
                f"{family}: engine produced infeasible kinematics"
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized: no training, asserts structural health")
    ap.add_argument("--train-steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--encoding", default="se2_fourier")
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--scenes-per-family", type=int, default=4)
    ap.add_argument("--samples", type=int, default=4)
    args = ap.parse_args()
    report = lambda name, val, extra="": print(f"{name},{val},{extra}",
                                               flush=True)
    if args.smoke:
        run(report, train_steps=0, num_map=16, num_agents=6, num_steps=10,
            n_scenes_per_family=2, n_samples=2, encoding=args.encoding,
            smoke=True)
    else:
        run(report, train_steps=args.train_steps, batch=args.batch,
            encoding=args.encoding, num_agents=args.agents,
            num_steps=args.steps,
            n_scenes_per_family=args.scenes_per_family,
            n_samples=args.samples)


if __name__ == "__main__":
    main()
