"""Kernel micro-benchmarks: wall time of the XLA paths + interpret-mode
parity checks of the Pallas kernels.

On this CPU host the Pallas kernels execute in interpret mode (Python), so
their wall time is not meaningful; the benchmark therefore reports
  * forward mode — the XLA linear-memory attention path (what the CPU/dry-run
    actually runs) and the SE(2) Fourier projection in its fused-XLA form,
  * backward mode — the same paths under ``jax.value_and_grad`` (full
    train-step attention cost: forward + dq/dk/dv),
and validates Pallas outputs AND gradients against the oracle at benchmark
shapes (the TPU-timing slot in the CSV is the integration point for real
hardware runs).

Standalone: ``python benchmarks/kernel_bench.py [--mode fwd|bwd|all]``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encodings
from repro.kernels import ops, ref
from repro.kernels.se2_project import se2_fourier_project


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def _bench_fwd(report, q, k, v):
    chunked = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="chunked",
                                                    causal=True))
    reference = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="ref",
                                                      causal=True))
    report("kernels/mha_chunked_1k_us", _time(chunked, q, k, v) * 1e6)
    report("kernels/mha_reference_1k_us", _time(reference, q, k, v) * 1e6)

    # parity of the Pallas kernel (interpret) against the oracle at a
    # benchmark-relevant shape
    qs = q[:, :, :256].astype(jnp.float32)
    ks = k[:, :, :256].astype(jnp.float32)
    vs = v[:, :, :256].astype(jnp.float32)
    flash = ops.flash_attention(qs, ks, vs, causal=True, block_q=64,
                                block_k=64, interpret=True)
    want = ref.mha_reference(qs, ks, vs, causal=True)
    err = float(jnp.max(jnp.abs(flash - want)))
    report("kernels/flash_interpret_parity_maxerr", err)
    assert err < 1e-4, err


def _bench_bwd(report, q, k, v):
    """Forward+backward timings and Pallas-backward gradient parity."""
    def train_loss(impl):
        def loss(q, k, v):
            o = ops.attention(q, k, v, impl=impl, causal=True)
            return jnp.sum(o.astype(jnp.float32) ** 2)
        return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))

    report("kernels/mha_chunked_1k_fwdbwd_us",
           _time(train_loss("chunked"), q, k, v) * 1e6)
    report("kernels/mha_reference_1k_fwdbwd_us",
           _time(train_loss("ref"), q, k, v) * 1e6)

    # gradient parity of the Pallas backward kernels (interpret mode)
    # against autodiff through the O(S^2) oracle at a benchmark shape
    qs = q[:, :, :256].astype(jnp.float32)
    ks = k[:, :, :256].astype(jnp.float32)
    vs = v[:, :, :256].astype(jnp.float32)
    g = jnp.ones(qs.shape, jnp.float32)

    def loss_flash(q, k, v):
        o = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                                interpret=True, bwd_impl="pallas")
        return jnp.sum(o * g)

    got = jax.grad(loss_flash, argnums=(0, 1, 2))(qs, ks, vs)
    want = ref.mha_grads_reference(qs, ks, vs, g, causal=True)
    err = max(float(jnp.max(jnp.abs(a - w))) for a, w in zip(got, want))
    report("kernels/flash_bwd_interpret_parity_maxerr", err)
    assert err < 1e-4, err


def _bench_se2(report):
    rng = np.random.default_rng(0)
    enc = encodings.SE2Fourier(head_dim=24, num_terms=18)
    x = jnp.asarray(rng.normal(size=(2048, 24)), jnp.float32)
    pose = jnp.asarray(
        np.concatenate([rng.uniform(-3, 3, (2048, 2)),
                        rng.uniform(-np.pi, np.pi, (2048, 1))], -1),
        jnp.float32)
    xla_proj = jax.jit(lambda x, p: enc.transform_k(x, p))
    report("kernels/se2_project_xla_2048tok_us", _time(xla_proj, x, pose) * 1e6)
    pallas_out = se2_fourier_project(x[:256], pose[:256], enc, "k",
                                     block_t=128, interpret=True)
    err = float(jnp.max(jnp.abs(pallas_out - enc.transform_k(x[:256],
                                                             pose[:256]))))
    report("kernels/se2_project_parity_maxerr", err)
    assert err < 1e-4, err


def run(report, mode: str = "all"):
    rng = np.random.default_rng(0)
    b, h, s, d = 1, 4, 1024, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)

    if mode in ("fwd", "all"):
        _bench_fwd(report, q, k, v)
        _bench_se2(report)
    if mode in ("bwd", "all"):
        _bench_bwd(report, q, k, v)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("fwd", "bwd", "all"), default="all")
    args = ap.parse_args()
    run(lambda name, val, extra="": print(f"{name},{val},{extra}"),
        mode=args.mode)
