"""Kernel micro-benchmarks: wall time of the XLA paths + interpret-mode
parity checks of the Pallas kernels.

On this CPU host the Pallas kernels execute in interpret mode (Python), so
their wall time is not meaningful; the benchmark therefore reports
  * forward mode — the XLA linear-memory attention path (what the CPU/dry-run
    actually runs) and the SE(2) Fourier projection in its fused-XLA form,
  * backward mode — the same paths under ``jax.value_and_grad`` (full
    train-step attention cost: forward + dq/dk/dv),
and validates Pallas outputs AND gradients against the oracle at benchmark
shapes (the TPU-timing slot in the CSV is the integration point for real
hardware runs).

Standalone: ``python benchmarks/kernel_bench.py [--mode fwd|bwd|all]``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encodings
from repro.kernels import ops, ref
from repro.kernels.flash_decode import dequantize_kv, quantize_kv
from repro.kernels.se2_project import se2_fourier_project


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def _bench_fwd(report, q, k, v):
    chunked = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="chunked",
                                                    causal=True))
    reference = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="ref",
                                                      causal=True))
    report("kernels/mha_chunked_1k_us", _time(chunked, q, k, v) * 1e6)
    report("kernels/mha_reference_1k_us", _time(reference, q, k, v) * 1e6)

    # parity of the Pallas kernel (interpret) against the oracle at a
    # benchmark-relevant shape
    qs = q[:, :, :256].astype(jnp.float32)
    ks = k[:, :, :256].astype(jnp.float32)
    vs = v[:, :, :256].astype(jnp.float32)
    flash = ops.flash_attention(qs, ks, vs, causal=True, block_q=64,
                                block_k=64, interpret=True)
    want = ref.mha_reference(qs, ks, vs, causal=True)
    err = float(jnp.max(jnp.abs(flash - want)))
    report("kernels/flash_interpret_parity_maxerr", err)
    assert err < 1e-4, err


def _bench_bwd(report, q, k, v):
    """Forward+backward timings and Pallas-backward gradient parity."""
    def train_loss(impl):
        def loss(q, k, v):
            o = ops.attention(q, k, v, impl=impl, causal=True)
            return jnp.sum(o.astype(jnp.float32) ** 2)
        return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))

    report("kernels/mha_chunked_1k_fwdbwd_us",
           _time(train_loss("chunked"), q, k, v) * 1e6)
    report("kernels/mha_reference_1k_fwdbwd_us",
           _time(train_loss("ref"), q, k, v) * 1e6)

    # gradient parity of the Pallas backward kernels (interpret mode)
    # against autodiff through the O(S^2) oracle at a benchmark shape
    qs = q[:, :, :256].astype(jnp.float32)
    ks = k[:, :, :256].astype(jnp.float32)
    vs = v[:, :, :256].astype(jnp.float32)
    g = jnp.ones(qs.shape, jnp.float32)

    def loss_flash(q, k, v):
        o = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                                interpret=True, bwd_impl="pallas")
        return jnp.sum(o * g)

    got = jax.grad(loss_flash, argnums=(0, 1, 2))(qs, ks, vs)
    want = ref.mha_grads_reference(qs, ks, vs, g, causal=True)
    err = max(float(jnp.max(jnp.abs(a - w))) for a, w in zip(got, want))
    report("kernels/flash_bwd_interpret_parity_maxerr", err)
    assert err < 1e-4, err


def _bench_se2(report):
    rng = np.random.default_rng(0)
    enc = encodings.SE2Fourier(head_dim=24, num_terms=18)
    x = jnp.asarray(rng.normal(size=(2048, 24)), jnp.float32)
    pose = jnp.asarray(
        np.concatenate([rng.uniform(-3, 3, (2048, 2)),
                        rng.uniform(-np.pi, np.pi, (2048, 1))], -1),
        jnp.float32)
    xla_proj = jax.jit(lambda x, p: enc.transform_k(x, p))
    report("kernels/se2_project_xla_2048tok_us", _time(xla_proj, x, pose) * 1e6)
    pallas_out = se2_fourier_project(x[:256], pose[:256], enc, "k",
                                     block_t=128, interpret=True)
    err = float(jnp.max(jnp.abs(pallas_out - enc.transform_k(x[:256],
                                                             pose[:256]))))
    report("kernels/se2_project_parity_maxerr", err)
    assert err < 1e-4, err


def _bench_decode(report, smoke: bool = False):
    """Decode-shape micro-times + split-K kernel parity.

    Times the two CPU-executable decode paths at the rollout shape (tiny
    q, huge preallocated cache, cursor-bounded live prefix):

      * the generic ``kv_length``-masked full-cache scan (what decode
        paid before the ragged kernel — O(max_len) per call), and
      * ``ops.decode_attention(impl="xla")`` — the cursor-bounded ragged
        path (O(live prefix)), for f32 and int8 caches,

    then re-times the ragged path with the cache preallocation 4x larger
    at the *same* cursor: the reported ``flatness`` ratio is the direct
    micro-scale measurement of the O(live)-not-O(max_len) claim (the
    engine-level regression assertion lives in ``rollout_bench``).
    Finally it pins the Pallas split-K kernel (interpret mode) against
    the O(S^2) oracle, for f32 and int8-with-scales caches.
    """
    rng = np.random.default_rng(0)
    b, h, sq, d = (2, 4, 8, 32) if smoke else (4, 8, 16, 64)
    smax = 1024 if smoke else 4096
    cursor = smax // 8
    q = jnp.asarray(rng.normal(size=(b, h, sq, d)), jnp.float32)

    def cache(s):
        k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
        k_times = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return k, v, k_times

    k, v, k_times = cache(smax)
    q_times = jnp.broadcast_to(
        cursor - sq + jnp.arange(sq, dtype=jnp.int32), (b, sq))
    kvl = jnp.full((b,), cursor, jnp.int32)
    kw = dict(kv_length=kvl, q_times=q_times, k_times=k_times)

    generic = jax.jit(lambda q, k, v: ops.attention(
        q, k, v, impl="chunked", causal=True, **kw))
    ragged = jax.jit(lambda q, k, v: ops.decode_attention(
        q, k, v, impl="xla", **kw))
    t_gen = _time(generic, q, k, v)
    t_rag = _time(ragged, q, k, v)
    report("kernels/decode_generic_fullscan_us", t_gen * 1e6,
           f"smax={smax} cursor={cursor}")
    report("kernels/decode_ragged_xla_us", t_rag * 1e6,
           f"smax={smax} cursor={cursor}")
    report("kernels/decode_ragged_speedup", f"{t_gen / t_rag:.2f}")

    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    ragged_i8 = jax.jit(lambda q, k, v, ks, vs: ops.decode_attention(
        q, k, v, impl="xla", k_scale=ks, v_scale=vs, **kw))
    report("kernels/decode_ragged_xla_int8_us",
           _time(ragged_i8, q, kq, vq, ks, vs) * 1e6)

    # flat-in-max_len at fixed cursor: same live prefix, 4x preallocation
    k4, v4, k4_times = cache(4 * smax)
    ragged4 = jax.jit(lambda q, k, v: ops.decode_attention(
        q, k, v, impl="xla", kv_length=kvl, q_times=q_times,
        k_times=k4_times))
    t_rag4 = _time(ragged4, q, k4, v4)
    report("kernels/decode_ragged_flatness", f"{t_rag4 / t_rag:.2f}",
           f"time at 4x max_len / time at 1x (1.0 = perfectly flat)")

    # Pallas split-K kernel parity (interpret mode) against the oracle,
    # f32 and int8 caches, at a multi-split shape
    s_par, blk, nsp = (256, 64, 2) if smoke else (512, 64, 4)
    qs = q[:1, :, :, :]
    kk, vv, tt = cache(s_par)
    kk, vv, tt = kk[:1], vv[:1], tt[:1]
    kvl_s = jnp.asarray([s_par - 37], jnp.int32)
    qt = jnp.broadcast_to(s_par - sq + jnp.arange(sq, dtype=jnp.int32),
                          (1, sq))
    got = ops.decode_attention(qs, kk, vv, impl="flash_decode",
                               kv_length=kvl_s, q_times=qt, k_times=tt,
                               block_k=blk, num_splits=nsp, interpret=True)
    want = ref.mha_reference(qs, kk, vv, causal=True, q_times=qt, k_times=tt,
                             kv_length=kvl_s)
    err = float(jnp.max(jnp.abs(got - want)))
    report("kernels/flash_decode_interpret_parity_maxerr", err)
    assert err < 1e-4, err
    kq1, ks1 = quantize_kv(kk)
    vq1, vs1 = quantize_kv(vv)
    got8 = ops.decode_attention(qs, kq1, vq1, impl="flash_decode",
                                k_scale=ks1, v_scale=vs1, kv_length=kvl_s,
                                q_times=qt, k_times=tt, block_k=blk,
                                num_splits=nsp, interpret=True)
    want8 = ref.mha_reference(qs, dequantize_kv(kq1, ks1),
                              dequantize_kv(vq1, vs1), causal=True,
                              q_times=qt, k_times=tt, kv_length=kvl_s)
    err8 = float(jnp.max(jnp.abs(got8 - want8)))
    report("kernels/flash_decode_int8_parity_maxerr", err8)
    assert err8 < 1e-4, err8


def run(report, mode: str = "all", smoke: bool = False):
    rng = np.random.default_rng(0)
    b, h, s, d = 1, 4, 1024, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)

    if mode in ("fwd", "all"):
        _bench_fwd(report, q, k, v)
        _bench_se2(report)
    if mode in ("bwd", "all"):
        _bench_bwd(report, q, k, v)
    if mode in ("decode", "all"):
        _bench_decode(report, smoke=smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("fwd", "bwd", "decode", "all"),
                    default="all")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized decode shapes")
    args = ap.parse_args()
    run(lambda name, val, extra="": print(f"{name},{val},{extra}"),
        mode=args.mode, smoke=args.smoke)
