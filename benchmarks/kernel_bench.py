"""Kernel micro-benchmarks: wall time of the XLA paths + interpret-mode
parity checks of the Pallas kernels.

On this CPU host the Pallas kernels execute in interpret mode (Python), so
their wall time is not meaningful; the benchmark therefore reports
  * the XLA linear-memory attention path (what the CPU/dry-run actually
    runs),
  * the SE(2) Fourier projection in its fused-XLA form,
and validates Pallas outputs against the oracle at benchmark shapes
(the TPU-timing slot in the CSV is the integration point for real
hardware runs).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import encodings
from repro.kernels import ops, ref
from repro.kernels.se2_project import se2_fourier_project


def _time(fn, *args, reps=5):
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready(), out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready(), out)
    return (time.perf_counter() - t0) / reps


def run(report):
    rng = np.random.default_rng(0)
    b, h, s, d = 1, 4, 1024, 64
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.bfloat16)

    chunked = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="chunked",
                                                    causal=True))
    reference = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="ref",
                                                      causal=True))
    report("kernels/mha_chunked_1k_us", _time(chunked, q, k, v) * 1e6)
    report("kernels/mha_reference_1k_us", _time(reference, q, k, v) * 1e6)

    # parity of the Pallas kernel (interpret) against the oracle at a
    # benchmark-relevant shape
    qs = q[:, :, :256].astype(jnp.float32)
    ks = k[:, :, :256].astype(jnp.float32)
    vs = v[:, :, :256].astype(jnp.float32)
    flash = ops.flash_attention(qs, ks, vs, causal=True, block_q=64,
                                block_k=64, interpret=True)
    want = ref.mha_reference(qs, ks, vs, causal=True)
    err = float(jnp.max(jnp.abs(flash - want)))
    report("kernels/flash_interpret_parity_maxerr", err)
    assert err < 1e-4, err

    # SE(2) Fourier projection: fused-XLA timing + Pallas parity
    enc = encodings.SE2Fourier(head_dim=24, num_terms=18)
    x = jnp.asarray(rng.normal(size=(2048, 24)), jnp.float32)
    pose = jnp.asarray(
        np.concatenate([rng.uniform(-3, 3, (2048, 2)),
                        rng.uniform(-np.pi, np.pi, (2048, 1))], -1),
        jnp.float32)
    xla_proj = jax.jit(lambda x, p: enc.transform_k(x, p))
    report("kernels/se2_project_xla_2048tok_us", _time(xla_proj, x, pose) * 1e6)
    pallas_out = se2_fourier_project(x[:256], pose[:256], enc, "k",
                                     block_t=128, interpret=True)
    err = float(jnp.max(jnp.abs(pallas_out - enc.transform_k(x[:256],
                                                             pose[:256]))))
    report("kernels/se2_project_parity_maxerr", err)
    assert err < 1e-4, err


if __name__ == "__main__":
    run(lambda name, val, extra="": print(f"{name},{val},{extra}"))
