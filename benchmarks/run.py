"""Benchmark harness: one module per paper table/figure + roofline summary.

Prints ``name,value,notes`` CSV rows. Modules:

  approx_error       — paper Fig. 3/4 (Fourier truncation error)
  attention_scaling  — the linear-vs-quadratic memory claim (Sec. II-B)
  agent_sim_table1   — Table I proxy on synthetic scenes (NLL by encoding)
  scenario_eval      — closed-loop per-family eval on the lane-graph
                       scenario suite (minADE/miss/collision/off-road)
  train_bench        — BC trainer throughput (steps/s, datagen cost, loss
                       trajectory) -> BENCH_train.json
  adaptive_basis     — beyond-paper: scale-adaptive basis truncation
  kernel_bench       — kernel micro-times + Pallas/oracle parity
  roofline_summary   — aggregates experiments/dryrun/*.json if present
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _report(name, value, extra=""):
    print(f"{name},{value},{extra}", flush=True)


def roofline_summary(report):
    here = os.path.dirname(os.path.abspath(__file__))
    d = os.path.join(here, "..", "experiments", "dryrun")
    if not os.path.isdir(d):
        report("roofline/available", 0, "run repro.launch.dryrun first")
        return
    n_ok = n_err = 0
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(d, name)) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            n_ok += 1
            t = rec.get("terms")
            if t is None:    # multi-pod cells are compile proofs only
                report(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
                       "compiled",
                       f"hbm_gib={rec.get('hbm_per_chip_gib', 0):.2f}")
                continue
            report(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
                   t["bound_s"],
                   f"dom={t['dominant']} compute_ms={t['compute_s']*1e3:.2f} "
                   f"mem_ms={t['memory_s']*1e3:.2f} "
                   f"coll_ms={t['collective_s']*1e3:.2f}")
        elif rec.get("status") == "error":
            n_err += 1
    report("roofline/cells_ok", n_ok)
    report("roofline/cells_error", n_err)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    ap.add_argument("--table1-steps", type=int, default=150)
    ap.add_argument("--scenario-train-steps", type=int, default=100)
    ap.add_argument("--train-bench-steps", type=int, default=80)
    args = ap.parse_args()

    from benchmarks import (adaptive_basis, agent_sim_table1, approx_error,
                            attention_scaling, kernel_bench, scenario_eval,
                            train_bench)

    benches = {
        "approx_error": lambda: approx_error.run(_report),
        "attention_scaling": lambda: attention_scaling.run(_report),
        "adaptive_basis": lambda: adaptive_basis.run(_report),
        "kernel_bench": lambda: kernel_bench.run(_report),
        "agent_sim_table1": lambda: agent_sim_table1.run(
            _report, steps=args.table1_steps),
        "scenario_eval": lambda: scenario_eval.run(
            _report, train_steps=args.scenario_train_steps),
        "train_bench": lambda: train_bench.run(
            _report, steps=args.train_bench_steps),
        "roofline_summary": lambda: roofline_summary(_report),
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    failures = 0
    for name, fn in benches.items():
        if name not in only:
            continue
        t0 = time.time()
        try:
            fn()
            _report(f"{name}/elapsed_s", f"{time.time() - t0:.1f}")
        except Exception as e:
            failures += 1
            _report(f"{name}/FAILED", type(e).__name__, str(e)[:200])
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
