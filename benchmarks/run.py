"""Benchmark harness: one module per paper table/figure + roofline summary.

Prints ``name,value,notes`` CSV rows. Modules:

  approx_error       — paper Fig. 3/4 (Fourier truncation error)
  attention_scaling  — the linear-vs-quadratic memory claim (Sec. II-B)
  agent_sim_table1   — Table I proxy on synthetic scenes (NLL by encoding)
  scenario_eval      — closed-loop per-family eval on the lane-graph
                       scenario suite (minADE/miss/collision/off-road)
  train_bench        — BC trainer throughput (steps/s, datagen cost, loss
                       trajectory) -> BENCH_train.json
  rollout_bench      — cached-decode throughput: ragged decode kernel vs
                       generic full-cache scan, cache-dtype sweep,
                       flat-in-max_len regression -> BENCH_rollout.json
  serve_bench        — continuous-batching SimServer under Poisson
                       arrivals: scenes/s + p50/p99 tick latency per
                       slot count, slab accounting, parity vs batch
                       eval -> BENCH_serve.json
  fleet_bench        — scene-sharded fleet rollouts on a forced
                       multi-device CPU mesh: scenes/s vs device count
                       (bit-parity enforced) + the real-budget Table-I
                       comparison through the dp_compress training path
                       -> BENCH_fleet.json (runs in a subprocess; see
                       its docstring)
  adaptive_basis     — beyond-paper: scale-adaptive basis truncation
  kernel_bench       — kernel micro-times + Pallas/oracle parity
                       (fwd, bwd, and ragged-decode modes)
  roofline_summary   — aggregates experiments/dryrun/*.json if present

Every registered benchmark additionally persists its CSV rows as
``BENCH_<name>.json`` at the repo root (status, elapsed, and the rows it
printed), so successive PRs accumulate a machine-readable perf
trajectory for *all* benchmarks, not just the ones that write their own
rich records (train_bench/rollout_bench keep doing that too).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def _report(name, value, extra=""):
    print(f"{name},{value},{extra}", flush=True)


def roofline_summary(report):
    here = os.path.dirname(os.path.abspath(__file__))
    d = os.path.join(here, "..", "experiments", "dryrun")
    if not os.path.isdir(d):
        report("roofline/available", 0, "run repro.launch.dryrun first")
        return
    n_ok = n_err = 0
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(d, name)) as f:
            rec = json.load(f)
        if rec.get("status") == "ok":
            n_ok += 1
            t = rec.get("terms")
            if t is None:    # multi-pod cells are compile proofs only
                report(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
                       "compiled",
                       f"hbm_gib={rec.get('hbm_per_chip_gib', 0):.2f}")
                continue
            report(f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
                   t["bound_s"],
                   f"dom={t['dominant']} compute_ms={t['compute_s']*1e3:.2f} "
                   f"mem_ms={t['memory_s']*1e3:.2f} "
                   f"coll_ms={t['collective_s']*1e3:.2f}")
        elif rec.get("status") == "error":
            n_err += 1
    report("roofline/cells_ok", n_ok)
    report("roofline/cells_error", n_err)


def _persist(name: str, rows, elapsed_s: float, status: str,
             error: str = "") -> str:
    """Write one benchmark's CSV rows to BENCH_<name>.json (repo root)."""
    here = os.path.dirname(os.path.abspath(__file__))
    path = os.path.join(here, "..", f"BENCH_{name}.json")
    rec = {"benchmark": name, "status": status,
           "elapsed_s": round(elapsed_s, 2), "rows": rows}
    if error:
        rec["error"] = error
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return os.path.abspath(path)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of benchmarks")
    ap.add_argument("--table1-steps", type=int, default=150)
    ap.add_argument("--scenario-train-steps", type=int, default=100)
    ap.add_argument("--train-bench-steps", type=int, default=80)
    ap.add_argument("--rollout-smoke", action="store_true",
                    help="run rollout_bench at CI (smoke) size")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="run serve_bench at CI (smoke) size")
    ap.add_argument("--fleet-smoke", action="store_true",
                    help="run fleet_bench at CI (smoke) size")
    args = ap.parse_args()

    from benchmarks import (adaptive_basis, agent_sim_table1, approx_error,
                            attention_scaling, kernel_bench, rollout_bench,
                            scenario_eval, serve_bench, train_bench)

    def run_rollout(report):
        if args.rollout_smoke:
            # smoke numbers go to /tmp so they never clobber the
            # committed full-size BENCH_rollout.json record
            return rollout_bench.run(report, num_agents=8, num_steps=32,
                                     num_map=8, n_scenes=2, n_samples=2,
                                     overalloc=4, reps=3, min_speedup=1.2,
                                     max_flat_dev=0.5, smoke=True,
                                     out="/tmp/BENCH_rollout_smoke.json")
        return rollout_bench.run(report, reps=2, min_speedup=2.0,
                                 max_flat_dev=0.2)

    def run_serve(report):
        if args.serve_smoke:
            # smoke numbers go to /tmp so they never clobber the
            # committed full-size BENCH_serve.json record
            return serve_bench.run(report, slot_counts=(2, 4), n_scenes=8,
                                   num_map=8, num_agents=4, num_steps=12,
                                   rate=1.0, smoke=True,
                                   out="/tmp/BENCH_serve_smoke.json")
        return serve_bench.run(report)

    def run_fleet(report):
        # fleet_bench needs XLA's forced host device count set BEFORE the
        # first jax init, and this process has already initialized jax by
        # the time benchmarks import — so it runs in a fresh subprocess
        # (its __main__ sets the flag) and its CSV rows are relayed.
        import subprocess
        here = os.path.dirname(os.path.abspath(__file__))
        cmd = [sys.executable, os.path.join(here, "fleet_bench.py")]
        if args.fleet_smoke:
            cmd.append("--smoke")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (os.path.join(here, "..", "src"),
                        env.get("PYTHONPATH")) if p)
        out = subprocess.run(cmd, env=env, capture_output=True, text=True)
        for line in out.stdout.splitlines():
            if line.startswith("fleet_bench/"):
                parts = (line.split(",", 2) + ["", ""])[:3]
                report(parts[0], parts[1], parts[2])
        if out.returncode:
            sys.stderr.write(out.stderr[-4000:])
            raise RuntimeError(f"fleet_bench exited {out.returncode}")

    benches = {
        "approx_error": lambda r: approx_error.run(r),
        "attention_scaling": lambda r: attention_scaling.run(r),
        "adaptive_basis": lambda r: adaptive_basis.run(r),
        "kernel_bench": lambda r: kernel_bench.run(r),
        "agent_sim_table1": lambda r: agent_sim_table1.run(
            r, steps=args.table1_steps),
        "scenario_eval": lambda r: scenario_eval.run(
            r, train_steps=args.scenario_train_steps),
        "train_bench": lambda r: train_bench.run(
            r, steps=args.train_bench_steps),
        "rollout_bench": run_rollout,
        "serve_bench": run_serve,
        "fleet_bench": run_fleet,
        "roofline_summary": lambda r: roofline_summary(r),
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    failures = 0
    for name, fn in benches.items():
        if name not in only:
            continue
        rows = []

        def report(n, value, extra=""):
            _report(n, value, extra)
            rows.append({"name": str(n), "value": str(value),
                         "notes": str(extra)})

        t0 = time.time()
        try:
            fn(report)
            elapsed = time.time() - t0
            _report(f"{name}/elapsed_s", f"{elapsed:.1f}")
            _persist(name, rows, elapsed, "ok")
        except Exception as e:
            failures += 1
            _report(f"{name}/FAILED", type(e).__name__, str(e)[:200])
            traceback.print_exc(file=sys.stderr)
            _persist(name, rows, time.time() - t0, "failed",
                     f"{type(e).__name__}: {e}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
