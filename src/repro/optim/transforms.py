"""Gradient-transform optimizers (minimal optax-like, sharding-friendly).

Implementation note: multi-output tree maps are done by flattening against
the *parameter* treedef (``treedef.flatten_up_to``) so optimizer-state
leaves may themselves be dicts (adafactor's factored statistics) without
any ``is_leaf`` ambiguity.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

OptState = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], Tuple[Any, OptState]]
    """update(grads, state, params) -> (updates, new_state); updates already
    carry the -lr sign and are *added* to params by the caller."""


def _to_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def chain(*opts: Optimizer) -> Optimizer:
    def init(params):
        return tuple(o.init(params) for o in opts)

    def update(grads, state, params):
        new_states = []
        for o, s in zip(opts, state):
            grads, ns = o.update(grads, s, params)
            new_states.append(ns)
        return grads, tuple(new_states)

    return Optimizer(init, update)


def clip_by_global_norm(max_norm: float) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params):
        leaves = jax.tree.leaves(grads)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in leaves))
        scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
        return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                       ).astype(g.dtype), grads), ()

    return Optimizer(init, update)


def sgd(lr) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        upd = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return upd, {"step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, mu_dtype=jnp.float32) -> Optimizer:
    sched = _to_schedule(lr)

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, mu_dtype), params),
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                               params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        g_flat, treedef = jax.tree.flatten(grads)
        mu_flat = treedef.flatten_up_to(state["mu"])
        nu_flat = treedef.flatten_up_to(state["nu"])
        p_flat = treedef.flatten_up_to(params)
        u_flat, mu_new, nu_new = [], [], []
        for g, mu, nu, p in zip(g_flat, mu_flat, nu_flat, p_flat):
            g = g.astype(jnp.float32)
            mu_n = b1 * mu.astype(jnp.float32) + (1 - b1) * g
            nu_n = b2 * nu + (1 - b2) * g * g
            u = -lr_t * (mu_n / b1c / (jnp.sqrt(nu_n / b2c) + eps)
                         + weight_decay * p.astype(jnp.float32))
            u_flat.append(u)
            mu_new.append(mu_n.astype(mu_dtype))
            nu_new.append(nu_n)
        return (treedef.unflatten(u_flat),
                {"step": step, "mu": treedef.unflatten(mu_new),
                 "nu": treedef.unflatten(nu_new)})

    return Optimizer(init, update)


def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0,
              weight_decay: float = 0.0,
              min_dim_size_to_factor: int = 128) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018).

    Matrices with both trailing dims >= ``min_dim_size_to_factor`` store two
    rank-1 statistics instead of the full second moment; everything else
    falls back to an unfactored accumulator. Momentum-free (the memory-lean
    configuration used by PaLM-scale trainings) — this is what lets the
    kimi-k2 1T-parameter train_step fit 16 GB/chip at 512 chips.
    """
    sched = _to_schedule(lr)

    def _factored(shape):
        return (len(shape) >= 2 and shape[-1] >= min_dim_size_to_factor
                and shape[-2] >= min_dim_size_to_factor)

    def init(params):
        def one(p):
            if _factored(p.shape):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(one, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = sched(step)
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        g_flat, treedef = jax.tree.flatten(grads)
        v_flat = treedef.flatten_up_to(state["v"])
        p_flat = treedef.flatten_up_to(params)
        u_out, v_out = [], []
        for g, v, p in zip(g_flat, v_flat, p_flat):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if "vr" in v:
                vr = beta * v["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * v["vc"] + (1 - beta) * g2.mean(axis=-2)
                row = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
                denom = row[..., None] * vc[..., None, :]
                u = g * jax.lax.rsqrt(jnp.maximum(denom, eps))
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta * v["v"] + (1 - beta) * g2}
                u = g * jax.lax.rsqrt(jnp.maximum(nv["v"], eps))
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            u = -lr_t * (u + weight_decay * p.astype(jnp.float32))
            u_out.append(u)
            v_out.append(nv)
        return (treedef.unflatten(u_out),
                {"step": step, "v": treedef.unflatten(v_out)})

    return Optimizer(init, update)


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype), params,
        updates)
