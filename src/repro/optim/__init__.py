"""Optimizers (optax-free): composable gradient transforms.

``adamw`` / ``adafactor`` + schedules + clipping, built on a minimal
``(init, update)`` transform protocol compatible with pjit sharding: every
optimizer-state leaf mirrors a parameter leaf (or a factored reduction of
one), so the parameter sharding rules apply transitively — this is what
makes ZeRO-style optimizer-state sharding fall out of the logical-axis
system for free.

``adafactor`` exists specifically for the trillion-parameter configs
(kimi-k2): factored second moments cut optimizer state from 8 bytes/param
to ~4 bytes/param + O(rows + cols), the difference between fitting and not
fitting on a 16 GB/chip v5e pod. (Same reasoning as PaLM-scale trainings.)
"""
from repro.optim.transforms import (OptState, Optimizer, adafactor, adamw,
                                    chain, clip_by_global_norm, sgd)
from repro.optim.schedules import (constant, cosine_decay, linear_warmup,
                                   warmup_cosine)
from repro.optim.compression import (compress_gradients, decompress_gradients,
                                     ErrorFeedbackCompressor)

__all__ = [
    "OptState", "Optimizer", "adafactor", "adamw", "chain",
    "clip_by_global_norm", "sgd", "constant", "cosine_decay", "linear_warmup",
    "warmup_cosine", "compress_gradients", "decompress_gradients",
    "ErrorFeedbackCompressor",
]
