"""Gradient compression for cross-pod all-reduce (distributed-optimization).

At multi-pod scale the inter-pod links (DCI) are an order of magnitude
slower than intra-pod ICI, so the cross-pod gradient reduction is the
bandwidth bottleneck. Two compressors are provided:

  * int8 stochastic-free linear quantization with per-tensor scales
    (8x fewer DCI bytes than f32, 2x fewer than bf16), plus
  * top-k sparsification with **error feedback** (the residual is carried
    to the next step so compression error doesn't bias convergence —
    Karimireddy et al., 2019).

These are applied *around* the cross-pod psum inside a ``shard_map``-based
data-parallel step (see ``repro.distributed.dp_compress``); within a pod
gradients still reduce at full precision over ICI.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def compress_gradients(grads, bits: int = 8):
    """Per-tensor symmetric linear quantization to int8."""
    assert bits == 8, "int8 only"

    def one(g):
        g32 = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale}

    return jax.tree.map(one, grads)


def decompress_gradients(comp):
    def one(c):
        return c["q"].astype(jnp.float32) * c["scale"]

    return jax.tree.map(one, comp,
                        is_leaf=lambda x: isinstance(x, dict) and "q" in x
                        and "scale" in x and len(x) == 2)


@dataclasses.dataclass(frozen=True)
class ErrorFeedbackCompressor:
    """Top-k sparsification with an error-feedback residual accumulator."""

    k_frac: float = 0.05

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads, residual):
        """Returns (sparse_grads_dense, new_residual). The "compressed"
        tensor is materialized densely (values at non-top-k positions are
        zero) — on the wire a sparse encoding would ship (idx, val) pairs;
        the dense stand-in keeps the algorithm exact for testing while the
        byte-count accounting lives in the roofline model."""
        def one(g, r):
            acc = g.astype(jnp.float32) + r
            flat = jnp.abs(acc).reshape(-1)
            k = max(1, int(flat.shape[0] * self.k_frac))
            thresh = jax.lax.top_k(flat, k)[0][-1]
            mask = (jnp.abs(acc) >= thresh).astype(jnp.float32)
            sent = acc * mask
            return sent, acc - sent

        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(residual)
        out = [one(g, r) for g, r in zip(flat_g, flat_r)]
        sent = treedef.unflatten([o[0] for o in out])
        new_r = treedef.unflatten([o[1] for o in out])
        return sent, new_r
