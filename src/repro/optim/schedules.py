"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup(peak: float, warmup_steps: int):
    def fn(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
        return peak * frac
    return fn


def cosine_decay(peak: float, decay_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return peak * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(peak: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak * step / max(warmup_steps, 1)
        t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                     0.0, 1.0)
        cos = peak * (final_frac + (1 - final_frac) * 0.5
                      * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup_steps, warm, cos)
    return fn
