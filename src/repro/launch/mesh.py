"""Production meshes.

Target fleet: TPU v5e pods of 256 chips arranged (16, 16); the multi-pod
configuration stacks 2 pods = 512 chips on a leading "pod" axis (data
parallelism over DCI, with gradient compression available for the cross-pod
reduction). Defined as FUNCTIONS so importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(num_devices: Optional[int] = None, model_axis: int = None):
    """Small-scale mesh for tests/examples on host platforms."""
    n = num_devices or len(jax.devices())
    m = model_axis or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // m, m), ("data", "model"))


def make_fleet_mesh(num_devices: Optional[int] = None, *, pods: int = 1):
    """Scene-axis mesh for fleet rollouts / closed-loop eval.

    The rollout tick is data-parallel over scene slots (no tensor
    parallelism — the sim models are small; the scale axis is scenes), so
    the fleet mesh carries only the DP axes ``("pod", "data")`` that
    :class:`repro.runtime.RolloutEngine` shard_maps its lanes over.
    ``num_devices`` defaults to every visible device and may name a
    PREFIX subset (the fleet-bench scaling sweep builds meshes over 1, 2,
    4, ... devices inside one forced-device-count process); ``pods``
    splits a leading cross-pod axis off for multi-pod runs.
    """
    import numpy as np

    devs = jax.devices()[:num_devices] if num_devices else jax.devices()
    n = len(devs)
    if n % max(pods, 1) != 0:
        raise ValueError(f"{n} devices do not split into {pods} pods")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs).reshape(pods, n // pods), ("pod", "data"))


# Hardware constants for the roofline model (TPU v5e).
HW = {
    "name": "tpu_v5e",
    "peak_flops_bf16": 197e12,     # per chip
    "hbm_bw": 819e9,               # bytes/s per chip
    "ici_bw": 50e9,                # bytes/s per link (~per chip per direction)
    "hbm_bytes": 16 * 1024**3,     # 16 GiB per chip
    "dci_bw": 6.25e9,              # cross-pod per chip (assumed 50 Gbit/s)
}
