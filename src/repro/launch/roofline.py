"""Roofline-term extraction from compiled (SPMD-partitioned) executables.

Three terms, each in seconds-per-step on the target hardware:

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes  / (chips * HBM_bw)
    collective = per-chip collective bytes / link_bw

``cost_analysis`` supplies FLOPs / bytes-accessed for the whole program
(all partitions); collective bytes are NOT in cost_analysis, so we parse
the optimized HLO text: after SPMD partitioning every op shape is
*per-partition*, so summing collective result shapes (x an op-specific ring
factor) directly estimates per-chip link traffic.

Ring factors (N = replica-group size):
    all-reduce:         2 * (N-1)/N * bytes     (reduce-scatter + all-gather)
    all-gather:         (N-1)/N * result_bytes
    reduce-scatter:     (N-1)/N * input_bytes  ~= (N-1) * result_bytes
    all-to-all:         (N-1)/N * bytes
    collective-permute: bytes
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# op start: `%name = <shape or tuple> <op-name>(`  (optionally `-start`)
_OP_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of a shape string like 'bf16[8,128]{1,0}' or a tuple."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).strip("{}")
        return len([x for x in first.split(",") if x.strip() != ""])
    return 2


@dataclasses.dataclass
class CollectiveStats:
    per_chip_bytes: float = 0.0
    by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    count: int = 0
    f32_bytes: float = 0.0     # moved bytes whose payload dtype is f32

    @property
    def bf16_corrected(self) -> float:
        """TPU-intent estimate: XLA's *CPU* float-normalization pass
        upcasts every bf16 dot/elementwise to f32, so collectives adjacent
        to bf16 compute are measured at 2x their TPU size. For bf16-compute
        models the corrected per-chip bytes halve the f32 share."""
        return self.per_chip_bytes - 0.5 * self.f32_bytes

    def to_dict(self):
        return {"per_chip_bytes": self.per_chip_bytes,
                "by_kind": self.by_kind, "count": self.count,
                "f32_bytes": self.f32_bytes,
                "bf16_corrected": self.bf16_corrected}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if ".remat" in line and m.group(2) not in line:  # defensive
            continue
        shape_str, kind, is_start = m.group(1), m.group(2), m.group(3)
        # "-done" ops repeat the shape of their "-start"; only count starts
        # and plain (non-async) ops.
        if f"{kind}-done" in line:
            continue
        n = _group_size(line)
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            moved = 2.0 * (n - 1) / n * b
        elif kind == "all-gather":
            moved = (n - 1) / n * b
        elif kind == "reduce-scatter":
            moved = float(n - 1) * b          # input ~= result * N
        elif kind == "all-to-all":
            moved = (n - 1) / n * b
        else:                                  # collective-permute
            moved = float(b)
        stats.per_chip_bytes += moved
        stats.by_kind[kind] = stats.by_kind.get(kind, 0.0) + moved
        stats.count += 1
        # dtype split for the CPU-float-normalization correction
        f32_b = 0
        for dt, dims in _SHAPE_RE.findall(shape_str):
            if dt != "f32":
                continue
            n = 1
            for d_ in (dims.split(",") if dims else []):
                n *= int(d_)
            f32_b += n * 4
        if b > 0:
            stats.f32_bytes += moved * (f32_b / b)
    return stats


def roofline_terms(flops: float, bytes_accessed: float,
                   coll: CollectiveStats, num_chips: int, hw: Dict,
                   cross_pod_bytes: float = 0.0) -> Dict[str, float]:
    """Terms in seconds-per-step.

    Empirically (validated against 6*N*D accounting on stablelm-3b),
    ``cost_analysis`` on the SPMD-partitioned module reports *per-partition*
    FLOPs/bytes, i.e. already HLO_FLOPs/chips — so the per-chip time is
    flops / peak directly. Collective bytes from the HLO census are also
    per-chip (post-partitioning shapes)."""
    compute = flops / hw["peak_flops_bf16"]
    memory = bytes_accessed / hw["hbm_bw"]
    collective_raw = coll.per_chip_bytes / hw["ici_bw"]
    collective = coll.bf16_corrected / hw["ici_bw"]
    if cross_pod_bytes:
        collective += cross_pod_bytes / hw["dci_bw"]
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])[0]
    total = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "collective_s_raw_f32": collective_raw,
        "dominant": dominant,
        "bound_s": total,
        "roofline_fraction_of_compute": compute / total if total > 0 else 0.0,
    }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train;
    2*N*D forward-only for prefill; 2*N_active per token for decode."""
    from repro.nn import module as nnm
    from repro.nn.transformer import build_model

    model = build_model(cfg)
    n_params = nnm.count_params(model.specs())
    n_active = n_params
    if cfg.moe is not None:
        # subtract non-routed share of expert params
        m = cfg.moe
        moe_layers = cfg.num_layers - m.first_k_dense
        expert_params = moe_layers * m.num_experts * 3 * cfg.d_model * m.expert_ff
        active_expert = moe_layers * m.top_k * 3 * cfg.d_model * m.expert_ff
        n_active = n_params - expert_params + active_expert
    tokens = shape.global_batch * (shape.seq_len if shape.mode == "train"
                                   else (shape.seq_len if shape.mode ==
                                         "prefill" else 1))
    mult = 6.0 if shape.mode == "train" else 2.0
    return mult * n_active * tokens


def summarize(record: Dict) -> str:
    t = record["terms"]
    return (f"{record['arch']:24s} {record['shape']:12s} {record['mesh']:6s} "
            f"compute={t['compute_s']*1e3:9.3f}ms memory={t['memory_s']*1e3:9.3f}ms "
            f"coll={t['collective_s']*1e3:9.3f}ms dom={t['dominant']:10s} "
            f"useful={record.get('useful_flops_frac', float('nan')):.3f}")
