"""Agent-sim BC training launcher: ``python -m repro.launch.train_sim``.

Wires the expert-demonstration pipeline (``repro.training.data``) ->
sharded BC train step (``repro.training.steps``) -> fault-tolerant
:class:`Trainer`, with periodic closed-loop evaluation through
``repro.runtime.evaluation`` riding the trainer's eval hook. The same
code path runs a reduced config end-to-end on this CPU host and the full
sim archs on a fleet (mesh axes span the devices; the data cursor shards
by host).

Modes:

  # single-encoding training with periodic closed-loop eval
  python -m repro.launch.train_sim --arch sim-se2-fourier --reduced \
      --steps 200 --eval-every 100

  # the paper's invariant-vs-absolute comparison table (identical budgets)
  python -m repro.launch.train_sim --compare --reduced --steps 200

``--smoke`` shrinks everything to CI size and asserts the run is healthy:
loss decreased from init and the final checkpoint round-trips bit-exactly.
"""
from __future__ import annotations

import argparse
import json
import logging
import os
import signal
import tempfile

import jax
import numpy as np

from repro import obs
from repro.configs import SIM_ARCH_NAMES, get_sim_arch
from repro.data.pipeline import ShardedIterator
from repro.distributed.sharding import (derive_opt_shardings,
                                        sharding_for_specs, use_mesh_rules)
from repro.launch.mesh import make_mesh_for, make_production_mesh
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimModel
from repro.runtime.evaluation import EvalConfig, evaluate_scenes
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.scenarios import registry
from repro.training.comparison import (COMPARISON_ENCODINGS, format_table,
                                       run_comparison)
from repro.training.data import holdout_batches, make_batch_fn
from repro.training.steps import (bc_optimizer, loss_summary,
                                  make_sim_eval_step, make_sim_train_step,
                                  open_loop_metrics)

log = logging.getLogger("repro.launch.train_sim")

DEFAULT_CKPT_ROOT = "/tmp/repro_sim_ckpt"


def resolve_ckpt_dir(root, arch, smoke: bool) -> str:
    """Per-(arch, shape) checkpoint dir under the chosen root.

    The subdir is salted with the model/scenario shape so restoring a
    checkpoint from a different encoding or a reduced-vs-full run of the
    same arch can never load a mismatched parameter tree. ``--smoke`` with
    no explicit root uses a fresh temp dir: smoke is a health assertion
    and must not silently resume a finished earlier run (0 steps trained,
    empty history).
    """
    if root is None:
        root = (tempfile.mkdtemp(prefix="repro_sim_smoke_") if smoke
                else DEFAULT_CKPT_ROOT)
    sig = (f"{arch.name}_d{arch.d_model}x{arch.num_layers}"
           f"_m{arch.num_map}a{arch.num_agents}t{arch.num_steps}")
    return os.path.join(root, sig)


def make_eval_cb(model, scen, *, holdout, n_scenes_per_family: int,
                 n_samples: int, seed: int):
    """Periodic evaluation closure for the Trainer's eval hook.

    Scenes, the rollout engine, and the jitted open-loop eval step are all
    built once and reused — only ``engine.params`` is swapped per call, so
    every eval after the first runs without recompilation.
    """
    from repro.runtime.rollout import RolloutEngine

    eval_cfg = EvalConfig(t_hist=max(1, scen.num_steps // 2),
                          n_samples=n_samples, seed=seed + 1)
    scenes = [registry.generate_scene(f, seed + 777, i, scen)
              for f in registry.names()
              for i in range(n_scenes_per_family)]
    eval_fn = jax.jit(make_sim_eval_step(model))
    state = {"engine": None, "last": None, "last_step": None}

    def eval_cb(step, params):
        state["last_step"] = step
        if state["engine"] is None:
            state["engine"] = RolloutEngine(
                model, params, scen,
                num_slots=min(32, len(scenes) * eval_cfg.n_samples))
        state["engine"].params = params
        closed = evaluate_scenes(state["engine"], scenes, eval_cfg)
        open_m = open_loop_metrics(model, params, holdout, eval_fn=eval_fn)
        state["last"] = {"open_loop": open_m,
                         "closed_loop": closed["overall"]}
        log.info(
            "eval @ step %d: nll %.4f acc %.3f | minADE %.3f miss %.3f "
            "collision %.3f offroad %.3f", step, open_m["nll"],
            open_m["accuracy"], closed["overall"]["min_ade"],
            closed["overall"]["miss_rate"],
            closed["overall"]["collision_rate"],
            closed["overall"]["offroad_rate"])

    return eval_cb, state


def _with_nan_injection(step_fn, at_step: int):
    """Failure drill (``--inject-nan-at``): poison the *reported* loss
    from host call ``at_step`` onward so the NaN guard trips and the
    flight-recorder dump path runs for real. The parameter update itself
    is untouched — this perturbs only the metric the guard reads."""
    calls = {"n": 0}

    def wrapped(params, opt_state, batch):
        new_params, new_opt, metrics = step_fn(params, opt_state, batch)
        if calls["n"] >= at_step:
            metrics = dict(metrics)
            metrics["loss"] = float("nan")
        calls["n"] += 1
        return new_params, new_opt, metrics

    return wrapped


def train_single(args) -> dict:
    arch = get_sim_arch(args.arch)
    if args.reduced:
        arch = arch.reduced()
    if args.smoke:
        arch = arch.reduced(num_map=12, num_agents=4, num_steps=8)
    cfg = arch.agent_sim_config()
    scen = arch.scenario_config()
    model = AgentSimModel(cfg)
    specs = model.specs()
    mesh = (make_production_mesh() if args.production_mesh
            else make_mesh_for())
    ckpt_dir = resolve_ckpt_dir(args.ckpt_dir, arch, args.smoke)

    opt = bc_optimizer(args.lr, args.steps)
    data = ShardedIterator(make_batch_fn(scen), batch_size=args.batch,
                           seed=args.seed,
                           host_rank=jax.process_index(),
                           world=jax.process_count())
    holdout = holdout_batches(scen, args.batch, args.holdout_batches,
                              seed=args.seed)

    with use_mesh_rules(mesh):
        param_sh = sharding_for_specs(specs, mesh)
        params = jax.jit(lambda k: nnm.init_params(specs, k),
                         out_shardings=param_sh)(jax.random.key(args.seed))
        opt_state = jax.jit(opt.init, out_shardings=derive_opt_shardings(
            specs, jax.eval_shape(opt.init, params), mesh))(params)
        step = obs.CostAccounted(jax.jit(make_sim_train_step(model, opt)),
                                 "train.step", labels={"arch": arch.name})
        if args.inject_nan_at is not None:
            step = _with_nan_injection(step, args.inject_nan_at)

        eval_cb, eval_state = make_eval_cb(
            model, scen, holdout=holdout,
            n_scenes_per_family=args.eval_scenes_per_family,
            n_samples=args.eval_samples, seed=args.seed)

        # graceful preemption: SIGTERM triggers checkpoint-and-exit
        stop = {"flag": False}
        signal.signal(signal.SIGTERM, lambda *_: stop.update(flag=True))

        flight = (obs.FlightRecorder(out_path=args.postmortem_out)
                  if args.postmortem_out else None)

        trainer = Trainer(
            step, params, opt_state, data, ckpt_dir,
            TrainerConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every,
                          log_every=max(1, args.steps // 20),
                          eval_every=args.eval_every),
            metrics_cb=lambda s, m: log.info(
                "step %d loss %.4f acc %.3f (%.2fs/step)", s, m["loss"],
                m.get("accuracy", float("nan")), m["sec_per_step"]),
            should_stop=lambda: stop["flag"],
            param_shardings=param_sh,
            eval_cb=eval_cb,
            flight=flight)
        trainer.restore_if_available(force=args.force)
        out = trainer.run()
        # final eval, unless the cadence already evaluated THIS step in
        # this process (a restored already-complete run, or a NaN-skipped
        # final step, never fired the in-loop hook)
        if eval_state["last_step"] != trainer.step:
            eval_cb(trainer.step, trainer.params)
        data.close()

    result = {
        "arch": arch.name, "encoding": arch.encoding, "status": out["status"],
        "steps": trainer.step,
        # NaN-guard outcome in the final summary: a run that silently
        # discarded updates must say so next to its loss numbers
        "nan_skipped": out.get("nan_skipped", 0),
        **loss_summary(trainer.history),
        **{f"final_{k2}": v for k2, v in
           (eval_state["last"] or {}).get("open_loop", {}).items()},
    }
    closed = (eval_state["last"] or {}).get("closed_loop", {})
    result.update({f"closed_{m}": closed.get(m, float("nan"))
                   for m in ("min_ade", "miss_rate", "collision_rate",
                             "offroad_rate")})
    log.info("finished: %s", result)

    if args.smoke:
        assert out["status"] == "done", out
        assert np.isfinite(result["loss_last"]), result
        assert result["loss_last"] < result["loss_first"], \
            f"loss did not decrease: {result}"
        # checkpoint round-trip: the final save must restore bit-exactly
        tree, extra = trainer.ckpt.restore(trainer.ckpt.latest_step())
        assert int(extra["step"]) == trainer.step
        for a, b in zip(jax.tree.leaves(tree["params"]),
                        jax.tree.leaves(trainer.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        log.info("smoke OK: loss %.4f -> %.4f, checkpoint round-trip exact",
                 result["loss_first"], result["loss_last"])
    return result


def train_compare(args) -> dict:
    arch = get_sim_arch(args.arch)
    if args.reduced or args.smoke:
        arch = arch.reduced()
    if args.smoke:
        arch = arch.reduced(num_map=12, num_agents=4, num_steps=8)
    encodings = (tuple(args.encodings.split(","))
                 if args.encodings else COMPARISON_ENCODINGS)
    if args.smoke and not args.encodings:
        # the acceptance pair: one relative encoding vs the baseline
        encodings = ("se2_fourier", "absolute")
    report = lambda name, val, extra="": print(f"{name},{val},{extra}",
                                               flush=True)
    rows = run_comparison(
        arch, encodings, steps=args.steps, batch=args.batch, lr=args.lr,
        seed=args.seed, holdout_n=args.holdout_batches,
        n_scenes_per_family=args.eval_scenes_per_family,
        eval_samples=args.eval_samples, report=report)
    print(format_table(rows))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(rows, f, indent=2)
        log.info("wrote %s", args.out)
    if args.smoke:
        for enc in encodings:
            row = rows[enc]
            assert row["status"] == "done", (enc, row)
            assert np.isfinite(row["open_loop_nll"]), (enc, row)
            assert np.isfinite(row["closed_loop_min_ade"]), (enc, row)
            assert row["loss_last"] < row["loss_first"], (enc, row)
        log.info("compare smoke OK: %s", list(encodings))
    return rows


def main():
    ap = argparse.ArgumentParser(
        description="Behavior-cloning training for the SE(2) agent-sim "
                    "model on scenario-family expert demonstrations.")
    ap.add_argument("--arch", default="sim-se2-fourier",
                    help=f"one of {SIM_ARCH_NAMES}")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-encoding config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint root (a per-arch+shape subdir is "
                         f"appended; default {DEFAULT_CKPT_ROOT}, or a "
                         "fresh temp dir under --smoke)")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--eval-every", type=int, default=0,
                    help="closed-loop eval cadence in steps (0 = final only)")
    ap.add_argument("--eval-scenes-per-family", type=int, default=2)
    ap.add_argument("--eval-samples", type=int, default=2)
    ap.add_argument("--holdout-batches", type=int, default=4)
    ap.add_argument("--compare", action="store_true",
                    help="train every encoding under one budget and print "
                         "the invariant-vs-absolute table")
    ap.add_argument("--encodings", default=None,
                    help="comma-separated subset for --compare")
    ap.add_argument("--out", default=None,
                    help="write --compare results to this JSON path")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="resume even from a checkpoint tagged with a "
                         "halt_reason (e.g. a NaN-halt save); without it "
                         "the trainer refuses to blindly replay the same "
                         "divergence")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run with health assertions")
    ap.add_argument("--telemetry-out", default=None, metavar="PATH",
                    help="write the run's Chrome/Perfetto telemetry trace "
                         "(trainer step/eval/checkpoint spans + registry "
                         "snapshot) to PATH; render with "
                         "python -m repro.launch.obs_report")
    ap.add_argument("--prom-out", default=None, metavar="PATH",
                    help="also dump the registry in Prometheus text "
                         "exposition format")
    ap.add_argument("--telemetry-dir", default=None, metavar="DIR",
                    help="write this process's trace as DIR/rankNNNNN."
                         "trace.jsonl, stamped with its fleet identity; "
                         "merge a fleet's worth with "
                         "python -m repro.launch.obs_merge DIR")
    ap.add_argument("--postmortem-out", default=None, metavar="PATH",
                    help="arm the flight recorder: on NaN-halt or SIGTERM "
                         "preemption, dump a postmortem bundle to PATH "
                         "(render with obs_report --postmortem)")
    ap.add_argument("--inject-nan-at", type=int, default=None, metavar="N",
                    help="failure drill: report NaN losses from step N "
                         "onward so the NaN guard halts and the flight "
                         "recorder fires (exits nonzero by design)")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="capture a jax.profiler trace of the whole run "
                         "into DIR")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    if args.smoke and args.steps == 200:
        args.steps = 40
    # one fresh registry as the process default: the Trainer, every
    # rollout engine the eval hook builds, and any SimServer all land in
    # the same timeline without threading a parameter through
    reg = obs.Registry()
    obs.set_registry(reg)
    if args.profile_dir:
        jax.profiler.start_trace(args.profile_dir)
    try:
        if args.compare:
            train_compare(args)
        else:
            train_single(args)
    finally:
        if args.profile_dir:
            jax.profiler.stop_trace()
            log.info("jax profiler trace written under %s", args.profile_dir)
        if args.telemetry_out:
            obs.write_chrome_trace(reg, args.telemetry_out)
            log.info("telemetry trace: %s", args.telemetry_out)
        if args.telemetry_dir:
            obs.fleet.stamp_process_identity(reg)
            log.info("per-rank telemetry trace: %s",
                     obs.fleet.write_rank_trace(reg, args.telemetry_dir,
                                                process_name="train_sim"))
        if args.prom_out:
            with open(args.prom_out, "w") as f:
                f.write(obs.prometheus_text(reg))
            log.info("prometheus exposition: %s", args.prom_out)


if __name__ == "__main__":
    main()
