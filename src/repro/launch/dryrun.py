import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

_DOC = """Multi-pod dry-run: AOT-lower + compile every (arch x shape x mesh) cell.

Per cell, three artifacts:

  1. FULL config, rolled layer scans, production mesh — ``.lower().compile()``
     must succeed. This is the sharding-coherence proof, and its
     ``memory_analysis`` (which sees the O(L) stacked remat carries inside
     the scan state) is the fits-in-HBM evidence.
  2. Two DEPTH VARIANTS (2 and 4 scan iterations, full width) with layer
     and attention-chunk loops FULLY UNROLLED — XLA's cost_analysis counts
     while bodies once, so unrolled shallow variants give exact
     per-iteration FLOPs / bytes / collective-bytes at production width.
     The roofline extrapolates linearly to full depth (layer groups are
     homogeneous; the two-point fit separates the per-layer slope from the
     depth-independent intercept: embeddings, logits, optimizer, loss).

The multi-pod pass (2x16x16) runs configuration 1 only: it exists to prove
the "pod" axis shards. The roofline table is single-pod by definition.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--skip-existing]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs import (ARCH_NAMES, SHAPES, SIM_ARCH_NAMES, get_config,
                           get_sim_arch)
from repro.distributed.sharding import (DEFAULT_RULES, derive_opt_shardings,
                                        sharding_for_specs, use_mesh_rules)
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.roofline import (CollectiveStats, model_flops_for,
                                   parse_collectives, roofline_terms)
from repro.nn import module as nnm
from repro.nn.transformer import build_model
from repro.optim import adafactor, adamw, chain, clip_by_global_norm
from repro.runtime.steps import (batch_shardings, input_specs,
                                 make_prefill_step, make_serve_step,
                                 make_train_step)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")
VARIANT_ITERS = (2, 4)
SIM_SHAPE = "sim_train"        # the one shape a sim arch lowers
SIM_TRAIN_BATCH = 256          # global batch for the sim train cell


def choose_optimizer(cfg):
    """Adafactor for the 1T config (optimizer-state memory: see optim docs);
    AdamW everywhere else."""
    if cfg.name.startswith("kimi"):
        return chain(clip_by_global_norm(1.0), adafactor(1e-4))
    return chain(clip_by_global_norm(1.0), adamw(3e-4))


def applicable(cfg, shape) -> bool:
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return False
    return True


def _compile_step(cfg, shape, mesh, rules, unroll: bool):
    """Lower + compile one step function; returns (compiled, t_lower, t_comp)."""
    model = build_model(cfg, unroll=unroll)
    specs = model.specs()
    aparams = nnm.abstract_params(specs)
    impl = "chunked_unrolled" if unroll else "chunked"
    t0 = time.time()
    with use_mesh_rules(mesh, rules):
        param_sh = sharding_for_specs(specs, mesh, rules)
        ins = input_specs(cfg, shape)
        in_sh = batch_shardings(ins, mesh, rules)
        if shape.mode == "train":
            opt = choose_optimizer(cfg)
            opt_abs = jax.eval_shape(opt.init, aparams)
            opt_sh = derive_opt_shardings(specs, opt_abs, mesh, rules)
            step = make_train_step(cfg, opt, remat=True, impl=impl,
                                   unroll=unroll)
            jitted = jax.jit(step, in_shardings=(param_sh, opt_sh, in_sh),
                             out_shardings=(param_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(aparams, opt_abs, ins)
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg, impl=impl, unroll=unroll)
            jitted = jax.jit(step, in_shardings=(param_sh, in_sh))
            lowered = jitted.lower(aparams, ins)
        else:
            step = make_serve_step(cfg, impl=impl, unroll=unroll)
            cache_sh = in_sh["cache"]
            args = [aparams, ins["cache"], ins["tokens"], ins["index"]]
            shs = [param_sh, cache_sh, in_sh["tokens"], in_sh["index"]]
            if cfg.enc_dec:
                args.append(ins["enc_out"])
                shs.append(in_sh["enc_out"])
            jitted = jax.jit(step, in_shardings=tuple(shs),
                             out_shardings=(None, cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _analyze(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), coll, mem)


def _memory_record(mem):
    """The shared fits-in-HBM accounting (LM and sim cells must agree)."""
    memory = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
    }
    hbm = ((memory["argument_bytes"] or 0)
           + (memory["temp_bytes"] or 0)) / 1024**3
    return {"memory": memory, "hbm_per_chip_gib": hbm,
            "fits_hbm": hbm < HW["hbm_bytes"] / 1024**3}


def lower_cell(arch: str, shape_name: str, multi_pod: bool, rules=None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    if not applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped",
                "reason": "full-attention arch at 500k decode "
                          "(see DESIGN.md Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or DEFAULT_RULES
    chips = mesh.devices.size
    n_params = nnm.count_params(build_model(cfg).specs())

    # --- 1. full config, rolled: sharding proof + memory analysis ---------
    compiled_full, t_lower, t_compile = _compile_step(cfg, shape, mesh, rules,
                                                      unroll=False)
    _, _, _, mem = _analyze(compiled_full)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "ok",
        "chips": chips, "n_params": n_params, "mode": shape.mode,
        "full_compile_s": t_compile, "full_lower_s": t_lower,
        **_memory_record(mem),
    }
    del compiled_full

    if multi_pod:
        # multi-pod pass = compile-success proof only
        return record

    # --- 2. depth variants, unrolled: per-iteration cost measurement ------
    meas = []
    for it in VARIANT_ITERS:
        vcfg = cfg.depth_variant(it)
        comp, _, tc = _compile_step(vcfg, shape, mesh, rules, unroll=True)
        f, b, coll, _ = _analyze(comp)
        meas.append({"iters": vcfg.scan_iters(), "flops": f, "bytes": b,
                     "coll": coll.per_chip_bytes,
                     "coll_by_kind": coll.by_kind, "compile_s": tc})
        del comp
    (m1, m2) = meas
    s1, s2 = m1["iters"], m2["iters"]
    s_full = cfg.scan_iters()

    def extrap(key):
        slope = (m2[key] - m1[key]) / (s2 - s1)
        return m1[key] + (s_full - s1) * slope, slope

    flops, flops_slope = extrap("flops")
    bytes_acc, _ = extrap("bytes")
    coll_bytes, _ = extrap("coll")
    coll_kinds = {}
    for k in set(m1["coll_by_kind"]) | set(m2["coll_by_kind"]):
        a = m1["coll_by_kind"].get(k, 0.0)
        b2 = m2["coll_by_kind"].get(k, 0.0)
        coll_kinds[k] = a + (s_full - s1) * (b2 - a) / (s2 - s1)

    coll = CollectiveStats(per_chip_bytes=coll_bytes, by_kind=coll_kinds)
    terms = roofline_terms(flops, bytes_acc, coll, chips, HW)
    mflops = model_flops_for(cfg, shape)
    record.update({
        "flops": flops, "bytes_accessed": bytes_acc,
        "per_iter_flops": flops_slope,
        "collectives": coll.to_dict(),
        "variant_measurements": meas,
        "terms": terms,
        "model_flops": mflops,
        "useful_flops_frac": (mflops / (flops * chips)) if flops else None,
    })
    return record


def lower_sim_cell(arch: str, multi_pod: bool, rules=None):
    """AOT proof for an agent-sim arch: compile its sharded BC train step
    (``repro.training.steps``) on the production mesh.

    This is the sharding-coherence + fits-in-HBM evidence for the new
    workload. The depth-variant roofline extrapolation is an LM-arch
    concept (homogeneous scanned groups measured at production width); sim
    cells record the full compile + memory analysis only, like the
    multi-pod pass does for LM archs.
    """
    from repro.nn.agent_sim import AgentSimModel
    from repro.training.steps import (make_sim_train_step, sim_batch_shardings,
                                      sim_input_specs)

    sim = get_sim_arch(arch)
    cfg = sim.agent_sim_config()
    scen = sim.scenario_config()
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    rules = rules or DEFAULT_RULES
    model = AgentSimModel(cfg)
    specs = model.specs()
    aparams = nnm.abstract_params(specs)
    opt = chain(clip_by_global_norm(1.0), adamw(3e-4))
    t0 = time.time()
    with use_mesh_rules(mesh, rules):
        param_sh = sharding_for_specs(specs, mesh, rules)
        ins = sim_input_specs(scen, SIM_TRAIN_BATCH)
        in_sh = sim_batch_shardings(ins, mesh, rules)
        opt_abs = jax.eval_shape(opt.init, aparams)
        opt_sh = derive_opt_shardings(specs, opt_abs, mesh, rules)
        jitted = jax.jit(make_sim_train_step(model, opt),
                         in_shardings=(param_sh, opt_sh, in_sh),
                         out_shardings=(param_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(aparams, opt_abs, ins)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    _, _, _, mem = _analyze(compiled)
    return {
        "arch": arch, "shape": SIM_SHAPE, "mesh": mesh_name, "status": "ok",
        "chips": mesh.devices.size, "n_params": nnm.count_params(specs),
        "mode": "train", "encoding": sim.encoding,
        "full_compile_s": t_compile, "full_lower_s": t_lower,
        **_memory_record(mem),
    }


def run_cell(arch, shape_name, multi_pod, out_dir, skip_existing=False):
    mesh_name = "multi" if multi_pod else "single"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") in ("ok", "skipped"):
            print(f"[cached] {arch} {shape_name} {mesh_name}", flush=True)
            return rec
    try:
        rec = (lower_sim_cell(arch, multi_pod)
               if arch in SIM_ARCH_NAMES
               else lower_cell(arch, shape_name, multi_pod))
    except Exception as e:  # record failures; they are bugs to fix
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    status = rec["status"]
    extra = ""
    if status == "ok":
        extra = (f" hbm={rec['hbm_per_chip_gib']:.2f}GiB "
                 f"compile={rec['full_compile_s']:.0f}s")
        if "terms" in rec:
            extra += f" dom={rec['terms']['dominant']}"
    print(f"[{status}] {arch} {shape_name} {mesh_name}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser(description=_DOC)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    all_archs = ARCH_NAMES + SIM_ARCH_NAMES
    archs = all_archs if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    # a sim arch has exactly one shape (its scenario config fixes the token
    # budget); LM archs iterate the assigned LM shapes
    cells = [(a, s) for a in archs
             for s in ([SIM_SHAPE] if a in SIM_ARCH_NAMES else shapes)]
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out,
                           skip_existing=args.skip_existing)
            failures += rec["status"] == "error"
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
