"""Chaos drill suite: ``python -m repro.launch.chaos``.

Runs the scripted fault scenarios end-to-end through the real
:class:`~repro.runtime.trainer.Trainer` and
:class:`~repro.runtime.sim_server.SimServer`, asserts the recovery
invariants the robustness layer promises (``docs/robustness.md``), and
writes a ``BENCH_chaos.json`` summary:

* **corrupt_ckpt_resume** — train, truncate the latest checkpoint's
  ``arrays.npz``, relaunch: the trainer must fall back to the previous
  *verified* step and the resumed run must be BIT-exact (params + loss
  history) with the fault-free trajectory.
* **nan_slot_quarantine** — poison one resident slot's poses/logits
  with NaN mid-rollout ({f32, int8} caches): the lane is quarantined
  (``SimResult.status == "failed"`` + reason + counter), every healthy
  lane stays bit-identical to a no-fault run, and a fresh scene admitted
  into the scrubbed slot bit-matches a solo engine.
* **dead_worker** — a deterministic ``make_batch`` failure must raise
  ``DataWorkerError`` within bounded retries (never hang, never
  silently respawn); a transient failure inside the retry budget must
  recover with the batch stream unchanged.
* **async_save_io** — transient save-IO failures are retried with
  backoff and the checkpoint still verifies; a persistent failure is
  re-raised at ``wait()`` instead of dying in the daemon thread; stale
  ``.tmp`` debris is swept at manager startup.
* **delay_tick** — injected tick latency perturbs timing only: the
  served results stay bit-identical.

Every drill dumps a flight-recorder bundle and re-renders it through
``obs_report``'s postmortem view — a drill that can't be debugged
afterwards failed, whatever its asserts said.

Faults come from a seeded :class:`~repro.chaos.FaultPlan`; the whole
suite is deterministic, which is what lets it demand bit-exactness.
"""
from __future__ import annotations

import argparse
import json
import logging
import os
import tempfile
import time
from typing import Any, Dict

import jax
import numpy as np

from repro import chaos, obs
from repro.checkpoint import CheckpointManager, CheckpointWriteError
from repro.data.pipeline import DataWorkerError, ShardedIterator
from repro.launch.obs_report import render_postmortem
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimConfig, AgentSimModel
from repro.optim import adamw, chain, clip_by_global_norm
from repro.runtime.rollout import RolloutEngine
from repro.runtime.sim_server import SceneRequest, SimServer
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.scenarios import ScenarioConfig
from repro.scenarios.registry import generate_mixed, generate_scene
from repro.training.data import make_batch_fn
from repro.training.steps import make_sim_train_step

log = logging.getLogger("repro.launch.chaos")

SCEN = ScenarioConfig(num_map=8, num_agents=3, num_steps=6)
T_HIST = 3


def _model(seed: int = 0):
    cfg = AgentSimConfig(d_model=32, num_layers=2, num_heads=2, head_dim=12,
                         d_ff=64, num_actions=SCEN.num_actions,
                         encoding="se2_fourier", attn_impl="ref")
    model = AgentSimModel(cfg)
    return model, nnm.init_params(model.specs(), jax.random.key(seed))


def _sim_trainer(ckpt_dir: str, total_steps: int, *, seed: int = 0,
                 step_fn=None, flight=None) -> Trainer:
    """A tiny but real BC training stack (the test suite's shape)."""
    model, params = _model(seed)
    opt = chain(clip_by_global_norm(1.0), adamw(3e-3))
    step = step_fn or jax.jit(make_sim_train_step(model, opt))
    data = ShardedIterator(make_batch_fn(SCEN), batch_size=2, seed=seed)
    return Trainer(step, params, opt.init(params), data, ckpt_dir,
                   TrainerConfig(total_steps=total_steps, ckpt_every=4,
                                 log_every=100),
                   flight=flight)


def _assert_bit_identical(got, want, label: str):
    got, want = np.asarray(got), np.asarray(want)
    if not np.array_equal(got, want):
        bad = np.flatnonzero((got != want).ravel())
        raise AssertionError(
            f"{label}: {bad.size}/{got.size} elements differ "
            f"(first at flat index {bad[0]})")


def _dump_and_render(fr: obs.FlightRecorder, path: str, *, reason: str,
                     **context) -> str:
    """Every drill must leave a postmortem the tooling can actually
    read: dump the bundle and round-trip it through the obs_report
    renderer."""
    out = fr.dump(reason=reason, path=path, **context)
    with open(out) as f:
        bundle = json.load(f)
    text = render_postmortem(bundle)
    assert reason in text, f"postmortem render lost the reason: {out}"
    return out


# ---------------------------------------------------------------------------
# scenario 1: corrupt-latest checkpoint -> fallback restore, bit-exact resume
# ---------------------------------------------------------------------------

def drill_corrupt_ckpt_resume(workdir: str, plan: chaos.FaultPlan,
                              bundle_path: str) -> Dict[str, Any]:
    steps_mid, steps_total = 8, 12

    # fault-free reference trajectory
    tr_ref = _sim_trainer(os.path.join(workdir, "ref"), steps_total)
    tr_ref.run()
    tr_ref.data.close()

    # interrupted run: checkpoints at 4 and 8, then the latest is torn
    ckpt_dir = os.path.join(workdir, "victim")
    tr_a = _sim_trainer(ckpt_dir, steps_mid)
    tr_a.run()
    tr_a.data.close()
    steps_before = CheckpointManager(ckpt_dir).available_steps()
    corruption = chaos.corrupt_checkpoint(
        ckpt_dir, "truncate_checkpoint_npz", plan=plan)

    # relaunch: must walk back to the newest VERIFIED step, not crash
    fr = obs.FlightRecorder()
    tr_b = _sim_trainer(ckpt_dir, steps_total, flight=fr)
    assert tr_b.restore_if_available(), "no checkpoint restored"
    report = tr_b.ckpt.last_restore_report
    assert report["step"] == 4, report
    assert [s["step"] for s in report["skipped"]] == [8], report
    tr_b.run()
    tr_b.data.close()

    _assert_bit_identical(
        np.asarray(tr_b.history), np.asarray(tr_ref.history[4:]),
        "loss history after fallback resume")
    for a, b in zip(jax.tree.leaves(tr_b.params),
                    jax.tree.leaves(tr_ref.params)):
        _assert_bit_identical(a, b, "params after fallback resume")

    _dump_and_render(fr, bundle_path, reason="chaos_corrupt_ckpt_resume",
                     corruption=corruption, fallback_step=report["step"])
    return {"passed": True, "steps_present_before": steps_before,
            "fallback_step": report["step"],
            "skipped": report["skipped"],
            "resume_bit_exact": True}


# ---------------------------------------------------------------------------
# scenario 2: NaN-poisoned slot -> quarantine; healthy slots bit-identical
# ---------------------------------------------------------------------------

def _submit_lanes(srv: SimServer, scenes, seed: int):
    for i, scene in enumerate(scenes):
        srv.submit(SceneRequest(uid=i, tensors=scene, t_hist=T_HIST,
                                seed=seed, scene_id=i))


def _drive(srv: SimServer, plan: chaos.FaultPlan, *,
           victim_uid: int = None) -> int:
    """Tick until drained, firing scheduled poison/delay faults against
    the drill's tick clock."""
    tick = 0
    while srv.queue or any(s.req for s in srv.slots):
        f = plan.fires("delay_tick", tick)
        if f is not None:
            time.sleep(f.param)
        f = plan.fires("poison_slot_nan", tick)
        if f is not None:
            chaos.poison_server_slot(srv, f.target, plan=None, tick=tick)
        srv.tick()
        tick += 1
        if tick > 10_000:
            raise RuntimeError("drill server did not drain")
    srv.flush()
    return tick


def drill_nan_slot_quarantine(workdir: str, plan_seed: int,
                              bundle_path: str) -> Dict[str, Any]:
    model, params = _model()
    scenes = generate_mixed(5, 0, 3, SCEN)
    out: Dict[str, Any] = {"passed": True}
    for cache_dtype in ("float32", "int8"):
        # fault-free reference: same submissions, no poison
        ref = SimServer(model, params, SCEN, num_slots=2,
                        cache_dtype=cache_dtype)
        _submit_lanes(ref, scenes, seed=11)
        _drive(ref, chaos.FaultPlan(seed=plan_seed))
        assert all(r.status == "ok" for r in ref.done.values())

        # poisoned run: NaN into slot 0 (the victim's) mid-rollout
        srv = SimServer(model, params, SCEN, num_slots=2,
                        cache_dtype=cache_dtype)
        plan = chaos.FaultPlan(
            [chaos.Fault("poison_slot_nan", at=4, target=0)],
            seed=plan_seed)
        _submit_lanes(srv, scenes, seed=11)
        _drive(srv, plan)
        assert plan.fired_counts().get("poison_slot_nan") == 1, plan.fired

        victim = srv.done[0]
        assert victim.status == "failed" and victim.reason, victim
        assert srv.quarantined == 1, srv.stats()
        healthy = [u for u in srv.done if srv.done[u].status == "ok"]
        assert len(healthy) == len(scenes) - 1, sorted(srv.done)
        for uid in healthy:
            _assert_bit_identical(srv.done[uid].future, ref.done[uid].future,
                                  f"healthy lane {uid} poses ({cache_dtype})")
            _assert_bit_identical(srv.done[uid].actions,
                                  ref.done[uid].actions,
                                  f"healthy lane {uid} acts ({cache_dtype})")

        # recovery: a fresh scene through the scrubbed slot bit-matches solo
        fresh = generate_scene("highway", 123, 0, SCEN)
        eng = RolloutEngine(model, params, SCEN, num_slots=1,
                            cache_dtype=cache_dtype)
        solo = eng.run([fresh], t_hist=T_HIST, n_samples=1, seed=21)
        srv.submit(SceneRequest(uid=99, tensors=fresh, t_hist=T_HIST,
                                seed=21, scene_id=0, sample_id=0))
        srv.run_until_drained()
        assert srv.done[99].status == "ok"
        _assert_bit_identical(srv.done[99].future, solo[0, 0],
                              f"post-quarantine admission ({cache_dtype})")
        out[cache_dtype] = {"quarantined": srv.quarantined,
                            "victim_reason": victim.reason,
                            "healthy_bit_identical": True,
                            "recycle_bit_identical": True}
        if cache_dtype == "int8":
            srv.dump_postmortem(bundle_path, reason="chaos_nan_quarantine")
            with open(bundle_path) as f:
                assert "chaos_nan_quarantine" in render_postmortem(
                    json.load(f))
    return out


# ---------------------------------------------------------------------------
# scenario 3: dead data worker -> bounded raise; transient -> exact recovery
# ---------------------------------------------------------------------------

def drill_dead_worker(workdir: str, plan_seed: int,
                      bundle_path: str) -> Dict[str, Any]:
    make_batch = make_batch_fn(SCEN)

    # deterministic failure: must raise within bounded retries, not hang
    plan = chaos.FaultPlan(
        [chaos.Fault("kill_data_worker", at=0, count=10 ** 6)],
        seed=plan_seed)
    it = ShardedIterator(chaos.flaky_make_batch(make_batch, plan),
                         batch_size=2, worker_retries=2,
                         retry_backoff=0.01)
    t0 = time.perf_counter()
    raised = False
    try:
        next(it)
    except DataWorkerError:
        raised = True
    raise_s = time.perf_counter() - t0
    it.close()
    assert raised, "deterministic make_batch failure did not propagate"
    assert raise_s < 30.0, f"raise took {raise_s:.1f}s — effectively a hang"
    attempts = plan.fired_counts()["kill_data_worker"]
    assert attempts == 3, f"expected 1 try + 2 retries, saw {attempts}"

    # transient failure inside the retry budget: the stream is unchanged
    it_c = ShardedIterator(make_batch, batch_size=2)
    clean = next(it_c)
    it_c.close()
    plan_t = chaos.FaultPlan(
        [chaos.Fault("kill_data_worker", at=0, count=2)], seed=plan_seed)
    it_t = ShardedIterator(chaos.flaky_make_batch(make_batch, plan_t),
                           batch_size=2, worker_retries=2,
                           retry_backoff=0.01)
    recovered = next(it_t)
    it_t.close()
    for k in clean:
        _assert_bit_identical(recovered[k], clean[k],
                              f"transient-recovery batch[{k}]")

    fr = obs.FlightRecorder()
    fr.add_provider("fault_plan", plan.summary)
    _dump_and_render(fr, bundle_path, reason="chaos_dead_worker",
                     raise_s=raise_s, attempts=attempts)
    return {"passed": True, "raise_s": raise_s, "attempts": attempts,
            "transient_recovered": True}


# ---------------------------------------------------------------------------
# scenario 4: async-save IO failures -> retry/backoff; persistent -> surfaced
# ---------------------------------------------------------------------------

def drill_async_save_io(workdir: str, plan_seed: int,
                        bundle_path: str) -> Dict[str, Any]:
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": np.ones(3, np.float32)}

    # transient: two failed write attempts ride the retry budget
    plan = chaos.FaultPlan(
        [chaos.Fault("fail_async_save_io", at=0, count=2)], seed=plan_seed)
    d1 = os.path.join(workdir, "transient")
    mgr = CheckpointManager(d1, save_retries=2, retry_backoff=0.01,
                            io_hook=chaos.checkpoint_io_hook(plan))
    mgr.save(3, tree)
    mgr.wait()                          # must NOT raise: retries absorbed it
    assert mgr.verify(3) is None, mgr.verify(3)
    got, _ = mgr.restore(3)
    for k in tree:
        _assert_bit_identical(got[k], tree[k], f"transient-save restore {k}")
    transient_attempts = plan.fired_counts()["fail_async_save_io"]

    # persistent: the daemon-thread failure must surface at wait()
    plan_p = chaos.FaultPlan(
        [chaos.Fault("fail_async_save_io", at=0, count=10 ** 6)],
        seed=plan_seed)
    d2 = os.path.join(workdir, "persistent")
    mgr_p = CheckpointManager(d2, save_retries=1, retry_backoff=0.01,
                              io_hook=chaos.checkpoint_io_hook(plan_p))
    mgr_p.save(1, tree)
    raised = False
    try:
        mgr_p.wait()
    except CheckpointWriteError:
        raised = True
    assert raised, "persistent save failure was swallowed"
    assert mgr_p.latest_step() is None

    # stale-tmp sweep: a crashed writer's debris disappears at startup
    chaos.corrupt_checkpoint(d1, "stale_checkpoint_tmp", plan=plan)
    assert any(n.endswith(".tmp") for n in os.listdir(d1))
    CheckpointManager(d1)
    assert not any(n.endswith(".tmp") for n in os.listdir(d1))
    assert CheckpointManager(d1).verify(3) is None

    fr = obs.FlightRecorder()
    fr.add_provider("fault_plan", plan.summary)
    _dump_and_render(fr, bundle_path, reason="chaos_async_save_io",
                     transient_attempts=transient_attempts)
    return {"passed": True, "transient_attempts": transient_attempts,
            "persistent_raised": True, "stale_tmp_cleaned": True}


# ---------------------------------------------------------------------------
# scenario 5: injected tick latency -> timing-only, results bit-identical
# ---------------------------------------------------------------------------

def drill_delay_tick(workdir: str, plan_seed: int,
                     bundle_path: str) -> Dict[str, Any]:
    model, params = _model()
    scenes = generate_mixed(9, 0, 3, SCEN)

    ref = SimServer(model, params, SCEN, num_slots=2)
    _submit_lanes(ref, scenes, seed=5)
    _drive(ref, chaos.FaultPlan(seed=plan_seed))

    srv = SimServer(model, params, SCEN, num_slots=2)
    plan = chaos.FaultPlan(
        [chaos.Fault("delay_tick", at=2, count=3, param=0.02)],
        seed=plan_seed)
    _submit_lanes(srv, scenes, seed=5)
    _drive(srv, plan)
    fired = plan.fired_counts().get("delay_tick", 0)
    assert fired == 3, plan.fired
    assert sorted(srv.done) == sorted(ref.done)
    for uid in ref.done:
        _assert_bit_identical(srv.done[uid].future, ref.done[uid].future,
                              f"delayed lane {uid} poses")
    srv.dump_postmortem(bundle_path, reason="chaos_delay_tick")
    with open(bundle_path) as f:
        assert "chaos_delay_tick" in render_postmortem(json.load(f))
    return {"passed": True, "delays_fired": fired, "bit_identical": True}


# ---------------------------------------------------------------------------

DRILLS = {
    "corrupt_ckpt_resume": drill_corrupt_ckpt_resume,
    "nan_slot_quarantine": None,      # special-cased: takes plan_seed
    "dead_worker": drill_dead_worker,
    "async_save_io": drill_async_save_io,
    "delay_tick": drill_delay_tick,
}


def run_drills(*, seed: int = 0, workdir: str, bundles_dir: str,
               only=None) -> Dict[str, Any]:
    os.makedirs(bundles_dir, exist_ok=True)
    t0 = time.perf_counter()
    scenarios: Dict[str, Any] = {}
    names = [n for n in DRILLS if only is None or n in only]
    for name in names:
        log.info("drill: %s", name)
        wd = os.path.join(workdir, name)
        os.makedirs(wd, exist_ok=True)
        bundle = os.path.join(bundles_dir, f"chaos_{name}.json")
        t1 = time.perf_counter()
        if name == "corrupt_ckpt_resume":
            rec = drill_corrupt_ckpt_resume(
                wd, chaos.FaultPlan(seed=seed), bundle)
        elif name == "nan_slot_quarantine":
            rec = drill_nan_slot_quarantine(wd, seed, bundle)
        else:
            rec = DRILLS[name](wd, seed, bundle)
        rec["wall_s"] = round(time.perf_counter() - t1, 3)
        rec["bundle"] = os.path.basename(bundle)
        scenarios[name] = rec
        log.info("drill %s: PASS (%.1fs)", name, rec["wall_s"])
    return {
        "kind": "chaos_drill",
        "seed": seed,
        "scenarios": scenarios,
        "all_passed": all(r.get("passed") for r in scenarios.values()),
        "n_scenarios": len(scenarios),
        "wall_s": round(time.perf_counter() - t0, 3),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Deterministic chaos drills: fault-inject the "
                    "checkpoint/serving/data layers and assert the "
                    "self-healing contracts hold bit-exactly.")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, metavar="PATH",
                    help="write the BENCH_chaos.json summary here")
    ap.add_argument("--bundles-dir", default=None, metavar="DIR",
                    help="where each drill's flight-recorder bundle lands "
                         "(default: a temp dir)")
    ap.add_argument("--only", default=None,
                    help=f"comma-separated subset of {sorted(DRILLS)}")
    ap.add_argument("--smoke", action="store_true",
                    help="alias for the default full suite (the drills are "
                         "already CI-sized); kept for CI-invocation symmetry")
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    workdir = tempfile.mkdtemp(prefix="repro_chaos_")
    bundles = args.bundles_dir or os.path.join(workdir, "bundles")
    only = set(args.only.split(",")) if args.only else None
    if only is not None and (bad := only - set(DRILLS)):
        ap.error(f"unknown drills {sorted(bad)}; known: {sorted(DRILLS)}")
    record = run_drills(seed=args.seed, workdir=workdir, bundles_dir=bundles,
                        only=only)
    print(json.dumps(record, indent=2))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(record, f, indent=2)
            f.write("\n")
        log.info("wrote %s", args.out)
    return 0 if record["all_passed"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
