"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs.

Usage: PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
import os


def load(d):
    recs = []
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                recs.append(json.load(f))
    return recs


def fmt_ms(x):
    return f"{x*1e3:.1f}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.dir)

    print("| arch | shape | status | compute ms | memory ms | coll ms | "
          "dominant | useful FLOPs | HBM GiB/chip | fits |")
    print("|---|---|---|---:|---:|---:|---|---:|---:|---|")
    n_ok = n_skip = n_err = 0
    for r in recs:
        if r.get("mesh") != args.mesh:
            continue
        if r["status"] == "skipped":
            n_skip += 1
            print(f"| {r['arch']} | {r['shape']} | skipped "
                  f"(sub-quadratic n/a) | | | | | | | |")
            continue
        if r["status"] != "ok":
            n_err += 1
            print(f"| {r['arch']} | {r['shape']} | ERROR: "
                  f"{r.get('error','')[:60]} | | | | | | | |")
            continue
        n_ok += 1
        t = r.get("terms")
        if not t:
            # compile-proof-only cells (agent-sim train step): no roofline
            # terms, but the sharding + memory evidence is still a row
            print(f"| {r['arch']} | {r['shape']} | compiled | | | | | "
                  f"| {r.get('hbm_per_chip_gib', 0.0):.1f} "
                  f"| {'Y' if r.get('fits_hbm') else 'N'} |")
            continue
        u = r.get("useful_flops_frac")
        print(f"| {r['arch']} | {r['shape']} | ok | {fmt_ms(t['compute_s'])} "
              f"| {fmt_ms(t['memory_s'])} | {fmt_ms(t['collective_s'])} "
              f"| {t['dominant']} | {u:.2f} | {r['hbm_per_chip_gib']:.1f} "
              f"| {'Y' if r['fits_hbm'] else 'N'} |")
    print(f"\nok={n_ok} skipped={n_skip} errors={n_err}")

    # multi-pod compile proof summary
    print("\nMulti-pod (2x16x16) compile proof:")
    ok = [r for r in recs if r.get("mesh") == "multi" and r["status"] == "ok"]
    err = [r for r in recs if r.get("mesh") == "multi"
           and r["status"] == "error"]
    skip = [r for r in recs if r.get("mesh") == "multi"
            and r["status"] == "skipped"]
    print(f"  compiled: {len(ok)}  skipped: {len(skip)}  errors: {len(err)}")
    for r in err:
        print(f"  ERROR {r['arch']} {r['shape']}: {r.get('error','')[:100]}")


if __name__ == "__main__":
    main()
