"""Merge per-rank telemetry traces into one Perfetto timeline.

Inputs are the ``rank*.trace.jsonl`` files a fleet run writes under
``--telemetry-dir`` (``benchmarks/fleet_bench.py``,
``python -m repro.launch.train_sim``): pass the directory, or the files
explicitly. The merged file gets one named track per rank, wall-clock
aligned via each registry's ``epoch``, with ``straggler.flagged``
decisions overlaid on the flagged rank's own track, and a combined
registry snapshot whose instruments carry a ``rank`` label — load it at
https://ui.perfetto.dev or render it with
``python -m repro.launch.obs_report``.

Run:  python -m repro.launch.obs_merge /tmp/fleet_tel
      python -m repro.launch.obs_merge rank00000.trace.jsonl \
          rank00001.trace.jsonl -o merged.trace.jsonl

Unusable inputs exit with status 2 and a one-line error on stderr.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.obs import fleet


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Merge per-rank repro telemetry traces into one "
                    "Perfetto timeline (one named track per rank).")
    ap.add_argument("inputs", nargs="+",
                    help="rank trace files, or one directory containing "
                         "rank*.trace.jsonl files")
    ap.add_argument("-o", "--out", default=None,
                    help="output path (default: merged.trace.jsonl next "
                         "to the inputs)")
    args = ap.parse_args(argv)

    try:
        if len(args.inputs) == 1 and os.path.isdir(args.inputs[0]):
            paths = fleet.discover_rank_traces(args.inputs[0])
            out = args.out or os.path.join(args.inputs[0],
                                           "merged.trace.jsonl")
        else:
            paths = list(args.inputs)
            out = args.out or os.path.join(
                os.path.dirname(paths[0]) or ".", "merged.trace.jsonl")
        summary = fleet.merge_traces(paths, out)
    except (fleet.MergeError, OSError) as e:
        print(f"error: {e}".splitlines()[0], file=sys.stderr)
        return 2

    ranks = summary["ranks"]
    print(f"merged {len(ranks)} rank trace(s) "
          f"(ranks {', '.join(map(str, ranks))}; "
          f"{summary['events']} events, "
          f"{summary['straggler_overlays']} straggler overlay(s)) "
          f"-> {summary['out']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
