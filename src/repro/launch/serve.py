"""Serving launcher: batched continuous-batching decode of an LM config.

``python -m repro.launch.serve --arch stablelm-3b --reduced --requests 8``
"""
from __future__ import annotations

import argparse
import logging
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.nn import module as nnm
from repro.nn.transformer import build_model
from repro.runtime.server import Request, Server

log = logging.getLogger("repro.launch.serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    if cfg.enc_dec:
        raise SystemExit("enc-dec serving demo: see examples/ for whisper")
    model = build_model(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(0))
    srv = Server(model, params, num_slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for uid in range(args.requests):
        srv.submit(Request(
            uid=uid, prompt=rng.integers(1, cfg.vocab_size, rng.integers(4, 12)),
            max_new_tokens=args.max_new, temperature=args.temperature))
    done = srv.run_until_drained()
    dt = time.time() - t0
    total_tokens = sum(len(r.generated) for r in done.values())
    log.info("served %d requests, %d tokens in %.2fs (%.1f tok/s, %d ticks)",
             len(done), total_tokens, dt, total_tokens / dt, srv.ticks)
    for uid in sorted(done):
        log.info("req %d -> %s", uid, done[uid].generated)


if __name__ == "__main__":
    main()
