"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires configs -> model -> sharded step -> fault-tolerant Trainer. On a real
TPU fleet, ``jax.distributed.initialize()`` is called per host and the same
code runs unchanged (mesh axes span the fleet); on this CPU host it runs
tiny reduced configs end-to-end for validation.
"""
from __future__ import annotations

import argparse
import logging
import signal

import jax
import numpy as np

from repro.configs import get_config
from repro.data import synthetic_lm
from repro.data.pipeline import ShardedIterator
from repro.distributed.sharding import (derive_opt_shardings,
                                        sharding_for_specs, use_mesh_rules)
from repro.launch.mesh import make_mesh_for, make_production_mesh
from repro.nn import module as nnm
from repro.nn.transformer import build_model
from repro.optim import adamw, chain, clip_by_global_norm, warmup_cosine
from repro.runtime.steps import make_train_step
from repro.runtime.trainer import Trainer, TrainerConfig

log = logging.getLogger("repro.launch.train")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized same-family config")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    if args.production_mesh:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = make_mesh_for()

    opt = chain(clip_by_global_norm(1.0),
                adamw(warmup_cosine(args.lr, 20, args.steps)))
    model = build_model(cfg)
    specs = model.specs()

    data_cfg = synthetic_lm.LMDataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=args.seq)

    def mk(seed, idx, bs):
        b = synthetic_lm.generate_batch(seed, idx, bs, data_cfg)
        if cfg.enc_dec:
            b["frames"] = np.zeros((bs, cfg.encoder_frames, cfg.d_model),
                                   np.float32)
        if cfg.vision_prefix:
            b["prefix"] = np.zeros((bs, cfg.vision_prefix, cfg.d_model),
                                   np.float32)
        return b

    data = ShardedIterator(mk, batch_size=args.batch, seed=0,
                           host_rank=jax.process_index(),
                           world=jax.process_count())

    with use_mesh_rules(mesh):
        param_sh = sharding_for_specs(specs, mesh)
        params = jax.jit(lambda k: nnm.init_params(specs, k),
                         out_shardings=param_sh)(jax.random.key(0))
        opt_state = jax.jit(opt.init, out_shardings=derive_opt_shardings(
            specs, jax.eval_shape(opt.init, params), mesh))(params)
        step = jax.jit(make_train_step(cfg, opt, remat=True))

        # graceful preemption: SIGTERM triggers checkpoint-and-exit
        stop = {"flag": False}
        signal.signal(signal.SIGTERM, lambda *_: stop.update(flag=True))

        trainer = Trainer(
            step, params, opt_state, data, args.ckpt_dir,
            TrainerConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every, log_every=10),
            metrics_cb=lambda s, m: log.info(
                "step %d loss %.4f (%.2fs/step)", s, m["loss"],
                m["sec_per_step"]),
            should_stop=lambda: stop["flag"],
            param_shardings=param_sh)
        trainer.restore_if_available()
        out = trainer.run()
        log.info("finished: %s", out)
        data.close()


if __name__ == "__main__":
    main()
