"""Render a telemetry trace as terminal tables.

Consumes the Chrome/Perfetto trace file the telemetry layer writes
(``repro.obs.write_chrome_trace``, or the ``--telemetry-out`` flag on
``launch/serve_sim.py`` / ``python -m repro.launch.train_sim``): the
span timeline gives per-region latency percentiles, and the embedded
``repro.registry_snapshot`` instant event gives counters (compile
counts, NaN skips, admissions), gauges (occupancy, resident slots,
slab bytes) and histogram aggregates — one file, both views.

Run:  python -m repro.launch.obs_report /tmp/run.trace.jsonl
      python -m repro.launch.obs_report /tmp/run.trace.jsonl --json
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List

from repro import obs

COMPILE_SUFFIX = "_traces"      # counters counting jit trace events


def _fmt(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v != v:                       # NaN
            return "-"
        if v and (abs(v) >= 1e5 or abs(v) < 1e-3):
            return f"{v:.3e}"
        return f"{v:,.3f}".rstrip("0").rstrip(".")
    return str(v)


def _table(title: str, headers: List[str],
           rows: List[List[Any]]) -> str:
    if not rows:
        return ""
    cells = [[_fmt(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells))
              for i, h in enumerate(headers)]
    def line(cols, pad=" "):
        return "  ".join(c.ljust(w, pad) if i == 0 else c.rjust(w, pad)
                         for i, (c, w) in enumerate(zip(cols, widths)))
    out = [f"== {title} ==", line(headers),
           line(["-" * w for w in widths])]
    out += [line(r) for r in cells]
    return "\n".join(out) + "\n"


def _label_str(labels: Dict[str, Any]) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


def span_rows(events: List[Dict[str, Any]]) -> List[List[Any]]:
    """Aggregate complete ("X") events per span name through the shared
    log-bucket histogram — the exact sketch the live registry uses."""
    hists: Dict[str, obs.Histogram] = {}
    for e in events:
        if e.get("ph") == "X":
            hists.setdefault(e["name"], obs.Histogram(e["name"])) \
                 .record(e.get("dur", 0.0) / 1e3)        # us -> ms
    rows = []
    for name, h in hists.items():
        rows.append([name, h.count, h.percentile(50), h.percentile(99),
                     h.mean, h.sum / 1e3])
    rows.sort(key=lambda r: -r[5])
    return rows


def snapshot_of(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    for e in reversed(events):
        if e.get("name") == obs.SNAPSHOT_EVENT:
            return e["args"]["snapshot"]
    return {}


def render(events: List[Dict[str, Any]]) -> str:
    snap = snapshot_of(events)
    parts = [_table("spans (from trace timeline)",
                    ["span", "count", "p50_ms", "p99_ms", "mean_ms",
                     "total_s"], span_rows(events))]

    counters = snap.get("counters", [])
    compiles = [c for c in counters if c["name"].endswith(COMPILE_SUFFIX)]
    parts.append(_table(
        "compilations (jit traces of resident impls)",
        ["counter", "labels", "count"],
        [[c["name"], _label_str(c["labels"]), c["value"]]
         for c in compiles]))
    parts.append(_table(
        "counters", ["counter", "labels", "value"],
        [[c["name"], _label_str(c["labels"]), c["value"]]
         for c in counters if not c["name"].endswith(COMPILE_SUFFIX)]))
    parts.append(_table(
        "gauges (last sampled value)", ["gauge", "labels", "value"],
        [[g["name"], _label_str(g["labels"]), g["value"]]
         for g in snap.get("gauges", [])]))
    ms = 1e3
    parts.append(_table(
        "histograms", ["histogram", "labels", "count", "p50_ms",
                       "p90_ms", "p99_ms", "mean_ms"],
        [[h["name"], _label_str(h["labels"]), h["count"],
          *((None if h[q] is None else h[q] * ms)
            for q in ("p50", "p90", "p99")),
          None if not h["count"] or h["sum"] is None
          else h["sum"] / h["count"] * ms]
         for h in snap.get("histograms", [])
         if h["name"].endswith(".seconds")]))

    instants = {}
    for e in events:
        if e.get("ph") == "i" and e["name"] != obs.SNAPSHOT_EVENT:
            instants[e["name"]] = instants.get(e["name"], 0) + 1
    parts.append(_table("instant events", ["event", "count"],
                        sorted(instants.items())))
    if snap.get("dropped_events"):
        parts.append(f"(trace ring dropped {snap['dropped_events']} "
                     "oldest events)\n")
    return "\n".join(p for p in parts if p)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a repro telemetry trace (spans + registry "
                    "snapshot) as terminal tables.")
    ap.add_argument("trace", help="trace file written by "
                                  "repro.obs.write_chrome_trace")
    ap.add_argument("--json", action="store_true",
                    help="emit the aggregates as JSON instead of tables")
    args = ap.parse_args(argv)
    events = obs.read_chrome_trace(args.trace)
    if args.json:
        print(json.dumps({
            "spans": {r[0]: {"count": r[1], "p50_ms": r[2], "p99_ms": r[3],
                             "mean_ms": r[4], "total_s": r[5]}
                      for r in span_rows(events)},
            "snapshot": snapshot_of(events)}, indent=2))
    else:
        print(render(events), end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
