"""Render a telemetry trace or flight-recorder bundle as terminal tables.

Consumes the Chrome/Perfetto trace file the telemetry layer writes
(``repro.obs.write_chrome_trace``, or the ``--telemetry-out`` flag on
``launch/serve_sim.py`` / ``python -m repro.launch.train_sim``): the
span timeline gives per-region latency percentiles, and the embedded
``repro.registry_snapshot`` instant event gives counters (compile
counts, NaN skips, admissions), gauges (occupancy, resident slots,
slab bytes), histogram aggregates, and the roofline-style compiled-cost
table (``cost.*`` gauges recorded once per jitted hot path at compile
time — see ``repro.obs.cost``) — one file, all views. Merged fleet
traces (``python -m repro.launch.obs_merge``) render with one span row
per rank.

Run:  python -m repro.launch.obs_report /tmp/run.trace.jsonl
      python -m repro.launch.obs_report /tmp/run.trace.jsonl --json
      python -m repro.launch.obs_report --postmortem /tmp/postmortem.json

Unusable inputs (missing/empty/truncated files, traces without the
embedded snapshot) exit with status 2 and a one-line error on stderr.
"""
from __future__ import annotations

import argparse
import datetime
import json
import sys
from typing import Any, Dict, List

from repro import obs
from repro.obs.flight import BUNDLE_KIND

COMPILE_SUFFIX = "_traces"      # counters counting jit trace events
COST_PREFIX = "cost."           # compiled-cost gauges (repro.obs.cost)


def _fmt(v: Any) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v != v:                       # NaN
            return "-"
        if v and (abs(v) >= 1e5 or abs(v) < 1e-3):
            return f"{v:.3e}"
        return f"{v:,.3f}".rstrip("0").rstrip(".")
    return str(v)


def _table(title: str, headers: List[str],
           rows: List[List[Any]]) -> str:
    if not rows:
        return ""
    cells = [[_fmt(c) for c in r] for r in rows]
    widths = [max(len(h), *(len(r[i]) for r in cells))
              for i, h in enumerate(headers)]
    def line(cols, pad=" "):
        return "  ".join(c.ljust(w, pad) if i == 0 else c.rjust(w, pad)
                         for i, (c, w) in enumerate(zip(cols, widths)))
    out = [f"== {title} ==", line(headers),
           line(["-" * w for w in widths])]
    out += [line(r) for r in cells]
    return "\n".join(out) + "\n"


def _label_str(labels: Dict[str, Any]) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(labels.items()))


def span_rows(events: List[Dict[str, Any]]) -> List[List[Any]]:
    """Aggregate complete ("X") events per span name through the shared
    log-bucket histogram — the exact sketch the live registry uses. On a
    merged fleet trace (several named processes) spans are keyed per
    rank track, so each rank gets its own row."""
    procs = {e.get("pid"): e.get("args", {}).get("name")
             for e in events
             if e.get("ph") == "M" and e.get("name") == "process_name"}
    multi = len(procs) > 1
    hists: Dict[str, obs.Histogram] = {}
    for e in events:
        if e.get("ph") == "X":
            key = e["name"]
            if multi:
                key = f"{procs.get(e.get('pid'), e.get('pid'))} :: {key}"
            hists.setdefault(key, obs.Histogram(key)) \
                 .record(e.get("dur", 0.0) / 1e3)        # us -> ms
    rows = []
    for name, h in hists.items():
        rows.append([name, h.count, h.percentile(50), h.percentile(99),
                     h.mean, h.sum / 1e3])
    rows.sort(key=lambda r: -r[5])
    return rows


def snapshot_of(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    for e in reversed(events):
        if e.get("name") == obs.SNAPSHOT_EVENT:
            return e["args"]["snapshot"]
    return {}


def cost_rows(snap: Dict[str, Any]) -> List[List[Any]]:
    """Roofline-style rows from the ``cost.*`` gauges: one row per
    (path, extra labels) with FLOPs, bytes accessed, arithmetic
    intensity, and the buffer/compile columns."""
    by_path: Dict[Any, Dict[str, float]] = {}
    for g in snap.get("gauges", []):
        if not g["name"].startswith(COST_PREFIX):
            continue
        labels = dict(g.get("labels") or {})
        path = labels.pop("path", "?")
        key = (path, tuple(sorted(labels.items())))
        by_path.setdefault(key, {})[g["name"][len(COST_PREFIX):]] = g["value"]
    rows = []
    for (path, labels), d in sorted(by_path.items()):
        flops = d.get("flops")
        nbytes = d.get("bytes_accessed")
        intensity = (flops / nbytes) if flops and nbytes else None
        mib = lambda k: (d[k] / 2 ** 20) if d.get(k) is not None else None
        rows.append([path, _label_str(dict(labels)), flops, nbytes,
                     intensity, mib("argument_bytes"), mib("output_bytes"),
                     mib("temp_bytes"), mib("peak_bytes"),
                     d.get("compile_seconds")])
    return rows


def _cost_table(snap: Dict[str, Any]) -> str:
    return _table(
        "compiled cost (per jitted hot path, analyzed once at compile)",
        ["path", "labels", "flops", "bytes", "flops/B", "arg_MiB",
         "out_MiB", "tmp_MiB", "peak_MiB", "compile_s"], cost_rows(snap))


def render(events: List[Dict[str, Any]]) -> str:
    snap = snapshot_of(events)
    parts = [_table("spans (from trace timeline)",
                    ["span", "count", "p50_ms", "p99_ms", "mean_ms",
                     "total_s"], span_rows(events))]

    counters = snap.get("counters", [])
    compiles = [c for c in counters if c["name"].endswith(COMPILE_SUFFIX)]
    parts.append(_table(
        "compilations (jit traces of resident impls)",
        ["counter", "labels", "count"],
        [[c["name"], _label_str(c["labels"]), c["value"]]
         for c in compiles]))
    parts.append(_cost_table(snap))
    parts.append(_table(
        "counters", ["counter", "labels", "value"],
        [[c["name"], _label_str(c["labels"]), c["value"]]
         for c in counters if not c["name"].endswith(COMPILE_SUFFIX)]))
    parts.append(_table(
        "gauges (last sampled value)", ["gauge", "labels", "value"],
        [[g["name"], _label_str(g["labels"]), g["value"]]
         for g in snap.get("gauges", [])
         if not g["name"].startswith(COST_PREFIX)]))
    ms = 1e3
    parts.append(_table(
        "histograms", ["histogram", "labels", "count", "p50_ms",
                       "p90_ms", "p99_ms", "mean_ms"],
        [[h["name"], _label_str(h["labels"]), h["count"],
          *((None if h[q] is None else h[q] * ms)
            for q in ("p50", "p90", "p99")),
          None if not h["count"] or h["sum"] is None
          else h["sum"] / h["count"] * ms]
         for h in snap.get("histograms", [])
         if h["name"].endswith(".seconds")]))

    instants = {}
    for e in events:
        if e.get("ph") == "i" and e["name"] != obs.SNAPSHOT_EVENT:
            instants[e["name"]] = instants.get(e["name"], 0) + 1
    parts.append(_table("instant events", ["event", "count"],
                        sorted(instants.items())))
    if snap.get("dropped_events"):
        parts.append(f"(trace ring dropped {snap['dropped_events']} "
                     "oldest events)\n")
    return "\n".join(p for p in parts if p)


# -- postmortem bundles -------------------------------------------------------

def render_postmortem(bundle: Dict[str, Any]) -> str:
    """Render a flight-recorder bundle (``repro.obs.FlightRecorder``)."""
    wall = bundle.get("wall_time_unix")
    when = (datetime.datetime.fromtimestamp(wall, datetime.timezone.utc)
            .isoformat() if isinstance(wall, (int, float)) else "-")
    head = [f"== flight recorder: {bundle.get('reason', '?')} ==",
            f"written   {when}"]
    if bundle.get("identity"):
        head.append(f"identity  {_label_str(bundle['identity'])}")
    if bundle.get("context"):
        head.append(f"context   {_label_str(bundle['context'])}")
    head.append(f"events    {len(bundle.get('events', []))} retained of "
                f"{bundle.get('trace_events_total', '?')} recorded")
    parts = ["\n".join(head) + "\n"]

    state = bundle.get("state", {})
    slots = (state.get("sim_server") or {}).get("slots")
    if slots:
        parts.append(_table(
            "sim_server slots", ["slot", "phase", "uid", "scene", "sample",
                                 "t", "t_hist", "t_total", "cursor_rows"],
            [[s.get("slot"), s.get("phase"), s.get("uid"),
              s.get("scene_id"), s.get("sample_id"), s.get("t"),
              s.get("t_hist"), s.get("t_total"), s.get("cursor_rows")]
             for s in slots]))
    for name, st in sorted(state.items()):
        if name == "sim_server" or not isinstance(st, dict):
            continue
        parts.append(_table(f"{name} state", ["key", "value"],
                            [[k, json.dumps(v) if isinstance(v, (dict, list))
                              else v] for k, v in sorted(st.items())]))

    snap = bundle.get("snapshot", {})
    parts.append(_cost_table(snap))
    parts.append(_table(
        "counters", ["counter", "labels", "value"],
        [[c["name"], _label_str(c["labels"]), c["value"]]
         for c in snap.get("counters", [])]))
    parts.append(_table("last events (tail of the trace ring)",
                        ["event", "count"],
                        sorted({e["name"]: sum(1 for x in bundle["events"]
                                               if x["name"] == e["name"])
                                for e in bundle.get("events", [])}.items())))
    return "\n".join(p for p in parts if p)


def _die(msg: str) -> int:
    print(f"error: {msg}".splitlines()[0], file=sys.stderr)
    return 2


def _postmortem_main(path: str, as_json: bool) -> int:
    try:
        with open(path) as f:
            bundle = json.load(f)
    except OSError as e:
        return _die(f"cannot read {path!r}: {e}")
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        return _die(f"cannot parse {path!r} as a postmortem bundle: {e}")
    if not isinstance(bundle, dict) or bundle.get("kind") != BUNDLE_KIND:
        return _die(f"{path!r} is not a flight-recorder bundle "
                    f"(expected kind={BUNDLE_KIND!r})")
    if as_json:
        print(json.dumps(bundle, indent=2))
    else:
        print(render_postmortem(bundle), end="")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Render a repro telemetry trace (spans + registry "
                    "snapshot + compiled-cost table) or a flight-recorder "
                    "postmortem bundle as terminal tables.")
    ap.add_argument("trace", help="trace file written by "
                                  "repro.obs.write_chrome_trace (or a "
                                  "postmortem bundle with --postmortem)")
    ap.add_argument("--json", action="store_true",
                    help="emit the aggregates as JSON instead of tables")
    ap.add_argument("--postmortem", action="store_true",
                    help="treat the input as a flight-recorder bundle")
    args = ap.parse_args(argv)

    if args.postmortem:
        return _postmortem_main(args.trace, args.json)

    try:
        events = obs.read_chrome_trace(args.trace)
    except OSError as e:
        return _die(f"cannot read {args.trace!r}: {e}")
    except (json.JSONDecodeError, UnicodeDecodeError, ValueError) as e:
        return _die(f"cannot parse {args.trace!r} as a trace: {e}")
    if not events:
        return _die(f"{args.trace!r} contains no trace events")
    snap = snapshot_of(events)
    if not snap:
        return _die(f"{args.trace!r} has no embedded registry snapshot "
                    f"({obs.SNAPSHOT_EVENT} event) — was the trace "
                    "truncated mid-write?")
    if args.json:
        print(json.dumps({
            "spans": {r[0]: {"count": r[1], "p50_ms": r[2], "p99_ms": r[3],
                             "mean_ms": r[4], "total_s": r[5]}
                      for r in span_rows(events)},
            "cost": [{"path": r[0], "labels": r[1], "flops": r[2],
                      "bytes_accessed": r[3], "intensity": r[4]}
                     for r in cost_rows(snap)],
            "snapshot": snap}, indent=2))
    else:
        print(render(events), end="")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
