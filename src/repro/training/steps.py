"""Sharded agent-sim train/eval steps (the BC analogue of runtime.steps).

``make_sim_train_step`` mirrors :func:`repro.runtime.steps.make_train_step`
exactly where it matters at scale: parameters are cast to the compute
dtype *inside* the loss (on the FSDP-sharded storage, so weight
all-gathers and the matmul-transpose gradient reductions move the compute
dtype, not f32), the loss is the validity-masked ``action_nll`` over
teacher-forced logits, and the model's attention is block-causal over
simulation times (``SimAttention`` with ``causal=True``) — the same mask
the incremental rollout cache relies on, so training and closed-loop
deployment see identical attention semantics.

Input sharding goes through the logical-axis rules
(``distributed.sharding``): every batch tensor is batch-leading and shards
over the (pod, data) axes via ``batch_sharding``; parameter/optimizer
shardings come from the ParamSpec logical axes like every other model in
the repo. ``sim_input_specs`` provides the ShapeDtypeStruct stand-ins the
AOT dry-run lowers at 512 devices without allocating anything.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import batch_sharding
from repro.nn.agent_sim import AgentSimModel, action_nll
from repro.nn.module import cast_params
from repro.optim.transforms import Optimizer, apply_updates
from repro.scenarios.core import ScenarioConfig
from repro.training.data import TRAIN_KEYS

__all__ = ["bc_optimizer", "loss_summary", "make_sim_train_step",
           "make_sim_dp_train_step", "sim_dp_state", "make_sim_eval_step",
           "open_loop_metrics", "sim_input_specs", "sim_batch_shardings"]


def bc_optimizer(lr: float, steps: int) -> Optimizer:
    """The one BC optimizer recipe, shared by the launcher and the
    comparison harness so 'identical budgets' stays true by construction:
    global-norm clip + AdamW on a warmup-cosine schedule."""
    from repro.optim import adamw, chain, clip_by_global_norm, warmup_cosine
    warmup = max(1, min(20, steps // 10))
    return chain(clip_by_global_norm(1.0),
                 adamw(warmup_cosine(lr, warmup, steps)))


def loss_summary(history: Sequence[float]) -> Dict[str, float]:
    """Endpoint means of a loss trajectory (k-step windows), the shared
    'did training move' summary."""
    k = max(1, min(5, len(history) // 2))
    return {
        "loss_first": float(np.mean(history[:k])) if len(history) else
        float("nan"),
        "loss_last": float(np.mean(history[-k:])) if len(history) else
        float("nan"),
    }


def _masked_accuracy(logits, actions, valid):
    """Fraction of valid agent steps whose argmax action matches the
    expert's — the cheap scalar that makes loss curves comparable across
    action-grid sizes."""
    pred = jnp.argmax(logits.astype(jnp.float32), axis=-1)
    w = valid.astype(jnp.float32)
    hit = (pred == actions).astype(jnp.float32)
    return jnp.sum(hit * w) / jnp.maximum(jnp.sum(w), 1.0)


def make_sim_train_step(model: AgentSimModel,
                        optimizer: Optimizer) -> Callable:
    """One BC update: teacher-forced masked NLL -> grads -> optimizer."""
    cfg = model.cfg

    def train_step(params, opt_state, batch):
        def loss_fn(p32):
            p = cast_params(p32, cfg.compute_dtype)
            logits, aux = model(p, batch)
            loss = action_nll(logits, batch["actions"], batch["agent_valid"])
            return loss + aux, (loss, logits)

        (_, (loss, logits)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        updates, new_opt = optimizer.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "accuracy": _masked_accuracy(logits, batch["actions"],
                                                batch["agent_valid"])}
        return new_params, new_opt, metrics

    return train_step


def sim_dp_state(optimizer: Optimizer, params) -> Dict[str, Any]:
    """Trainer-compatible state for :func:`make_sim_dp_train_step`: the
    optimizer state plus the error-feedback residual the compressed
    cross-pod reduction carries between steps (zeros at init — nothing
    untransmitted yet)."""
    return {"opt": optimizer.init(params),
            "residual": jax.tree.map(
                lambda p: jnp.zeros_like(p, jnp.float32), params)}


def make_sim_dp_train_step(model: AgentSimModel, optimizer: Optimizer,
                           mesh, *, compress: bool = True) -> Callable:
    """The fleet-scale BC update: same masked-NLL loss as
    :func:`make_sim_train_step`, but the gradient reduction goes through
    ``distributed.dp_compress.make_compressed_dp_step`` — shard_map over
    the DP axes with a full-precision intra-pod psum and (when the mesh
    carries a "pod" axis and ``compress`` is on) an int8 + error-feedback
    cross-pod psum carrying the DCI gradient traffic.

    Returns ``step(params, state, batch) -> (params, state, metrics)``
    with ``state = sim_dp_state(...)`` (opt state + EF residual), so the
    fault-tolerant :class:`~repro.runtime.trainer.Trainer` runs it
    unmodified and checkpoints the residual alongside the optimizer.
    ``batch`` must shard over the mesh's DP axes: the leading batch dim
    has to divide their product.
    """
    from repro.distributed.dp_compress import make_compressed_dp_step

    cfg = model.cfg
    dp_size = int(np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")]))

    def loss_fn(p32, batch):
        p = cast_params(p32, cfg.compute_dtype)
        logits, aux = model(p, batch)
        return action_nll(logits, batch["actions"],
                          batch["agent_valid"]) + aux

    dp_step = make_compressed_dp_step(loss_fn, optimizer, mesh,
                                      compress=compress)

    def train_step(params, state, batch):
        b = jax.tree.leaves(batch)[0].shape[0]
        if b % dp_size:
            raise ValueError(f"batch {b} does not divide the mesh's "
                             f"{dp_size} DP shards")
        params, opt_state, residual, loss = dp_step(
            params, state["opt"], state["residual"], batch)
        return params, {"opt": opt_state, "residual": residual}, \
            {"loss": loss}

    return train_step


def make_sim_eval_step(model: AgentSimModel) -> Callable:
    """Open-loop evaluation on one batch: masked NLL + argmax accuracy."""
    cfg = model.cfg

    def eval_step(params, batch):
        p = cast_params(params, cfg.compute_dtype)
        logits, _ = model(p, batch)
        return {
            "nll": action_nll(logits, batch["actions"],
                              batch["agent_valid"]),
            "accuracy": _masked_accuracy(logits, batch["actions"],
                                         batch["agent_valid"]),
        }

    return eval_step


def open_loop_metrics(model: AgentSimModel, params,
                      batches: Sequence[Dict[str, Any]],
                      eval_fn: Optional[Callable] = None
                      ) -> Dict[str, float]:
    """Mean open-loop NLL / accuracy over a list of (host) batches.

    Pass a pre-jitted ``eval_fn`` when calling repeatedly (periodic eval
    inside a training run) — a fresh ``jax.jit`` wrapper per call would
    recompile every time.
    """
    if not batches:
        return {"nll": float("nan"), "accuracy": float("nan")}
    if eval_fn is None:
        eval_fn = jax.jit(make_sim_eval_step(model))
    rows = []
    for b in batches:
        rows.append({k: float(v) for k, v in
                     eval_fn(params, {k: jnp.asarray(v)
                                      for k, v in b.items()}).items()})
    return {k: float(np.mean([r[k] for r in rows])) for k in rows[0]}


def sim_input_specs(scen: ScenarioConfig, batch_size: int) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one training batch (dry-run input)."""
    b, m = batch_size, scen.num_map
    t, a = scen.num_steps, scen.num_agents
    f32, i32, bl = jnp.float32, jnp.int32, jnp.bool_
    shapes = {
        "map_feats": ((b, m, scen.map_feat_dim), f32),
        "map_pose": ((b, m, 3), f32),
        "map_valid": ((b, m), bl),
        "agent_feats": ((b, t, a, scen.agent_feat_dim), f32),
        "agent_pose": ((b, t, a, 3), f32),
        "agent_valid": ((b, t, a), bl),
        "actions": ((b, t, a), i32),
    }
    assert set(shapes) == set(TRAIN_KEYS)
    return {k: jax.ShapeDtypeStruct(*v) for k, v in shapes.items()}


def sim_batch_shardings(specs: Dict[str, Any], mesh, rules=None):
    """NamedShardings for a batch-leading sim batch (every leaf shards its
    first axis over the DP axes, mirroring runtime.steps.batch_shardings)."""
    return {k: batch_sharding(mesh, v.shape, rules)
            for k, v in specs.items()}
