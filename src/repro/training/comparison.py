"""The paper's headline experiment: invariant vs. absolute, trained.

Trains one agent-sim model per attention mechanism — the Table-I rows
``rope2d`` / ``se2_repr`` / ``se2_fourier`` plus the non-invariant
``absolute`` baseline — under IDENTICAL budgets (same expert stream, same
optimizer schedule, same step/batch counts, same init seed), then scores
every run both ways:

* **open-loop**: held-out next-action NLL + argmax accuracy (teacher
  forcing, the paper's Table-I metric);
* **closed-loop**: sampled rollouts through the cached
  :class:`repro.runtime.RolloutEngine` scored by the evaluation harness —
  minADE / miss / collision / off-road per scenario family.

Each run goes through the full fault-tolerant :class:`Trainer` (NaN guard,
checkpointing, restartable data cursor), so the comparison exercises the
production path end to end, not a side-channel loop.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import tempfile
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import obs
from repro.configs.base import SimArch
from repro.data.pipeline import ShardedIterator
from repro.nn import module as nnm
from repro.nn.agent_sim import AgentSimModel
from repro.runtime.evaluation import EvalConfig, evaluate_families
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.training.data import holdout_batches, make_batch_fn
from repro.training.steps import (bc_optimizer, loss_summary,
                                  make_sim_dp_train_step,
                                  make_sim_train_step, open_loop_metrics,
                                  sim_dp_state)

log = logging.getLogger("repro.training.comparison")

__all__ = ["COMPARISON_ENCODINGS", "train_one", "run_comparison",
           "format_table"]

# Table-I rows: three relative mechanisms vs. the absolute baseline.
COMPARISON_ENCODINGS = ("absolute", "rope2d", "se2_repr", "se2_fourier")

CLOSED_LOOP_METRICS = ("min_ade", "miss_rate", "collision_rate",
                       "offroad_rate")


def train_one(arch: SimArch, *, steps: int, batch: int, lr: float = 3e-3,
              seed: int = 0, ckpt_dir: Optional[str] = None,
              eval_every: int = 0, eval_cb=None, mesh=None,
              dp_compress: bool = True
              ) -> Tuple[AgentSimModel, object, Dict[str, float]]:
    """Train one encoding through the fault-tolerant Trainer.

    Returns (model, trained params, summary dict). The summary carries the
    loss trajectory endpoints so callers can assert training actually
    moved. A fresh ``ckpt_dir`` per call keeps encodings from restoring
    each other's checkpoints; pass an existing one to resume.

    ``mesh``: optional DP mesh — the run then goes through
    :func:`make_sim_dp_train_step` (shard_map over the mesh's
    ``("pod", "data")`` axes, with ``dp_compress`` selecting the int8 +
    error-feedback cross-pod reduction when a "pod" axis is present), so
    fleet-budget comparisons exercise the production gradient path rather
    than a single-device twin.
    """
    cfg = arch.agent_sim_config()
    scen = arch.scenario_config()
    model = AgentSimModel(cfg)
    params = nnm.init_params(model.specs(), jax.random.key(seed))
    opt = bc_optimizer(lr, steps)
    if mesh is None:
        step_fn = jax.jit(make_sim_train_step(model, opt))
        opt_state = opt.init(params)
    else:
        step_fn = jax.jit(make_sim_dp_train_step(model, opt, mesh,
                                                 compress=dp_compress))
        opt_state = sim_dp_state(opt, params)
    # compiled FLOPs/bytes land as cost.* gauges labeled per encoding
    step_fn = obs.CostAccounted(step_fn, "train.step",
                                labels={"encoding": arch.encoding})
    data = ShardedIterator(make_batch_fn(scen), batch_size=batch, seed=seed)
    if ckpt_dir is None:
        ckpt_dir = tempfile.mkdtemp(prefix=f"simcmp_{arch.encoding}_")
    t0 = time.time()
    trainer = Trainer(
        step_fn, params, opt_state, data, ckpt_dir,
        TrainerConfig(total_steps=steps, ckpt_every=max(steps, 1),
                      log_every=max(1, steps // 5),
                      eval_every=eval_every),
        metrics_cb=lambda s, m: log.info(
            "[%s] step %d loss %.4f acc %.3f", arch.encoding, s,
            m["loss"], m.get("accuracy", float("nan"))),
        eval_cb=eval_cb)
    trainer.restore_if_available()
    out = trainer.run()
    data.close()
    summary = {
        "status": out["status"],
        "steps": float(trainer.step),
        "train_s": time.time() - t0,
        **loss_summary(trainer.history),
    }
    return model, trainer.params, summary


def run_comparison(arch: SimArch,
                   encodings: Sequence[str] = COMPARISON_ENCODINGS, *,
                   steps: int = 300, batch: int = 8, lr: float = 3e-3,
                   seed: int = 0, holdout_n: int = 4,
                   n_scenes_per_family: int = 2, eval_samples: int = 4,
                   ckpt_root: Optional[str] = None,
                   report=None, mesh=None, dp_compress: bool = True,
                   eval_mesh=None, eval_num_slots: Optional[int] = None
                   ) -> Dict[str, Dict[str, float]]:
    """Train every encoding under one budget; score open- and closed-loop.

    ``arch`` fixes everything except the encoding (size, scenario shapes,
    budget), so differences between rows are attributable to the attention
    mechanism alone. Returns ``{encoding: row}`` plus a ``"summary"`` entry
    with the paper's qualitative claim (best relative NLL <= absolute NLL)
    evaluated on this run.

    ``mesh``/``dp_compress`` route training through the sharded
    compressed-DP step (see :func:`train_one`); ``eval_mesh`` runs the
    closed-loop scoring through the scene-sharded fleet engine (with
    ``eval_num_slots`` lanes) — at 10k+-scene budgets the eval dominates
    wall-clock, so the fleet path is what makes real budgets reachable.
    """
    report = report or (lambda name, value, extra="": None)
    scen = arch.scenario_config()
    eval_cfg = EvalConfig(t_hist=max(1, scen.num_steps // 2),
                          n_samples=eval_samples, seed=seed + 1)
    holdout = holdout_batches(scen, batch, holdout_n, seed=seed)
    rows: Dict[str, Dict[str, float]] = {}
    for enc in encodings:
        arch_e = dataclasses.replace(
            arch, name=f"{arch.name}-cmp-{enc}", encoding=enc)
        ckpt = (os.path.join(ckpt_root, enc) if ckpt_root else None)
        model, params, summary = train_one(
            arch_e, steps=steps, batch=batch, lr=lr, seed=seed,
            ckpt_dir=ckpt, mesh=mesh, dp_compress=dp_compress)
        open_m = open_loop_metrics(model, params, holdout)
        closed = evaluate_families(
            model, params, scen, eval_cfg,
            n_scenes_per_family=n_scenes_per_family,
            scene_seed=seed + 777, mesh=eval_mesh,
            num_slots=eval_num_slots)
        row = dict(summary)
        row["open_loop_nll"] = open_m["nll"]
        row["open_loop_accuracy"] = open_m["accuracy"]
        for m in CLOSED_LOOP_METRICS:
            row[f"closed_loop_{m}"] = closed["overall"][m]
        # full per-family closed-loop tables ride along (agent-weighted;
        # the fleet bench prints them as the paper's per-family rows)
        row["families"] = {f: dict(v) for f, v in closed.items()}
        rows[enc] = row
        report(f"comparison/{enc}/open_loop_nll", f"{row['open_loop_nll']:.4f}",
               f"train_s={row['train_s']:.1f}")
        for m in CLOSED_LOOP_METRICS:
            report(f"comparison/{enc}/{m}", f"{row[f'closed_loop_{m}']:.4f}")
    relative = [e for e in encodings if e != "absolute"]
    if relative and "absolute" in rows:
        best_rel = min(rows[e]["open_loop_nll"] for e in relative)
        abs_nll = rows["absolute"]["open_loop_nll"]
        # strict comparison; the signed margin is reported alongside so
        # noisy short-budget runs are judged by the consumer, not by a
        # slack silently baked into the boolean
        beats = bool(best_rel <= abs_nll)
        rows["summary"] = {"relative_beats_absolute": float(beats),
                           "nll_margin": abs_nll - best_rel,
                           "best_relative_nll": best_rel,
                           "absolute_nll": abs_nll}
        report("comparison/relative_beats_absolute", float(beats),
               f"margin={abs_nll - best_rel:.4f}")
    return rows


def format_table(rows: Dict[str, Dict[str, float]]) -> str:
    """Markdown table of the comparison results (the paper's Table I shape:
    one row per encoding, open-loop NLL plus closed-loop metrics)."""
    cols = ["open_loop_nll", "open_loop_accuracy"] + \
        [f"closed_loop_{m}" for m in CLOSED_LOOP_METRICS]
    head = ("| encoding | NLL | acc | minADE | miss | collision | offroad |",
            "|---|---:|---:|---:|---:|---:|---:|")
    lines = list(head)
    for enc, row in rows.items():
        if enc == "summary":
            continue
        vals = " | ".join(f"{row[c]:.4f}" if np.isfinite(row[c]) else "nan"
                          for c in cols)
        lines.append(f"| {enc} | {vals} |")
    if "summary" in rows:
        s = rows["summary"]
        lines.append("")
        lines.append(f"relative_beats_absolute: "
                     f"{bool(s['relative_beats_absolute'])} "
                     f"(best relative NLL {s['best_relative_nll']:.4f} vs "
                     f"absolute {s['absolute_nll']:.4f})")
    return "\n".join(lines)
