"""Expert-demonstration dataset: scenario-family rollouts as training batches.

The demonstrations are the rule-based reference policies of
``repro.scenarios.policies`` (IDM gap keeping + pure pursuit + yielding)
rolled over every registered family. Their actions are *already* exact
labels in the model's discrete (accel x yaw-rate) vocabulary: the
simulate() loop snaps each command to the scenario grid and integrates the
quantized action, so behavior cloning has zero label noise from
discretization.

Batches satisfy the :class:`repro.data.pipeline.ShardedIterator` contract —
``make_batch(seed, start_index, batch_size)`` is a pure function of its
arguments (all randomness flows through ``registry.family_rng``), so the
training stream is deterministic, restartable from the integer cursor
alone, and shards across data-loader hosts with no coordination. Families
are interleaved deterministically by index (``registry.generate_mixed``),
every scene pads to the config's static shapes, and validity masks carry
the per-scene variation — one compiled train step serves all families.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.scenarios import registry
from repro.scenarios.core import ScenarioConfig

__all__ = ["TRAIN_KEYS", "make_sim_batch", "make_batch_fn",
           "holdout_batches", "HOLDOUT_SEED_OFFSET"]

# The model-facing subset of a Scene's tensors: everything AgentSimModel
# tokenizes plus the action labels and the loss mask. Host-side metadata
# (behavior categories, agent types, lane graphs) stays out of the device
# batch — closed-loop evaluation regenerates scenes with full metadata.
TRAIN_KEYS = ("map_feats", "map_pose", "map_valid",
              "agent_feats", "agent_pose", "agent_valid", "actions")

# Held-out batches draw from a far-away seed, not a far-away index: index
# offsets collide with the training stream under a different world size /
# batch size, a disjoint seed never does (family_rng salts by seed).
HOLDOUT_SEED_OFFSET = 100_003


def make_sim_batch(seed: int, start_index: int, batch_size: int,
                   scen: ScenarioConfig,
                   families: Optional[Sequence[str]] = None
                   ) -> Dict[str, np.ndarray]:
    """One mixed-family expert batch with the ShardedIterator signature.

    Returns the TRAIN_KEYS dict of stacked static-shape arrays:
    map_feats (B, M, Fm), map_pose (B, M, 3), map_valid (B, M),
    agent_feats (B, T, A, Fa), agent_pose (B, T, A, 3),
    agent_valid (B, T, A), actions (B, T, A) int32.
    """
    batch = registry.generate_mixed_batch(seed, start_index, batch_size,
                                          scen, families)
    return {k: batch[k] for k in TRAIN_KEYS}


def make_batch_fn(scen: ScenarioConfig,
                  families: Optional[Sequence[str]] = None):
    """Bind config + families into the pure ``(seed, index, batch) -> dict``
    the ShardedIterator consumes."""
    fams = tuple(families) if families is not None else None

    def make_batch(seed: int, start_index: int, batch_size: int):
        return make_sim_batch(seed, start_index, batch_size, scen, fams)

    return make_batch


def holdout_batches(scen: ScenarioConfig, batch_size: int, n_batches: int,
                    seed: int = 0,
                    families: Optional[Sequence[str]] = None):
    """Deterministic held-out batches for open-loop evaluation, on a seed
    stream disjoint from any training cursor position."""
    return [make_sim_batch(seed + HOLDOUT_SEED_OFFSET, i * batch_size,
                           batch_size, scen, families)
            for i in range(n_batches)]
