"""Behavior-cloning training for the SE(2) agent-sim model.

The learn side of the scenario suite: expert demonstrations come from the
rule-based reference policies (``repro.scenarios.policies``) rolled over
every registered family, the model is trained by teacher-forced
next-action NLL under the block-causal scene mask, and the result is
evaluated both open-loop (held-out NLL / accuracy) and closed-loop
(``repro.runtime.evaluation``). ``comparison.run_comparison`` trains every
Table-I encoding plus the ``absolute`` baseline under identical budgets —
the paper's headline invariant-vs-non-invariant table.

Entry point: ``python -m repro.launch.train_sim`` (see docs/training.md).
"""
from repro.training.data import (TRAIN_KEYS, holdout_batches, make_batch_fn,
                                 make_sim_batch)
from repro.training.steps import (make_sim_eval_step, make_sim_train_step,
                                  open_loop_metrics, sim_batch_shardings,
                                  sim_input_specs)
from repro.training.comparison import (COMPARISON_ENCODINGS, format_table,
                                       run_comparison, train_one)

__all__ = [
    "TRAIN_KEYS", "holdout_batches", "make_batch_fn", "make_sim_batch",
    "make_sim_eval_step", "make_sim_train_step", "open_loop_metrics",
    "sim_batch_shardings", "sim_input_specs",
    "COMPARISON_ENCODINGS", "format_table", "run_comparison", "train_one",
]
