"""Fault injectors: make a :class:`~repro.chaos.plan.FaultPlan` real.

Two families:

* **At-rest corruption** (:func:`corrupt_checkpoint`) mutates a
  checkpoint directory the way real failures do — a truncated
  ``arrays.npz`` (crashed writer / torn copy), a flipped bit in one
  stored array (disk rot; CRC catches it), a deleted ``manifest.json``,
  a leftover ``step_*.tmp`` from a writer that died mid-save. All
  randomness comes from ``plan.rng``, so the same plan corrupts the
  same byte.

* **In-flight wrappers** hand a component a seam the plan fires
  through: :func:`checkpoint_io_hook` raises ``OSError`` out of
  scheduled save attempts (drills ``CheckpointManager``'s bounded
  retry), :func:`flaky_make_batch` raises out of scheduled produce
  calls (drills ``ShardedIterator``'s worker-error propagation), and
  :func:`poison_server_slot` writes non-finite poses/logits into one
  ``SimServer`` slot (drills quarantine). Each wrapper keeps its own
  :class:`~repro.chaos.plan.Clock`, so ``Fault.at`` indexes that
  injector's calls and nothing depends on wall time.
"""
from __future__ import annotations

import os
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.chaos.plan import Clock, FaultPlan

__all__ = ["corrupt_checkpoint", "checkpoint_io_hook", "flaky_make_batch",
           "poison_server_slot", "ChaosInjectionError"]


class ChaosInjectionError(RuntimeError):
    """Raised when an injector cannot apply its scheduled fault (e.g. no
    checkpoint exists to corrupt) — a drill misconfiguration, never a
    component failure."""


# -- at-rest checkpoint corruption -------------------------------------------

def _manifest_steps(directory: str):
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp") \
                and os.path.exists(os.path.join(directory, name,
                                                "manifest.json")):
            out.append(int(name.split("_")[1]))
    return sorted(out)


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"step_{step:010d}")


def _pick_step(directory: str, step: Optional[int]) -> int:
    steps = _manifest_steps(directory)
    if not steps:
        raise ChaosInjectionError(
            f"no checkpoints under {directory} to corrupt")
    if step is None:
        return steps[-1]
    if step not in steps:
        raise ChaosInjectionError(
            f"step {step} not present under {directory} (have {steps})")
    return step


def _truncate_npz(directory: str, step: int,
                  plan: FaultPlan) -> Dict[str, Any]:
    path = os.path.join(_step_dir(directory, step), "arrays.npz")
    size = os.path.getsize(path)
    # cut somewhere inside the payload: a torn write never respects the
    # zip structure, so neither do we
    keep = int(plan.rng(salt=step).integers(1, max(2, size // 2)))
    with open(path, "rb+") as f:
        f.truncate(keep)
    return {"file": path, "orig_bytes": size, "kept_bytes": keep}


def _bitflip_array(directory: str, step: int,
                   plan: FaultPlan) -> Dict[str, Any]:
    path = os.path.join(_step_dir(directory, step), "arrays.npz")
    with np.load(path) as z:
        arrs = {k: np.array(z[k]) for k in z.files}
    victims = sorted(k for k, v in arrs.items() if v.nbytes > 0)
    if not victims:
        raise ChaosInjectionError(f"{path} holds no non-empty arrays")
    rng = plan.rng(salt=step + 1)
    key = victims[int(rng.integers(len(victims)))]
    buf = bytearray(arrs[key].tobytes())
    byte = int(rng.integers(len(buf)))
    bit = int(rng.integers(8))
    buf[byte] ^= 1 << bit
    arrs[key] = np.frombuffer(bytes(buf), dtype=arrs[key].dtype) \
        .reshape(arrs[key].shape)
    np.savez(path, **arrs)
    return {"file": path, "key": key, "byte": byte, "bit": bit}


def _drop_manifest(directory: str, step: int,
                   plan: FaultPlan) -> Dict[str, Any]:
    path = os.path.join(_step_dir(directory, step), "manifest.json")
    os.remove(path)
    return {"file": path}


def _stale_tmp(directory: str, step: int, plan: FaultPlan) -> Dict[str, Any]:
    """Leave the debris of a writer that died mid-save: a ``.tmp`` step
    dir holding a half-written arrays.npz and no manifest."""
    tmp = _step_dir(directory, step) + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    junk = plan.rng(salt=step + 2).integers(0, 256, 333).astype(np.uint8)
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(junk.tobytes())
    return {"dir": tmp}


_CORRUPTIONS = {
    "truncate_checkpoint_npz": _truncate_npz,
    "bitflip_checkpoint_array": _bitflip_array,
    "drop_checkpoint_manifest": _drop_manifest,
    "stale_checkpoint_tmp": _stale_tmp,
}


def corrupt_checkpoint(directory: str, mode: str, *,
                       step: Optional[int] = None,
                       plan: Optional[FaultPlan] = None) -> Dict[str, Any]:
    """Apply one at-rest corruption ``mode`` (a checkpoint fault kind
    from :data:`~repro.chaos.plan.FAULT_KINDS`) to ``directory``.

    ``step=None`` targets the newest manifest-complete checkpoint —
    except ``stale_checkpoint_tmp``, which plants its debris at
    ``latest + 1`` (the save that "died"). Returns a JSON-able record of
    exactly what was damaged, and logs the firing on ``plan``.
    """
    if mode not in _CORRUPTIONS:
        raise ValueError(f"unknown corruption mode {mode!r}; "
                         f"known: {sorted(_CORRUPTIONS)}")
    plan = plan if plan is not None else FaultPlan(seed=0)
    if mode == "stale_checkpoint_tmp":
        steps = _manifest_steps(directory)
        step = step if step is not None else (steps[-1] + 1 if steps else 0)
    else:
        step = _pick_step(directory, step)
    detail = _CORRUPTIONS[mode](directory, step, plan)
    plan.fired.append({"kind": mode, "clock": step, "target": 0,
                       "param": 0.0, **detail})
    return {"mode": mode, "step": step, **detail}


# -- in-flight injector wrappers ---------------------------------------------

def checkpoint_io_hook(plan: FaultPlan) -> Callable[[int, int], None]:
    """An ``io_hook`` for :class:`~repro.checkpoint.CheckpointManager`:
    raises ``OSError`` on write attempts covered by a
    ``fail_async_save_io`` fault. The clock counts write *attempts*
    across all saves (retries included), so ``Fault(at=0, count=2)``
    with ``save_retries >= 2`` is a transient outage the manager rides
    out, while a large ``count`` is a dead disk."""
    clock = Clock()

    def hook(step: int, attempt: int) -> None:
        c = clock.next()
        if plan.fires("fail_async_save_io", c, step=step,
                      attempt=attempt) is not None:
            raise OSError(
                f"chaos: injected async-save IO failure "
                f"(attempt clock {c}, step {step}, attempt {attempt})")

    return hook


def flaky_make_batch(make_batch: Callable[[int, int, int], Dict[str, Any]],
                     plan: FaultPlan) -> Callable[[int, int, int],
                                                  Dict[str, Any]]:
    """Wrap a ``make_batch`` so scheduled produce calls raise — the
    data-worker kill drill. The clock counts calls into ``make_batch``
    (worker retries included): ``count <= worker_retries`` is a
    transient blip the iterator retries through; a larger ``count``
    must surface as ``DataWorkerError`` from ``__next__``."""
    clock = Clock()

    def wrapped(seed: int, start_index: int, batch_size: int):
        c = clock.next()
        if plan.fires("kill_data_worker", c, seed=seed,
                      start_index=start_index) is not None:
            raise RuntimeError(
                f"chaos: injected data-worker failure (produce call {c}, "
                f"start_index {start_index})")
        return make_batch(seed, start_index, batch_size)

    return wrapped


def poison_server_slot(server, slot: int, *,
                       plan: Optional[FaultPlan] = None,
                       tick: Optional[int] = None) -> None:
    """Overwrite slot ``slot``'s poses and logits with NaN — the
    numerically poisoned lane. From the next tick on, every pose that
    slot emits is non-finite; the server's drain-side health check must
    quarantine it while healthy slots stay bit-identical."""
    import jax.numpy as jnp

    state = dict(server.state)
    for key in ("pose", "logits"):
        state[key] = state[key].at[slot].set(
            jnp.full(state[key].shape[1:], jnp.nan, state[key].dtype))
    server.state = state
    if plan is not None:
        plan.fired.append({"kind": "poison_slot_nan",
                           "clock": int(tick if tick is not None else -1),
                           "target": int(slot), "param": 0.0})
