"""Deterministic fault plans: *when* and *what* breaks, decided up front.

A :class:`FaultPlan` is a seeded, fully explicit schedule of faults —
"truncate the latest checkpoint after save #2", "fail the async-save
write twice, then let it through", "poison slot 1's poses with NaN at
tick 7", "kill the data worker from produce-call 3 onward". The plan is
pure data: nothing fires until a component-side injector (``inject.py``)
or the drill driver (``repro.launch.chaos``) asks ``fires(kind, clock)``
— and every firing is recorded, so a drill can assert afterwards that
the faults it scripted actually went off (a chaos suite whose faults
silently missed their window proves nothing).

Determinism contract: the same ``FaultPlan(faults, seed=s)`` produces
the same firings against the same sequence of clock queries, and every
randomized corruption detail (which array a bitflip hits, which byte) is
drawn from ``plan.rng(salt)`` — ``np.random.default_rng(seed ^ salt)``
— never from global RNG state. Two runs of a drill are bit-identical,
which is what lets the recovery invariants demand bit-exactness.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["Fault", "FaultPlan", "FAULT_KINDS", "Clock"]

#: The fault vocabulary. Checkpoint-corruption kinds are applied to
#: at-rest checkpoint directories by ``inject.corrupt_checkpoint``; the
#: IO/worker/slot/tick kinds fire through injector wrappers against a
#: per-injector call clock.
FAULT_KINDS = (
    "truncate_checkpoint_npz",     # arrays.npz cut short mid-file
    "bitflip_checkpoint_array",    # one flipped bit in one stored array
    "drop_checkpoint_manifest",    # manifest.json deleted
    "stale_checkpoint_tmp",        # a crashed writer's step_*.tmp left behind
    "fail_async_save_io",          # OSError out of the save thread's write
    "poison_slot_nan",             # non-finite poses/logits in one slot
    "kill_data_worker",            # make_batch raises in the worker thread
    "delay_tick",                  # injected latency on the serve tick
)


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault.

    ``at``: the injector-local clock value (save attempt, produce call,
    server tick, ...) at which the fault starts firing. ``count``: how
    many consecutive clock values it covers — ``count=2`` on
    ``fail_async_save_io`` is a transient outage two write attempts
    wide; a huge count is a hard persistent failure. ``target``: kind-
    specific victim (slot index for ``poison_slot_nan``; ignored
    elsewhere). ``param``: kind-specific magnitude (seconds for
    ``delay_tick``).
    """
    kind: str
    at: int
    count: int = 1
    target: int = 0
    param: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {FAULT_KINDS}")
        if self.at < 0 or self.count < 1:
            raise ValueError(f"need at >= 0 and count >= 1, got "
                             f"(at={self.at}, count={self.count})")

    def covers(self, clock: int) -> bool:
        return self.at <= clock < self.at + self.count


class Clock:
    """A monotone injector-local clock: each ``next()`` is one query."""

    def __init__(self):
        self.n = 0

    def next(self) -> int:
        v = self.n
        self.n += 1
        return v


class FaultPlan:
    """A seeded, schedulable set of :class:`Fault`\\ s plus a firing log."""

    def __init__(self, faults: Sequence[Fault] = (), *, seed: int = 0):
        self.faults: Tuple[Fault, ...] = tuple(
            sorted(faults, key=lambda f: (f.kind, f.at, f.target)))
        self.seed = int(seed)
        self.fired: List[Dict[str, Any]] = []

    # -- construction helpers ------------------------------------------------
    @classmethod
    def single(cls, kind: str, at: int = 0, *, count: int = 1,
               target: int = 0, param: float = 0.0,
               seed: int = 0) -> "FaultPlan":
        return cls([Fault(kind, at, count=count, target=target,
                          param=param)], seed=seed)

    def rng(self, salt: int = 0) -> np.random.Generator:
        """Deterministic per-purpose RNG (corruption byte choice etc.)."""
        return np.random.default_rng(np.uint64(self.seed) ^ np.uint64(salt))

    # -- querying ------------------------------------------------------------
    def for_kind(self, kind: str) -> Tuple[Fault, ...]:
        return tuple(f for f in self.faults if f.kind == kind)

    def fires(self, kind: str, clock: int,
              target: Optional[int] = None, **context) -> Optional[Fault]:
        """The scheduled fault covering ``(kind, clock[, target])``, or
        None. A hit is appended to :attr:`fired` together with any
        injector-supplied context, so drills can assert their faults
        actually triggered where they meant to."""
        for f in self.for_kind(kind):
            if f.covers(clock) and (target is None or f.target == target):
                self.fired.append({"kind": kind, "clock": int(clock),
                                   "target": f.target, "param": f.param,
                                   **context})
                return f
        return None

    # -- reporting -----------------------------------------------------------
    def fired_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for rec in self.fired:
            out[rec["kind"]] = out.get(rec["kind"], 0) + 1
        return out

    def summary(self) -> Dict[str, Any]:
        """JSON-able plan + firing log (lands in drill records/bundles)."""
        return {
            "seed": self.seed,
            "scheduled": [dataclasses.asdict(f) for f in self.faults],
            "fired": list(self.fired),
            "fired_counts": self.fired_counts(),
        }
