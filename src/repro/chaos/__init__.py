"""Deterministic fault injection + the self-healing drill harness.

``plan``   — :class:`FaultPlan` / :class:`Fault`: seeded, schedulable
             faults as pure data, with a firing log.
``inject`` — the injectors that make a plan real: at-rest checkpoint
             corruption, async-save IO failures, data-worker kills,
             NaN-poisoned server slots.

The scripted end-to-end drills (corrupt-latest resume, quarantine
parity, dead-worker propagation, ...) live in ``repro.launch.chaos``
(``python -m repro.launch.chaos``); ``docs/robustness.md`` states the
fault model and the recovery contracts they pin.
"""
from repro.chaos.inject import (ChaosInjectionError, checkpoint_io_hook,
                                corrupt_checkpoint, flaky_make_batch,
                                poison_server_slot)
from repro.chaos.plan import FAULT_KINDS, Clock, Fault, FaultPlan

__all__ = ["Fault", "FaultPlan", "FAULT_KINDS", "Clock",
           "corrupt_checkpoint", "checkpoint_io_hook", "flaky_make_batch",
           "poison_server_slot", "ChaosInjectionError"]
