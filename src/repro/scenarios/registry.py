"""Scenario family registry.

A *family* is a named procedural generator: ``(seed, index, cfg) ->
Scene``, pairing a lane-graph map generator with rule-based reference
policies. Families self-register at import via :func:`register`;
``repro.scenarios`` imports the ``families`` package so simply importing
the subsystem populates the registry.

Determinism contract: a family derives ALL randomness from
``family_rng(name, seed, index)`` — one ``np.random.Generator`` seeded by
a stable per-family salt plus (seed, index) — so any scene is
reproducible from its cursor alone and the index space shards trivially
across data-loader hosts (same contract as ``repro.data.pipeline``).
"""
from __future__ import annotations

import zlib
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.scenarios.core import Scene, ScenarioConfig, stack_scenes

FamilyFn = Callable[[int, int, ScenarioConfig], Scene]

_FAMILIES: Dict[str, FamilyFn] = {}


def register(name: str) -> Callable[[FamilyFn], FamilyFn]:
    """Decorator: ``@register("highway")`` over a generate function."""
    def deco(fn: FamilyFn) -> FamilyFn:
        if name in _FAMILIES:
            raise ValueError(f"scenario family {name!r} already registered")
        _FAMILIES[name] = fn
        return fn
    return deco


def names() -> List[str]:
    """All registered family names, sorted (discoverability surface)."""
    return sorted(_FAMILIES)


def get(name: str) -> FamilyFn:
    try:
        return _FAMILIES[name]
    except KeyError:
        raise KeyError(f"unknown scenario family {name!r}; "
                       f"registered: {names()}") from None


def family_rng(name: str, seed: int, index: int) -> np.random.Generator:
    """The one rng a family may draw from: salted by the family name so
    e.g. highway scene (7, 3) and merge scene (7, 3) are independent."""
    salt = zlib.crc32(name.encode())
    return np.random.default_rng(np.random.SeedSequence([salt, seed, index]))


def generate_scene(name: str, seed: int, index: int,
                   cfg: ScenarioConfig) -> Scene:
    return get(name)(seed, index, cfg)


def generate_mixed(seed: int, start_index: int, count: int,
                   cfg: ScenarioConfig,
                   families: Optional[Sequence[str]] = None) -> List[Scene]:
    """``count`` scenes cycling deterministically over ``families``
    (default: every registered family) — the mixed-family stream the
    closed-loop evaluation harness and training batches consume."""
    fams = list(families) if families is not None else names()
    return [generate_scene(fams[(start_index + i) % len(fams)], seed,
                           start_index + i, cfg)
            for i in range(count)]


def generate_mixed_batch(seed: int, start_index: int, batch_size: int,
                         cfg: ScenarioConfig,
                         families: Optional[Sequence[str]] = None):
    """Mixed-family training batch with the ``ShardedIterator`` signature
    ``(seed, start_index, batch_size) -> dict of stacked arrays``."""
    return stack_scenes(generate_mixed(seed, start_index, batch_size, cfg,
                                       families))
