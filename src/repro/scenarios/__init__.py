"""Scenario suite: lane-graph world model + procedural scenario families.

The evaluation surface of the repo: a :class:`LaneGraph` world
(`lane_graph.py`), rule-based reference policies (`policies.py`), and a
registry of procedural families (`families/`) that each emit the same
``AgentSimModel`` tensor dict — variable agent counts via validity masks,
deterministic from ``(family, seed, index)``. The closed-loop evaluation
harness over these scenes lives in ``repro.runtime.evaluation``.

>>> from repro import scenarios
>>> scenarios.registry.names()
['freeform', 'highway', 'onramp_merge', 'pedestrian_crossing',
 'roundabout', 'signalized_intersection', 'unprotected_left']
>>> scene = scenarios.generate_scene("roundabout", seed=0, index=3,
...                                  cfg=scenarios.ScenarioConfig())
"""
from repro.scenarios import core, lane_graph, policies, registry
from repro.scenarios import families  # noqa: F401  (registers families)
from repro.scenarios.core import (AGENT_TYPE, DT, MAX_SPEED, Scene,
                                  ScenarioConfig, assemble_scene,
                                  classify_behavior, decode_action,
                                  encode_action, rollout_metrics,
                                  stack_scenes, step_kinematics,
                                  transform_poses, transform_scene)
from repro.scenarios.lane_graph import LaneGraph
from repro.scenarios.registry import (generate_mixed, generate_mixed_batch,
                                      generate_scene)

__all__ = [
    "core", "lane_graph", "policies", "registry", "families",
    "AGENT_TYPE", "DT", "MAX_SPEED", "Scene", "ScenarioConfig",
    "assemble_scene", "classify_behavior", "decode_action", "encode_action",
    "rollout_metrics", "stack_scenes", "step_kinematics", "transform_poses",
    "transform_scene", "LaneGraph", "generate_mixed", "generate_mixed_batch",
    "generate_scene",
]
