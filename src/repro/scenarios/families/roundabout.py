"""Roundabout: circulating ring with four entries/exits; entering yields.

                 exit   entry
                    \\   /
                  .--->---.
                 /         \\
        entry --<    ring   >-- exit
                 \\         /
                  `---<---'

The ring is four counterclockwise quadrant arcs; at each quadrant
boundary a route either continues or exits (random fork at trace time).
Entering agents (priority 1) yield to circulating agents (priority 2) at
the ring conflict point.
"""
from __future__ import annotations

import numpy as np

from repro.scenarios import registry
from repro.scenarios.core import Scene, ScenarioConfig, assemble_scene
from repro.scenarios.lane_graph import LaneGraph, arc_lane, straight_lane
from repro.scenarios.policies import agent_on_route, simulate

RING_R = 14.0
ENTRY_LEN = 40.0


@registry.register("roundabout")
def generate(seed: int, index: int, cfg: ScenarioConfig) -> Scene:
    rng = registry.family_rng("roundabout", seed, index)
    g = LaneGraph()
    quad, entry, exits = [], [], []
    for k in range(4):
        th = k * np.pi / 2                       # boundary angle
        p = RING_R * np.array([np.cos(th), np.sin(th)])
        quad.append(g.add(arc_lane(p, th + np.pi / 2, RING_R, np.pi / 2,
                                   speed_limit=7.0)))
    for k in range(4):
        g.connect(quad[k], quad[(k + 1) % 4])
    for k in range(4):
        th = k * np.pi / 2
        p = RING_R * np.array([np.cos(th), np.sin(th)])
        tangent = th + np.pi / 2
        # entry: straight aimed at the ring boundary point, angled 30deg
        # off the ring tangent (a deliberate kink — drivers slow and turn
        # onto the ring; pure pursuit absorbs it)
        a_dir = tangent - np.pi / 6
        start = p - ENTRY_LEN * np.array([np.cos(a_dir), np.sin(a_dir)])
        entry.append(g.add(straight_lane(start, a_dir, ENTRY_LEN,
                                         speed_limit=9.0)))
        g.connect(entry[k], quad[k])
        # exit: straight leaving the boundary point outward
        x_dir = tangent + np.pi / 6
        exits.append(g.add(straight_lane(p, x_dir, ENTRY_LEN,
                                         speed_limit=9.0)))
        g.connect(quad[(k - 1) % 4], exits[k])

    cap = cfg.num_agents
    n_ring = int(rng.integers(1, max(2, min(3, cap))))
    n_ent = int(rng.integers(1, max(2, min(4, cap - n_ring + 1))))
    agents = []
    ring_starts = rng.permutation(4)[:n_ring]
    for k in ring_starts:
        route = g.trace_route(quad[int(k)], 120.0, rng)
        xy, hd = g.route_points(route)
        agents.append(agent_on_route(
            float(rng.uniform(0.0, 0.5 * RING_R)), xy, hd,
            v0=float(rng.uniform(5.0, 7.0)), rng=rng, priority=2,
            lateral_noise=0.15))
    ent_starts = rng.permutation(4)[:n_ent]
    for k in ent_starts:
        route = g.trace_route(entry[int(k)], 120.0, rng)
        xy, hd = g.route_points(route)
        agents.append(agent_on_route(
            float(rng.uniform(2.0, ENTRY_LEN * 0.6)), xy, hd,
            v0=float(rng.uniform(6.0, 9.0)), rng=rng, priority=1,
            lateral_noise=0.15))
    agents = agents[:cap]
    pose, feats, actions = simulate(cfg, rng, agents, cfg.num_steps)
    types = np.zeros(len(agents), np.int32)
    return assemble_scene("roundabout", cfg, g, pose, feats, actions, types)
