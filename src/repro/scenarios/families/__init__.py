"""Procedural scenario families. Importing this package registers every
family with ``repro.scenarios.registry`` (import side effect by design —
the registry is the discovery surface, see ``registry.names()``)."""
from repro.scenarios.families import (freeform, highway, intersection,
                                      left_turn, merge, pedestrian,
                                      roundabout)

__all__ = ["freeform", "highway", "intersection", "left_turn", "merge",
           "pedestrian", "roundabout"]
