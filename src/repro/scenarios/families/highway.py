"""Highway cruise: parallel straight lanes, free-flow + car-following.

    ============================================>  lane 2
    =====car=========car========================>  lane 1
    ==========car===============car=============>  lane 0

The whole corridor is randomly re-posed per scene (rotation + offset), so
absolute-position models can't overfit a canonical frame.
"""
from __future__ import annotations

import numpy as np

from repro.scenarios import registry
from repro.scenarios.core import Scene, ScenarioConfig, assemble_scene
from repro.scenarios.lane_graph import LaneGraph, straight_lane
from repro.scenarios.policies import agent_on_route, simulate, spaced_starts

LANE_WIDTH = 3.5


@registry.register("highway")
def generate(seed: int, index: int, cfg: ScenarioConfig) -> Scene:
    rng = registry.family_rng("highway", seed, index)
    heading = rng.uniform(-np.pi, np.pi)
    origin = rng.uniform(-0.3 * cfg.map_radius, 0.3 * cfg.map_radius, 2)
    length = 180.0
    n_lanes = int(rng.integers(2, 4))
    normal = np.array([-np.sin(heading), np.cos(heading)])
    start0 = origin - 0.5 * length * np.array([np.cos(heading),
                                               np.sin(heading)])

    g = LaneGraph()
    lane_ids = []
    for li in range(n_lanes):
        lane_ids.append(g.add(straight_lane(
            start0 + li * LANE_WIDTH * normal, heading, length,
            speed_limit=14.0)))
    for li in range(n_lanes - 1):
        g.set_neighbors(lane_ids[li], left=lane_ids[li + 1])
        g.set_neighbors(lane_ids[li + 1], right=lane_ids[li])

    n_agents = int(rng.integers(min(3, cfg.num_agents),
                                cfg.num_agents + 1))
    per_lane = [n_agents // n_lanes + (1 if li < n_agents % n_lanes else 0)
                for li in range(n_lanes)]
    agents = []
    for li, count in enumerate(per_lane):
        xy, hd = g.route_points([lane_ids[li]])
        starts = spaced_starts(rng, count, 10.0, 0.6 * length, min_gap=18.0)
        for s0 in starts:
            agents.append(agent_on_route(
                float(s0), xy, hd, v0=float(rng.uniform(8.0, 14.0)), rng=rng))
    pose, feats, actions = simulate(cfg, rng, agents, cfg.num_steps)
    types = np.zeros(len(agents), np.int32)
    return assemble_scene("highway", cfg, g, pose, feats, actions, types)
