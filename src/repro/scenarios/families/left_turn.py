"""Unprotected left turn: a turner crosses oncoming traffic, no signal.

                         | ^ |
                         | N |
                         |   |
         ----------------+   +----------------
           W <---------- o <-- oncoming <-- W
         ------------\\---+---------------------
           E --> car --`(left turn across W)

The eastbound left turner (priority 1) must find a gap in the oncoming
westbound stream (priority 2) — the canonical interaction the paper's
turning-minADE column stresses.
"""
from __future__ import annotations

import numpy as np

from repro.scenarios import registry
from repro.scenarios.core import Scene, ScenarioConfig, assemble_scene
from repro.scenarios.lane_graph import LaneGraph, arc_lane, straight_lane
from repro.scenarios.policies import agent_on_route, simulate, spaced_starts

LANE_OFF = 1.75
TURN_X = 0.0           # where the turn leaves the eastbound lane
APPROACH = 80.0


@registry.register("unprotected_left")
def generate(seed: int, index: int, cfg: ScenarioConfig) -> Scene:
    rng = registry.family_rng("unprotected_left", seed, index)
    g = LaneGraph()
    # two-way EW road through the origin
    e1 = g.add(straight_lane((-APPROACH, -LANE_OFF), 0.0, APPROACH,
                             speed_limit=12.0))
    e2 = g.add(straight_lane((0.0, -LANE_OFF), 0.0, APPROACH,
                             speed_limit=12.0))
    w = g.add(straight_lane((APPROACH, LANE_OFF), np.pi, 2 * APPROACH,
                            speed_limit=12.0))
    g.connect(e1, e2)
    # left-turn arc: quarter turn from the end of e1 into a northbound exit
    radius = 8.0
    turn = g.add(arc_lane((0.0, -LANE_OFF), 0.0, radius, np.pi / 2,
                          speed_limit=5.0))
    north = g.add(straight_lane((radius, -LANE_OFF + radius), np.pi / 2,
                                60.0, speed_limit=12.0))
    g.connect(e1, turn)
    g.connect(turn, north)

    cap = cfg.num_agents
    # the protagonist: always one left turner, close to the junction
    turn_xy, turn_hd = g.route_points([e1, turn, north])
    agents = [agent_on_route(
        float(APPROACH - rng.uniform(18.0, 32.0)), turn_xy, turn_hd,
        v0=float(rng.uniform(5.0, 8.0)), rng=rng, priority=1)]
    # oncoming westbound stream
    n_onc = int(rng.integers(1, max(2, min(4, cap))))
    onc_xy, onc_hd = g.route_points([w])
    for s0 in spaced_starts(rng, n_onc, 40.0, 2 * APPROACH - 50.0,
                            min_gap=16.0):
        agents.append(agent_on_route(
            float(s0), onc_xy, onc_hd, v0=float(rng.uniform(8.0, 12.0)),
            rng=rng, priority=2))
    # optional eastbound through follower behind the turner
    if cap - len(agents) > 0 and rng.uniform() < 0.7:
        thr_xy, thr_hd = g.route_points([e1, e2])
        agents.append(agent_on_route(
            float(rng.uniform(15.0, 35.0)), thr_xy, thr_hd,
            v0=float(rng.uniform(8.0, 12.0)), rng=rng, priority=2))
    agents = agents[:cap]
    pose, feats, actions = simulate(cfg, rng, agents, cfg.num_steps)
    types = np.zeros(len(agents), np.int32)
    return assemble_scene("unprotected_left", cfg, g, pose, feats, actions,
                          types)
