"""The legacy free-form generator, as a registered scenario family.

This is the original ``repro.data.scenarios`` generator moved verbatim:
a few disconnected arcs/straights, a fixed agent count, and the three
hand-assigned behavior modes (stationary / straight / turny). It keeps
its original RNG stream — seeded by ``(seed, index)`` directly, NOT the
registry's family-salted rng — so ``repro.data.scenarios.generate_scene``
(now a thin shim over this module) returns bit-identical tensors to every
pre-refactor release; training curves and cached metrics stay comparable.

Beyond the move, the family now also builds a :class:`LaneGraph` from the
very lane chains it drew (after all rng draws, so determinism is
untouched), which is what lets the closed-loop evaluation harness score
off-road rates for freeform scenes like any other family.
"""
from __future__ import annotations

import numpy as np

from repro.core.kinematics import DT
from repro.scenarios import registry
from repro.scenarios.core import (Scene, ScenarioConfig, decode_action,
                                  encode_action, step_kinematics)
from repro.scenarios.lane_graph import LaneGraph, polyline_lane


def _make_lanes(rng, cfg: ScenarioConfig):
    """A few arcs/straights through the scene; returns per-segment pose+feat."""
    poses = np.zeros((cfg.num_map, 3), np.float32)
    feats = np.zeros((cfg.num_map, cfg.map_feat_dim), np.float32)
    n_lanes = rng.integers(2, 5)
    seg_per_lane = cfg.num_map // n_lanes
    idx = 0
    lanes = []
    for li in range(n_lanes):
        start = rng.uniform(-cfg.map_radius * 0.5, cfg.map_radius * 0.5, 2)
        heading = rng.uniform(-np.pi, np.pi)
        curvature = rng.uniform(-0.02, 0.02)
        seg_len = rng.uniform(5.0, 10.0)
        pts = []
        x, y, th = start[0], start[1], heading
        for si in range(seg_per_lane):
            if idx >= cfg.num_map:
                break
            poses[idx] = (x, y, th)
            feats[idx, 0] = seg_len / 10.0
            feats[idx, 1] = curvature * 50.0
            feats[idx, 2] = 1.0  # type: lane
            feats[idx, 3] = li / n_lanes
            pts.append((x, y, th, seg_len))
            x += seg_len * np.cos(th)
            y += seg_len * np.sin(th)
            th += curvature * seg_len
            idx += 1
        lanes.append(pts)
    return poses, feats, lanes


def _lane_graph_from_chains(lanes) -> LaneGraph:
    """Deterministic LaneGraph over the drawn segment chains (no rng)."""
    g = LaneGraph()
    for pts in lanes:
        if not pts:
            continue
        xy = [(p[0], p[1]) for p in pts]
        last = pts[-1]
        xy.append((last[0] + last[3] * np.cos(last[2]),
                   last[1] + last[3] * np.sin(last[2])))
        g.add(polyline_lane(np.asarray(xy, np.float64)))
    return g


def generate_tensors(seed: int, index: int, cfg: ScenarioConfig):
    """The legacy scene dict (exact pre-refactor arrays) + the lane chains."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, index]))
    map_pose, map_feats, lanes = _make_lanes(rng, cfg)

    a, t = cfg.num_agents, cfg.num_steps
    pose = np.zeros((a, 3), np.float32)
    speed = rng.uniform(0.0, 12.0, a).astype(np.float32)
    behavior = rng.integers(0, 3, a)  # 0 stationary-ish, 1 straight, 2 turny
    for ai in range(a):
        lane = lanes[rng.integers(0, len(lanes))]
        seg = lane[rng.integers(0, len(lane))]
        pose[ai] = (seg[0] + rng.normal(0, 1.0), seg[1] + rng.normal(0, 1.0),
                    seg[2] + rng.normal(0, 0.1))
        if behavior[ai] == 0:
            speed[ai] = rng.uniform(0, 0.5)

    agent_pose = np.zeros((t, a, 3), np.float32)
    agent_feats = np.zeros((t, a, cfg.agent_feat_dim), np.float32)
    actions = np.zeros((t, a), np.int64)
    cur_pose, cur_speed = pose, speed
    for ti in range(t):
        agent_pose[ti] = cur_pose
        agent_feats[ti, :, 0] = cur_speed / 10.0
        agent_feats[ti, :, 1] = (behavior == 1)
        agent_feats[ti, :, 2] = (behavior == 2)
        agent_feats[ti, :, 3] = 1.0
        # policy: noisy accel; turny agents sweep yaw rate sinusoidally
        accel = np.where(behavior == 0,
                         -cur_speed / DT * 0.5,
                         rng.normal(0.3, 0.8, a))
        yaw = np.where(behavior == 2,
                       cfg.max_yaw_rate * 0.7
                       * np.sin(0.4 * ti + np.arange(a)),
                       rng.normal(0, 0.03, a))
        accel = np.clip(accel, -cfg.max_accel, cfg.max_accel)
        yaw = np.clip(yaw, -cfg.max_yaw_rate, cfg.max_yaw_rate)
        act_id = encode_action(cfg, accel, yaw)
        actions[ti] = act_id
        # integrate with the *quantized* action so labels are exact
        qa, qy = decode_action(cfg, act_id)
        cur_pose, cur_speed = step_kinematics(cur_pose, cur_speed, qa, qy)

    tensors = {
        "map_feats": map_feats,
        "map_pose": map_pose,
        "map_valid": np.ones(cfg.num_map, bool),
        "agent_feats": agent_feats,
        "agent_pose": agent_pose,
        "agent_valid": np.ones((t, a), bool),
        "actions": actions.astype(np.int32),
        "behavior": behavior.astype(np.int32),
        "agent_type": np.zeros(a, np.int32),       # all vehicles
    }
    return tensors, lanes


@registry.register("freeform")
def generate(seed: int, index: int, cfg: ScenarioConfig) -> Scene:
    tensors, lanes = generate_tensors(seed, index, cfg)
    return Scene(family="freeform", tensors=tensors,
                 lane_graph=_lane_graph_from_chains(lanes))
