"""Pedestrian crossing: heterogeneous agent types on a two-way road.

           |  ped  |
    =======|...|...|=======>  eastbound lane
    <======|...v...|========  westbound lane
           | cross |
           |  walk |

Pedestrians (agent_type 1, walking speed, top priority) cross on a
crosswalk lane; vehicles on both lanes yield to them at the conflict
points. The only family with non-vehicle dynamics — it exercises the
heterogeneous-agent path of the model features and the per-type
exemptions in the evaluation metrics (pedestrians are never "off-road").
"""
from __future__ import annotations

import numpy as np

from repro.scenarios import registry
from repro.scenarios.core import (AGENT_TYPE, Scene, ScenarioConfig,
                                  assemble_scene)
from repro.scenarios.lane_graph import LaneGraph, straight_lane
from repro.scenarios.policies import (IDMParams, agent_on_route, simulate,
                                      spaced_starts)

LANE_OFF = 1.75
ROAD_LEN = 140.0
WALK_HALF = 8.0        # crosswalk half-length


@registry.register("pedestrian_crossing")
def generate(seed: int, index: int, cfg: ScenarioConfig) -> Scene:
    rng = registry.family_rng("pedestrian_crossing", seed, index)
    g = LaneGraph()
    e = g.add(straight_lane((-ROAD_LEN / 2, -LANE_OFF), 0.0, ROAD_LEN,
                            speed_limit=11.0))
    w = g.add(straight_lane((ROAD_LEN / 2, LANE_OFF), np.pi, ROAD_LEN,
                            speed_limit=11.0))
    north = g.add(straight_lane((0.0, -WALK_HALF), np.pi / 2, 2 * WALK_HALF,
                                kind="crosswalk", speed_limit=1.5))
    south = g.add(straight_lane((0.0, WALK_HALF), -np.pi / 2, 2 * WALK_HALF,
                                kind="crosswalk", speed_limit=1.5))

    cap = cfg.num_agents
    n_ped = int(rng.integers(1, max(2, min(4, cap))))
    n_veh = int(rng.integers(1, max(2, min(5, cap - n_ped + 1))))
    agents, types = [], []
    ped_idm = IDMParams(accel_max=1.0, brake=1.5, headway=0.8, min_gap=0.6)
    for _ in range(n_ped):
        lane = north if rng.uniform() < 0.5 else south
        xy, hd = g.route_points([lane])
        agents.append(agent_on_route(
            float(rng.uniform(0.0, WALK_HALF)), xy, hd,
            v0=float(rng.uniform(1.0, 1.8)), rng=rng,
            agent_type=AGENT_TYPE["pedestrian"], priority=3,
            lateral_noise=0.4, heading_noise=0.08, speed_frac=(0.6, 1.0),
            idm=ped_idm))
        types.append(AGENT_TYPE["pedestrian"])
    for li, count in ((e, (n_veh + 1) // 2), (w, n_veh // 2)):
        xy, hd = g.route_points([li])
        for s0 in spaced_starts(rng, count, 15.0, ROAD_LEN / 2 - 6.0,
                                min_gap=16.0):
            agents.append(agent_on_route(
                float(s0), xy, hd, v0=float(rng.uniform(7.0, 11.0)),
                rng=rng, priority=1))
            types.append(AGENT_TYPE["vehicle"])
    agents, types = agents[:cap], types[:cap]
    pose, feats, actions = simulate(cfg, rng, agents, cfg.num_steps)
    return assemble_scene("pedestrian_crossing", cfg, g, pose, feats,
                          actions, np.asarray(types, np.int32))
