"""Signalized four-way intersection: phased through traffic + left turns.

                    |  ^  |
                    |  N  |
                    | [|] |
            --------+-----+--------
              E <--   box    <-- E
            --------+-----+--------
                    | [|] |
                    |  S  |
                    |  ^  |

Each approach runs to a stop line, crosses the box (straight, or a left-
turn arc chosen at the route fork), and exits. A two-phase signal
(NS green / EW green, random period offset) gates the stop lines through
the simulate() stop hook; left turners additionally carry lower priority
than oncoming through traffic, so they yield inside the box.
"""
from __future__ import annotations

import numpy as np

from repro.scenarios import registry
from repro.scenarios.core import Scene, ScenarioConfig, assemble_scene
from repro.scenarios.lane_graph import LaneGraph, arc_lane, straight_lane
from repro.scenarios.policies import agent_on_route, simulate

HALF_BOX = 10.0        # intersection half-extent (stop-line distance)
LANE_OFF = 1.75        # right-hand lane offset from the road centerline
APPROACH = 70.0        # approach/exit length

# the four compass directions: heading, unit dir
_DIRS = {
    "E": 0.0, "N": np.pi / 2, "W": np.pi, "S": -np.pi / 2,
}
_LEFT_OF = {"E": "N", "N": "W", "W": "S", "S": "E"}


def _unit(th):
    return np.array([np.cos(th), np.sin(th)], np.float32)


def _build_graph():
    """Per direction: approach -> {through box, left box} -> exits."""
    g = LaneGraph()
    ids = {}
    for name, th in _DIRS.items():
        d, n = _unit(th), _unit(th + np.pi / 2)
        off = -LANE_OFF * n                    # keep-right lane offset
        appr_start = off - (HALF_BOX + APPROACH) * d
        ids[name, "approach"] = g.add(straight_lane(
            appr_start, th, APPROACH, speed_limit=12.0))
        ids[name, "through"] = g.add(straight_lane(
            off - HALF_BOX * d, th, 2 * HALF_BOX, speed_limit=10.0))
        ids[name, "exit"] = g.add(straight_lane(
            off + HALF_BOX * d, th, APPROACH, speed_limit=12.0))
    for name, th in _DIRS.items():
        left = _LEFT_OF[name]
        d, n = _unit(th), _unit(th + np.pi / 2)
        start = -LANE_OFF * n - HALF_BOX * d
        # quarter arc from the stop line into the left direction's exit
        ids[name, "left"] = g.add(arc_lane(
            start, th, _left_turn_radius(name), np.pi / 2, speed_limit=6.0))
        g.connect(ids[name, "approach"], ids[name, "through"])
        g.connect(ids[name, "approach"], ids[name, "left"])
        g.connect(ids[name, "through"], ids[name, "exit"])
        g.connect(ids[name, "left"], ids[left, "exit"])
    return g, ids


def _left_turn_radius(name):
    """Radius that lands the quarter arc on the left exit's lane line."""
    th = _DIRS[name]
    d, n = _unit(th), _unit(th + np.pi / 2)
    start = -LANE_OFF * n - HALF_BOX * d
    left = _LEFT_OF[name]
    dl, nl = _unit(_DIRS[left]), _unit(_DIRS[left] + np.pi / 2)
    target = -LANE_OFF * nl + HALF_BOX * dl
    # arc turning +90deg from `start` heading th ends at
    # start + r*(d + n_perp_delta); solve |along-d displacement| = r
    return float(np.dot(target - start, d))


@registry.register("signalized_intersection")
def generate(seed: int, index: int, cfg: ScenarioConfig) -> Scene:
    rng = registry.family_rng("signalized_intersection", seed, index)
    g, ids = _build_graph()
    dirs = list(_DIRS)
    cap = cfg.num_agents
    n_agents = int(rng.integers(min(3, cap), cap + 1))

    agents, stop_lines, groups = [], [], []
    order = [dirs[int(rng.integers(4))] for _ in range(n_agents)]
    per_dir = {}
    for i, name in enumerate(order):
        route = [ids[name, "approach"]]
        turn_left = rng.uniform() < 0.3
        if turn_left:
            route += [ids[name, "left"], ids[_LEFT_OF[name], "exit"]]
        else:
            route += [ids[name, "through"], ids[name, "exit"]]
        xy, hd = g.route_points(route)
        k = per_dir.get(name, 0)
        per_dir[name] = k + 1
        s0 = float(APPROACH - 15.0 - 22.0 * k - rng.uniform(0.0, 6.0))
        if s0 < 2.0:
            continue                           # approach is full
        agents.append(agent_on_route(
            s0, xy, hd, v0=float(rng.uniform(7.0, 11.0)), rng=rng,
            priority=1 if turn_left else 2))
        stop_lines.append(APPROACH)            # approach lane ends there
        groups.append(0 if name in ("N", "S") else 1)

    period = max(4, cfg.num_steps // 2)
    offset = int(rng.integers(0, 2 * period))

    def stop_hook(i, t):
        green_group = ((t + offset) // period) % 2      # 0 = NS, 1 = EW
        if groups[i] == green_group:
            return None
        if agents[i].s > stop_lines[i] - 1.0:
            return None                        # already past the line
        return stop_lines[i]

    pose, feats, actions = simulate(cfg, rng, agents, cfg.num_steps,
                                    stop_hook=stop_hook)
    types = np.zeros(len(agents), np.int32)
    return assemble_scene("signalized_intersection", cfg, g, pose, feats,
                          actions, types)
