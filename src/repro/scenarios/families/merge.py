"""On-ramp merge: ramp traffic yields into a mainline gap.

    ===========================o==================>  mainline
                              /
                         ____/   on-ramp (arc)
                        /
                       car

The ramp route shares the downstream mainline lane, so the conflict
detector sees a merge point; ramp agents (priority 1) gap-accept against
mainline agents (priority 2) via the standard yield rule.
"""
from __future__ import annotations

import numpy as np

from repro.scenarios import registry
from repro.scenarios.core import Scene, ScenarioConfig, assemble_scene
from repro.scenarios.lane_graph import LaneGraph, arc_lane, straight_lane
from repro.scenarios.policies import agent_on_route, simulate, spaced_starts

RAMP_ANGLE = 0.45      # rad between ramp approach and mainline
RAMP_RADIUS = 60.0


@registry.register("onramp_merge")
def generate(seed: int, index: int, cfg: ScenarioConfig) -> Scene:
    rng = registry.family_rng("onramp_merge", seed, index)
    g = LaneGraph()
    # mainline split at the merge point (origin): upstream -> downstream
    up = g.add(straight_lane((-90.0, 0.0), 0.0, 90.0, speed_limit=14.0))
    down = g.add(straight_lane((0.0, 0.0), 0.0, 90.0, speed_limit=14.0))
    g.connect(up, down)
    # ramp: straight approach at RAMP_ANGLE, then an arc that straightens
    # out exactly at the merge point (built at the origin, then shifted)
    arc = arc_lane((0.0, 0.0), RAMP_ANGLE, RAMP_RADIUS, -RAMP_ANGLE)
    shift = -arc.points[-1]
    arc.points = arc.points + shift
    approach_len = 44.0   # multiple of STEP so the joint to the arc is exact
    d = np.array([np.cos(RAMP_ANGLE), np.sin(RAMP_ANGLE)], np.float32)
    approach = straight_lane(arc.points[0] - approach_len * d, RAMP_ANGLE,
                             approach_len, speed_limit=9.0)
    ramp_a = g.add(approach)
    ramp_b = g.add(arc)
    g.connect(ramp_a, ramp_b)
    g.connect(ramp_b, down)

    cap = cfg.num_agents
    n_main = int(rng.integers(1, max(2, min(4, cap))))
    n_ramp = int(rng.integers(1, max(2, min(3, cap - n_main + 1))))
    main_xy, main_hd = g.route_points([up, down])
    ramp_xy, ramp_hd = g.route_points([ramp_a, ramp_b, down])
    agents = []
    for s0 in spaced_starts(rng, n_main, 10.0, 80.0, min_gap=20.0):
        agents.append(agent_on_route(
            float(s0), main_xy, main_hd, v0=float(rng.uniform(10.0, 14.0)),
            rng=rng, priority=2))
    for s0 in spaced_starts(rng, n_ramp, 5.0, approach_len - 5.0,
                            min_gap=15.0):
        agents.append(agent_on_route(
            float(s0), ramp_xy, ramp_hd, v0=float(rng.uniform(6.0, 10.0)),
            rng=rng, priority=1))
    agents = agents[:cap]
    pose, feats, actions = simulate(cfg, rng, agents, cfg.num_steps)
    types = np.zeros(len(agents), np.int32)
    return assemble_scene("onramp_merge", cfg, g, pose, feats, actions, types)
