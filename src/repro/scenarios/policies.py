"""Rule-based reference policies that drive agents along lane-graph routes.

Every family shares one simulation loop (:func:`simulate`): each agent
follows a dense route polyline with a pure-pursuit steering law, keeps
gaps with an IDM-style longitudinal law, and yields at route conflict
points (crossings/merges) to higher-priority traffic; families inject
extra stop constraints (traffic signals, stop lines) through a hook.

Actions are snapped to the scenario's discrete (accel x yaw-rate) grid
and the state integrates with the *quantized* action through the shared
unicycle (`repro.core.kinematics`), so the recorded action labels are
exact — the same convention the freeform generator always used.

Everything is numpy on host; all randomness flows through the single
``np.random.Generator`` a family derives from ``(family, seed, index)``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Tuple

import numpy as np

from repro.core.kinematics import DT, step_kinematics
from repro.scenarios.core import (ScenarioConfig, decode_action,
                                  encode_action)
from repro.scenarios.lane_graph import STEP

CAR_LENGTH = 4.5        # m, bumper-to-bumper allowance in gap keeping
LATERAL_TOL = 2.0       # m, how far off my route a lead can sit
CONFLICT_RADIUS = 2.5   # m, route points closer than this conflict
STOP_MARGIN = 3.0       # m, stop this far before a conflict / stop line
YIELD_HORIZON = 8.0     # s, care about conflicts this far out


@dataclasses.dataclass
class IDMParams:
    accel_max: float = 2.0      # comfortable acceleration a
    brake: float = 3.0          # comfortable deceleration b
    headway: float = 1.5        # desired time gap T
    min_gap: float = 2.0        # jam distance s0


def idm_accel(v, v0, gap, dv, p: IDMParams) -> float:
    """Intelligent Driver Model longitudinal acceleration.

    v own speed, v0 desired speed, gap bumper gap to lead (inf if free
    road), dv = v - v_lead (closing speed).
    """
    v0 = max(v0, 0.1)
    free = 1.0 - (v / v0) ** 4
    if not np.isfinite(gap):
        return p.accel_max * free
    s_star = p.min_gap + max(
        0.0, v * p.headway + v * dv / (2.0 * np.sqrt(p.accel_max * p.brake)))
    return p.accel_max * (free - (s_star / max(gap, 0.1)) ** 2)


def pursuit_yaw_rate(pose, target_xy, speed, dt: float = DT,
                     gain: float = 0.6) -> float:
    """Proportional pure pursuit: steer the heading toward the lookahead
    point on the route. Speed-independent (unicycle turns in place fine)."""
    bearing = np.arctan2(target_xy[1] - pose[1], target_xy[0] - pose[0])
    err = np.arctan2(np.sin(bearing - pose[2]), np.cos(bearing - pose[2]))
    return gain * err / dt


@dataclasses.dataclass
class RouteAgent:
    """One simulated agent bound to a dense route polyline."""
    route_xy: np.ndarray          # (N, 2) centerline of the full route
    route_heading: np.ndarray     # (N,)
    s: float                      # arclength progress along the route
    pose: np.ndarray              # (3,) current (x, y, theta)
    speed: float
    v0: float                     # desired cruise speed
    agent_type: int = 0           # AGENT_TYPE: 0 vehicle, 1 pedestrian
    priority: int = 1             # yields to strictly higher priority
    idm: IDMParams = dataclasses.field(default_factory=IDMParams)

    @property
    def route_len(self) -> float:
        return STEP * (len(self.route_xy) - 1)

    def point_at(self, s: float) -> np.ndarray:
        i = min(int(round(s / STEP)), len(self.route_xy) - 1)
        return self.route_xy[max(i, 0)]


def agent_on_route(start_s: float, route_xy, route_heading, v0: float,
                   rng: np.random.Generator, *, agent_type: int = 0,
                   priority: int = 1, lateral_noise: float = 0.3,
                   heading_noise: float = 0.03,
                   speed_frac: Tuple[float, float] = (0.5, 1.0),
                   idm: Optional[IDMParams] = None) -> RouteAgent:
    """Spawn an agent at arclength ``start_s`` of a route with small pose
    noise and a random fraction of its desired speed."""
    i = min(int(round(start_s / STEP)), len(route_xy) - 1)
    th = float(route_heading[i])
    normal = np.array([-np.sin(th), np.cos(th)])
    xy = route_xy[i] + normal * rng.normal(0.0, lateral_noise)
    pose = np.array([xy[0], xy[1], th + rng.normal(0.0, heading_noise)],
                    np.float32)
    speed = float(v0 * rng.uniform(*speed_frac))
    return RouteAgent(route_xy=np.asarray(route_xy, np.float32),
                      route_heading=np.asarray(route_heading, np.float32),
                      s=STEP * i, pose=pose, speed=speed, v0=v0,
                      agent_type=agent_type, priority=priority,
                      idm=idm or IDMParams())


def spaced_starts(rng: np.random.Generator, n: int, lo: float, hi: float,
                  min_gap: float = 10.0) -> np.ndarray:
    """Sorted start arclengths in [lo, hi] with pairwise gaps >= min_gap
    (slot-and-jitter, so it never rejects): slot i is [lo + i*w, lo+(i+1)*w)
    and the jitter stays min_gap short of the slot end. When the range
    cannot fit n starts at min_gap spacing, FEWER than n are returned —
    the gap guarantee wins over the count (families absorb the shortfall
    through their validity masks)."""
    n = min(n, max(1, int((hi - lo) / min_gap)))
    if n <= 0:
        return np.zeros(0, np.float32)
    w = (hi - lo) / n
    jitter = rng.uniform(0.0, max(w - min_gap, 1e-3), size=n)
    return (lo + w * np.arange(n) + jitter).astype(np.float32)


def route_conflicts(agents: List[RouteAgent],
                    radius: float = CONFLICT_RADIUS
                    ) -> List[Tuple[int, int, float, float]]:
    """Pairwise route crossing/merge points.

    Returns (i, j, s_i, s_j): the first arclength along i's route where it
    comes within ``radius`` of j's route, and the matching arclength on
    j's. Pairs whose routes run parallel from the start (followers on the
    same lane) are excluded — gap keeping handles those.
    """
    out = []
    for i in range(len(agents)):
        for j in range(len(agents)):
            if i == j:
                continue
            a, b = agents[i].route_xy, agents[j].route_xy
            d = np.linalg.norm(a[:, None, :] - b[None, :, :], axis=-1)
            close = d < radius
            if not close.any():
                continue
            ii = int(np.argmax(close.any(axis=1)))
            jj = int(np.argmin(d[ii]))
            # same-direction overlap from the very start = same lane
            if ii == 0 and jj == 0:
                continue
            out.append((i, j, STEP * ii, STEP * jj))
    return out


def _lead_gap(agents: List[RouteAgent], i: int) -> Tuple[float, float]:
    """Bumper gap and closing speed to the nearest agent ahead on (or
    laterally within LATERAL_TOL of) agent i's route."""
    me = agents[i]
    gap, dv = np.inf, 0.0
    for j, other in enumerate(agents):
        if j == i:
            continue
        d = np.linalg.norm(me.route_xy - other.pose[:2], axis=-1)
        k = int(np.argmin(d))
        if d[k] > LATERAL_TOL:
            continue
        s_other = STEP * k
        if s_other <= me.s + 0.1:
            continue
        g = s_other - me.s - CAR_LENGTH
        if g < gap:
            gap, dv = g, me.speed - other.speed
    return gap, dv


def _yield_stop(agents: List[RouteAgent], i: int,
                conflicts: List[Tuple[int, int, float, float]]
                ) -> Optional[float]:
    """Arclength to stop before, if agent i must yield at a conflict."""
    me = agents[i]
    stop = None
    for (a, b, s_a, s_b) in conflicts:
        if a != i:
            continue
        other = agents[b]
        if other.priority <= me.priority:
            continue                      # only yield upward in priority
        if me.s > s_a - STOP_MARGIN * 0.5:
            continue                      # already committed to the zone
        if other.s > s_b + CAR_LENGTH:
            continue                      # they already cleared it
        tta = (s_b - other.s) / max(other.speed, 0.5)
        if tta > YIELD_HORIZON and (s_b - other.s) > 30.0:
            continue                      # far away, slow: do not wait
        s_stop = s_a - STOP_MARGIN
        stop = s_stop if stop is None else min(stop, s_stop)
    return stop


StopHook = Callable[[int, int], Optional[float]]


def simulate(cfg: ScenarioConfig, rng: np.random.Generator,
             agents: List[RouteAgent], num_steps: int,
             stop_hook: Optional[StopHook] = None,
             accel_noise: float = 0.25, yaw_noise: float = 0.015):
    """Roll the shared rule-based policy forward ``num_steps`` steps.

    ``stop_hook(agent_idx, t)`` may return an arclength the agent must
    stop before at step t (signals, stop lines), or None.

    Returns (agent_pose (T, A, 3), agent_feats (T, A, Fa),
    actions (T, A) int32) for the A real agents — the caller pads to the
    config's agent cap. Feature convention (the only contract the rollout
    engine relies on is channel 0):
      [0] speed / 10 (dynamic; everything else static per agent)
      [1] vehicle flag   [2] pedestrian flag
      [3] desired speed / 10   [4] priority / 2
    """
    a, t_n = len(agents), num_steps
    conflicts = route_conflicts(agents)
    agent_pose = np.zeros((t_n, a, 3), np.float32)
    agent_feats = np.zeros((t_n, a, cfg.agent_feat_dim), np.float32)
    actions = np.zeros((t_n, a), np.int64)
    for i, ag in enumerate(agents):
        agent_feats[:, i, 1] = 1.0 if ag.agent_type == 0 else 0.0
        agent_feats[:, i, 2] = 1.0 if ag.agent_type == 1 else 0.0
        agent_feats[:, i, 3] = ag.v0 / 10.0
        agent_feats[:, i, 4] = ag.priority / 2.0

    for t in range(t_n):
        # snapshot, then decide all, then move all (simultaneous update)
        accel_cmd = np.zeros(a, np.float32)
        yaw_cmd = np.zeros(a, np.float32)
        for i, ag in enumerate(agents):
            agent_pose[t, i] = ag.pose
            agent_feats[t, i, 0] = ag.speed / 10.0
            gap, dv = _lead_gap(agents, i)
            stops = [s for s in (
                _yield_stop(agents, i, conflicts),
                stop_hook(i, t) if stop_hook is not None else None)
                if s is not None]
            for s_stop in stops:
                g = s_stop - ag.s
                if g < gap:
                    gap, dv = max(g, 0.0), ag.speed
            v0 = ag.v0
            if ag.s >= ag.route_len - STEP:       # route exhausted: stop
                v0, gap, dv = 0.1, min(gap, 1.0), ag.speed
            accel = idm_accel(ag.speed, v0, gap, dv, ag.idm)
            look = max(4.0, 1.2 * ag.speed)
            target = ag.point_at(ag.s + look)
            yaw = pursuit_yaw_rate(ag.pose, target, ag.speed)
            accel_cmd[i] = accel + rng.normal(0.0, accel_noise)
            yaw_cmd[i] = yaw + rng.normal(0.0, yaw_noise)
        accel_cmd = np.clip(accel_cmd, -cfg.max_accel, cfg.max_accel)
        yaw_cmd = np.clip(yaw_cmd, -cfg.max_yaw_rate, cfg.max_yaw_rate)
        act_id = encode_action(cfg, accel_cmd, yaw_cmd)
        actions[t] = act_id
        qa, qy = decode_action(cfg, act_id)
        for i, ag in enumerate(agents):
            new_pose, new_speed = step_kinematics(ag.pose, ag.speed,
                                                  float(qa[i]), float(qy[i]))
            ag.pose = np.asarray(new_pose, np.float32)
            # dead-reckoned route progress; pure pursuit absorbs drift
            ag.s = min(ag.s + 0.5 * (ag.speed + new_speed) * DT,
                       ag.route_len)
            ag.speed = float(new_speed)
    return agent_pose, agent_feats, actions.astype(np.int32)
