"""Shared scenario substrate: config, action codec, the Scene container,
behavior classification, rigid re-posing, and mask-aware rollout metrics.

``ScenarioConfig`` (and the action grid codec) is the single source of
truth for scene tensor shapes — ``repro.data.scenarios`` re-exports it
for back-compat, and every scenario family pads its output to the
config's ``num_map`` / ``num_agents`` caps with validity masks, so mixed-
family batches stack into one static-shape tensor dict.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.kinematics import (DT, MAX_SPEED, step_kinematics,
                                   wrap_angle)
from repro.scenarios.lane_graph import LaneGraph

__all__ = [
    "DT", "MAX_SPEED", "step_kinematics", "ScenarioConfig", "Scene",
    "encode_action", "decode_action", "assemble_scene", "classify_behavior",
    "transform_poses", "transform_scene", "stack_scenes",
    "rollout_metrics", "AGENT_TYPE",
]

AGENT_TYPE = {"vehicle": 0, "pedestrian": 1}

# behavior categories (paper Table I columns)
BEHAVIOR = {"stationary": 0, "straight": 1, "turning": 2}


@dataclasses.dataclass(frozen=True)
class ScenarioConfig:
    num_map: int = 32             # lane-segment tokens per scene (cap)
    num_agents: int = 8           # agent slots per scene (cap; masked)
    num_steps: int = 16           # history+future steps tokenized
    accel_bins: int = 7           # action grid
    yaw_bins: int = 9
    max_accel: float = 3.0        # m/s^2
    max_yaw_rate: float = 0.5     # rad/s
    map_radius: float = 60.0
    agent_feat_dim: int = 8
    map_feat_dim: int = 8

    @property
    def num_actions(self) -> int:
        return self.accel_bins * self.yaw_bins

    def accel_values(self):
        return np.linspace(-self.max_accel, self.max_accel, self.accel_bins)

    def yaw_values(self):
        return np.linspace(-self.max_yaw_rate, self.max_yaw_rate,
                           self.yaw_bins)


def encode_action(cfg: ScenarioConfig, accel, yaw_rate):
    """Nearest grid cell -> action id."""
    ai = np.argmin(np.abs(cfg.accel_values()[None, :]
                          - np.asarray(accel)[..., None]), axis=-1)
    yi = np.argmin(np.abs(cfg.yaw_values()[None, :]
                          - np.asarray(yaw_rate)[..., None]), axis=-1)
    return ai * cfg.yaw_bins + yi


def decode_action(cfg: ScenarioConfig, action_id):
    ai, yi = np.divmod(np.asarray(action_id), cfg.yaw_bins)
    return cfg.accel_values()[ai], cfg.yaw_values()[yi]


@dataclasses.dataclass
class Scene:
    """One generated scene: the model-facing tensor dict plus host-side
    world metadata the evaluation harness needs (never fed to the model).

    ``tensors`` has the :class:`repro.nn.agent_sim.AgentSimModel` layout:
      map_feats (M, Fm), map_pose (M, 3), map_valid (M,) bool
      agent_feats (T, A, Fa), agent_pose (T, A, 3), agent_valid (T, A)
      actions (T, A) int32, behavior (A,) int32, agent_type (A,) int32
    Agent slots are packed valid-first; ``agent_valid`` is constant over
    time per slot (agents don't appear/disappear mid-scene) and False for
    padding slots beyond the family's drawn agent count.
    """
    family: str
    tensors: Dict[str, np.ndarray]
    lane_graph: Optional[LaneGraph] = None

    @property
    def num_valid_agents(self) -> int:
        return int(self.tensors["agent_valid"][0].sum())


def assemble_scene(family: str, cfg: ScenarioConfig, lane_graph: LaneGraph,
                   agent_pose: np.ndarray, agent_feats: np.ndarray,
                   actions: np.ndarray, agent_type: np.ndarray) -> Scene:
    """Pack simulated trajectories + a lane graph into a model-ready Scene.

    agent_pose (T, n, 3) / agent_feats (T, n, Fa) / actions (T, n) for the
    n *real* agents (n <= cfg.num_agents); slots [n, num_agents) are
    padding with ``agent_valid`` False. Map tokens come from the lane
    graph, padded/masked to ``cfg.num_map`` the same way.
    """
    t, n = agent_pose.shape[:2]
    a = cfg.num_agents
    assert n <= a, f"family {family!r} produced {n} agents > cap {a}"
    map_pose, map_feats, map_valid = lane_graph.map_tokens(
        cfg.num_map, cfg.map_feat_dim)
    pad = lambda arr, fill=0: np.concatenate(
        [arr, np.full((t, a - n) + arr.shape[2:], fill, arr.dtype)], axis=1)
    pose = pad(agent_pose.astype(np.float32))
    feats = pad(agent_feats.astype(np.float32))
    acts = pad(actions.astype(np.int32))
    valid = np.zeros((t, a), bool)
    valid[:, :n] = True
    types = np.concatenate(
        [np.asarray(agent_type, np.int32),
         np.zeros(a - n, np.int32)])
    tensors = {
        "map_feats": map_feats,
        "map_pose": map_pose,
        "map_valid": map_valid,
        "agent_feats": feats,
        "agent_pose": pose,
        "agent_valid": valid,
        "actions": acts,
        "behavior": classify_behavior(pose, valid),
        "agent_type": types,
    }
    return Scene(family=family, tensors=tensors, lane_graph=lane_graph)


def classify_behavior(agent_pose: np.ndarray, agent_valid: np.ndarray,
                      stationary_disp: float = 2.0,
                      turning_yaw: float = 0.3) -> np.ndarray:
    """Label each agent stationary / straight / turning from its
    ground-truth trajectory (paper Table I's per-category split).

    agent_pose (T, A, 3); agent_valid (T, A). Invalid agents get -1.
    """
    disp = np.linalg.norm(agent_pose[-1, :, :2] - agent_pose[0, :, :2],
                          axis=-1)
    dth = np.abs(wrap_angle(agent_pose[-1, :, 2] - agent_pose[0, :, 2],
                            xp=np))
    out = np.where(disp < stationary_disp, BEHAVIOR["stationary"],
                   np.where(dth > turning_yaw, BEHAVIOR["turning"],
                            BEHAVIOR["straight"]))
    return np.where(agent_valid[0], out, -1).astype(np.int32)


def transform_poses(z, pose):
    """Left-compose a global SE(2) transform with (..., 3) poses (numpy)."""
    z = np.asarray(z, np.float32)
    pose = np.asarray(pose, np.float32)
    c, s = np.cos(z[2]), np.sin(z[2])
    x = z[0] + c * pose[..., 0] - s * pose[..., 1]
    y = z[1] + s * pose[..., 0] + c * pose[..., 1]
    return np.stack([x, y, pose[..., 2] + z[2]], -1).astype(np.float32)


def transform_scene(scene: Scene, z) -> Scene:
    """The whole scene rigidly re-posed by z = (x, y, theta): map tokens,
    agent trajectories, and the lane graph. Features, actions, masks, and
    all relative geometry are untouched — an SE(2)-invariant model + the
    metric stack must not notice (property-tested in tests/test_scenarios)."""
    t = dict(scene.tensors)
    t["map_pose"] = transform_poses(z, t["map_pose"])
    t["agent_pose"] = transform_poses(z, t["agent_pose"])
    lg = scene.lane_graph.transformed(z) if scene.lane_graph else None
    return Scene(family=scene.family, tensors=t, lane_graph=lg)


def stack_scenes(scenes: List[Scene]) -> Dict[str, np.ndarray]:
    """Stack same-config scenes (any mix of families) into one batch dict."""
    keys = scenes[0].tensors.keys()
    return {k: np.stack([s.tensors[k] for s in scenes]) for k in keys}


def rollout_metrics(cfg: ScenarioConfig, gt_pose, sampled_poses, behavior,
                    agent_valid=None):
    """minADE over samples, split by ground-truth behavior category.

    gt_pose (T, A, 3); sampled_poses (K, T, A, 3); behavior (A,);
    agent_valid (T, A) or (A,) bool — invalid agents/steps are excluded
    from the displacement average instead of silently dragging the mean
    (padding slots used to be averaged in as if they were real agents).
    Returns dict of minADE per category (paper Table I columns).
    """
    gt_pose = np.asarray(gt_pose)
    sampled_poses = np.asarray(sampled_poses)
    t, a = gt_pose.shape[:2]
    if agent_valid is None:
        valid = np.ones((t, a), bool)
    else:
        valid = np.asarray(agent_valid, bool)
        if valid.ndim == 1:
            valid = np.broadcast_to(valid[None, :], (t, a))
    d = np.linalg.norm(sampled_poses[..., :2] - gt_pose[None, ..., :2],
                       axis=-1)                     # (K, T, A)
    w = valid.astype(np.float64)                    # (T, A)
    steps = w.sum(axis=0)                           # (A,)
    ade = (d * w[None]).sum(axis=1) / np.maximum(steps[None], 1.0)  # (K, A)
    min_ade = ade.min(axis=0)                       # (A,)
    alive = steps > 0
    out = {}
    for name, b in (("stationary", 0), ("straight", 1), ("turning", 2)):
        sel = (np.asarray(behavior) == b) & alive
        out[name] = float(min_ade[sel].mean()) if sel.any() else float("nan")
    return out
