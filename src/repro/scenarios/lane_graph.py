"""Lane-graph world model for procedural driving scenarios.

A :class:`LaneGraph` is a set of directed lane centerlines (dense 2-D
polylines with per-point headings) plus topology: ``successors`` (which
lanes a lane flows into), and optional ``left``/``right`` neighbors for
lane changes. Everything is numpy and deterministic — graphs are built by
the scenario families from an ``np.random.Generator`` seeded by
``(family, seed, index)``, so a scene is reproducible from its cursor
alone (the same contract as the rest of the data pipeline).

Geometry conventions:

* centerline points are spaced ``STEP`` meters apart, so index distance
  is arclength distance — route following and gap computation are O(1)
  index arithmetic;
* lane headings are the tangent direction of travel (lanes are directed);
* queries (`nearest`, `distance`, `on_road`) are vectorized over
  arbitrary batches of points and are the basis of the off-road metric in
  ``repro.runtime.evaluation``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.kinematics import wrap_angle

STEP = 2.0  # meters between consecutive centerline points

LANE_KIND = {"lane": 0, "crosswalk": 1}


@dataclasses.dataclass
class Lane:
    """One directed lane centerline: points (P, 2), headings (P,)."""
    points: np.ndarray
    headings: np.ndarray
    kind: str = "lane"
    speed_limit: float = 13.0

    def __post_init__(self):
        self.points = np.asarray(self.points, np.float32)
        self.headings = np.asarray(self.headings, np.float32)
        assert self.points.ndim == 2 and self.points.shape[1] == 2
        assert self.headings.shape == (self.points.shape[0],)

    @property
    def length(self) -> float:
        return STEP * (len(self.points) - 1)

    def arclengths(self) -> np.ndarray:
        return STEP * np.arange(len(self.points), dtype=np.float32)


def straight_lane(start, heading, length, *, kind="lane",
                  speed_limit=13.0) -> Lane:
    """Straight centerline from ``start`` along ``heading`` for ``length``m."""
    n = max(2, int(round(length / STEP)) + 1)
    s = STEP * np.arange(n, dtype=np.float32)
    direction = np.array([np.cos(heading), np.sin(heading)], np.float32)
    pts = np.asarray(start, np.float32)[None, :] + s[:, None] * direction
    return Lane(pts, np.full(n, heading, np.float32), kind=kind,
                speed_limit=speed_limit)


def arc_lane(start, heading, radius, angle, *, kind="lane",
             speed_limit=13.0) -> Lane:
    """Arc centerline: turn through ``angle`` rad (signed; + is left) with
    turning radius ``radius``. Arclength = radius * |angle|."""
    length = abs(angle) * radius
    n = max(2, int(round(length / STEP)) + 1)
    s = np.linspace(0.0, length, n, dtype=np.float32)
    sgn = np.sign(angle) if angle != 0.0 else 1.0
    curv = sgn / radius
    th = heading + curv * s
    # closed-form arc integral of the unicycle at constant curvature
    x = start[0] + (np.sin(th) - np.sin(heading)) / curv
    y = start[1] - (np.cos(th) - np.cos(heading)) / curv
    return Lane(np.stack([x, y], -1).astype(np.float32),
                th.astype(np.float32), kind=kind, speed_limit=speed_limit)


def polyline_lane(points, *, kind="lane", speed_limit=13.0) -> Lane:
    """Resample an arbitrary polyline to STEP spacing (for e.g. the
    freeform family's legacy segment chains)."""
    pts = np.asarray(points, np.float64)
    seg = np.diff(pts, axis=0)
    seg_len = np.linalg.norm(seg, axis=-1)
    cum = np.concatenate([[0.0], np.cumsum(seg_len)])
    total = float(cum[-1])
    n = max(2, int(round(total / STEP)) + 1)
    s = np.linspace(0.0, total, n)
    x = np.interp(s, cum, pts[:, 0])
    y = np.interp(s, cum, pts[:, 1])
    out = np.stack([x, y], -1)
    d = np.gradient(out, axis=0)
    headings = np.arctan2(d[:, 1], d[:, 0])
    return Lane(out.astype(np.float32), headings.astype(np.float32),
                kind=kind, speed_limit=speed_limit)


class LaneGraph:
    """Directed lane centerlines + successor/left/right topology."""

    def __init__(self):
        self.lanes: List[Lane] = []
        self.successors: List[List[int]] = []
        self.left: List[Optional[int]] = []
        self.right: List[Optional[int]] = []

    # -- construction --------------------------------------------------------
    def add(self, lane: Lane) -> int:
        self.lanes.append(lane)
        self.successors.append([])
        self.left.append(None)
        self.right.append(None)
        return len(self.lanes) - 1

    def connect(self, a: int, b: int):
        """Declare lane ``b`` a successor of lane ``a``."""
        if b not in self.successors[a]:
            self.successors[a].append(b)

    def set_neighbors(self, a: int, *, left: Optional[int] = None,
                      right: Optional[int] = None):
        if left is not None:
            self.left[a] = left
        if right is not None:
            self.right[a] = right

    # -- routes --------------------------------------------------------------
    def trace_route(self, start: int, min_length: float,
                    rng: np.random.Generator) -> List[int]:
        """Follow successors from ``start`` (uniform random at forks) until
        the route is at least ``min_length`` meters or a dead end."""
        route, total, cur = [start], self.lanes[start].length, start
        while total < min_length and self.successors[cur]:
            nxt = self.successors[cur][
                int(rng.integers(len(self.successors[cur])))]
            if nxt in route:       # refuse to loop forever (roundabouts)
                break
            route.append(nxt)
            total += self.lanes[nxt].length
            cur = nxt
        return route

    def route_points(self, route: Sequence[int]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Concatenate a route's centerlines into one dense polyline.

        Returns (xy (N, 2), headings (N,)); joint points (the shared
        endpoint of consecutive lanes) are deduplicated so arclength stays
        ``STEP * index``.
        """
        xs, hs = [], []
        for i, li in enumerate(route):
            lane = self.lanes[li]
            pts, hd = lane.points, lane.headings
            if i > 0:
                pts, hd = pts[1:], hd[1:]
            xs.append(pts)
            hs.append(hd)
        return np.concatenate(xs, 0), np.concatenate(hs, 0)

    # -- queries -------------------------------------------------------------
    def all_points(self, kinds: Optional[Sequence[str]] = None
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """(P, 2) stacked centerline points and (P,) owning lane index,
        optionally restricted to lane ``kinds`` (e.g. ``("lane",)`` to
        exclude crosswalks)."""
        sel = [(i, l) for i, l in enumerate(self.lanes)
               if kinds is None or l.kind in kinds]
        if not sel:
            sel = list(enumerate(self.lanes))     # degenerate graph: use all
        pts = np.concatenate([l.points for _, l in sel], 0)
        owner = np.concatenate([
            np.full(len(l.points), i, np.int32) for i, l in sel])
        return pts, owner

    def nearest(self, xy, kinds: Optional[Sequence[str]] = None
                ) -> Tuple[np.ndarray, np.ndarray]:
        """Nearest lane and distance for each query point.

        xy (..., 2) -> (lane_idx (...,) int32, dist (...,) float32).
        """
        pts, owner = self.all_points(kinds)
        q = np.asarray(xy, np.float32)
        flat = q.reshape(-1, 2)
        d = np.linalg.norm(flat[:, None, :] - pts[None, :, :], axis=-1)
        arg = d.argmin(axis=1)
        return (owner[arg].reshape(q.shape[:-1]),
                d[np.arange(len(flat)), arg].reshape(q.shape[:-1])
                .astype(np.float32))

    def distance(self, xy, kinds: Optional[Sequence[str]] = None
                 ) -> np.ndarray:
        """Distance (...,) from each point to the nearest centerline of
        the given ``kinds`` (default: any)."""
        return self.nearest(xy, kinds)[1]

    def on_road(self, xy, threshold: float = 3.5,
                kinds: Optional[Sequence[str]] = None) -> np.ndarray:
        """True where a point lies within ``threshold`` m of a centerline
        (half a lane width plus slack — the off-road metric's predicate)."""
        return self.distance(xy, kinds) <= threshold

    # -- model-facing map tokens --------------------------------------------
    def map_tokens(self, num_map: int, feat_dim: int
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Tokenize the graph into at most ``num_map`` map tokens.

        The token budget is split across lanes proportionally to their
        point counts with **at least one token per lane** (largest-
        remainder rounding) — no lane an agent drives on is ever silently
        absent from the map — and each lane is sampled uniformly along its
        arclength (deterministic — no rng). Features: [0] sample spacing
        / 10, [1] local curvature * 50, [2] lane flag, [3] lane fraction,
        [4] crosswalk flag, [5] speed_limit / 10. Returns
        (pose (num_map, 3), feats (num_map, feat_dim), valid (num_map,)
        bool) — padded with zeros / False beyond the actual token count,
        i.e. *variable map size via masks*.
        """
        n_lanes = len(self.lanes)
        per_lane = []
        sizes = np.array([len(l.points) for l in self.lanes], np.float64)
        if num_map >= n_lanes > 0:
            alloc = np.ones(n_lanes, int)
            frac = sizes / sizes.sum() * (num_map - n_lanes)
            alloc += np.floor(frac).astype(int)
            order = np.argsort(-(frac - np.floor(frac)))
            alloc[order[:num_map - int(alloc.sum())]] += 1
        else:                       # budget below lane count: first lanes
            alloc = (np.arange(n_lanes) < num_map).astype(int)
        for li, lane in enumerate(self.lanes):
            n_tok = min(int(alloc[li]), len(lane.points))
            if n_tok == 0:
                continue
            idx = np.unique(np.linspace(0, len(lane.points) - 1,
                                        n_tok).astype(int))
            spacing = lane.length / max(n_tok - 1, 1)
            for pi in idx:
                curv = 0.0
                if 0 < pi < len(lane.points) - 1:
                    dth = wrap_angle(lane.headings[pi + 1]
                                     - lane.headings[pi - 1])
                    curv = float(dth) / (2.0 * STEP)
                per_lane.append((lane.points[pi, 0], lane.points[pi, 1],
                                 lane.headings[pi], spacing, curv,
                                 lane.kind, li / n_lanes, lane.speed_limit))
        m = min(len(per_lane), num_map)
        pose = np.zeros((num_map, 3), np.float32)
        feats = np.zeros((num_map, feat_dim), np.float32)
        valid = np.zeros(num_map, bool)
        for i in range(m):
            x, y, th, slen, curv, kind, frac, vlim = per_lane[i]
            pose[i] = (x, y, th)
            feats[i, 0] = slen / 10.0
            feats[i, 1] = curv * 50.0
            feats[i, 2] = 1.0 if kind == "lane" else 0.0
            feats[i, 3] = frac
            if feat_dim > 4:
                feats[i, 4] = 1.0 if kind == "crosswalk" else 0.0
            if feat_dim > 5:
                feats[i, 5] = vlim / 10.0
            valid[i] = True
        return pose, feats, valid

    # -- rigid transforms ----------------------------------------------------
    def transformed(self, z) -> "LaneGraph":
        """The graph re-posed by a global SE(2) transform z = (x, y, th)."""
        z = np.asarray(z, np.float32)
        c, s = np.cos(z[2]), np.sin(z[2])
        rot = np.array([[c, -s], [s, c]], np.float32)
        out = LaneGraph()
        for lane in self.lanes:
            out.add(Lane(lane.points @ rot.T + z[:2],
                         wrap_angle(lane.headings + z[2],
                                    xp=np).astype(np.float32),
                         kind=lane.kind, speed_limit=lane.speed_limit))
        out.successors = [list(s_) for s_ in self.successors]
        out.left = list(self.left)
        out.right = list(self.right)
        return out
