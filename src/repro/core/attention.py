"""Relative scaled dot-product attention (paper Algorithms 1 and 2).

``relative_attention_quadratic`` materializes phi(p_{n->m}) for every pair —
O(N*M) memory — and serves as the correctness oracle.

``relative_attention_linear`` implements Algorithm 2: O(N + M) memory
pre/post-processing around a *standard* SDPA kernel (injectable, so the
Pallas flash-attention kernel drops in unchanged).

Conventions: q ``(..., N, d)``, k/v ``(..., M, d)``, poses ``(..., N, pose_dim)``
/ ``(..., M, pose_dim)``; mask ``(..., N, M)`` boolean (True = attend) or None.
Leading dims broadcast (batch, heads, ...).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.encodings import GroupEncoding

SdpaFn = Callable[..., jnp.ndarray]

_NEG_INF = -1e30


def sdpa_reference(q, k, v, mask=None, scale: Optional[float] = None):
    """Plain softmax attention; the jnp stand-in for a flash kernel."""
    if scale is None:
        scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(q.dtype)
    logits = jnp.einsum("...nd,...md->...nm", q, k).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("...nm,...md->...nd", probs, v.astype(jnp.float32)).astype(v.dtype)


def relative_attention_quadratic(enc: GroupEncoding, q, k, v, pose_q, pose_k,
                                 mask=None, scale: Optional[float] = None):
    """Algorithm 1: the O(N*M)-memory oracle.

    b_{nm} = q_n^T phi(p_{n->m}) k_m;  o_n = sum_m softmax(b)_{nm} phi(p_{n->m}) v_m
    """
    from repro.core import se2

    d = q.shape[-1]
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    if enc.pose_dim == 3:
        p_rel = se2.relative(pose_q[..., :, None, :], pose_k[..., None, :, :])
    else:
        p_rel = pose_k[..., None, :, :] - pose_q[..., :, None, :]
    # phi(p_rel) applied to k (and v), then contracted against q.
    phik = enc.apply_phi(p_rel, jnp.broadcast_to(
        k[..., None, :, :], p_rel.shape[:-1] + k.shape[-1:]))
    logits = jnp.einsum("...nd,...nmd->...nm", q, phik).astype(jnp.float32) * scale
    if mask is not None:
        logits = jnp.where(mask, logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if enc.transforms_values:
        phiv = enc.apply_phi(p_rel, jnp.broadcast_to(
            v[..., None, :, :], p_rel.shape[:-1] + v.shape[-1:]))
        out = jnp.einsum("...nm,...nmd->...nd", probs, phiv.astype(jnp.float32))
    else:
        out = jnp.einsum("...nm,...md->...nd", probs, v.astype(jnp.float32))
    return out.astype(v.dtype)


def relative_attention_linear(enc: GroupEncoding, q, k, v, pose_q, pose_k,
                              mask=None, scale: Optional[float] = None,
                              sdpa_fn: SdpaFn = sdpa_reference,
                              fold_scale: bool = False,
                              **sdpa_kwargs):
    """Algorithm 2: linear-memory relative attention around standard SDPA.

    Args:
      enc: the group encoding (phi_q / phi_k factorization).
      sdpa_fn: any standard SDPA with signature (q, k, v, mask=..., scale=...)
        — e.g. :func:`sdpa_reference` or the Pallas flash-attention wrapper.
      fold_scale: if True, reproduce the paper's Algorithm 2 verbatim by
        folding ``(c/d)^{1/4}`` into q-tilde and k-tilde and letting the SDPA
        kernel use its default ``1/sqrt(c)`` scaling. If False (default) the
        correct ``1/sqrt(d)`` scale is passed to the kernel explicitly —
        mathematically identical, one less multiply.
    """
    d = q.shape[-1]
    qt = enc.transform_q(q, pose_q)
    kt = enc.transform_k(k, pose_k)
    vt = enc.transform_v(v, pose_k)
    if fold_scale:
        c = qt.shape[-1]
        gamma = (float(c) / float(d)) ** 0.25
        qt = qt * jnp.asarray(gamma, qt.dtype)
        kt = kt * jnp.asarray(gamma, kt.dtype)
        eff_scale = None  # kernel default 1/sqrt(c) -> overall 1/sqrt(d)
    else:
        eff_scale = (1.0 / float(d) ** 0.5) if scale is None else scale
    ot = sdpa_fn(qt, kt, vt, mask=mask, scale=eff_scale, **sdpa_kwargs)
    if enc.transforms_values:
        ot = enc.untransform_out(ot, pose_q)
    return ot


def invariance_gap(enc: GroupEncoding, q, k, v, pose_q, pose_k, z,
                   mask=None, linear: bool = True):
    """Max-abs difference of attention outputs under a global transform z.

    For exact encodings (rope1d/rope2d/se2_repr) this is ~0; for se2_fourier
    it is bounded by the Fourier truncation error (paper Sec. IV-A).
    """
    from repro.core import se2

    fn = relative_attention_linear if linear else relative_attention_quadratic
    out = fn(enc, q, k, v, pose_q, pose_k, mask=mask)
    if enc.pose_dim == 3:
        zq = se2.compose(jnp.broadcast_to(z, pose_q.shape), pose_q)
        zk = se2.compose(jnp.broadcast_to(z, pose_k.shape), pose_k)
    else:
        zq, zk = pose_q + z, pose_k + z
    out_z = fn(enc, q, k, v, zq, zk, mask=mask)
    return jnp.max(jnp.abs(out - out_z))
