"""SE(2) group operations.

Poses are stored as arrays whose trailing dimension is 3: ``(x, y, theta)``.
All functions broadcast over leading dimensions and are jit/vmap friendly.

The group product follows the usual convention for rigid transforms acting on
the plane: a pose ``p = (x, y, theta)`` corresponds to the homogeneous matrix

    psi(p) = [[cos t, -sin t, x],
              [sin t,  cos t, y],
              [0,      0,     1]]

so that ``psi(p1 @ p2) = psi(p1) psi(p2)``.
"""
from __future__ import annotations

import jax.numpy as jnp


def wrap_angle(theta):
    """Wrap an angle (radians) into ``[-pi, pi)``."""
    return (theta + jnp.pi) % (2.0 * jnp.pi) - jnp.pi


def identity(shape=(), dtype=jnp.float32):
    """Identity pose(s) of the given leading shape."""
    return jnp.zeros(tuple(shape) + (3,), dtype=dtype)


def compose(p1, p2):
    """Group product ``p1 * p2`` (apply p2 in the frame of p1)."""
    x1, y1, t1 = p1[..., 0], p1[..., 1], p1[..., 2]
    x2, y2, t2 = p2[..., 0], p2[..., 1], p2[..., 2]
    c, s = jnp.cos(t1), jnp.sin(t1)
    x = x1 + c * x2 - s * y2
    y = y1 + s * x2 + c * y2
    t = wrap_angle(t1 + t2)
    return jnp.stack([x, y, t], axis=-1)


def inverse(p):
    """Group inverse: ``compose(inverse(p), p) == identity``."""
    x, y, t = p[..., 0], p[..., 1], p[..., 2]
    c, s = jnp.cos(t), jnp.sin(t)
    xi = -(c * x + s * y)
    yi = -(-s * x + c * y)
    return jnp.stack([xi, yi, wrap_angle(-t)], axis=-1)


def relative(p_n, p_m):
    """Relative pose ``p_{n->m} = p_n^{-1} p_m``.

    Broadcasts: pass ``p_n[..., :, None, :]`` and ``p_m[..., None, :, :]`` to
    get the full pairwise grid.
    """
    xn, yn, tn = p_n[..., 0], p_n[..., 1], p_n[..., 2]
    xm, ym, tm = p_m[..., 0], p_m[..., 1], p_m[..., 2]
    c, s = jnp.cos(tn), jnp.sin(tn)
    dx, dy = xm - xn, ym - yn
    x_rel = c * dx + s * dy
    y_rel = -s * dx + c * dy
    t_rel = wrap_angle(tm - tn)
    return jnp.stack([x_rel, y_rel, t_rel], axis=-1)


def matrix(p):
    """Homogeneous 3x3 matrix representation ``psi(p)``."""
    x, y, t = p[..., 0], p[..., 1], p[..., 2]
    c, s = jnp.cos(t), jnp.sin(t)
    zeros = jnp.zeros_like(x)
    ones = jnp.ones_like(x)
    row0 = jnp.stack([c, -s, x], axis=-1)
    row1 = jnp.stack([s, c, y], axis=-1)
    row2 = jnp.stack([zeros, zeros, ones], axis=-1)
    return jnp.stack([row0, row1, row2], axis=-2)


def from_matrix(m):
    """Inverse of :func:`matrix`."""
    x = m[..., 0, 2]
    y = m[..., 1, 2]
    t = jnp.arctan2(m[..., 1, 0], m[..., 0, 0])
    return jnp.stack([x, y, t], axis=-1)


def rot2(theta):
    """2D rotation matrix ``rho(theta)`` with trailing shape (2, 2)."""
    c, s = jnp.cos(theta), jnp.sin(theta)
    row0 = jnp.stack([c, -s], axis=-1)
    row1 = jnp.stack([s, c], axis=-1)
    return jnp.stack([row0, row1], axis=-2)


def transform_points(p, pts):
    """Apply pose ``p`` to 2D points ``pts`` (trailing dim 2)."""
    x, y, t = p[..., 0:1], p[..., 1:2], p[..., 2]
    c, s = jnp.cos(t)[..., None], jnp.sin(t)[..., None]
    px, py = pts[..., 0:1], pts[..., 1:2]
    nx = c * px - s * py + x
    ny = s * px + c * py + y
    return jnp.concatenate([nx, ny], axis=-1)
