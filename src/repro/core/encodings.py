"""Group-relative position encodings (the paper's core abstraction).

The paper (Pronovost et al., "Linear Memory SE(2) Invariant Attention")
frames relative attention for a group ``G`` via a triple of functions

    phi   : G -> R^{d x d}
    phi_q : G -> R^{d x c}
    phi_k : G -> R^{c x d}      with  phi(p_n^{-1} p_m) = phi_q(p_n) phi_k(p_m)

Algorithm 1 (quadratic memory) applies ``phi`` to every query/key pair;
Algorithm 2 (linear memory) pre-transforms queries with ``phi_q^T``, keys and
values with ``phi_k``, runs a *standard* SDPA kernel (e.g. Flash Attention),
and post-transforms the output with ``phi_q``.

Every encoding below implements both views:

  * ``transform_q / transform_k / transform_v / untransform_out`` — the
    linear-memory (Algorithm 2) factorized form, O(N) memory.
  * ``apply_phi(p_rel, vec)`` — the exact target ``phi(p_rel) @ vec`` used by
    the quadratic oracle (Algorithm 1) and by approximation-error tests.

Encodings:

  * :class:`AbsoluteEncoding`  — no-op transforms; models add a learned pose
    embedding to token features instead (baseline in the paper's Table I).
  * :class:`Rope1D`            — G = R, classic rotary embeddings [Su et al.].
  * :class:`Rope2D`            — G = R^2, axis-aligned rotary blocks
    (translation invariant, not rotation invariant).
  * :class:`SE2Repr`           — G = SE(2) via the 3x3 homogeneous matrix
    representation (exact, GTA-like; unstable for large positions).
  * :class:`SE2Fourier`        — G = SE(2), the paper's contribution: block
    diagonal 2D rotations by (x_rel, y_rel, theta_rel), factorized through a
    truncated Fourier series in the query heading. Approximate but
    numerically well-behaved; invariance error is bounded by the series
    truncation error.

All transforms operate on the trailing feature dimension and broadcast over
any leading (batch / head / sequence) dimensions. Poses have trailing
dimension ``pose_dim`` (1 for R, 2 for R^2, 3 for SE(2)).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import fourier, se2


def _as_f32(x):
    return x.astype(jnp.float32)


def _rotate_pairs(x0, x1, cos, sin):
    """Apply rho(angle) with components given by (cos, sin) to pairs."""
    return x0 * cos - x1 * sin, x0 * sin + x1 * cos


class GroupEncoding:
    """Interface shared by all encodings."""

    name: str = "base"
    pose_dim: int = 0
    head_dim: int = 0

    @property
    def expanded_dim(self) -> int:
        """c — feature dim after phi_q^T / phi_k (equals head_dim for RoPE)."""
        return self.head_dim

    @property
    def expanded_v_dim(self) -> int:
        """Feature dim of a cached value row: ``expanded_dim`` when phi acts
        on values (phi_k-transformed values are what gets cached), else the
        raw head_dim. KV caches sized off this never need re-projection —
        ``transform_k``/``transform_v`` depend only on the token's own pose,
        so a cached row stays valid as the scene grows (the factorization
        property that makes incremental SE(2)-invariant decode sound; see
        docs/rollout.md)."""
        return self.expanded_dim if self.transforms_values else self.head_dim

    # --- Algorithm 2 (linear memory) ------------------------------------
    def transform_q(self, q, pose):
        return q

    def transform_k(self, k, pose):
        return k

    def transform_v(self, v, pose):
        return v

    def untransform_out(self, o, pose):
        return o

    # --- Algorithm 1 oracle ----------------------------------------------
    def apply_phi(self, p_rel, vec):
        """Exact ``phi(p_rel) @ vec``; p_rel ``(..., pose_dim)``, vec ``(..., d)``."""
        return vec

    @property
    def transforms_values(self) -> bool:
        """Whether phi acts on values too (needs output untransform)."""
        return False


@dataclasses.dataclass(frozen=True)
class AbsoluteEncoding(GroupEncoding):
    """No relative encoding; pose information is injected additively upstream."""

    head_dim: int = 0
    pose_dim: int = 3
    name: str = "absolute"


def rope_frequencies(num_freqs: int, base: float = 10000.0,
                     max_freq: float = 1.0) -> np.ndarray:
    """Geometric frequency ladder a la RoPE: max_freq * base^{-i/(n-1)}...

    We follow the RoFormer convention: frequencies base^{-2i/d} for
    i in [0, d/2); ``max_freq`` rescales the whole ladder (useful when the
    coordinate is metric rather than an integer token index).
    """
    if num_freqs == 1:
        return np.array([max_freq])
    i = np.arange(num_freqs)
    return max_freq * (base ** (-2.0 * i / (2.0 * num_freqs)))


@dataclasses.dataclass(frozen=True)
class Rope1D(GroupEncoding):
    """Rotary embeddings for G = R (token index or any scalar coordinate).

    Uses the "split half" layout (LLaMA convention): feature ``i`` pairs with
    feature ``i + head_dim // 2``.
    """

    head_dim: int = 64
    base: float = 10000.0
    max_freq: float = 1.0
    pose_dim: int = 1
    name: str = "rope1d"

    def __post_init__(self):
        if self.head_dim % 2 != 0:
            raise ValueError(f"rope1d head_dim must be even, got {self.head_dim}")

    def _freqs(self, dtype):
        return jnp.asarray(
            rope_frequencies(self.head_dim // 2, self.base, self.max_freq),
            dtype=dtype)

    def _cos_sin(self, pose):
        pos = pose[..., 0]
        ang = pos[..., None].astype(jnp.float32) * self._freqs(jnp.float32)
        return jnp.cos(ang), jnp.sin(ang)

    def _rotate(self, x, pose, sign):
        cos, sin = self._cos_sin(pose)
        cos, sin = cos.astype(x.dtype), (sign * sin).astype(x.dtype)
        h = self.head_dim // 2
        x0, x1 = x[..., :h], x[..., h:]
        r0, r1 = _rotate_pairs(x0, x1, cos, sin)
        return jnp.concatenate([r0, r1], axis=-1)

    def transform_q(self, q, pose):
        # phi_q(p)^T q = rho(-alpha p)^T q = rho(alpha p) q ... but matching
        # RoPE convention we rotate q by +p and k by +p so the score picks up
        # rho(p_m - p_n): q^T rho(-p_n)^T rho(p_m) k? Standard RoPE rotates
        # both by their own position; the score is then q^T rho(p_m - p_n) k.
        return self._rotate(q, pose, sign=+1.0)

    def transform_k(self, k, pose):
        return self._rotate(k, pose, sign=+1.0)

    def apply_phi(self, p_rel, vec):
        return self._rotate(vec, p_rel, sign=+1.0)


@dataclasses.dataclass(frozen=True)
class Rope2D(GroupEncoding):
    """Axis-aligned rotary embeddings for G = R^2 (paper Sec. II-D).

    First half of the feature dim encodes x, second half encodes y, each with
    its own geometric frequency ladder.
    """

    head_dim: int = 64
    base: float = 100.0
    max_freq: float = 1.0
    pose_dim: int = 2
    name: str = "rope2d"

    def __post_init__(self):
        if self.head_dim % 4 != 0:
            raise ValueError(f"rope2d head_dim must be divisible by 4, got {self.head_dim}")

    def _sub(self):
        return Rope1D(head_dim=self.head_dim // 2, base=self.base,
                      max_freq=self.max_freq)

    def _rotate(self, x, pose):
        sub = self._sub()
        h = self.head_dim // 2
        rx = sub.transform_q(x[..., :h], pose[..., 0:1])
        ry = sub.transform_q(x[..., h:], pose[..., 1:2])
        return jnp.concatenate([rx, ry], axis=-1)

    def transform_q(self, q, pose):
        return self._rotate(q, pose)

    def transform_k(self, k, pose):
        return self._rotate(k, pose)

    def apply_phi(self, p_rel, vec):
        return self._rotate(vec, p_rel)


def _log_spaced(n: int, lo: float, hi: float) -> np.ndarray:
    if n == 1:
        return np.array([hi])
    return np.exp(np.linspace(np.log(lo), np.log(hi), n))


@dataclasses.dataclass(frozen=True)
class SE2Repr(GroupEncoding):
    """SE(2) via the homogeneous 3x3 representation (paper Sec. II-E).

    phi(p) = psi(p), phi_q(p_n) = psi(p_n^{-1}), phi_k(p_m) = psi(p_m).
    Exact (no approximation) and c == d, but the score contains raw x/y
    coordinates, which the paper observes destabilizes training when
    positions are large. ``scales`` downscale positions per 3-wide block.
    """

    head_dim: int = 48
    min_scale: float = 0.25
    max_scale: float = 1.0
    pose_dim: int = 3
    name: str = "se2_repr"

    def __post_init__(self):
        if self.head_dim % 3 != 0:
            raise ValueError(f"se2_repr head_dim must be divisible by 3, got {self.head_dim}")

    @property
    def num_blocks(self) -> int:
        return self.head_dim // 3

    def _scales(self, dtype):
        return jnp.asarray(
            _log_spaced(self.num_blocks, self.min_scale, self.max_scale),
            dtype=dtype)

    def _apply_psi(self, x, pose, inverse: bool, transpose: bool):
        """Apply psi(pose) (optionally of the inverse pose, optionally
        transposed) blockwise to trailing dim."""
        *lead, d = x.shape
        nb = self.num_blocks
        xb = _as_f32(x).reshape(*lead, nb, 3)
        scales = self._scales(jnp.float32)
        p = pose.astype(jnp.float32)
        p = jnp.concatenate(
            [p[..., None, 0:2] * scales[:, None], p[..., None, 2:3]
             * jnp.ones_like(scales)[:, None]], axis=-1)  # (..., nb, 3)
        if inverse:
            p = se2.inverse(p)
        m = se2.matrix(p)  # (..., nb, 3, 3)
        if transpose:
            m = jnp.swapaxes(m, -1, -2)
        out = jnp.einsum("...ij,...j->...i", m, xb)
        return out.reshape(*lead, d).astype(x.dtype)

    def transform_q(self, q, pose):
        # q_tilde = phi_q(p)^T q = psi(p^{-1})^T q
        return self._apply_psi(q, pose, inverse=True, transpose=True)

    def transform_k(self, k, pose):
        return self._apply_psi(k, pose, inverse=False, transpose=False)

    def transform_v(self, v, pose):
        return self._apply_psi(v, pose, inverse=False, transpose=False)

    def untransform_out(self, o, pose):
        return self._apply_psi(o, pose, inverse=True, transpose=False)

    def apply_phi(self, p_rel, vec):
        return self._apply_psi(vec, p_rel, inverse=False, transpose=False)

    @property
    def transforms_values(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class SE2Fourier(GroupEncoding):
    """The paper's SE(2) Fourier encoding (Sec. III).

    Feature layout: ``head_dim`` must be divisible by 6; each 6-wide input
    block ``(x0, x1, y0, y1, t0, t1)`` is acted on by
    ``diag[rho(a_b * x_rel), rho(a_b * y_rel), rho(theta_rel)]`` where ``a_b``
    is the block's spatial scale. The factorized (linear memory) form expands
    each block to ``4F + 2`` features, so ``c = (head_dim / 6) * (4F + 2)``.

    ``num_terms`` (F) controls the Fourier truncation. Per the paper's Fig. 3,
    F = 12/18/28 reaches ~bf16-level approximation error for position
    magnitudes <= 2/4/8 respectively (positions should be downscaled so that
    ``max |a_b * (x, y)|`` stays within that budget).

    **Beyond-paper: scale-adaptive truncation** (``adaptive_terms=True``).
    The target function ``cos(a_b * u(theta))`` has Jacobi-Anger bandwidth
    ~ ``a_b * r_max``; the paper spends the same F on every block, but
    low-scale blocks are massively over-resolved. With adaptive truncation
    block ``b`` gets ``F_b ~= F * a_b / a_max`` (floored at 4), shrinking the
    expanded dim — and with it every q~/k~/v~ byte and attention MXU FLOP —
    by ~35-40% at matched worst-block error (measured in
    ``benchmarks/adaptive_basis.py``).
    """

    head_dim: int = 48
    num_terms: int = 18
    min_scale: float = 0.25
    max_scale: float = 1.0
    adaptive_terms: bool = False
    min_terms: int = 4
    term_margin: int = 3   # Jacobi-Anger tail: F_b = ceil(F*a_b/a_max)+margin
    pose_dim: int = 3
    name: str = "se2_fourier"

    def __post_init__(self):
        if self.head_dim % 6 != 0:
            raise ValueError(f"se2_fourier head_dim must be divisible by 6, got {self.head_dim}")
        if self.num_terms < 1:
            raise ValueError("num_terms must be >= 1")

    @property
    def num_blocks(self) -> int:
        return self.head_dim // 6

    def block_terms(self) -> Tuple[int, ...]:
        """Fourier basis size per block (all equal unless adaptive)."""
        if not self.adaptive_terms:
            return (self.num_terms,) * self.num_blocks
        scales = _log_spaced(self.num_blocks, self.min_scale, self.max_scale)
        return tuple(
            min(self.num_terms,
                max(self.min_terms,
                    int(np.ceil(self.num_terms * s / self.max_scale))
                    + self.term_margin))
            for s in scales)

    @property
    def expanded_dim(self) -> int:
        return sum(4 * f + 2 for f in self.block_terms())

    def _scales(self, dtype):
        return jnp.asarray(
            _log_spaced(self.num_blocks, self.min_scale, self.max_scale),
            dtype=dtype)

    def _split_blocks(self, x):
        *lead, d = x.shape
        return _as_f32(x).reshape(*lead, self.num_blocks, 6)

    def _scaled_xy(self, pose):
        """Per-block scaled (x, y); returns (..., nb) arrays plus theta (...,)."""
        scales = self._scales(jnp.float32)
        x = pose[..., 0:1].astype(jnp.float32) * scales
        y = pose[..., 1:2].astype(jnp.float32) * scales
        theta = pose[..., 2].astype(jnp.float32)
        return x, y, theta

    # -- query side -------------------------------------------------------
    def _query_pieces(self, pose):
        """v_n^{(x)}, v_n^{(y)} per block and the basis vector b_n."""
        x, y, theta = self._scaled_xy(pose)
        c, s = jnp.cos(theta)[..., None], jnp.sin(theta)[..., None]
        v_x = -x * c - y * s          # (..., nb)
        v_y = x * s - y * c           # (..., nb)
        b = fourier.eval_basis(theta, self.num_terms)  # (..., F)
        return v_x, v_y, b, theta

    def transform_q(self, q, pose):
        qb = self._split_blocks(q)                      # (..., nb, 6)
        v_x, v_y, b_full, theta = self._query_pieces(pose)
        ct, st = jnp.cos(theta)[..., None], jnp.sin(theta)[..., None]
        terms = self.block_terms()
        segs = []
        for bi, F in enumerate(terms):
            b = b_full[..., None, :F]                   # (..., 1, F)
            parts = []
            for (q0, q1, v) in ((qb[..., bi:bi + 1, 0], qb[..., bi:bi + 1, 1],
                                 v_x[..., bi:bi + 1]),
                                (qb[..., bi:bi + 1, 2], qb[..., bi:bi + 1, 3],
                                 v_y[..., bi:bi + 1])):
                cv, sv = jnp.cos(v), jnp.sin(v)
                r0, r1 = _rotate_pairs(q0, q1, cv, -sv)  # rho(-v) [q0; q1]
                parts.append(jnp.concatenate(
                    [r0[..., None] * b, r1[..., None] * b], axis=-1))
            t0, t1 = _rotate_pairs(qb[..., bi:bi + 1, 4], qb[..., bi:bi + 1, 5],
                                   ct, st)
            parts.append(jnp.stack([t0, t1], axis=-1))
            segs.append(jnp.concatenate(parts, axis=-1)[..., 0, :])
        res = jnp.concatenate(segs, axis=-1)            # (..., sum(4F_b + 2))
        return res.astype(q.dtype)

    # -- key side -----------------------------------------------------------
    def _key_coeffs(self, pose):
        """Quadrature Fourier coefficients, each (..., nb, F)."""
        x, y, _ = self._scaled_xy(pose)
        return fourier.xy_coefficients(x, y, self.num_terms)

    def _expand_k(self, k, pose):
        qb = self._split_blocks(k)                      # (..., nb, 6)
        gx, lx, gy, ly = self._key_coeffs(pose)
        theta = pose[..., 2].astype(jnp.float32)
        ct, st = jnp.cos(theta)[..., None], jnp.sin(theta)[..., None]
        terms = self.block_terms()
        segs = []
        for bi, F in enumerate(terms):
            parts = []
            for (k0, k1, gamma, lam) in (
                    (qb[..., bi:bi + 1, 0], qb[..., bi:bi + 1, 1],
                     gx[..., bi:bi + 1, :F], lx[..., bi:bi + 1, :F]),
                    (qb[..., bi:bi + 1, 2], qb[..., bi:bi + 1, 3],
                     gy[..., bi:bi + 1, :F], ly[..., bi:bi + 1, :F])):
                top = gamma * k0[..., None] - lam * k1[..., None]
                bot = lam * k0[..., None] + gamma * k1[..., None]
                parts.append(jnp.concatenate([top, bot], axis=-1))
            t0, t1 = _rotate_pairs(qb[..., bi:bi + 1, 4], qb[..., bi:bi + 1, 5],
                                   ct, st)
            parts.append(jnp.stack([t0, t1], axis=-1))
            segs.append(jnp.concatenate(parts, axis=-1)[..., 0, :])
        res = jnp.concatenate(segs, axis=-1)
        return res.astype(k.dtype)

    def transform_k(self, k, pose):
        return self._expand_k(k, pose)

    def transform_v(self, v, pose):
        return self._expand_k(v, pose)

    def untransform_out(self, o, pose):
        """o = phi_q(p_n) o_tilde, contracting (..., c) back to (..., d)."""
        *lead, c = o.shape
        of = _as_f32(o)
        v_x, v_y, b_full, theta = self._query_pieces(pose)
        ct, st = jnp.cos(theta), jnp.sin(theta)
        terms = self.block_terms()
        outs = []
        off = 0
        for bi, F in enumerate(terms):
            b = b_full[..., :F]
            seg = of[..., off:off + 4 * F + 2]
            off += 4 * F + 2
            for idx, v in ((0, v_x[..., bi]), (1, v_y[..., bi])):
                sub = seg[..., idx * 2 * F:(idx + 1) * 2 * F]
                top = jnp.sum(b * sub[..., :F], axis=-1)
                bot = jnp.sum(b * sub[..., F:], axis=-1)
                cv, sv = jnp.cos(v), jnp.sin(v)
                o0, o1 = _rotate_pairs(top, bot, cv, sv)  # rho(v) [top; bot]
                outs.extend([o0, o1])
            t0, t1 = _rotate_pairs(seg[..., 4 * F], seg[..., 4 * F + 1],
                                   ct, -st)
            outs.extend([t0, t1])
        res = jnp.stack(outs, axis=-1)   # (..., nb*6) grouped per block
        return res.astype(o.dtype)

    # -- oracle ---------------------------------------------------------------
    def apply_phi(self, p_rel, vec):
        """Exact target: diag[rho(a_b x_rel), rho(a_b y_rel), rho(theta_rel)] v."""
        vb = self._split_blocks(vec)                    # (..., nb, 6)
        scales = self._scales(jnp.float32)
        xr = p_rel[..., 0:1].astype(jnp.float32) * scales
        yr = p_rel[..., 1:2].astype(jnp.float32) * scales
        tr = p_rel[..., 2].astype(jnp.float32)[..., None] * jnp.ones_like(scales)
        outs = []
        for ang, i0 in ((xr, 0), (yr, 2), (tr, 4)):
            c, s = jnp.cos(ang), jnp.sin(ang)
            r0, r1 = _rotate_pairs(vb[..., i0], vb[..., i0 + 1], c, s)
            outs.extend([r0, r1])
        res = jnp.stack([outs[0], outs[1], outs[2], outs[3], outs[4], outs[5]],
                        axis=-1)
        *lead, nb, six = res.shape
        return res.reshape(*lead, nb * six).astype(vec.dtype)

    @property
    def transforms_values(self) -> bool:
        return True


ENCODINGS: Dict[str, type] = {
    "absolute": AbsoluteEncoding,
    "rope1d": Rope1D,
    "rope2d": Rope2D,
    "se2_repr": SE2Repr,
    "se2_fourier": SE2Fourier,
}


def make_encoding(name: str, head_dim: int, **kwargs) -> GroupEncoding:
    if name not in ENCODINGS:
        raise ValueError(f"unknown encoding {name!r}; options: {sorted(ENCODINGS)}")
    if name == "absolute":
        return AbsoluteEncoding(head_dim=head_dim)
    return ENCODINGS[name](head_dim=head_dim, **kwargs)
