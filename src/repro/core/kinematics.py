"""Unicycle kinematics shared by every layer that integrates agent motion.

One implementation, array-API-agnostic: the host-side scenario generators
call it on numpy arrays, the jitted :class:`repro.runtime.RolloutEngine`
tick calls it on jax arrays (tracers included), and both integrate
*identically* — same midpoint scheme, same speed clamp, same constants.
This replaces the numpy/jnp twin functions that previously lived in
``repro.data.scenarios`` and ``repro.runtime.rollout`` and were held in
sync only by a NOTE comment (the parity test in ``tests/test_decode.py``
now pins a tautology, which is the point).
"""
from __future__ import annotations

import numpy as np

DT = 0.5          # seconds per simulation step
MAX_SPEED = 25.0  # m/s clamp in the unicycle integrator


def _namespace(x):
    """numpy for host arrays/scalars, jax.numpy for jax arrays & tracers."""
    if type(x).__module__.split(".")[0] in ("jax", "jaxlib"):
        import jax.numpy as jnp
        return jnp
    return np


def wrap_angle(theta, xp=None):
    """Wrap angles to (-pi, pi], numpy or jax alike (the one shared
    implementation — `repro.core.se2.wrap_angle` stays jax-only for jit)."""
    if xp is None:
        xp = _namespace(theta)
    return xp.arctan2(xp.sin(theta), xp.cos(theta))


def step_kinematics(pose, speed, accel, yaw_rate, dt: float = DT, xp=None):
    """Midpoint-speed unicycle step.

    pose (..., 3) = (x, y, theta); speed/accel/yaw_rate broadcastable to
    pose[..., 0]. Returns (new_pose, new_speed). ``xp`` overrides the
    array namespace (numpy / jax.numpy); by default it is inferred from
    ``pose`` so the same function serves the host data pipeline and the
    jitted engine tick.
    """
    if xp is None:
        xp = _namespace(pose)
    speed_new = xp.clip(speed + accel * dt, 0.0, MAX_SPEED)
    theta_new = pose[..., 2] + yaw_rate * dt
    mid_speed = 0.5 * (speed + speed_new)
    x = pose[..., 0] + mid_speed * xp.cos(theta_new) * dt
    y = pose[..., 1] + mid_speed * xp.sin(theta_new) * dt
    return xp.stack([x, y, theta_new], axis=-1), speed_new
