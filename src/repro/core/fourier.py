"""Fourier-series machinery for the SE(2) Fourier attention encoding.

The paper approximates ``cos(u_m(theta))`` and ``sin(u_m(theta))`` — where
``u_m(theta) = x_m cos(theta) + y_m sin(theta)`` for the x-block and
``u_m(theta) = -x_m sin(theta) + y_m cos(theta)`` for the y-block — with a
truncated Fourier series in ``theta`` using the basis

    g_0(z) = 1
    g_i(z) = sin(((i + 1) / 2) z)   for odd i
    g_i(z) = cos((i / 2) z)         for even i

The coefficients (paper Eq. 14/15) are computed by numerical quadrature with
``2F`` uniformly spaced points on ``[-pi, pi)``; because the integrand is
2*pi-periodic the rectangle rule is spectrally accurate (it is exactly the
real DFT of the sampled function).

Everything here is pure jnp and differentiable w.r.t. the positions.
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np


def basis_frequencies(num_terms: int) -> np.ndarray:
    """Integer frequency of each basis element g_i (0, 1, 1, 2, 2, ...)."""
    i = np.arange(num_terms)
    return np.where(i % 2 == 0, i // 2, (i + 1) // 2)


def eval_basis(z, num_terms: int):
    """Evaluate ``[g_0(z), ..., g_{F-1}(z)]``; output shape ``z.shape + (F,)``.

    Vectorized: build frequency vector, take cos on even slots / sin on odd.
    """
    freqs = jnp.asarray(basis_frequencies(num_terms), dtype=z.dtype)
    is_odd = jnp.asarray(np.arange(num_terms) % 2 == 1)
    zf = z[..., None] * freqs
    return jnp.where(is_odd, jnp.sin(zf), jnp.cos(zf))


@functools.lru_cache(maxsize=None)
def _quadrature_constants(num_terms: int):
    """Static quadrature nodes and the (2F, F) projection matrix.

    ``proj[j, i] = a_i * g_i(z_j) / (2F)`` so that for samples
    ``f_j = f(z_j)`` the Fourier coefficients are ``coeffs = f @ proj``.
    Computed in float64 numpy for accuracy; cached per basis size.
    """
    f = int(num_terms)
    nodes = -np.pi + 2.0 * np.pi * np.arange(2 * f) / (2 * f)
    freqs = basis_frequencies(f)
    i = np.arange(f)
    g = np.where(
        i[None, :] % 2 == 1,
        np.sin(nodes[:, None] * freqs[None, :]),
        np.cos(nodes[:, None] * freqs[None, :]),
    )
    a = np.where(i == 0, 1.0, 2.0)
    proj = g * a[None, :] / (2 * f)
    return nodes, proj


def quadrature_nodes(num_terms: int, dtype=jnp.float32):
    nodes, _ = _quadrature_constants(num_terms)
    return jnp.asarray(nodes, dtype=dtype)


def quadrature_projection(num_terms: int, dtype=jnp.float32):
    _, proj = _quadrature_constants(num_terms)
    return jnp.asarray(proj, dtype=dtype)


def fourier_coefficients(fn_samples, num_terms: int):
    """Coefficients of the basis fit given samples at the 2F quadrature nodes.

    Args:
      fn_samples: ``(..., 2F)`` samples of the target function at
        :func:`quadrature_nodes`.
      num_terms: basis size F.

    Returns:
      ``(..., F)`` coefficients c such that ``f(z) ~= sum_i c_i g_i(z)``.
    """
    proj = quadrature_projection(num_terms, dtype=fn_samples.dtype)
    return fn_samples @ proj


def xy_coefficients(x, y, num_terms: int):
    """The four coefficient vectors used by the SE(2) Fourier encoding.

    For key position ``(x, y)`` (arbitrary leading batch shape) returns
    ``(gamma_x, lambda_x, gamma_y, lambda_y)``, each ``(..., F)``:

      gamma_x: coefficients of cos(u^x(z)),  u^x(z) =  x cos z + y sin z
      lambda_x: coefficients of sin(u^x(z))
      gamma_y: coefficients of cos(u^y(z)),  u^y(z) = -x sin z + y cos z
      lambda_y: coefficients of sin(u^y(z))
    """
    nodes = quadrature_nodes(num_terms, dtype=x.dtype)
    cz, sz = jnp.cos(nodes), jnp.sin(nodes)
    u_x = x[..., None] * cz + y[..., None] * sz
    u_y = -x[..., None] * sz + y[..., None] * cz
    proj = quadrature_projection(num_terms, dtype=x.dtype)
    gamma_x = jnp.cos(u_x) @ proj
    lambda_x = jnp.sin(u_x) @ proj
    gamma_y = jnp.cos(u_y) @ proj
    lambda_y = jnp.sin(u_y) @ proj
    return gamma_x, lambda_x, gamma_y, lambda_y


def approx_cos_sin(x, y, theta, num_terms: int, which: str = "x"):
    """Truncated-series approximation of ``(cos(u(theta)), sin(u(theta)))``.

    Used by tests and the approximation-error benchmark (paper Fig. 3/4).
    """
    gx, lx, gy, ly = xy_coefficients(x, y, num_terms)
    b = eval_basis(theta, num_terms)
    if which == "x":
        gamma, lam = gx, lx
    elif which == "y":
        gamma, lam = gy, ly
    else:
        raise ValueError(f"which must be 'x' or 'y', got {which!r}")
    cos_u = jnp.sum(b * gamma, axis=-1)
    sin_u = jnp.sum(b * lam, axis=-1)
    return cos_u, sin_u
