"""Core library: SE(2) group math, Fourier machinery, relative attention.

This package holds the paper's primary contribution — linear-memory
SE(2)-invariant scaled dot-product attention — as composable, framework-
agnostic JAX functions. Higher layers (models, kernels, launchers) build on
these primitives.
"""
from repro.core import attention, encodings, fourier, se2
from repro.core.attention import (
    relative_attention_linear,
    relative_attention_quadratic,
    sdpa_reference,
)
from repro.core.encodings import (
    ENCODINGS,
    AbsoluteEncoding,
    GroupEncoding,
    Rope1D,
    Rope2D,
    SE2Fourier,
    SE2Repr,
    make_encoding,
)

__all__ = [
    "attention", "encodings", "fourier", "se2",
    "relative_attention_linear", "relative_attention_quadratic",
    "sdpa_reference", "ENCODINGS", "AbsoluteEncoding", "GroupEncoding",
    "Rope1D", "Rope2D", "SE2Fourier", "SE2Repr", "make_encoding",
]
