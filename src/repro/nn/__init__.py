"""Neural-network substrate: param system, layers, blocks, full models."""
from repro.nn import (agent_sim, attention, blocks, layers, mlp, module, moe,
                      ssm, transformer)
from repro.nn.module import (abstract_params, cast_params, count_params,
                             init_params, param_axes, ParamSpec, stack_specs)
from repro.nn.transformer import build_model, EncDecLM, TransformerLM

__all__ = [
    "agent_sim", "attention", "blocks", "layers", "mlp", "module", "moe",
    "ssm", "transformer", "abstract_params", "cast_params", "count_params",
    "init_params", "param_axes", "ParamSpec", "stack_specs", "build_model",
    "EncDecLM", "TransformerLM",
]
