"""Basic layers: projections, norms, embeddings.

Each layer is a frozen dataclass with ``specs()`` (ParamSpec tree) and a pure
``apply``-style ``__call__``. Logical axis names on every parameter drive the
sharding layer; nothing here touches a mesh directly.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.module import ParamSpec


@dataclasses.dataclass(frozen=True)
class Dense:
    """y = x @ W (+ b); W has shape in_shape + out_shape (DenseGeneral)."""

    in_shape: Tuple[int, ...]
    out_shape: Tuple[int, ...]
    in_axes: Tuple[Optional[str], ...]
    out_axes: Tuple[Optional[str], ...]
    use_bias: bool = False
    init: str = "fan_in"

    def specs(self):
        s = {"kernel": ParamSpec(self.in_shape + self.out_shape,
                                 init=self.init,
                                 axes=self.in_axes + self.out_axes)}
        if self.use_bias:
            s["bias"] = ParamSpec(self.out_shape, init="zeros",
                                  axes=self.out_axes)
        return s

    def __call__(self, params, x):
        nin = len(self.in_shape)
        w = params["kernel"].astype(x.dtype)
        y = jax.lax.dot_general(
            x, w,
            ((tuple(range(x.ndim - nin, x.ndim)), tuple(range(nin))), ((), ())))
        if self.use_bias:
            y = y + params["bias"].astype(x.dtype)
        return y


@dataclasses.dataclass(frozen=True)
class RMSNorm:
    dim: int
    eps: float = 1e-6
    weight_offset: float = 0.0    # gemma stores (w - 1)

    def specs(self):
        init = "zeros" if self.weight_offset else "ones"
        return {"scale": ParamSpec((self.dim,), init=init, axes=("embed_no_fsdp",))}

    def __call__(self, params, x):
        x32 = x.astype(jnp.float32)
        var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + self.eps)
        w = params["scale"].astype(jnp.float32) + self.weight_offset
        return (y * w).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class LayerNorm:
    dim: int
    eps: float = 1e-5

    def specs(self):
        return {"scale": ParamSpec((self.dim,), init="ones", axes=("embed_no_fsdp",)),
                "bias": ParamSpec((self.dim,), init="zeros", axes=("embed_no_fsdp",))}

    def __call__(self, params, x):
        x32 = x.astype(jnp.float32)
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.var(x32, axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + self.eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class Embedding:
    vocab_size: int
    dim: int
    scale_by_sqrt_dim: bool = False   # gemma multiplies embeddings by sqrt(d)
    one_hot: bool = False             # matmul lookup (refuted: see §Perf)

    def specs(self):
        return {"embedding": ParamSpec((self.vocab_size, self.dim),
                                       init="normal", scale=0.02,
                                       axes=("vocab", "embed"))}

    def __call__(self, params, tokens, dtype=jnp.bfloat16):
        emb = params["embedding"].astype(dtype)
        if self.one_hot:
            # one-hot contraction: the lookup (and, critically, its
            # transpose — the embedding gradient) stays sharded over the
            # vocab axis; a gather's scatter-add gradient forces full-table
            # all-reduces over the model axis instead.
            oh = jax.nn.one_hot(tokens, self.vocab_size, dtype=dtype)
            out = jax.lax.dot_general(oh, emb, (((oh.ndim - 1,), (0,)),
                                                ((), ())))
        else:
            out = jnp.take(emb, tokens, axis=0)
        if self.scale_by_sqrt_dim:
            out = out * jnp.asarray(np.sqrt(self.dim), dtype)
        return out

    def attend(self, params, x):
        """Tied-weights logits: x @ E^T."""
        emb = params["embedding"].astype(x.dtype)
        return jax.lax.dot_general(x, emb,
                                   (((x.ndim - 1,), (1,)), ((), ())))


def sinusoidal_positions(length: int, dim: int, max_timescale: float = 10000.0):
    """Standard transformer sin/cos table (whisper encoder positions)."""
    positions = np.arange(length)[:, None]
    dims = np.arange(dim // 2)[None, :]
    angles = positions / (max_timescale ** (2 * dims / dim))
    table = np.concatenate([np.sin(angles), np.cos(angles)], axis=-1)
    return jnp.asarray(table, dtype=jnp.float32)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}
