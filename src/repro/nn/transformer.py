"""Config-driven transformer models: decoder LMs (all 10 families) + enc-dec.

The model is assembled from :class:`repro.configs.base.ModelConfig` into a
sequence of *layer groups* ``(block_template, count)``; homogeneous groups
are scanned (``lax.scan`` over stacked parameters, with optional remat), so
the lowered HLO is O(#distinct layer types), not O(#layers) — essential to
keep 61-layer × 512-device dry-run compiles fast.

Group patterns cover the architectures' structure:
  * plain stacks (stablelm, phi4, granite, internvl, whisper, rwkv6)
  * leading dense layers before MoE (deepseek-v2, kimi-k2)
  * alternating local/global attention (gemma2) — scanned in pairs
  * mostly-local with a few global layers (hymba)
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.encodings import Rope1D
from repro.distributed.sharding import logical_constraint
from repro.nn.attention import Attention, MLAttention
from repro.nn.blocks import Block
from repro.nn.layers import (Dense, Embedding, LayerNorm, RMSNorm,
                             sinusoidal_positions)
from repro.nn.mlp import MLP, GatedMLP, RWKVChannelMix
from repro.nn.module import ParamSpec, stack_specs
from repro.nn.moe import MoE
from repro.nn.ssm import MambaMixer, RWKV6TimeMix


def _tree_stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


class TransformerLM:
    """Decoder-only LM (optionally with a stubbed modality prefix)."""

    def __init__(self, cfg: ModelConfig, impl: Optional[str] = None,
                 unroll: bool = False):
        self.cfg = cfg
        self.impl = impl
        # unroll=True expands the layer scans in the lowered HLO. Used by the
        # dry-run so cost_analysis / collective parsing see every layer
        # (XLA counts while-loop bodies once); rolled scans keep compiles
        # fast everywhere else.
        self.unroll = unroll
        self.embedding = Embedding(cfg.padded_vocab, cfg.d_model,
                                   scale_by_sqrt_dim=cfg.scale_embeddings)
        self.groups = self._build_groups()
        self.final_norm = self._norm()
        if not cfg.tie_embeddings:
            self.lm_head = Dense((cfg.d_model,), (cfg.padded_vocab,),
                                 ("embed",), ("vocab",))

    # ------------------------------------------------------------------
    def _norm(self):
        cfg = self.cfg
        if cfg.norm == "layer":
            return LayerNorm(cfg.d_model)
        if cfg.norm == "rms_offset":
            return RMSNorm(cfg.d_model, weight_offset=1.0)
        return RMSNorm(cfg.d_model)

    def _encoding(self):
        cfg = self.cfg
        if cfg.pos_enc == "rope1d":
            return Rope1D(head_dim=self._rot_dim(), base=cfg.rope_base)
        return None

    def _rot_dim(self):
        cfg = self.cfg
        rd = int(cfg.resolved_head_dim * cfg.rope_fraction)
        return rd - rd % 2

    def _attention(self, window=None):
        cfg = self.cfg
        if cfg.attention_kind == "none":
            return None
        if cfg.attention_kind == "mla":
            m = cfg.mla
            return MLAttention(
                d_model=cfg.d_model, num_heads=cfg.num_q_heads,
                kv_lora_rank=m.kv_lora_rank, qk_nope_dim=m.qk_nope_dim,
                qk_rope_dim=m.qk_rope_dim, v_head_dim=m.v_head_dim,
                q_lora_rank=m.q_lora_rank, rope_base=cfg.rope_base)
        return Attention(
            d_model=cfg.d_model, num_q_heads=cfg.num_q_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            encoding=self._encoding(), rope_fraction=cfg.rope_fraction,
            causal=True, window=window, softcap=cfg.attn_softcap,
            query_scale=cfg.query_scale, use_bias=cfg.attn_bias)

    def _ssm(self):
        cfg = self.cfg
        if cfg.ssm is None:
            return None
        if cfg.ssm.kind == "rwkv6":
            return RWKV6TimeMix(d_model=cfg.d_model,
                                head_dim=cfg.ssm.head_dim,
                                chunk=cfg.ssm.chunk)
        return MambaMixer(d_model=cfg.d_model, d_inner=cfg.ssm.d_inner,
                          state_size=cfg.ssm.state_size,
                          conv_width=cfg.ssm.conv_width, chunk=cfg.ssm.chunk)

    def _mlp(self, d_ff=None, moe=False):
        cfg = self.cfg
        if moe:
            m = cfg.moe
            return MoE(d_model=cfg.d_model, num_experts=m.num_experts,
                       top_k=m.top_k, expert_ff=m.expert_ff,
                       num_shared=m.num_shared,
                       capacity_factor=m.capacity_factor,
                       aux_weight=m.aux_weight, activation=cfg.activation)
        d_ff = d_ff or cfg.d_ff
        if cfg.mlp_kind == "rwkv":
            return RWKVChannelMix(cfg.d_model, d_ff)
        if cfg.mlp_kind == "plain":
            return MLP(cfg.d_model, d_ff, activation=cfg.activation,
                       use_bias=cfg.attn_bias)
        return GatedMLP(cfg.d_model, d_ff, activation=cfg.activation)

    def _block(self, window=None, moe=False, d_ff=None):
        cfg = self.cfg
        return Block(
            d_model=cfg.d_model,
            attention=self._attention(window=window),
            ssm=self._ssm(),
            mlp=self._mlp(d_ff=d_ff, moe=moe),
            norm=cfg.norm, post_norms=(cfg.norm == "rms_offset"),
            parallel_ssm=cfg.parallel_ssm)

    def _build_groups(self) -> List[Tuple[Block, int]]:
        cfg = self.cfg
        n = cfg.num_layers
        groups: List[Tuple[Block, int]] = []
        is_moe = cfg.moe is not None
        if is_moe and cfg.moe.first_k_dense:
            k = cfg.moe.first_k_dense
            groups.append((self._block(moe=False, d_ff=cfg.moe.dense_ff
                                       or cfg.d_ff), k))
            n -= k
        if cfg.window_pattern == "alternating":
            # scanned in (local, global) pairs
            assert n % 2 == 0, n
            groups.append((("pair", self._block(window=cfg.window, moe=is_moe),
                            self._block(window=None, moe=is_moe)), n // 2))
        elif cfg.window_pattern == "mostly_local":
            # global at the first, middle, and last layer (hymba)
            assert n >= 5, n
            mid1 = (n - 3) // 2
            mid2 = (n - 3) - mid1
            groups.append((self._block(window=None, moe=is_moe), 1))
            groups.append((self._block(window=cfg.window, moe=is_moe), mid1))
            groups.append((self._block(window=None, moe=is_moe), 1))
            groups.append((self._block(window=cfg.window, moe=is_moe), mid2))
            groups.append((self._block(window=None, moe=is_moe), 1))
        else:
            groups.append((self._block(window=cfg.window, moe=is_moe), n))
        return groups

    # ------------------------------------------------------------------
    def specs(self):
        cfg = self.cfg
        s: Dict[str, Any] = {"embedding": self.embedding.specs()}
        for gi, (blk, count) in enumerate(self.groups):
            if isinstance(blk, tuple):            # alternating pair
                _, a, b = blk
                sub = {"a": a.specs(), "b": b.specs()}
            else:
                sub = blk.specs()
            if count > 1:
                sub = stack_specs(sub, count)
            s[f"group{gi}"] = sub
        s["final_norm"] = self.final_norm.specs()
        if not cfg.tie_embeddings:
            s["lm_head"] = self.lm_head.specs()
        if cfg.learned_positions:
            s["pos_embedding"] = {"embedding": ParamSpec(
                (cfg.max_position, cfg.d_model), init="normal", scale=0.01,
                axes=(None, "embed"))}
        return s

    # ------------------------------------------------------------------
    def _apply_block(self, blk, params, x, pose, segment_ids, cache,
                     cache_index):
        if isinstance(blk, tuple):
            _, a, b = blk
            ca = cache.get("a") if cache else None
            cb = cache.get("b") if cache else None
            x, aux1, nca = a(params["a"], x, pose, segment_ids, ca, cache_index,
                             impl=self.impl)
            x, aux2, ncb = b(params["b"], x, pose, segment_ids, cb, cache_index,
                             impl=self.impl)
            nc = None
            if nca is not None or ncb is not None:
                nc = {"a": nca, "b": ncb}
            return x, aux1 + aux2, nc
        return blk(params, x, pose, segment_ids, cache, cache_index,
                   impl=self.impl)

    def _scan_group(self, blk, params, x, pose, segment_ids, cache,
                    cache_index, remat: bool):
        """lax.scan over a stacked layer group (optionally rematerialized)."""
        has_cache = cache is not None

        def body(x, xs):
            lp, lc = xs if has_cache else (xs, None)
            x, aux, nc = self._apply_block(blk, lp, x, pose, segment_ids, lc,
                                           cache_index)
            return x, (aux, nc) if has_cache else (aux, 0)

        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        xs = (params, cache) if has_cache else params
        length = jax.tree.leaves(params)[0].shape[0]
        x, (auxs, ncs) = jax.lax.scan(body, x, xs,
                                      unroll=length if self.unroll else 1)
        return x, jnp.sum(auxs), (ncs if has_cache else None)

    def __call__(self, params, tokens, *, positions=None, prefix_embeds=None,
                 cache=None, cache_index=None, remat: bool = True,
                 return_hidden: bool = False):
        """tokens (B, S) int32 -> logits (B, S', padded_vocab).

        ``prefix_embeds`` (B, P, d): stubbed modality frontend output
        (internvl patches / whisper frames are handled by EncDec below);
        prepended before the token embeddings at prefill/train time.
        ``cache``/``cache_index``: decode path; S is the new-token chunk.
        """
        cfg = self.cfg
        dtype = cfg.compute_dtype
        x = self.embedding(params["embedding"], tokens, dtype=dtype)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
        b, s, _ = x.shape
        if positions is None:
            start = 0 if cache_index is None else cache_index
            if getattr(start, "ndim", 0) == 1:      # per-slot cursors
                positions = start[:, None] + jnp.arange(s, dtype=jnp.int32)
            else:
                positions = jnp.broadcast_to(
                    start + jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
        if cfg.learned_positions:
            pe = jnp.take(params["pos_embedding"]["embedding"], positions,
                          axis=0).astype(dtype)
            x = x + pe
        pose = positions.astype(jnp.float32)[..., None]
        x = logical_constraint(x, "act_batch", "act_seq", "act_embed")

        aux = jnp.zeros((), jnp.float32)
        new_cache: Dict[str, Any] = {}
        for gi, (blk, count) in enumerate(self.groups):
            gp = params[f"group{gi}"]
            gc = cache.get(f"group{gi}") if cache else None
            if count > 1:
                x, gaux, nc = self._scan_group(
                    blk, gp, x, pose, None, gc, cache_index,
                    remat=remat and cache is None)
            else:
                x, gaux, nc = self._apply_block(blk, gp, x, pose, None, gc,
                                                cache_index)
            aux = aux + gaux
            if nc is not None:
                new_cache[f"group{gi}"] = nc
        x = self.final_norm(params["final_norm"], x)
        if return_hidden:
            return x, aux, (new_cache or None)
        if cfg.tie_embeddings:
            logits = self.embedding.attend(params["embedding"], x)
        else:
            logits = self.lm_head(params["lm_head"], x)
        if cfg.final_softcap:
            logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
        logits = logical_constraint(logits, "act_batch", "act_seq", "act_vocab")
        return logits, aux, (new_cache or None)

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        cache = {}
        for gi, (blk, count) in enumerate(self.groups):
            if isinstance(blk, tuple):
                _, a, b = blk
                one = {"a": a.init_cache(batch, max_len, dtype),
                       "b": b.init_cache(batch, max_len, dtype)}
            else:
                one = blk.init_cache(batch, max_len, dtype)
            if count > 1:
                one = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (count,) + x.shape).copy(),
                    one)
            cache[f"group{gi}"] = one
        return cache


class EncDecLM:
    """Encoder-decoder transformer (whisper family; conv frontend stubbed —
    inputs are precomputed frame embeddings)."""

    def __init__(self, cfg: ModelConfig, impl: Optional[str] = None,
                 unroll: bool = False):
        assert cfg.enc_dec
        self.cfg = cfg
        self.impl = impl
        self.unroll = unroll
        d = cfg.d_model
        self.embedding = Embedding(cfg.padded_vocab, d)
        enc_attn = Attention(d_model=d, num_q_heads=cfg.num_q_heads,
                             num_kv_heads=cfg.num_kv_heads,
                             head_dim=cfg.resolved_head_dim, encoding=None,
                             causal=False, use_bias=True)
        self.enc_block = Block(d_model=d, attention=enc_attn,
                               mlp=MLP(d, cfg.d_ff, activation="gelu"),
                               norm="layer")
        dec_self = Attention(d_model=d, num_q_heads=cfg.num_q_heads,
                             num_kv_heads=cfg.num_kv_heads,
                             head_dim=cfg.resolved_head_dim, encoding=None,
                             causal=True, use_bias=True)
        self.dec_block = Block(d_model=d, attention=dec_self,
                               mlp=MLP(d, cfg.d_ff, activation="gelu"),
                               norm="layer")
        self.cross_attn = Attention(d_model=d, num_q_heads=cfg.num_q_heads,
                                    num_kv_heads=cfg.num_kv_heads,
                                    head_dim=cfg.resolved_head_dim,
                                    encoding=None, causal=False, use_bias=True)
        self.enc_norm = LayerNorm(d)
        self.dec_norm = LayerNorm(d)
        self.cross_norm = LayerNorm(d)

    def specs(self):
        cfg = self.cfg
        return {
            "embedding": self.embedding.specs(),
            "pos_embedding": {"embedding": ParamSpec(
                (cfg.max_position, cfg.d_model), init="normal", scale=0.01,
                axes=(None, "embed"))},
            "encoder": stack_specs(self.enc_block.specs(), cfg.encoder_layers),
            "decoder": stack_specs(self.dec_block.specs(), cfg.num_layers),
            "cross": stack_specs({"norm": self.cross_norm.specs(),
                                  "attn": self.cross_attn.specs()},
                                 cfg.num_layers),
            "enc_norm": self.enc_norm.specs(),
            "dec_norm": self.dec_norm.specs(),
        }

    def encode(self, params, frames):
        """frames (B, F, d_model): stubbed conv-frontend output."""
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype)
        pos = sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
        x = x + pos[None]

        def body(x, lp):
            x, _, _ = self.enc_block(lp, x)
            return x, 0

        x, _ = jax.lax.scan(body, x, params["encoder"],
                            unroll=self.cfg.encoder_layers if self.unroll
                            else 1)
        return self.enc_norm(params["enc_norm"], x)

    def decode(self, params, tokens, enc_out, cache=None, cache_index=None):
        cfg = self.cfg
        dtype = cfg.compute_dtype
        x = self.embedding(params["embedding"], tokens, dtype=dtype)
        b, s, _ = x.shape
        start = 0 if cache_index is None else cache_index
        positions = start + jnp.arange(s, dtype=jnp.int32)[None, :]
        pe = jnp.take(params["pos_embedding"]["embedding"],
                      jnp.broadcast_to(positions, (b, s)), axis=0)
        x = x + pe.astype(dtype)
        has_cache = cache is not None

        def body(x, xs):
            if has_cache:
                (dp, xp), lc = xs
            else:
                (dp, xp), lc = xs, None
            x, _, nc = self.dec_block(dp, x, cache=lc, cache_index=cache_index,
                                      impl=self.impl)
            h = self.cross_norm(xp["norm"], x)
            c_out, _ = self.cross_attn(xp["attn"], h, kv=enc_out,
                                       impl=self.impl)
            x = x + c_out
            return x, (0, nc) if has_cache else (0, 0)

        xs = ((params["decoder"], params["cross"]), cache) if has_cache else \
            (params["decoder"], params["cross"])
        x, (_, ncs) = jax.lax.scan(body, x, xs,
                                   unroll=self.cfg.num_layers if self.unroll
                                   else 1)
        x = self.dec_norm(params["dec_norm"], x)
        logits = self.embedding.attend(params["embedding"], x)
        return logits, (ncs if has_cache else None)

    def __call__(self, params, frames, tokens, cache=None, cache_index=None):
        enc_out = self.encode(params, frames)
        logits, nc = self.decode(params, tokens, enc_out, cache=cache,
                                 cache_index=cache_index)
        return logits, jnp.zeros((), jnp.float32), nc

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        one = self.dec_block.init_cache(batch, max_len, dtype)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(
                x, (self.cfg.num_layers,) + x.shape).copy(), one)


def build_model(cfg: ModelConfig, impl: Optional[str] = None,
                unroll: bool = False):
    if cfg.enc_dec:
        return EncDecLM(cfg, impl=impl, unroll=unroll)
    return TransformerLM(cfg, impl=impl, unroll=unroll)
