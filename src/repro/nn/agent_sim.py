"""Agent-simulation model (paper Sec. IV-B): next-action prediction over
tokenized traffic scenes with SE(2)-relative attention.

Scene tokenization (mirrors the paper's setup): each map element and each
(agent, timestep) pair is one token with an associated SE(2) pose. Tokens
are ordered [map..., agents@t0, agents@t1, ...]; attention is block-causal
over *times* (map tokens have time 0, agents at step t have time t+1, and
tokens of the same step attend to each other bidirectionally). The model
predicts a categorical distribution over a discrete (acceleration x yaw
rate) action grid for every agent token.

The relative attention mechanism is pluggable — the four rows of the paper's
Table I:

  * ``absolute``     — learned Fourier-feature pose embedding added to token
    features, standard SDPA.
  * ``rope2d``       — translation-invariant only (Sec. II-D).
  * ``se2_repr``     — homogeneous-matrix SE(2) representation (Sec. II-E).
  * ``se2_fourier``  — the paper's contribution (Sec. III).

Positions are downscaled by ``pos_scale`` so magnitudes stay within the
Fourier basis budget (paper: <= 4 with F = 18).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encodings import GroupEncoding, make_encoding
from repro.kernels import ops as kops
from repro.kernels.flash_decode import canonical_cache_dtype, quantize_kv
from repro.nn.attention import _merge_heads, _split_heads
from repro.nn.layers import Dense, RMSNorm
from repro.nn.mlp import GatedMLP
from repro.nn.module import stack_specs


@dataclasses.dataclass(frozen=True)
class AgentSimConfig:
    d_model: int = 256
    num_layers: int = 4
    num_heads: int = 8
    head_dim: int = 24            # divisible by 6/4/3/2: works for every enc
    d_ff: int = 1024
    num_actions: int = 63         # 7 accel bins x 9 yaw-rate bins
    agent_feat_dim: int = 8
    map_feat_dim: int = 8
    encoding: str = "se2_fourier"
    fourier_terms: int = 12
    min_scale: float = 0.25
    max_scale: float = 1.0
    pos_scale: float = 0.05       # world meters -> encoder units (<= 4)
    attn_impl: str = "ref"        # scenes are small; ref is fine on CPU
    #: attention impl for the cached decode path (``kops.decode_attention``
    #: names: "auto" / "flash_decode" / "xla" / "ref" / "chunked").
    #: None falls back to ``attn_impl`` — the pre-decode-kernel behavior,
    #: which scans the whole preallocated cache and is kept as the oracle.
    decode_impl: Optional[str] = None
    dtype: str = "float32"

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def _scatter_rows(buf, new, cursor):
    """Write ``new`` rows into ``buf`` at per-row cursors along the length
    axis: buf (B, S, ...) or (B, H, S, ...), new the matching (B, n, ...) /
    (B, H, n, ...), cursor (B,) int32. The caller guarantees
    cursor + n <= S (dynamic_update_slice clamps, it does not wrap)."""
    axis = 1 if buf.ndim == 2 else buf.ndim - 2    # length axis of buf
    return jax.vmap(
        lambda b_, u, i: jax.lax.dynamic_update_slice_in_dim(
            b_, u, i, axis=axis - 1))(buf, new, cursor)


def _scatter_layer_rows(buf, layer, new, cursor):
    """Write one layer's new rows into the *stacked* cache in place.

    buf (L, B, H, S, c) or (L, B, H, S); new (B, H, n, c) / (B, H, n);
    layer a static int; cursor (B,). A chain of per-slot
    ``dynamic_update_slice`` ops, each touching only the n written rows
    of (layer, slot) — under jit with a donated cache the whole update
    is O(B * n), not O(max_len). The tempting alternatives both
    silently copy the entire preallocated buffer every tick and erase
    the ragged-decode win: threading the cache through ``lax.scan``
    xs/ys (slice-in/stack-out copies), and ``vmap`` over the slot axis
    (in_axes=1 inserts full-buffer transposes). The engine-level
    regression guard is ``benchmarks/rollout_bench.py``'s flatness
    assertion.
    """
    b = buf.shape[1]
    for bi in range(b):
        starts = (layer, bi, 0, cursor[bi]) + (0,) * (buf.ndim - 4)
        buf = jax.lax.dynamic_update_slice(
            buf, new[bi][None, None], starts)
    return buf


def install_slot_rows(cache, sub, si, n_rows: int):
    """Install the first ``n_rows`` rows of a freshly written 1-slot cache
    ``sub`` into slot ``si`` of a multi-slot cache (continuous-batching
    admission: a retiring scene's slot is reused by the next scene).

    ``si`` may be a traced scalar, so one compilation serves every slot.
    This deliberately rewrites ONLY rows ``[0, n_rows)`` plus the slot's
    cursor: rows at and beyond the (reset) cursor keep whatever the
    evicted scene left behind — including segment ids claiming validity.
    They are unreachable anyway, because every decode masks keys at
    positions >= ``kv_length = cursor + n`` and the cursor only ever
    advances over freshly written rows (the isolation contract pinned by
    ``tests/test_sim_server.py``). Scrubbing them would cost an
    O(max_len) write per admission just to hide from that contract.
    """
    out = dict(cache)
    for key in ("k", "v"):
        rows = jax.lax.slice_in_dim(sub[key], 0, n_rows, axis=3)
        out[key] = jax.lax.dynamic_update_slice(
            cache[key], rows, (0, si, 0, 0, 0))
    for key in ("k_scale", "v_scale"):
        if key in cache:
            rows = jax.lax.slice_in_dim(sub[key], 0, n_rows, axis=3)
            out[key] = jax.lax.dynamic_update_slice(
                cache[key], rows, (0, si, 0, 0))
    for key in ("times", "seg"):
        out[key] = jax.lax.dynamic_update_slice(
            cache[key], sub[key][:, :n_rows], (si, 0))
    out["cursor"] = jax.lax.dynamic_update_slice(
        cache["cursor"], sub["cursor"], (si,))
    return out


def build_sim_encoding(cfg: AgentSimConfig) -> Optional[GroupEncoding]:
    if cfg.encoding == "absolute":
        return None
    kwargs: Dict[str, Any] = {}
    if cfg.encoding == "se2_fourier":
        kwargs = dict(num_terms=cfg.fourier_terms, min_scale=cfg.min_scale,
                      max_scale=cfg.max_scale)
    elif cfg.encoding == "se2_repr":
        kwargs = dict(min_scale=cfg.min_scale, max_scale=cfg.max_scale)
    elif cfg.encoding == "rope2d":
        kwargs = dict(max_freq=cfg.max_scale, base=100.0)
    return make_encoding(cfg.encoding, cfg.head_dim, **kwargs)


class SimAttention:
    """Relative attention over scene tokens (Alg. 2 around the SDPA kernel).

    Attention is **block-causal over times** (``causal=True`` with explicit
    per-token times): a token at simulation step t attends tokens at steps
    <= t, and tokens sharing a step attend each other bidirectionally. This
    is not just the autoregressive training mask — it is what makes the
    incremental decode cache sound: a token's attention output can never
    change when later tokens arrive, so per-layer K/V rows written once
    stay valid for the rest of the rollout.

    The cached rows are the *encoding-transformed* keys/values
    ``k~ = phi_k(p_m) k`` / ``v~ = phi_k(p_m) v``: the paper's per-token
    factorization means they depend only on the token's own pose, never on
    the (growing) rest of the scene — see ``docs/rollout.md``.
    """

    def __init__(self, cfg: AgentSimConfig):
        self.cfg = cfg
        self.enc = build_sim_encoding(cfg)
        d, h, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
        self.projs = {
            "q": Dense((d,), (h, hd), ("embed",), ("heads", "head_dim")),
            "k": Dense((d,), (h, hd), ("embed",), ("heads", "head_dim")),
            "v": Dense((d,), (h, hd), ("embed",), ("heads", "head_dim")),
            "o": Dense((h, hd), (d,), ("heads", "head_dim"), ("embed",)),
        }

    def specs(self):
        return {k: p.specs() for k, p in self.projs.items()}

    @property
    def cache_dims(self) -> Tuple[int, int]:
        """(key_dim, value_dim) of one cached row (post-transform)."""
        if self.enc is None:
            return self.cfg.head_dim, self.cfg.head_dim
        return self.enc.expanded_dim, self.enc.expanded_v_dim

    def _qkv(self, params, x, pose):
        """Project new tokens and apply the per-token encoding transforms.

        Returns (q~, k~, v~), each (B, H, n, ·) — exactly the rows a cache
        stores. Everything here depends only on each token's own features
        and pose: the factorization that legitimizes caching.
        """
        cfg = self.cfg
        h, hd = cfg.num_heads, cfg.head_dim
        q = _split_heads(self.projs["q"](params["q"], x), h, hd)
        k = _split_heads(self.projs["k"](params["k"], x), h, hd)
        v = _split_heads(self.projs["v"](params["v"], x), h, hd)
        if self.enc is not None:
            p4 = pose[:, None]                       # (B, 1, n, 3)
            if self.enc.pose_dim == 2:
                p4 = p4[..., :2]
            q = self.enc.transform_q(q, p4)
            k = self.enc.transform_k(k, p4)
            if self.enc.transforms_values:
                v = self.enc.transform_v(v, p4)
        return q, k, v

    def _finish(self, params, out, pose):
        if self.enc is not None and self.enc.transforms_values:
            out = self.enc.untransform_out(out, pose[:, None])
        return self.projs["o"](params["o"], _merge_heads(out))

    def __call__(self, params, x, pose, times, segment_ids):
        cfg = self.cfg
        q, k, v = self._qkv(params, x, pose)
        scale = 1.0 / float(cfg.head_dim) ** 0.5
        out = kops.attention(q, k, v, impl=cfg.attn_impl, scale=scale,
                             causal=True,
                             q_times=times, k_times=times,
                             q_segment_ids=segment_ids,
                             k_segment_ids=segment_ids)
        return self._finish(params, out, pose)

    def decode_step(self, params, x, pose, times, segment_ids,
                    kv_cache, layer, cache_times, cache_seg, cursor,
                    impl=None):
        """Incremental decode: attend ``n`` new tokens over the cache.

        x (B, n, d_model); pose (B, n, 3) *encoder-scaled*; times (B, n);
        segment_ids (B, n); ``kv_cache`` is the model's layer-STACKED
        cache: ``{"k": (L, B, H, S_max, c), "v": (L, B, H, S_max, cv)}``
        plus, for int8 caches, per-(head, token) ``"k_scale"``/
        ``"v_scale"`` (L, B, H, S_max) float32 living beside the rows
        they scale; ``layer`` is this layer's static index. The stacked
        buffers are written with O(n) in-place scatters and read by the
        ragged decode paths through in-place (layer, block) slices — a
        per-layer (B, H, S_max, .) copy never exists. cache_times /
        cache_seg (B, S_max) are **already updated** with the new tokens'
        rows (they are layer-independent, so the model writes them once);
        cursor (B,) — rows written *before* this call. Returns
        (out (B, n, d_model), updated kv_cache).

        New rows are written at [cursor, cursor + n) — quantized on
        write for int8 caches (a row's absmax never changes after the
        write, so per-row scales are exact). The query attends the cache
        with the same block-causal times + segment mask as the full
        forward, plus cursor masking (``kv_length = cursor + n``) so
        never-written slots are unreachable even where ``cache_seg`` has
        been scribbled on by a retired scene. ``impl`` (or
        ``cfg.decode_impl``, or ``cfg.attn_impl``) picks the
        ``kops.decode_attention`` backend: the split-K ragged decode
        kernel / its XLA twin pay O(cursor) per call; the generic-kernel
        names scan all of S_max and remain the parity oracle.
        """
        cfg = self.cfg
        n = x.shape[1]
        q, k_new, v_new = self._qkv(params, x, pose)
        kv_cache = dict(kv_cache)
        if "k_scale" in kv_cache:
            k_q, k_s = quantize_kv(k_new)
            v_q, v_s = quantize_kv(v_new)
            kv_cache["k"] = _scatter_layer_rows(kv_cache["k"], layer, k_q,
                                                cursor)
            kv_cache["v"] = _scatter_layer_rows(kv_cache["v"], layer, v_q,
                                                cursor)
            kv_cache["k_scale"] = _scatter_layer_rows(
                kv_cache["k_scale"], layer, k_s, cursor)
            kv_cache["v_scale"] = _scatter_layer_rows(
                kv_cache["v_scale"], layer, v_s, cursor)
        else:
            kv_cache["k"] = _scatter_layer_rows(
                kv_cache["k"], layer,
                k_new.astype(kv_cache["k"].dtype), cursor)
            kv_cache["v"] = _scatter_layer_rows(
                kv_cache["v"], layer,
                v_new.astype(kv_cache["v"].dtype), cursor)
        scale = 1.0 / float(cfg.head_dim) ** 0.5
        out = kops.decode_attention(
            q, kv_cache["k"], kv_cache["v"],
            kv_length=cursor + n, layer=layer,
            impl=impl or cfg.decode_impl or cfg.attn_impl,
            scale=scale, q_times=times, k_times=cache_times,
            q_segment_ids=segment_ids, k_segment_ids=cache_seg,
            k_scale=kv_cache.get("k_scale"),
            v_scale=kv_cache.get("v_scale"))
        return self._finish(params, out, pose), kv_cache


class AgentSimModel:
    """Scene transformer -> per-(agent, t) action logits."""

    def __init__(self, cfg: AgentSimConfig):
        self.cfg = cfg
        d = cfg.d_model
        self.map_enc = Dense((cfg.map_feat_dim,), (d,), (None,), ("embed",))
        self.agent_enc = Dense((cfg.agent_feat_dim,), (d,), (None,), ("embed",))
        self.attn = SimAttention(cfg)
        self.mlp = GatedMLP(d, cfg.d_ff)
        self.norm1 = RMSNorm(d)
        self.norm2 = RMSNorm(d)
        self.final_norm = RMSNorm(d)
        self.head = Dense((d,), (cfg.num_actions,), ("embed",), (None,))
        # learned Fourier pose embedding for the "absolute" baseline
        self.pose_freqs = 16

    def specs(self):
        cfg = self.cfg
        block = {"attn": self.attn.specs(), "mlp": self.mlp.specs(),
                 "norm1": self.norm1.specs(), "norm2": self.norm2.specs()}
        s = {
            "map_enc": self.map_enc.specs(),
            "agent_enc": self.agent_enc.specs(),
            "blocks": stack_specs(block, cfg.num_layers),
            "final_norm": self.final_norm.specs(),
            "head": self.head.specs(),
        }
        if cfg.encoding == "absolute":
            s["pose_proj"] = Dense((3 * self.pose_freqs,), (cfg.d_model,),
                                   ("basis",), ("embed",)).specs()
        return s

    def _pose_embedding(self, params, pose):
        """Fourier features of (x, y, theta) -> d_model (absolute baseline)."""
        freqs = jnp.asarray(2.0 ** np.arange(self.pose_freqs // 2),
                            jnp.float32)
        scaled = jnp.concatenate(
            [pose[..., 0:1] * self.cfg.pos_scale,
             pose[..., 1:2] * self.cfg.pos_scale, pose[..., 2:3]], -1)
        ang = scaled[..., None] * freqs                  # (..., 3, PF/2)
        feats = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)
        feats = feats.reshape(*pose.shape[:-1], 3 * self.pose_freqs)
        return Dense((3 * self.pose_freqs,), (self.cfg.d_model,), ("basis",),
                     ("embed",))(params["pose_proj"], feats)

    def tokenize(self, batch):
        """Assemble scene tokens.

        batch: dict with
          map_feats (B, M, Fm), map_pose (B, M, 3), map_valid (B, M) bool
          agent_feats (B, T, A, Fa), agent_pose (B, T, A, 3),
          agent_valid (B, T, A) bool
        Returns (feats, pose, times, segment_ids) with S = M + T*A.
        """
        b, m, _ = batch["map_feats"].shape
        _, t, a, _ = batch["agent_feats"].shape
        pose = jnp.concatenate(
            [batch["map_pose"],
             batch["agent_pose"].reshape(b, t * a, 3)], axis=1)
        times = jnp.concatenate(
            [jnp.zeros((b, m), jnp.int32),
             jnp.broadcast_to(1 + jnp.arange(t, dtype=jnp.int32)[None, :, None],
                              (b, t, a)).reshape(b, t * a)], axis=1)
        valid = jnp.concatenate(
            [batch["map_valid"],
             batch["agent_valid"].reshape(b, t * a)], axis=1)
        segment_ids = jnp.where(valid, 0, -1).astype(jnp.int32)
        return pose, times, segment_ids

    def __call__(self, params, batch):
        """Returns logits (B, T, A, num_actions) and aux (zeros)."""
        cfg = self.cfg
        dt = cfg.compute_dtype
        b, m, _ = batch["map_feats"].shape
        _, t, a, _ = batch["agent_feats"].shape
        pose, times, segment_ids = self.tokenize(batch)
        mtok = self.map_enc(params["map_enc"], batch["map_feats"].astype(dt))
        atok = self.agent_enc(params["agent_enc"],
                              batch["agent_feats"].astype(dt))
        x = jnp.concatenate([mtok, atok.reshape(b, t * a, -1)], axis=1)
        if cfg.encoding == "absolute":
            x = x + self._pose_embedding(params, pose).astype(dt)
        enc_pose = pose.astype(jnp.float32) * jnp.asarray(
            [cfg.pos_scale, cfg.pos_scale, 1.0], jnp.float32)

        def body(x, lp):
            h = self.norm1(lp["norm1"], x)
            x = x + self.attn(lp["attn"], h, enc_pose, times, segment_ids)
            h = self.norm2(lp["norm2"], x)
            x = x + self.mlp(lp["mlp"], h)
            return x, 0

        x, _ = jax.lax.scan(body, x, params["blocks"])
        x = self.final_norm(params["final_norm"], x)
        logits = self.head(params["head"], x[:, m:])
        return logits.reshape(b, t, a, cfg.num_actions), jnp.zeros(
            (), jnp.float32)

    # -- incremental decode ---------------------------------------------------
    #
    # The per-token factorization (encodings.GroupEncoding) means a cached
    # k~/v~ row depends only on that token's own features and pose, and the
    # block-causal times mask means a token's output never changes as the
    # scene grows — so `prefill` + repeated `step` reproduces `__call__`'s
    # logits exactly (tests/test_decode.py) at O(T) instead of O(T^2) work
    # per rollout step. See docs/rollout.md for the soundness argument.

    #: layer-stacked cache entries scanned alongside the block params
    _LAYER_CACHE_KEYS = ("k", "v", "k_scale", "v_scale")

    def init_cache(self, batch_size: int, max_len: int, dtype=None):
        """Preallocate the decode cache for ``batch_size`` scene slots.

        Layout: per-layer transformed keys/values stacked on a leading layer
        axis (the block parameters are scanned, so the cache scans too),
        plus layer-independent times / segment ids / per-slot cursors.
        Segment ids start at -1, so unwritten rows are always masked.

        ``dtype`` selects the cache storage dtype: a jnp dtype or one of
        the strings "float32" / "bfloat16" / "int8" (the
        ``RolloutEngine(cache_dtype=...)`` spelling). int8 caches carry
        per-(head, token) float32 ``k_scale``/``v_scale`` arrays beside
        the rows (quantized on write, dequantized inside the decode
        kernel), shrinking the decode working set ~4x at the cost of one
        f32 scalar per row.
        """
        cfg = self.cfg
        dtype = canonical_cache_dtype(dtype, default=cfg.compute_dtype)
        ck, cv = self.attn.cache_dims
        l, b, h, s = cfg.num_layers, batch_size, cfg.num_heads, max_len
        cache = {
            "k": jnp.zeros((l, b, h, s, ck), dtype),
            "v": jnp.zeros((l, b, h, s, cv), dtype),
            "times": jnp.zeros((b, s), jnp.int32),
            "seg": jnp.full((b, s), -1, jnp.int32),
            "cursor": jnp.zeros((b,), jnp.int32),
        }
        if dtype == jnp.int8:
            # scale 0 dequantizes unwritten rows to exact zeros (they are
            # cursor-masked anyway)
            cache["k_scale"] = jnp.zeros((l, b, h, s), jnp.float32)
            cache["v_scale"] = jnp.zeros((l, b, h, s), jnp.float32)
        return cache

    def _extend(self, params, cache, x, pose, times, segment_ids, impl=None):
        """Feed ``n`` new tokens through every layer against the cache.

        x (B, n, d_model) embedded tokens; pose (B, n, 3) raw world poses;
        times/segment_ids (B, n). Returns (logits (B, n, A), new cache).
        Used for both prefill (n = whole history) and rollout steps (n =
        num_agents): the mask semantics are identical, so prefill is just a
        big first step. ``impl`` overrides the decode attention backend
        (see ``SimAttention.decode_step``).
        """
        cfg = self.cfg
        n = x.shape[1]
        cursor = cache["cursor"]
        enc_pose = pose.astype(jnp.float32) * jnp.asarray(
            [cfg.pos_scale, cfg.pos_scale, 1.0], jnp.float32)
        cache_times = _scatter_rows(cache["times"], times, cursor)
        cache_seg = _scatter_rows(cache["seg"], segment_ids, cursor)
        kv_cache = {k: cache[k] for k in self._LAYER_CACHE_KEYS
                    if k in cache}

        # Python loop, NOT lax.scan: the layer index must be static so
        # the decode kernels can address the stacked cache in place, and
        # scanning the cache through xs/ys would copy the whole
        # preallocated buffer every tick (see _scatter_layer_rows).
        # num_layers is small; the unrolled loop costs only compile time.
        for li in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[li], params["blocks"])
            h = self.norm1(lp["norm1"], x)
            attn_out, kv_cache = self.attn.decode_step(
                lp["attn"], h, enc_pose, times, segment_ids,
                kv_cache, li, cache_times, cache_seg, cursor, impl=impl)
            x = x + attn_out
            h = self.norm2(lp["norm2"], x)
            x = x + self.mlp(lp["mlp"], h)

        x = self.final_norm(params["final_norm"], x)
        logits = self.head(params["head"], x)
        new_cache = {**kv_cache, "times": cache_times,
                     "seg": cache_seg, "cursor": cursor + n}
        return logits, new_cache

    def prefill(self, params, cache, batch, impl=None):
        """Write a scene's map + agent history into the cache.

        ``batch`` has the ``__call__`` layout with T = history length.
        Returns (logits (B, T, A, num_actions) for the history's agent
        tokens, updated cache). Requires max_len >= cursor + M + T*A.
        """
        cfg = self.cfg
        dt = cfg.compute_dtype
        b, m, _ = batch["map_feats"].shape
        _, t, a, _ = batch["agent_feats"].shape
        pose, times, segment_ids = self.tokenize(batch)
        mtok = self.map_enc(params["map_enc"], batch["map_feats"].astype(dt))
        atok = self.agent_enc(params["agent_enc"],
                              batch["agent_feats"].astype(dt))
        x = jnp.concatenate([mtok, atok.reshape(b, t * a, -1)], axis=1)
        if cfg.encoding == "absolute":
            x = x + self._pose_embedding(params, pose).astype(dt)
        logits, cache = self._extend(params, cache, x, pose, times,
                                     segment_ids, impl=impl)
        return logits[:, m:].reshape(b, t, a, cfg.num_actions), cache

    def admit_map(self, params, cache, map_feats, map_pose, map_valid,
                  impl=None):
        """Write ONLY a scene's map tokens into the cache.

        The continuous-batching admission primitive: map tokens are the
        one token block whose width (M) differs from the per-tick A agent
        tokens, so a sim server admits a scene by extending its slot with
        the map here and then streaming history steps through the shared
        tick (``step`` with teacher-forced inputs) — prefill becomes
        incremental, exactly like the LM server's token-by-token prompt
        prefill. map_feats (B, M, Fm); map_pose (B, M, 3); map_valid
        (B, M) bool. Returns (map-token logits — meaningless, discarded
        by callers — and the updated cache)."""
        cfg = self.cfg
        dt = cfg.compute_dtype
        b, m, _ = map_feats.shape
        x = self.map_enc(params["map_enc"], map_feats.astype(dt))
        if cfg.encoding == "absolute":
            x = x + self._pose_embedding(params, map_pose).astype(dt)
        times = jnp.zeros((b, m), jnp.int32)
        seg = jnp.where(map_valid, 0, -1).astype(jnp.int32)
        return self._extend(params, cache, x, map_pose, times, seg,
                            impl=impl)

    def step(self, params, cache, agent_feats, agent_pose, agent_valid,
             step_time, impl=None):
        """Advance every scene slot by one simulation step.

        agent_feats (B, A, Fa); agent_pose (B, A, 3); agent_valid (B, A)
        bool; step_time (B,) int32 — the simulation step index t of these
        tokens (their attention time is t + 1, matching ``tokenize``).
        Returns (action logits (B, A, num_actions), updated cache).
        """
        cfg = self.cfg
        dt = cfg.compute_dtype
        b, a, _ = agent_feats.shape
        x = self.agent_enc(params["agent_enc"], agent_feats.astype(dt))
        if cfg.encoding == "absolute":
            x = x + self._pose_embedding(params, agent_pose).astype(dt)
        times = jnp.broadcast_to((step_time + 1)[:, None], (b, a))
        times = times.astype(jnp.int32)
        segment_ids = jnp.where(agent_valid, 0, -1).astype(jnp.int32)
        return self._extend(params, cache, x, agent_pose, times, segment_ids,
                            impl=impl)


def action_nll(logits, actions, valid):
    """Mean NLL of ground-truth actions over valid agent steps."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, actions[..., None], axis=-1)[..., 0]
    w = valid.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)
