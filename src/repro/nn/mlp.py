"""Feed-forward blocks: gated (SwiGLU/GeGLU), vanilla, and RWKV channel-mix."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from repro.nn.layers import ACTIVATIONS, Dense
from repro.nn.module import ParamSpec


@dataclasses.dataclass(frozen=True)
class GatedMLP:
    """SwiGLU / GeGLU: down( act(gate(x)) * up(x) )."""

    d_model: int
    d_ff: int
    activation: str = "silu"

    def _projs(self):
        d, f = self.d_model, self.d_ff
        return {
            "gate": Dense((d,), (f,), ("embed",), ("mlp",)),
            "up": Dense((d,), (f,), ("embed",), ("mlp",)),
            "down": Dense((f,), (d,), ("mlp",), ("embed",)),
        }

    def specs(self):
        return {k: l.specs() for k, l in self._projs().items()}

    def __call__(self, params, x):
        p = self._projs()
        act = ACTIVATIONS[self.activation]
        h = act(p["gate"](params["gate"], x)) * p["up"](params["up"], x)
        h = logical_constraint(h, "act_batch", "act_seq", "act_mlp")
        y = p["down"](params["down"], h)
        return logical_constraint(y, "act_batch", "act_seq", "act_embed")


@dataclasses.dataclass(frozen=True)
class MLP:
    """Plain two-matrix FFN (granite/whisper style, with biases)."""

    d_model: int
    d_ff: int
    activation: str = "gelu"
    use_bias: bool = True

    def _projs(self):
        d, f = self.d_model, self.d_ff
        return {
            "up": Dense((d,), (f,), ("embed",), ("mlp",), use_bias=self.use_bias),
            "down": Dense((f,), (d,), ("mlp",), ("embed",), use_bias=self.use_bias),
        }

    def specs(self):
        return {k: l.specs() for k, l in self._projs().items()}

    def __call__(self, params, x):
        p = self._projs()
        act = ACTIVATIONS[self.activation]
        h = act(p["up"](params["up"], x))
        h = logical_constraint(h, "act_batch", "act_seq", "act_mlp")
        y = p["down"](params["down"], h)
        return logical_constraint(y, "act_batch", "act_seq", "act_embed")


@dataclasses.dataclass(frozen=True)
class RWKVChannelMix:
    """RWKV-6 channel mixing: token-shift lerp + squared-relu key."""

    d_model: int
    d_ff: int

    def specs(self):
        d, f = self.d_model, self.d_ff
        return {
            "mix_k": ParamSpec((d,), init="uniform", scale=0.5,
                               axes=("embed_no_fsdp",)),
            "mix_r": ParamSpec((d,), init="uniform", scale=0.5,
                               axes=("embed_no_fsdp",)),
            "key": Dense((d,), (f,), ("embed",), ("mlp",)).specs(),
            "value": Dense((f,), (d,), ("mlp",), ("embed",)).specs(),
            "receptance": Dense((d,), (d,), ("embed",), ("embed_no_fsdp",)).specs(),
        }

    def __call__(self, params, x, shifted=None):
        """``shifted``: previous-token activations (decode passes the state)."""
        d, f = self.d_model, self.d_ff
        if shifted is None:
            shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
        mk = params["mix_k"].astype(x.dtype)
        mr = params["mix_r"].astype(x.dtype)
        xk = x + (shifted - x) * mk
        xr = x + (shifted - x) * mr
        key = Dense((d,), (f,), ("embed",), ("mlp",))(params["key"], xk)
        k = jnp.square(jax.nn.relu(key))
        k = logical_constraint(k, "act_batch", "act_seq", "act_mlp")
        v = Dense((f,), (d,), ("mlp",), ("embed",))(params["value"], k)
        r = jax.nn.sigmoid(
            Dense((d,), (d,), ("embed",), ("embed_no_fsdp",))(
                params["receptance"], xr))
        return r * v
