"""Transformer blocks: composable residual layers covering all 10 families.

A :class:`Block` bundles an optional attention mixer, an optional SSM mixer
(parallel or exclusive), and a channel mixer (MLP / MoE / RWKV channel-mix),
with pre- (and optionally post-) norms. One template is instantiated per
distinct layer type and scanned over the layer axis by the model wrapper.

``apply`` signature is uniform across families so the scan body never
branches: (params, x, pose, segment_ids, cache, cache_index) ->
(x, aux_loss, new_cache). Caches are dicts with optional "attn"/"ssm" parts.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax.numpy as jnp

from repro.nn.attention import Attention, MLAttention
from repro.nn.layers import LayerNorm, RMSNorm
from repro.nn.mlp import MLP, GatedMLP, RWKVChannelMix
from repro.nn.moe import MoE
from repro.nn.ssm import MambaMixer, RWKV6TimeMix

Mixer = Union[Attention, MLAttention, None]
ChannelMixer = Union[GatedMLP, MLP, MoE, RWKVChannelMix]


@dataclasses.dataclass(frozen=True)
class Block:
    d_model: int
    attention: Mixer = None
    ssm: Optional[Union[MambaMixer, RWKV6TimeMix]] = None
    mlp: Optional[ChannelMixer] = None
    norm: str = "rms"                 # "rms" | "layer" | "rms_offset"
    post_norms: bool = False          # gemma2 post-sublayer norms
    parallel_ssm: bool = False        # hymba: attn + ssm fused in parallel

    def _norm(self):
        if self.norm == "layer":
            return LayerNorm(self.d_model)
        if self.norm == "rms_offset":
            return RMSNorm(self.d_model, weight_offset=1.0)
        return RMSNorm(self.d_model)

    def specs(self):
        n = self._norm()
        s = {}
        if self.attention is not None or self.ssm is not None:
            s["norm_mix"] = n.specs()
        if self.attention is not None:
            s["attn"] = self.attention.specs()
        if self.ssm is not None:
            s["ssm"] = self.ssm.specs()
        if self.parallel_ssm:
            # learned per-branch output norms (hymba fuses by averaging)
            s["attn_out_norm"] = RMSNorm(self.d_model).specs()
            s["ssm_out_norm"] = RMSNorm(self.d_model).specs()
        if self.mlp is not None:
            s["norm_mlp"] = n.specs()
            s["mlp"] = self.mlp.specs()
        if self.post_norms:
            if self.attention is not None:
                s["post_norm_mix"] = n.specs()
            if self.mlp is not None:
                s["post_norm_mlp"] = n.specs()
        return s

    def __call__(self, params, x, pose=None, segment_ids=None, cache=None,
                 cache_index=None, impl=None):
        n = self._norm()
        aux = jnp.zeros((), jnp.float32)
        new_cache = {}
        cache = cache or {}

        if self.attention is not None or self.ssm is not None:
            h = n(params["norm_mix"], x)
            parts = []
            if self.attention is not None:
                a_out, a_cache = self.attention(
                    params["attn"], h, pose=pose, segment_ids=segment_ids,
                    cache=cache.get("attn"), cache_index=cache_index,
                    impl=impl)
                if a_cache is not None:
                    new_cache["attn"] = a_cache
                parts.append(("attn", a_out))
            if self.ssm is not None:
                s_out, s_state = self.ssm(params["ssm"], h,
                                          state=cache.get("ssm"))
                if cache.get("ssm") is not None:
                    new_cache["ssm"] = s_state
                parts.append(("ssm", s_out))
            if self.parallel_ssm and len(parts) == 2:
                a = RMSNorm(self.d_model)(params["attn_out_norm"], parts[0][1])
                s = RMSNorm(self.d_model)(params["ssm_out_norm"], parts[1][1])
                mixed = (a + s) * 0.5
            else:
                mixed = parts[0][1]
                for _, p in parts[1:]:
                    mixed = mixed + p
            if self.post_norms:
                mixed = n(params["post_norm_mix"], mixed)
            x = x + mixed

        if self.mlp is not None:
            h = n(params["norm_mlp"], x)
            if isinstance(self.mlp, MoE):
                m_out, moe_aux = self.mlp(params["mlp"], h)
                aux = aux + moe_aux
            elif isinstance(self.mlp, RWKVChannelMix):
                shift = cache.get("cmix_shift")
                if shift is not None:
                    shifted = jnp.concatenate([shift[:, None], h[:, :-1]], 1)
                    m_out = self.mlp(params["mlp"], h, shifted=shifted)
                    new_cache["cmix_shift"] = h[:, -1]
                else:
                    m_out = self.mlp(params["mlp"], h)
            else:
                m_out = self.mlp(params["mlp"], h)
            if self.post_norms:
                m_out = n(params["post_norm_mlp"], m_out)
            x = x + m_out

        return x, aux, (new_cache if new_cache else None)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        c = {}
        if self.attention is not None:
            c["attn"] = self.attention.init_cache(batch, max_len, dtype)
        if self.ssm is not None:
            c["ssm"] = self.ssm.init_state(batch, dtype)
        if isinstance(self.mlp, RWKVChannelMix):
            c["cmix_shift"] = jnp.zeros((batch, self.d_model), dtype)
        return c
