"""Mixture-of-Experts with shard-local capacity dispatch + expert parallelism.

Dispatch strategy (all static shapes; sort/scatter provably shard-local):

  1. tokens are grouped into ``G`` dispatch groups matching the mesh's
     data-parallel shards (``G = pod x data``; 1 without a mesh);
  2. router top-k over ``E`` experts per token (plain SPMD einsum);
  3. the group-local work — stable-sort assignments by expert id, rank
     within expert via ``searchsorted``, scatter into a per-group
     ``(E, C_g, d)`` buffer with capacity dropping — runs inside a
     ``shard_map`` over the DP axes, so XLA lowers it as purely local
     sorts/gathers (GSPMD's gather partitioner otherwise replicates these
     at global token count, which is exactly the quadratic-ish blow-up this
     layer exists to avoid);
  4. expert FFN ``(G, E, C, d) x (E, d, f)`` back in SPMD-land: the buffer
     is sharded on its group dim (data) and constrained on its expert dim
     (model), so GSPMD inserts the dispatch all-to-all and the expert
     einsums run where the weights live;
  5. combine: a second shard_map gathers each group's expert outputs back
     to token order and applies router weights (the EP combine collective
     is the buffer's model-axis unshard at the shard_map boundary).

A shared-experts branch (deepseek/kimi) runs densely. Load-balance aux loss
follows Switch Transformer. Capacity semantics are GShard-style per
(group, expert) — the standard "dropping" strategy.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (active_mesh, dp_shard_count,
                                        logical_constraint)
from repro.nn.layers import ACTIVATIONS
from repro.nn.mlp import GatedMLP
from repro.nn.module import ParamSpec


def _dispatch_local(xt, eid, w, cap: int, num_experts: int):
    """Group-local dispatch. xt (Tg, d); eid/w (Tg, k).

    Returns buf (E, cap, d), and sorted (eid_s, tok_s, w_s, pos) each
    (Tg*k,) for the combine step."""
    tg, d = xt.shape
    k = eid.shape[-1]
    flat_eid = eid.reshape(tg * k)
    flat_tok = jnp.arange(tg * k, dtype=jnp.int32) // k
    flat_w = w.reshape(tg * k)
    order = jnp.argsort(flat_eid, stable=True)
    eid_s = flat_eid[order]
    tok_s = flat_tok[order]
    w_s = flat_w[order]
    first = jnp.searchsorted(eid_s, eid_s, side="left")
    pos = jnp.arange(tg * k, dtype=jnp.int32) - first.astype(jnp.int32)
    pos = jnp.where(pos < cap, pos, cap)                       # cap -> drop
    buf = jnp.zeros((num_experts, cap + 1, d), xt.dtype)
    buf = buf.at[eid_s, pos].set(xt[tok_s], mode="drop")
    return buf[:, :cap], eid_s, tok_s, w_s, pos


def _combine_local(eo, eid_s, tok_s, w_s, pos, cap: int, tg: int):
    """Group-local combine. eo (E, cap, d) -> y (Tg, d) float32."""
    d = eo.shape[-1]
    gathered = eo[eid_s, jnp.minimum(pos, cap - 1)]            # (Tg*k, d)
    valid = (pos < cap)[:, None]
    contrib = jnp.where(valid, gathered.astype(jnp.float32)
                        * w_s[:, None].astype(jnp.float32), 0.0)
    return jnp.zeros((tg, d), jnp.float32).at[tok_s].add(contrib)


@dataclasses.dataclass(frozen=True)
class MoE:
    d_model: int
    num_experts: int
    top_k: int
    expert_ff: int
    num_shared: int = 0
    capacity_factor: float = 1.25
    aux_weight: float = 0.01
    activation: str = "silu"
    routed_scale: float = 1.0

    def _shared(self) -> Optional[GatedMLP]:
        if self.num_shared == 0:
            return None
        return GatedMLP(self.d_model, self.num_shared * self.expert_ff,
                        self.activation)

    def specs(self):
        d, e, f = self.d_model, self.num_experts, self.expert_ff
        s = {
            "router": ParamSpec((d, e), init="normal", scale=0.006,
                                axes=("embed_no_fsdp", None)),
            "gate": ParamSpec((e, d, f), init="fan_in",
                              axes=("experts", "embed", "mlp")),
            "up": ParamSpec((e, d, f), init="fan_in",
                            axes=("experts", "embed", "mlp")),
            "down": ParamSpec((e, f, d), init="fan_in",
                              axes=("experts", "mlp", "embed")),
        }
        shared = self._shared()
        if shared is not None:
            s["shared"] = shared.specs()
        return s

    def capacity(self, tokens_per_group: int) -> int:
        cap = int(tokens_per_group * self.top_k * self.capacity_factor
                  / self.num_experts)
        return max(8, cap + (-cap) % 8)

    def __call__(self, params, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """x: (B, S, d). Returns (y, aux_loss)."""
        b, s, d = x.shape
        e, k = self.num_experts, self.top_k
        t = b * s
        mesh = active_mesh()
        groups = dp_shard_count()
        if t % groups != 0 or (b % groups != 0 and groups > 1):
            groups = 1
        tg = t // groups
        cap = self.capacity(tg)
        xt = x.reshape(groups, tg, d)
        xt = logical_constraint(xt, "act_tokens", None, None)

        logits = jnp.einsum(
            "gtd,de->gte", xt.astype(jnp.float32),
            params["router"].astype(jnp.float32))                # (G, Tg, E)
        probs = jax.nn.softmax(logits, axis=-1)
        top_logits, top_ids = jax.lax.top_k(logits, k)           # (G, Tg, k)
        weights = jax.nn.softmax(top_logits, axis=-1) * self.routed_scale

        # ---- aux load-balance loss (Switch-style) ----
        density = jnp.zeros((e,), jnp.float32).at[top_ids.reshape(-1)].add(
            1.0) / (t * k)
        mean_prob = probs.mean(axis=(0, 1))
        aux = self.aux_weight * e * jnp.sum(density * mean_prob)

        # ---- shard-local dispatch ----
        if mesh is not None and groups > 1:
            dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
            dspec = P(dp if len(dp) > 1 else dp[0])

            def disp(xt_l, eid_l, w_l):
                buf, eid_s, tok_s, w_s, pos = _dispatch_local(
                    xt_l[0], eid_l[0], w_l[0], cap, e)
                return (buf[None], eid_s[None], tok_s[None], w_s[None],
                        pos[None])

            buf, eid_s, tok_s, w_s, pos = shard_map(
                disp, mesh=mesh,
                in_specs=(dspec, dspec, dspec),
                out_specs=(dspec,) * 5,
                check_rep=False)(xt, top_ids, weights)
        else:
            buf, eid_s, tok_s, w_s, pos = jax.vmap(
                lambda a, b_, c: _dispatch_local(a, b_, c, cap, e))(
                    xt, top_ids, weights)
        expert_in = logical_constraint(buf, "act_tokens", "act_experts",
                                       None, None)               # (G, E, C, d)

        # ---- expert FFN (SPMD: data x experts sharding) ----
        act = ACTIVATIONS[self.activation]
        g = jnp.einsum("gecd,edf->gecf", expert_in,
                       params["gate"].astype(expert_in.dtype))
        u = jnp.einsum("gecd,edf->gecf", expert_in,
                       params["up"].astype(expert_in.dtype))
        h = act(g) * u
        h = logical_constraint(h, "act_tokens", "act_experts", None,
                               "act_mlp")
        eo = jnp.einsum("gecf,efd->gecd", h,
                        params["down"].astype(h.dtype))          # (G, E, C, d)
        eo = logical_constraint(eo, "act_tokens", "act_experts", None, None)

        # ---- shard-local combine ----
        if mesh is not None and groups > 1:
            def comb(eo_l, eid_l, tok_l, w_l, pos_l):
                y = _combine_local(eo_l[0], eid_l[0], tok_l[0], w_l[0],
                                   pos_l[0], cap, tg)
                return y[None]

            y = shard_map(comb, mesh=mesh,
                          in_specs=(dspec,) * 5, out_specs=dspec,
                          check_rep=False)(eo, eid_s, tok_s, w_s, pos)
        else:
            y = jax.vmap(lambda a, b_, c, dd, ee: _combine_local(
                a, b_, c, dd, ee, cap, tg))(eo, eid_s, tok_s, w_s, pos)
        y = logical_constraint(y, "act_tokens", None, None)

        shared = self._shared()
        if shared is not None:
            y = y + shared(params["shared"], xt).astype(jnp.float32)
        y = y.astype(x.dtype).reshape(b, s, d)
        return logical_constraint(y, "act_batch", "act_seq", "act_embed"), aux
