"""Attention layers: GQA/MQA/MHA with group-relative encodings, MLA, caches.

Two families:

  * :class:`Attention` — standard multi-head attention with grouped KV heads.
    Position information goes through a pluggable ``GroupEncoding`` (the
    paper's abstraction): ``rope1d`` for LMs, ``rope2d`` / ``se2_repr`` /
    ``se2_fourier`` for spatial models, ``absolute``/None for models that add
    position embeddings upstream (granite, whisper). Supports causal masks,
    sliding windows (gemma2 local layers, hymba), logit softcap (gemma2),
    partial-rotary (stablelm), and a decode KV cache.

  * :class:`MLAttention` — DeepSeek-style Multi-head Latent Attention:
    compressed KV latent + decoupled RoPE key. The decode path uses the
    *absorbed* formulation (queries projected into latent space), so the KV
    cache stays at ``kv_lora + rope_dim`` per token — the feature that makes
    deepseek-v2/kimi-k2 long-context serving cheap.

Shapes: activations ``(B, S, d_model)``; caches ``(B, Hkv, Smax, D)`` plus an
integer cursor handled by the caller (all cache slots are preallocated so
serve steps are shape-stable under jit).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.encodings import GroupEncoding, Rope1D
from repro.distributed.sharding import logical_constraint
from repro.kernels import ops as kops
from repro.kernels.flash_decode import (canonical_cache_dtype, dequantize_kv,
                                        quantize_kv)
from repro.nn.layers import Dense


def _split_heads(x, num_heads, head_dim):
    if x.ndim == 4:            # DenseGeneral already produced (B, S, H, D)
        return x.transpose(0, 2, 1, 3)
    b, s, _ = x.shape
    return x.reshape(b, s, num_heads, head_dim).transpose(0, 2, 1, 3)


def _merge_heads(x):
    """(B, H, S, D) -> (B, S, H, D); the output projection is a
    DenseGeneral contracting both head axes."""
    return x.transpose(0, 2, 1, 3)


def _apply_encoding(enc, transform, x, pose):
    """Apply an encoding transform to (B, H, S, D) given pose (B, S, P)."""
    return transform(x, pose[:, None, :, :])


def _cache_update(cache, new, index):
    """Write ``new`` (B, H, S, D) into ``cache`` at position ``index`` along
    the length axis. ``index`` may be a scalar (synchronized decode) or a
    per-row (B,) vector (continuous batching: per-slot cursors)."""
    new = new.astype(cache.dtype)
    if getattr(index, "ndim", 0) == 1:
        assert new.shape[2] == 1, "vector cursors require single-token steps"
        b = cache.shape[0]
        return cache.at[jnp.arange(b), :, index, :].set(new[:, :, 0, :])
    return jax.lax.dynamic_update_slice_in_dim(cache, new, index, axis=2)


@dataclasses.dataclass(frozen=True)
class Attention:
    d_model: int
    num_q_heads: int
    num_kv_heads: int
    head_dim: int
    encoding: Optional[GroupEncoding] = None
    rope_fraction: float = 1.0          # stablelm partial rotary
    causal: bool = True
    window: Optional[int] = None
    softcap: Optional[float] = None
    query_scale: Optional[float] = None  # gemma2 query_pre_attn_scalar
    use_bias: bool = False
    out_dim: Optional[int] = None
    impl: str = "chunked"

    def __post_init__(self):
        assert self.num_q_heads % self.num_kv_heads == 0

    @property
    def _odim(self):
        return self.out_dim or self.d_model

    def _projs(self):
        h, hk, hd, d = (self.num_q_heads, self.num_kv_heads, self.head_dim,
                        self.d_model)
        return {
            "q": Dense((d,), (h, hd), ("embed",), ("heads", "head_dim"),
                       use_bias=self.use_bias),
            "k": Dense((d,), (hk, hd), ("embed",), ("kv_heads", "head_dim"),
                       use_bias=self.use_bias),
            "v": Dense((d,), (hk, hd), ("embed",), ("kv_heads", "head_dim"),
                       use_bias=self.use_bias),
            "o": Dense((h, hd), (self._odim,), ("heads", "head_dim"),
                       ("embed",), use_bias=self.use_bias),
        }

    def specs(self):
        return {k: l.specs() for k, l in self._projs().items()}

    @property
    def _rot_dim(self):
        if self.encoding is None:
            return 0
        rd = int(self.head_dim * self.rope_fraction)
        return rd - rd % 2

    def _encode(self, q, k, pose):
        """Apply the group encoding to (possibly a fraction of) q/k."""
        enc = self.encoding
        if enc is None or pose is None:
            return q, k
        rd = self._rot_dim
        if rd == self.head_dim:
            q = _apply_encoding(enc, enc.transform_q, q, pose)
            k = _apply_encoding(enc, enc.transform_k, k, pose)
            return q, k
        qr = _apply_encoding(enc, enc.transform_q, q[..., :rd], pose)
        kr = _apply_encoding(enc, enc.transform_k, k[..., :rd], pose)
        return (jnp.concatenate([qr, q[..., rd:]], -1),
                jnp.concatenate([kr, k[..., rd:]], -1))

    def _scale(self):
        if self.query_scale is not None:
            return self.query_scale ** -0.5
        return 1.0 / float(self.head_dim) ** 0.5

    def __call__(self, params, x, pose=None, *, kv=None, segment_ids=None,
                 cache=None, cache_index=None, impl=None):
        """Returns (out, new_cache). ``pose``: (B, S, pose_dim) or (B, S)
        integer positions for rope1d. ``kv``: cross-attention source (keys/
        values projected from it instead of x). With a cache, x is the
        current chunk (usually S=1 decode) written at ``cache_index``."""
        impl = impl or self.impl
        projs = self._projs()
        kv_src = x if kv is None else kv
        if pose is not None and pose.ndim == 2:
            pose = pose[..., None].astype(jnp.float32)
        q = _split_heads(projs["q"](params["q"], x), self.num_q_heads,
                         self.head_dim)
        k = _split_heads(projs["k"](params["k"], kv_src), self.num_kv_heads,
                         self.head_dim)
        v = _split_heads(projs["v"](params["v"], kv_src), self.num_kv_heads,
                         self.head_dim)
        q = logical_constraint(q, "act_batch", "act_heads", "act_seq", None)
        k = logical_constraint(k, "act_batch", "act_kv", "act_seq", None)
        q, k = self._encode(q, k, pose)
        if (self.encoding is not None and self.encoding.transforms_values
                and pose is not None):
            v = _apply_encoding(self.encoding, self.encoding.transform_v, v,
                                pose)
        scale = self._scale()

        new_cache = None
        if cache is not None:
            if "k_scale" in cache:
                # int8 cache: quantize the new rows on write (per-row
                # scales beside the values), dequantize for the XLA
                # fallback attention below. The cache's HBM footprint is
                # what shrinks; the rollout-path Pallas decode kernel
                # (repro.kernels.flash_decode) dequantizes per-tile in
                # VMEM instead of materializing the cache in f32.
                kq, ks = quantize_kv(k)
                vq, vs = quantize_kv(v)
                ck = _cache_update(cache["k"], kq, cache_index)
                cv = _cache_update(cache["v"], vq, cache_index)
                cks = _cache_update(cache["k_scale"][..., None],
                                    ks[..., None], cache_index)[..., 0]
                cvs = _cache_update(cache["v_scale"][..., None],
                                    vs[..., None], cache_index)[..., 0]
                new_cache = {"k": ck, "v": cv, "k_scale": cks,
                             "v_scale": cvs}
                ck = dequantize_kv(ck, cks, dtype=q.dtype)
                cv = dequantize_kv(cv, cvs, dtype=q.dtype)
            else:
                ck = _cache_update(cache["k"], k, cache_index)
                cv = _cache_update(cache["v"], v, cache_index)
                new_cache = {"k": ck, "v": cv}
            out = kops.attention(
                q, ck, cv, impl="chunked" if impl == "flash" else impl,
                causal=self.causal, window=self.window, softcap=self.softcap,
                scale=scale, q_offset=cache_index)
        else:
            out = kops.attention(
                q, k, v, impl=impl, causal=self.causal, window=self.window,
                softcap=self.softcap, scale=scale,
                q_segment_ids=segment_ids, k_segment_ids=segment_ids)
        if (self.encoding is not None and self.encoding.transforms_values
                and pose is not None):
            out = _apply_encoding(self.encoding, self.encoding.untransform_out,
                                  out, pose)
        out = logical_constraint(out, "act_batch", "act_heads", "act_seq", None)
        y = projs["o"](params["o"], _merge_heads(out))
        return logical_constraint(y, "act_batch", "act_seq", "act_embed"), new_cache

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        """``dtype``: jnp dtype or "float32"/"bfloat16"/"int8". int8
        caches store per-(head, token) float32 scales beside K/V
        (quantize-on-write; see ``repro.kernels.flash_decode``)."""
        dtype = canonical_cache_dtype(dtype, default=jnp.bfloat16)
        hd = self.head_dim
        # cache stores encoded keys; for dim-preserving encodings hd is right
        if self.encoding is not None and self.encoding.transforms_values:
            raise NotImplementedError(
                "KV cache with value-transforming encodings")
        cache = {
            "k": jnp.zeros((batch, self.num_kv_heads, max_len, hd), dtype),
            "v": jnp.zeros((batch, self.num_kv_heads, max_len, hd), dtype),
        }
        if dtype == jnp.int8:
            cache["k_scale"] = jnp.zeros(
                (batch, self.num_kv_heads, max_len), jnp.float32)
            cache["v_scale"] = jnp.zeros(
                (batch, self.num_kv_heads, max_len), jnp.float32)
        return cache


@dataclasses.dataclass(frozen=True)
class MLAttention:
    """Multi-head Latent Attention (deepseek-v2 family)."""

    d_model: int
    num_heads: int
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: Optional[int] = None
    rope_base: float = 10000.0
    causal: bool = True
    impl: str = "chunked"

    @property
    def qk_dim(self):
        return self.qk_nope_dim + self.qk_rope_dim

    def _rope(self):
        return Rope1D(head_dim=self.qk_rope_dim, base=self.rope_base)

    def _projs(self):
        d, h = self.d_model, self.num_heads
        dn, dr, dv, r = (self.qk_nope_dim, self.qk_rope_dim, self.v_head_dim,
                         self.kv_lora_rank)
        p = {}
        if self.q_lora_rank:
            p["q_down"] = Dense((d,), (self.q_lora_rank,), ("embed",),
                                ("kv_lora",))
            p["q_up"] = Dense((self.q_lora_rank,), (h, dn + dr), ("kv_lora",),
                              ("heads", "head_dim"))
        else:
            p["q"] = Dense((d,), (h, dn + dr), ("embed",),
                           ("heads", "head_dim"))
        p["kv_down"] = Dense((d,), (r,), ("embed",), ("kv_lora",))
        p["k_rope"] = Dense((d,), (dr,), ("embed",), ("head_dim",))
        p["k_up"] = Dense((r,), (h, dn), ("kv_lora",), ("heads", "head_dim"))
        p["v_up"] = Dense((r,), (h, dv), ("kv_lora",), ("heads", "head_dim"))
        p["o"] = Dense((h, dv), (d,), ("heads", "head_dim"), ("embed",))
        return p

    def specs(self):
        s = {k: l.specs() for k, l in self._projs().items()}
        from repro.nn.layers import RMSNorm
        s["kv_norm"] = RMSNorm(self.kv_lora_rank).specs()
        return s

    def _queries(self, params, projs, x):
        b, s, _ = x.shape
        if self.q_lora_rank:
            ql = projs["q_down"](params["q_down"], x)
            q = projs["q_up"](params["q_up"], ql)
        else:
            q = projs["q"](params["q"], x)
        return q.transpose(0, 2, 1, 3)  # (B, H, S, dn+dr)

    def _latent(self, params, projs, x):
        from repro.nn.layers import RMSNorm
        ckv = projs["kv_down"](params["kv_down"], x)          # (B, S, r)
        ckv = RMSNorm(self.kv_lora_rank)(params["kv_norm"], ckv)
        kr = projs["k_rope"](params["k_rope"], x)             # (B, S, dr)
        return ckv, kr

    def __call__(self, params, x, pose=None, *, segment_ids=None, cache=None,
                 cache_index=None, impl=None):
        impl = impl or self.impl
        projs = self._projs()
        rope = self._rope()
        b, s, _ = x.shape
        if pose is None:
            pose = jnp.arange(s, dtype=jnp.float32)[None, :].repeat(b, 0)
        if pose.ndim == 2:
            pose = pose[..., None].astype(jnp.float32)
        q = self._queries(params, projs, x)
        qn, qr = q[..., :self.qk_nope_dim], q[..., self.qk_nope_dim:]
        qr = rope.transform_q(qr, pose[:, None, :, :])
        ckv, kr = self._latent(params, projs, x)
        kr = rope.transform_k(kr[:, None], pose[:, None, :, :])  # (B,1,S,dr)

        if cache is not None:
            # Absorbed decode: score = qn W_uk . ckv + qr . kr over the cache.
            cc = _cache_update(cache["ckv"], ckv[:, None], cache_index)
            ckr = _cache_update(cache["kr"], kr, cache_index)
            new_cache = {"ckv": cc, "kr": ckr}
            wk = params["k_up"]["kernel"].astype(x.dtype)   # (r, H, dn)
            q_lat = jnp.einsum("bhsd,rhd->bhsr", qn, wk)    # (B,H,S,r)
            q_full = jnp.concatenate([q_lat, qr], -1)       # (B,H,S,r+dr)
            k_full = jnp.concatenate([cc, ckr], -1)         # (B,1,Smax,r+dr)
            scale = 1.0 / float(self.qk_dim) ** 0.5
            o_lat = kops.attention(q_full, k_full, cc, impl="chunked",
                                   causal=self.causal, scale=scale,
                                   q_offset=cache_index)    # (B,H,S,r)
            wv = params["v_up"]["kernel"].astype(x.dtype)   # (r, H, dv)
            out = jnp.einsum("bhsr,rhd->bhsd", o_lat, wv)
            y = projs["o"](params["o"], _merge_heads(out))
            return y, new_cache

        kn = projs["k_up"](params["k_up"], ckv).transpose(0, 2, 1, 3)
        v = projs["v_up"](params["v_up"], ckv).transpose(0, 2, 1, 3)
        k = jnp.concatenate(
            [kn, jnp.broadcast_to(kr, kn.shape[:3] + (self.qk_rope_dim,))], -1)
        qf = jnp.concatenate([qn, qr], -1)
        qf = logical_constraint(qf, "act_batch", "act_heads", "act_seq", None)
        scale = 1.0 / float(self.qk_dim) ** 0.5
        out = kops.attention(qf, k, v, impl=impl, causal=self.causal,
                             scale=scale, q_segment_ids=segment_ids,
                             k_segment_ids=segment_ids)
        y = projs["o"](params["o"], _merge_heads(out))
        return logical_constraint(y, "act_batch", "act_seq", "act_embed"), None

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        return {
            "ckv": jnp.zeros((batch, 1, max_len, self.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, 1, max_len, self.qk_rope_dim), dtype),
        }
