"""State-space sequence mixers: Mamba-style selective SSM (hymba's parallel
branch) and RWKV-6 "Finch" time mixing with data-dependent decay.

Both are implemented with *chunked* scans: sequential ``lax.scan`` over
chunks with parallel (associative-scan / matmul) work inside each chunk.
This keeps the sequential depth at ``T / chunk`` while bounding the
materialized per-chunk state — the TPU-friendly middle ground between a
step-by-step scan (sequential-bound) and a full associative scan over T
(memory-bound at long context).

Numerical-stability notes for RWKV-6: all decay exponentials appear only as
``exp(sum of log w over (s, t])`` with ``log w <= 0``, i.e. always <= 1 —
computed via pairwise differences of the within-chunk cumulative log-decay
(never ``exp(-cumsum)`` alone, which overflows). ``log w`` is clamped to
``>= -6`` per step; with chunk=16 the worst pairwise exponent magnitude is
96 < log(f32 max).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import logical_constraint
from repro.nn.layers import Dense
from repro.nn.module import ParamSpec


def diag_ssm_scan(a, b, h0, chunk: int = 128):
    """h_t = a_t * h_{t-1} + b_t for diagonal SSMs.

    a, b: ``(B, T, ...)``; h0 ``(B, ...)``. Returns (h_all ``(B, T, ...)``,
    h_last). Chunked: sequential over T/chunk, associative within a chunk.
    """
    btshape = a.shape
    t = btshape[1]
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    def combine(x, y):
        (a1, b1), (a2, b2) = x, y
        return a1 * a2, b2 + a2 * b1

    def body(h, ab):
        ac, bc = ab  # (chunk, B, ...)
        aa, bb = jax.lax.associative_scan(combine, (ac, bc), axis=0)
        h_all = aa * h[None] + bb
        return h_all[-1], h_all

    a_c = jnp.moveaxis(a, 1, 0).reshape((n_chunks, chunk) + a.shape[:1]
                                        + a.shape[2:])
    b_c = jnp.moveaxis(b, 1, 0).reshape((n_chunks, chunk) + b.shape[:1]
                                        + b.shape[2:])
    h_last, hs = jax.lax.scan(body, h0, (a_c, b_c))
    hs = jnp.moveaxis(hs.reshape((t,) + a.shape[:1] + a.shape[2:]), 0, 1)
    return hs, h_last


def selective_ssm_fused(dt, bmat, cmat, xc, a_diag, h0, chunk: int = 128):
    """Fully fused selective-SSM: discretization + scan + output projection
    per chunk, with a remat'd body.

    The naive formulation materializes da/db/h_all at (B, T, d, N) — 16x the
    residual stream, in f32: what blew hymba's train cell to 310 GiB/chip
    (§Perf it. 7). Here the (chunk, B, d, N) tensors exist only inside one
    chunk iteration, forward AND backward (``jax.checkpoint`` on the body
    recomputes them from the (B, d, N) chunk-entry state in the bwd pass).

    dt (B,T,d) f32; bmat/cmat (B,T,N) f32; xc (B,T,d); a_diag (d,N) < 0.
    Returns y (B,T,d) f32, h_last (B,d,N).
    """
    t = dt.shape[1]
    assert t % chunk == 0, (t, chunk)
    n_chunks = t // chunk

    def combine(x, y):
        (a1, b1), (a2, b2) = x, y
        return a1 * a2, b2 + a2 * b1

    @jax.checkpoint
    def body(h, inputs):
        dtc, bc, cc, xcc = inputs              # (chunk, B, d) / (chunk, B, N)
        da = jnp.exp(dtc[..., None] * a_diag)             # (chunk, B, d, N)
        db = dtc[..., None] * bc[:, :, None, :] *             xcc.astype(jnp.float32)[..., None]
        aa, bb = jax.lax.associative_scan(combine, (da, db), axis=0)
        h_all = aa * h[None] + bb
        y = jnp.einsum("tbdn,tbn->tbd", h_all, cc)
        return h_all[-1], y

    resh = lambda z: jnp.moveaxis(z, 1, 0).reshape(
        (n_chunks, chunk) + z.shape[:1] + z.shape[2:])
    h_last, ys = jax.lax.scan(body, h0, (resh(dt), resh(bmat), resh(cmat),
                                         resh(xc)))
    y = jnp.moveaxis(ys.reshape((t,) + dt.shape[:1] + dt.shape[2:]), 0, 1)
    return y, h_last


@dataclasses.dataclass(frozen=True)
class MambaMixer:
    """Selective state-space mixer (Mamba-1 style, diagonal A)."""

    d_model: int
    d_inner: Optional[int] = None
    state_size: int = 16
    conv_width: int = 4
    dt_rank: Optional[int] = None
    chunk: int = 128

    @property
    def _di(self):
        return self.d_inner or 2 * self.d_model

    @property
    def _dtr(self):
        return self.dt_rank or max(16, self.d_model // 16)

    def specs(self):
        d, di, n, r = self.d_model, self._di, self.state_size, self._dtr
        return {
            "in_proj": Dense((d,), (2 * di,), ("embed",), ("mlp",)).specs(),
            "conv": ParamSpec((self.conv_width, di), init="fan_in",
                              axes=("conv", "mlp")),
            "conv_bias": ParamSpec((di,), init="zeros", axes=("mlp",)),
            "x_dt": Dense((di,), (r,), ("mlp",), (None,)).specs(),
            "dt_proj": Dense((r,), (di,), (None,), ("mlp",),
                             use_bias=True).specs(),
            "x_bc": Dense((di,), (2 * n,), ("mlp",), ("state",)).specs(),
            "a_log": ParamSpec((di, n), init="zeros", axes=("mlp", "state")),
            "d_skip": ParamSpec((di,), init="ones", axes=("mlp",)),
            "out_proj": Dense((di,), (d,), ("mlp",), ("embed",)).specs(),
        }

    def _conv(self, params, x, state=None):
        """Causal depthwise conv. x (B, T, di); state (B, W-1, di) or None."""
        w = params["conv"].astype(x.dtype)                  # (W, di)
        if state is None:
            pad = jnp.zeros((x.shape[0], self.conv_width - 1, x.shape[2]),
                            x.dtype)
        else:
            pad = state.astype(x.dtype)
        xp = jnp.concatenate([pad, x], axis=1)              # (B, T+W-1, di)
        out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(self.conv_width))
        new_state = xp[:, -(self.conv_width - 1):]
        return out + params["conv_bias"].astype(x.dtype), new_state

    def _ssm_inputs(self, params, xc):
        di, n = self._di, self.state_size
        dt = Dense((di,), (self._dtr,), ("mlp",), (None,))(params["x_dt"], xc)
        dt = Dense((self._dtr,), (di,), (None,), ("mlp",), use_bias=True)(
            params["dt_proj"], dt)
        dt = jax.nn.softplus(dt.astype(jnp.float32))        # (B, T, di)
        bc = Dense((di,), (2 * n,), ("mlp",), ("state",))(params["x_bc"], xc)
        bmat, cmat = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
        a = -jnp.exp(params["a_log"].astype(jnp.float32))   # (di, n), < 0
        return dt, bmat, cmat, a

    def __call__(self, params, x, state=None):
        """x (B, T, d). state: None (train) or dict(h, conv) for decode.
        Returns (y, new_state)."""
        di, n = self._di, self.state_size
        xz = Dense((self.d_model,), (2 * di,), ("embed",), ("mlp",))(
            params["in_proj"], x)
        xi, z = jnp.split(xz, 2, axis=-1)
        conv_state = None if state is None else state["conv"]
        xc, new_conv = self._conv(params, xi, conv_state)
        xc = jax.nn.silu(xc)
        dt, bmat, cmat, a = self._ssm_inputs(params, xc)
        h0 = (jnp.zeros((x.shape[0], di, n), jnp.float32) if state is None
              else state["h"])
        if x.shape[1] == 1:  # decode fast path
            da = jnp.exp(dt[:, 0, :, None] * a)
            db = dt[:, 0, :, None] * bmat[:, 0, None, :] * \
                xc.astype(jnp.float32)[:, 0, :, None]
            h_last = da * h0 + db
            y = jnp.einsum("bdn,bn->bd", h_last, cmat[:, 0])[:, None]
        else:
            chunk = min(self.chunk, x.shape[1])
            y, h_last = selective_ssm_fused(dt, bmat, cmat, xc, a, h0,
                                            chunk=chunk)
        y = y + xc.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)
        y = (y.astype(x.dtype)) * jax.nn.silu(z)
        out = Dense((di,), (self.d_model,), ("mlp",), ("embed",))(
            params["out_proj"], y)
        return out, {"h": h_last, "conv": new_conv}

    def init_state(self, batch: int, dtype=jnp.float32):
        return {"h": jnp.zeros((batch, self._di, self.state_size), jnp.float32),
                "conv": jnp.zeros((batch, self.conv_width - 1, self._di),
                                  dtype)}


@dataclasses.dataclass(frozen=True)
class RWKV6TimeMix:
    """RWKV-6 time mixing: data-dependent per-channel decay (Finch)."""

    d_model: int
    head_dim: int = 64
    decay_lora: int = 64
    chunk: int = 16
    min_log_w: float = -6.0

    @property
    def num_heads(self):
        assert self.d_model % self.head_dim == 0
        return self.d_model // self.head_dim

    def specs(self):
        d = self.d_model
        mix = lambda: ParamSpec((d,), init="uniform", scale=0.5,
                                axes=("embed_no_fsdp",))
        return {
            "mix_r": mix(), "mix_k": mix(), "mix_v": mix(), "mix_w": mix(),
            "mix_g": mix(),
            "receptance": Dense((d,), (d,), ("embed",), ("heads",)).specs(),
            "key": Dense((d,), (d,), ("embed",), ("heads",)).specs(),
            "value": Dense((d,), (d,), ("embed",), ("heads",)).specs(),
            "gate": Dense((d,), (d,), ("embed",), ("heads",)).specs(),
            "output": Dense((d,), (d,), ("heads",), ("embed",)).specs(),
            "w0": ParamSpec((d,), init="uniform", scale=1.0,
                            axes=("embed_no_fsdp",)),
            "w_lora_a": Dense((d,), (self.decay_lora,), ("embed",),
                              (None,)).specs(),
            "w_lora_b": Dense((self.decay_lora,), (d,), (None,),
                              ("heads",)).specs(),
            "bonus": ParamSpec((d,), init="uniform", scale=0.5,
                               axes=("embed_no_fsdp",)),
            "ln_scale": ParamSpec((d,), init="ones", axes=("embed_no_fsdp",)),
            "ln_bias": ParamSpec((d,), init="zeros", axes=("embed_no_fsdp",)),
        }

    def _proj(self, params, name, x):
        d = self.d_model
        out_ax = ("embed",) if name == "output" else ("heads",)
        in_ax = ("heads",) if name == "output" else ("embed",)
        return Dense((d,), (d,), in_ax, out_ax)(params[name], x)

    def _mixed_inputs(self, params, x, shifted):
        mix = lambda name: x + (shifted - x) * params[name].astype(x.dtype)
        xr, xk, xv, xw, xg = (mix("mix_r"), mix("mix_k"), mix("mix_v"),
                              mix("mix_w"), mix("mix_g"))
        b, t, d = x.shape
        h, n = self.num_heads, self.head_dim
        r = self._proj(params, "receptance", xr).reshape(b, t, h, n)
        k = self._proj(params, "key", xk).reshape(b, t, h, n)
        v = self._proj(params, "value", xv).reshape(b, t, h, n)
        g = jax.nn.silu(self._proj(params, "gate", xg))
        wl = Dense((d,), (self.decay_lora,), ("embed",), (None,))(
            params["w_lora_a"], jnp.tanh(xw))
        wl = Dense((self.decay_lora,), (d,), (None,), ("heads",))(
            params["w_lora_b"], wl)
        log_w = -jnp.exp(
            jnp.clip((params["w0"].astype(jnp.float32) + wl.astype(jnp.float32)),
                     -10.0, 1.8))
        log_w = jnp.clip(log_w, self.min_log_w, -1e-5).reshape(b, t, h, n)
        return r, k, v, g, log_w

    def _wkv_chunk(self, s0, rkvw):
        """One chunk of the WKV recurrence. s0 (B,H,N,N); r/k/v/lw (B,L,H,N)."""
        r, k, v, lw, u = rkvw
        b, L, h, n = r.shape
        la = jnp.cumsum(lw, axis=1)                      # inclusive (B,L,H,N)
        la_excl = la - lw
        rf = r.astype(jnp.float32)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        # inter-chunk: r_t decayed to chunk start, applied to s0
        r_dec = rf * jnp.exp(la_excl)
        y = jnp.einsum("blhn,bhnm->blhm", r_dec, s0)
        # intra-chunk strictly-lower-triangular attention with decay
        expo = la_excl[:, :, None] - la[:, None, :]      # (B, L, S, H, N)
        tri = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :])
        expo = jnp.where(tri[None, :, :, None, None], expo, -jnp.inf)
        scores = jnp.einsum("blhn,bshn,blshn->blsh", rf, kf,
                            jnp.exp(expo))
        y = y + jnp.einsum("blsh,bshm->blhm", scores, vf)
        # diagonal bonus term
        c = jnp.sum(rf * u * kf, axis=-1)                # (B, L, H)
        y = y + c[..., None] * vf
        # state update to chunk end
        decay_out = jnp.exp(la[:, -1])                   # (B, H, N)
        k_dec = kf * jnp.exp(la[:, -1:] - la)            # (B, L, H, N)
        s_new = s0 * decay_out[..., None] + jnp.einsum(
            "blhn,blhm->bhnm", k_dec, vf)
        return s_new, y

    def __call__(self, params, x, state=None):
        """x (B, T, d); state dict(s, shift) for decode. Returns (y, state)."""
        b, t, d = x.shape
        h, n = self.num_heads, self.head_dim
        if state is None:
            shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
            s0 = jnp.zeros((b, h, n, n), jnp.float32)
        else:
            shifted = jnp.concatenate([state["shift"][:, None], x[:, :-1]], 1)
            s0 = state["s"]
        r, k, v, g, lw = self._mixed_inputs(params, x, shifted)
        u = params["bonus"].astype(jnp.float32).reshape(h, n)

        if t == 1:
            rf, kf, vf = (z[:, 0].astype(jnp.float32) for z in (r, k, v))
            y1 = jnp.einsum("bhn,bhnm->bhm", rf, s0)
            y1 = y1 + jnp.sum(rf * u * kf, -1)[..., None] * vf
            s_new = s0 * jnp.exp(lw[:, 0])[..., None] + jnp.einsum(
                "bhn,bhm->bhnm", kf, vf)
            y = y1[:, None]
        else:
            chunk = min(self.chunk, t)
            assert t % chunk == 0, (t, chunk)
            nc = t // chunk
            resh = lambda z: jnp.moveaxis(
                z.reshape(b, nc, chunk, h, n), 1, 0)

            def body(s, inputs):
                rc, kc, vc, lwc = inputs
                s_new, y = self._wkv_chunk(s, (rc, kc, vc, lwc, u))
                return s_new, y

            s_new, ys = jax.lax.scan(body, s0, (resh(r), resh(k), resh(v),
                                                resh(lw)))
            y = jnp.moveaxis(ys, 0, 1).reshape(b, t, h, n)

        # per-head group norm
        y32 = y.reshape(b, -1, h, n).astype(jnp.float32)
        mu = y32.mean(-1, keepdims=True)
        var = y32.var(-1, keepdims=True)
        y32 = (y32 - mu) * jax.lax.rsqrt(var + 64e-5)
        yn = y32.reshape(b, -1, d) * params["ln_scale"].astype(jnp.float32) \
            + params["ln_bias"].astype(jnp.float32)
        yn = (yn.astype(x.dtype) * g)
        out = self._proj(params, "output", yn)
        new_state = {"s": s_new, "shift": x[:, -1]}
        return logical_constraint(out, "act_batch", "act_seq", "act_embed"), new_state

    def init_state(self, batch: int, dtype=jnp.float32):
        h, n = self.num_heads, self.head_dim
        return {"s": jnp.zeros((batch, h, n, n), jnp.float32),
                "shift": jnp.zeros((batch, self.d_model), dtype)}
