"""Minimal functional parameter system (flax-free, dry-run-first).

Models are plain Python objects that expose

  * ``specs() -> dict``  — a nested dict of :class:`ParamSpec` leaves
    describing every parameter: shape, dtype, initializer, and *logical
    axis names* used by the sharding layer.
  * ``apply(params, ...)`` / ``__call__`` — pure functions of a parameter
    pytree with the same structure.

From one spec tree we derive everything the framework needs without ever
materializing weights:

  * ``init_params(specs, key)``      — real arrays (deterministic per path).
  * ``abstract_params(specs)``       — ShapeDtypeStructs for AOT lowering
    (the multi-pod dry-run compiles trillion-parameter configs this way).
  * ``sharding_for_specs`` (distributed.sharding) — NamedSharding tree.
  * ``count_params(specs)``          — exact parameter counts for roofline
    MODEL_FLOPS = 6 * N * D accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # normal | zeros | ones | fan_in | uniform
    scale: float = 0.02           # stddev for normal, bound for uniform
    axes: Tuple[Optional[str], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(s) for s in self.shape))
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} vs shape {self.shape}")

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(spec.dtype)
    if spec.init == "fan_in":
        fan_in = spec.shape[0] if len(spec.shape) >= 1 else 1
        if len(spec.shape) >= 2:
            fan_in = int(np.prod(spec.shape[:-1]))
        std = (1.0 / max(fan_in, 1)) ** 0.5
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    if spec.init == "uniform":
        return jax.random.uniform(key, spec.shape, minval=-spec.scale,
                                  maxval=spec.scale).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def _walk(tree, path=()):
    if is_spec(tree):
        yield path, tree
        return
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk(tree[k], path + (k,))
        return
    raise TypeError(f"spec trees are nested dicts of ParamSpec; got "
                    f"{type(tree)} at {'/'.join(map(str, path))}")


def init_params(spec_tree, key):
    """Materialize a spec tree; each leaf key is derived from its path, so
    adding/removing siblings never reshuffles other parameters."""
    def build(tree, path=()):
        if is_spec(tree):
            leaf_key = jax.random.fold_in(
                key, zlib_crc32("/".join(map(str, path))))
            return _init_leaf(tree, leaf_key)
        return {k: build(v, path + (k,)) for k, v in tree.items()}

    return build(spec_tree)


def zlib_crc32(s: str) -> int:
    import zlib
    return zlib.crc32(s.encode()) & 0x7FFFFFFF


def abstract_params(spec_tree):
    """ShapeDtypeStruct tree — the dry-run stand-in for real weights."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree,
        is_leaf=is_spec)


def param_axes(spec_tree):
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=is_spec)


def count_params(spec_tree) -> int:
    return sum(s.size for _, s in _walk(spec_tree))


def stack_specs(spec_tree, num: int, axis_name: str = "layers"):
    """Prepend a stacking dimension (for scan-over-layers parameters)."""
    def one(s: ParamSpec) -> ParamSpec:
        return ParamSpec(shape=(num,) + s.shape, dtype=s.dtype, init=s.init,
                         scale=s.scale, axes=(axis_name,) + tuple(s.axes))

    return jax.tree.map(one, spec_tree, is_leaf=is_spec)


def cast_params(params, dtype):
    """Cast floating-point leaves (compute-dtype entry into the model)."""
    def one(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(one, params)
