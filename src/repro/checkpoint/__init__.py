"""Checkpoint substrate: atomic/async/keep-k manager with elastic restore."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
