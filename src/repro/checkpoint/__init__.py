"""Checkpoint substrate: atomic/async/keep-k manager with verified
(CRC32 + fallback) elastic restore."""
from repro.checkpoint.manager import CheckpointManager, CheckpointWriteError

__all__ = ["CheckpointManager", "CheckpointWriteError"]
