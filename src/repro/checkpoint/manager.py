"""Checkpointing: atomic, async, keep-last-k, elastic-restore.

Orbax-free implementation on npz shards + a JSON manifest:

  * **atomic**  — written to ``step_<n>.tmp`` then ``os.replace``d into
    place; a crash mid-write never corrupts the latest checkpoint.
  * **async**   — ``save`` snapshots the (host) arrays and hands the disk
    I/O to a background thread; the train loop only blocks if a previous
    save is still in flight (one outstanding save, like Orbax).
  * **elastic** — arrays are stored unsharded (gathered); ``restore`` takes
    an optional sharding tree and puts each leaf onto the *current* mesh,
    so restoring onto a different topology (scale up/down) just works.
    At real multi-pod scale the same manifest format would hold per-shard
    files keyed by PartitionSpec; the gather/scatter boundary is isolated
    in ``_to_host`` / ``_from_host``.
  * **self-describing** — the manifest stores the flattened key paths, so
    restore validates structure and reports missing/unexpected keys.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        if not tree:
            return {"/".join(path + ("__empty_dict__",)): np.zeros(0)}
        out = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], path + (str(k),)))
        return out
    if isinstance(tree, (tuple, list)):
        if not tree:
            return {"/".join(path + ("__empty_tuple__",)): np.zeros(0)}
        out = {}
        for i, v in enumerate(tree):
            out.update(_flatten(v, path + (f"#{i}",)))
        return out
    return {"/".join(path): tree}


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict) and set(node) == {"__empty_tuple__"}:
            return ()
        if isinstance(node, dict) and set(node) == {"__empty_dict__"}:
            return {}
        if isinstance(node, dict) and node and all(
                k.startswith("#") for k in node):
            return tuple(fix(node[f"#{i}"]) for i in range(len(node)))
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                manifest = os.path.join(self.directory, name, "manifest.json")
                if os.path.exists(manifest):
                    steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None):
        """Snapshot to host memory now, write to disk (a)synchronously."""
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        self.wait()

        def write():
            tmp = self._step_dir(step) + ".tmp"
            final = self._step_dir(step)
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step,
                "keys": sorted(host),
                "time": time.time(),
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if self.async_save:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, n,
                                            "manifest.json")))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, step: Optional[int] = None, shardings=None,
                strict: bool = True):
        """Returns (tree, extra). ``shardings``: optional matching tree of
        NamedShardings — leaves are device_put onto the current mesh
        (elastic restore)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        if strict and sorted(flat) != manifest["keys"]:
            raise IOError(f"checkpoint {d} corrupt: key mismatch")
        tree = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            flat_t = _flatten(tree)
            if strict and set(flat_s) != set(flat_t):
                missing = set(flat_s) ^ set(flat_t)
                raise IOError(f"structure mismatch on restore: {sorted(missing)[:5]}")
            put = {k: jax.device_put(flat_t[k], flat_s[k]) for k in flat_t}
            tree = _unflatten(put)
        return tree, manifest.get("extra", {})
