"""Checkpointing: atomic, async, keep-last-k, elastic-restore, verified.

Orbax-free implementation on npz shards + a JSON manifest:

  * **atomic**  — written to ``step_<n>.tmp`` then ``os.replace``d into
    place; a crash mid-write never corrupts the latest checkpoint, and
    any ``*.tmp`` debris such a crash leaves behind is swept at startup.
  * **async**   — ``save`` snapshots the (host) arrays and hands the disk
    I/O to a background thread; the train loop only blocks if a previous
    save is still in flight (one outstanding save, like Orbax). A write
    that fails is retried with backoff (transient IO), and a save that
    dies anyway is **captured and re-raised** at the next ``wait()`` /
    ``save()`` instead of evaporating in the daemon thread.
  * **verified** — the manifest carries a CRC32 per stored array;
    ``verify`` recomputes them (plus structural checks) and ``restore``
    with ``fallback=True`` walks back to the newest checkpoint that
    passes, reporting every step it skipped and why. A truncated or
    bit-rotted latest checkpoint costs ``ckpt_every`` steps of rework,
    not the run.
  * **elastic** — arrays are stored unsharded (gathered); ``restore`` takes
    an optional sharding tree and puts each leaf onto the *current* mesh,
    so restoring onto a different topology (scale up/down) just works.
    At real multi-pod scale the same manifest format would hold per-shard
    files keyed by PartitionSpec; the gather/scatter boundary is isolated
    in ``_to_host`` / ``_from_host``.
  * **self-describing** — the manifest stores the flattened key paths, so
    restore validates structure and reports missing/unexpected keys.

The failure drills for all of this live in ``repro.chaos`` +
``python -m repro.launch.chaos``; ``docs/robustness.md`` states the
contracts.
"""
from __future__ import annotations

import json
import logging
import os
import shutil
import threading
import time
import zlib
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

log = logging.getLogger("repro.checkpoint")


class CheckpointWriteError(IOError):
    """An async save failed after its bounded retries; re-raised on the
    training thread at the next ``wait()`` or ``save()``."""


def _flatten(tree, path=()):
    if isinstance(tree, dict):
        if not tree:
            return {"/".join(path + ("__empty_dict__",)): np.zeros(0)}
        out = {}
        for k in sorted(tree):
            out.update(_flatten(tree[k], path + (str(k),)))
        return out
    if isinstance(tree, (tuple, list)):
        if not tree:
            return {"/".join(path + ("__empty_tuple__",)): np.zeros(0)}
        out = {}
        for i, v in enumerate(tree):
            out.update(_flatten(v, path + (f"#{i}",)))
        return out
    return {"/".join(path): tree}


def _unflatten(flat: Dict[str, Any]):
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict) and set(node) == {"__empty_tuple__"}:
            return ()
        if isinstance(node, dict) and set(node) == {"__empty_dict__"}:
            return {}
        if isinstance(node, dict) and node and all(
                k.startswith("#") for k in node):
            return tuple(fix(node[f"#{i}"]) for i in range(len(node)))
        if isinstance(node, dict):
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True,
                 *, save_retries: int = 2, retry_backoff: float = 0.05,
                 io_hook: Optional[Callable[[int, int], None]] = None):
        """``save_retries``: extra write attempts after a failed one
        (``OSError``), with exponential backoff ``retry_backoff * 2**i``
        seconds between attempts. ``io_hook(step, attempt)``: called at
        the start of every write attempt — the fault-injection seam
        (``repro.chaos.checkpoint_io_hook``); an exception it raises is
        indistinguishable from a real IO failure."""
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self.save_retries = int(save_retries)
        self.retry_backoff = float(retry_backoff)
        self.io_hook = io_hook
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None
        self._save_error: Optional[BaseException] = None
        #: filled by every ``restore(fallback=True)``: the step restored
        #: plus the corrupt steps walked over, each with its reason
        self.last_restore_report: Dict[str, Any] = {}
        self._cleanup_stale_tmp()

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def _cleanup_stale_tmp(self):
        """Sweep ``*.tmp`` debris left by a writer that died mid-save (or
        mid-GC). Their content is by construction incomplete — the final
        rename never ran — so deleting them can only reclaim space."""
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                path = os.path.join(self.directory, name)
                log.warning("removing stale checkpoint temp %s", path)
                if os.path.isdir(path):
                    shutil.rmtree(path, ignore_errors=True)
                else:
                    try:
                        os.remove(path)
                    except OSError:
                        pass

    def available_steps(self) -> List[int]:
        """Steps with a manifest-complete directory, ascending (no
        content verification — see :meth:`verify`)."""
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                manifest = os.path.join(self.directory, name, "manifest.json")
                if os.path.exists(manifest):
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return max(steps) if steps else None

    def _candidate_steps(self) -> List[int]:
        """Every non-tmp step directory, even manifest-less ones — the
        fallback walk must *report* a checkpoint whose manifest was lost,
        not pretend the step never existed."""
        steps = set()
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp") \
                    and os.path.isdir(os.path.join(self.directory, name)):
                try:
                    steps.add(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def wait(self):
        """Block until the in-flight save lands — and surface its error
        if it died: a checkpoint the caller believes exists but doesn't
        is exactly the silent failure mode this layer exists to kill."""
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        if self._save_error is not None:
            err, self._save_error = self._save_error, None
            raise CheckpointWriteError(
                f"async checkpoint save failed after "
                f"{self.save_retries + 1} attempts: {err}") from err

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict[str, Any]] = None):
        """Snapshot to host memory now, write to disk (a)synchronously.

        Raises a :class:`CheckpointWriteError` from the *previous* save
        if that one failed (via the ``wait()`` below) — an async
        failure is surfaced one save late at worst, never swallowed.
        """
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}
        crcs = {k: _crc(v) for k, v in host.items()}
        self.wait()

        def write():
            last: Optional[BaseException] = None
            for attempt in range(self.save_retries + 1):
                try:
                    self._write_once(step, host, crcs, extra, attempt)
                    return
                except OSError as e:
                    last = e
                    log.warning(
                        "checkpoint save step %d attempt %d/%d failed: %s",
                        step, attempt + 1, self.save_retries + 1, e)
                    if attempt < self.save_retries:
                        time.sleep(self.retry_backoff * (2 ** attempt))
                except BaseException as e:   # non-IO: don't retry
                    last = e
                    break
            self._save_error = last

        if self.async_save:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()
        else:
            write()
            self.wait()

    def _write_once(self, step: int, host: Dict[str, np.ndarray],
                    crcs: Dict[str, int], extra: Optional[Dict[str, Any]],
                    attempt: int):
        tmp = self._step_dir(step) + ".tmp"
        final = self._step_dir(step)
        if self.io_hook is not None:
            self.io_hook(step, attempt)
        if os.path.exists(tmp):             # debris from a failed attempt
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "keys": sorted(host),
            "crc32": crcs,
            "time": time.time(),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            # Re-saving an existing step: never rmtree the live dir and
            # then replace — between those two a concurrent reader sees
            # the step half-deleted or vanished, and if anything
            # re-creates ``final`` the replace dies on ENOTEMPTY.
            # Rename the old dir aside (atomic; readers keep a coherent
            # old view), swing the new one in, then delete the orphan.
            old = final + ".old.tmp"
            if os.path.exists(old):
                shutil.rmtree(old)
            os.replace(final, old)
            os.replace(tmp, final)
            shutil.rmtree(old, ignore_errors=True)
        else:
            os.replace(tmp, final)
        self._gc()

    def _gc(self):
        for s in self.available_steps()[:-self.keep]:
            # rename-then-delete: a reader listing the directory never
            # sees a manifest-complete step dir with half its arrays
            # already unlinked (.tmp names are invisible to readers)
            live = self._step_dir(s)
            trash = live + ".gc.tmp"
            try:
                os.replace(live, trash)
            except OSError:
                continue
            shutil.rmtree(trash, ignore_errors=True)

    # ------------------------------------------------------------------
    def verify(self, step: int) -> Optional[str]:
        """Integrity-check one checkpoint; returns None if it passes or
        a one-line reason: manifest missing/unreadable, arrays.npz
        missing/truncated/unreadable, key mismatch, or a per-array CRC32
        mismatch. Pre-CRC (legacy) manifests pass on the structural
        checks alone."""
        d = self._step_dir(step)
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, ValueError) as e:
            return f"manifest missing/unreadable: {e}"
        crcs = manifest.get("crc32")
        try:
            with np.load(os.path.join(d, "arrays.npz")) as z:
                if sorted(z.files) != manifest.get("keys"):
                    return "key mismatch between manifest and arrays.npz"
                for k in z.files:
                    arr = z[k]          # full decompress: torn files fail here
                    if crcs is not None and _crc(arr) != crcs.get(k):
                        return f"crc32 mismatch on array {k!r}"
        except Exception as e:  # noqa: BLE001 — any load failure is corrupt
            return f"arrays.npz unreadable: {type(e).__name__}: {e}"
        return None

    def restore(self, step: Optional[int] = None, shardings=None,
                strict: bool = True, fallback: bool = False):
        """Returns (tree, extra). ``shardings``: optional matching tree of
        NamedShardings — leaves are device_put onto the current mesh
        (elastic restore).

        ``fallback=True`` (with ``step=None``): instead of trusting the
        newest directory, walk newest -> oldest and restore the first
        checkpoint that passes :meth:`verify`; every corrupt step walked
        over is logged and recorded in :attr:`last_restore_report` as
        ``{"step": restored, "skipped": [{"step", "reason"}, ...]}``.
        Raises ``IOError`` only when *no* checkpoint verifies. With an
        explicit ``step``, corruption raises (the caller asked for that
        exact payload)."""
        if step is None:
            if fallback:
                return self._restore_fallback(shardings, strict)
            step = self.latest_step()
        if step is None:
            return None, None
        if strict:
            reason = self.verify(step)
            if reason is not None:
                raise IOError(
                    f"checkpoint {self._step_dir(step)} corrupt: {reason}")
        return self._load(step, shardings, strict)

    def _restore_fallback(self, shardings, strict: bool):
        skipped: List[Dict[str, Any]] = []
        for step in reversed(self._candidate_steps()):
            reason = self.verify(step)
            if reason is None:
                self.last_restore_report = {"step": step, "skipped": skipped}
                for s in skipped:
                    log.warning(
                        "checkpoint step %d failed verification (%s); "
                        "fell back past it", s["step"], s["reason"])
                if skipped:
                    log.warning("restoring from fallback step %d", step)
                return self._load(step, shardings, strict)
            skipped.append({"step": step, "reason": reason})
        if skipped:
            raise IOError(
                "no checkpoint passed verification; tried "
                + "; ".join(f"step {s['step']}: {s['reason']}"
                            for s in skipped))
        self.last_restore_report = {"step": None, "skipped": []}
        return None, None

    def _load(self, step: int, shardings, strict: bool):
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(d, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        if strict and sorted(flat) != manifest["keys"]:
            raise IOError(f"checkpoint {d} corrupt: key mismatch")
        tree = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            flat_t = _flatten(tree)
            if strict and set(flat_s) != set(flat_t):
                missing = set(flat_s) ^ set(flat_t)
                raise IOError(f"structure mismatch on restore: "
                              f"{sorted(missing)[:5]}")
            put = {k: jax.device_put(flat_t[k], flat_s[k]) for k in flat_t}
            tree = _unflatten(put)
        return tree, manifest.get("extra", {})
