"""Architecture configs + shapes. Import side effect: registry population."""
from repro.configs import archs  # noqa: F401  (registers the 10 architectures)
from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig, all_configs,
                                get_config, register)

ARCH_NAMES = sorted(all_configs())

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "all_configs",
           "get_config", "register", "ARCH_NAMES"]
