"""Architecture configs + shapes. Import side effect: registry population."""
from repro.configs import archs  # noqa: F401  (registers the architectures)
from repro.configs.base import (SHAPES, ModelConfig, ShapeConfig, SimArch,
                                all_configs, all_sim_archs, get_config,
                                get_sim_arch, register, register_sim)

ARCH_NAMES = sorted(all_configs())
SIM_ARCH_NAMES = sorted(all_sim_archs())

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "SimArch", "all_configs",
           "all_sim_archs", "get_config", "get_sim_arch", "register",
           "register_sim", "ARCH_NAMES", "SIM_ARCH_NAMES"]
