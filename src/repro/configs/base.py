"""Model / run configuration dataclasses and the architecture registry."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    expert_ff: int = 1024
    num_shared: int = 0
    first_k_dense: int = 0          # leading dense layers (deepseek/kimi)
    dense_ff: Optional[int] = None  # d_ff of those dense layers
    capacity_factor: float = 1.25
    aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba"             # "mamba" | "rwkv6"
    state_size: int = 16
    head_dim: int = 64              # rwkv6 wkv head size
    d_inner: Optional[int] = None
    conv_width: int = 4
    chunk: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | vlm | hybrid | audio | ssm
    num_layers: int
    d_model: int
    num_q_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_q_heads
    # --- attention / positions ---
    attention_kind: str = "gqa"             # gqa | mla | none
    pos_enc: str = "rope1d"                 # rope1d | absolute | sinusoidal | none
    rope_base: float = 10000.0
    rope_fraction: float = 1.0
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None
    window: Optional[int] = None            # sliding window for local layers
    window_pattern: str = "none"            # none|alternating|mostly_local
    attn_bias: bool = False
    mla: Optional[MLAConfig] = None
    # --- channel mixer ---
    activation: str = "silu"
    mlp_kind: str = "gated"                 # gated | plain | rwkv
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    parallel_ssm: bool = False              # hymba
    # --- embeddings / norms ---
    norm: str = "rms"                       # rms | layer | rms_offset
    tie_embeddings: bool = False
    scale_embeddings: bool = False          # gemma sqrt(d) embed scaling
    learned_positions: bool = False         # granite / whisper decoder
    max_position: int = 1 << 20
    # --- enc-dec (whisper) ---
    enc_dec: bool = False
    encoder_layers: int = 0
    encoder_frames: int = 1500
    frontend_dim: Optional[int] = None      # stubbed modality frontend width
    # --- vlm ---
    vision_prefix: int = 0                  # patch-embedding prefix length
    # --- bookkeeping ---
    long_context_ok: bool = False           # sub-quadratic -> run long_500k
    notes: str = ""
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_q_heads

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so TP sharding over <=16 chips divides evenly."""
        mult = 128
        return self.vocab_size + (-self.vocab_size) % mult

    def depth_variant(self, iters: int) -> "ModelConfig":
        """Full-width config whose every *scanned* layer group runs ``iters``
        iterations. Used by the dry-run's per-layer cost extrapolation:
        lowering two shallow variants fully unrolled measures the exact
        per-iteration FLOPs/bytes/collective cost at production width, which
        extrapolates linearly to the full depth (layer groups are
        homogeneous by construction)."""
        if self.window_pattern == "alternating":
            n = 2 * iters
        elif self.window_pattern == "mostly_local":
            n = 3 + 2 * iters
        elif self.moe and self.moe.first_k_dense:
            n = self.moe.first_k_dense + iters
        else:
            n = iters
        kw = dict(num_layers=n)
        if self.enc_dec:
            kw["encoder_layers"] = iters
            kw["num_layers"] = iters
        return dataclasses.replace(self, **kw)

    def scan_iters(self) -> int:
        """Total scan iterations across multi-layer groups (the linear
        extrapolation variable matching :meth:`depth_variant`)."""
        if self.window_pattern == "alternating":
            return self.num_layers // 2
        if self.window_pattern == "mostly_local":
            return self.num_layers - 3
        if self.moe and self.moe.first_k_dense:
            return self.num_layers - self.moe.first_k_dense
        if self.enc_dec:
            return self.num_layers  # enc+dec counts move together (equal)
        return self.num_layers

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        n_small = min(self.num_layers,
                      2 + (self.moe.first_k_dense if self.moe else 0))
        if self.window_pattern == "mostly_local":
            n_small = 5       # pattern needs first/middle/last global layers
        small: Dict = dict(
            num_layers=n_small,
            d_model=128,
            num_q_heads=4,
            num_kv_heads=max(1, min(self.num_kv_heads, 2)),
            head_dim=32,
            d_ff=256,
            vocab_size=256,
            window=16 if self.window else None,
            max_position=4096,
        )
        if self.moe:
            # capacity_factor high enough that smoke tests never drop tokens
            # (capacity dropping makes decode-vs-prefill comparisons flaky)
            small["moe"] = dataclasses.replace(
                self.moe, num_experts=8, top_k=2, expert_ff=64,
                dense_ff=256 if self.moe.dense_ff else None,
                capacity_factor=8.0)
        if self.mla:
            small["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_dim=16,
                                     qk_rope_dim=16, v_head_dim=32)
        if self.ssm:
            small["ssm"] = dataclasses.replace(
                self.ssm, d_inner=None, state_size=8,
                head_dim=32 if self.ssm.kind == "rwkv6" else self.ssm.head_dim,
                chunk=16)
        if self.enc_dec:
            small["encoder_layers"] = 2
            small["encoder_frames"] = 32
            small["frontend_dim"] = 128
        if self.vision_prefix:
            small["vision_prefix"] = 8
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                    # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

@dataclasses.dataclass(frozen=True)
class SimArch:
    """Agent-simulation architecture: one row of the paper's Table I.

    Pairs the scene-transformer hyperparameters (an
    :class:`repro.nn.agent_sim.AgentSimConfig`) with the
    :class:`repro.scenarios.ScenarioConfig` whose action grid it predicts —
    the two must agree on ``num_actions`` and the feature dims, so the pair
    is registered as one unit. Builder methods import lazily (configs must
    stay importable before jax device init, and ``repro.nn`` imports configs
    back).
    """
    name: str
    encoding: str                 # absolute | rope2d | se2_repr | se2_fourier
    d_model: int = 256
    num_layers: int = 6
    num_heads: int = 8
    head_dim: int = 24            # divisible by 6/4/3/2: works for every enc
    d_ff: int = 1024
    fourier_terms: int = 12
    pos_scale: float = 0.05
    # scenario-side shapes (the model's token budget: num_map + T*A)
    num_map: int = 48
    num_agents: int = 12
    num_steps: int = 24
    dtype: str = "float32"
    notes: str = ""

    def scenario_config(self):
        """The ScenarioConfig this arch trains and evaluates on."""
        from repro.scenarios.core import ScenarioConfig
        return ScenarioConfig(num_map=self.num_map,
                              num_agents=self.num_agents,
                              num_steps=self.num_steps)

    def agent_sim_config(self):
        from repro.nn.agent_sim import AgentSimConfig
        scen = self.scenario_config()
        return AgentSimConfig(
            d_model=self.d_model, num_layers=self.num_layers,
            num_heads=self.num_heads, head_dim=self.head_dim,
            d_ff=self.d_ff, num_actions=scen.num_actions,
            agent_feat_dim=scen.agent_feat_dim,
            map_feat_dim=scen.map_feat_dim,
            encoding=self.encoding, fourier_terms=self.fourier_terms,
            pos_scale=self.pos_scale, dtype=self.dtype)

    def reduced(self, **overrides) -> "SimArch":
        """CPU-sized same-encoding config (mirrors ModelConfig.reduced)."""
        small: Dict = dict(d_model=64, num_layers=2, num_heads=4,
                           head_dim=24, d_ff=256,
                           num_map=16, num_agents=6, num_steps=10,
                           dtype="float32")
        small.update(overrides)
        return dataclasses.replace(self, **small)


_REGISTRY: Dict[str, ModelConfig] = {}
_SIM_REGISTRY: Dict[str, SimArch] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def register_sim(arch: SimArch) -> SimArch:
    _SIM_REGISTRY[arch.name] = arch
    return arch


def get_sim_arch(name: str) -> SimArch:
    import repro.configs  # noqa: F401  (ensure registrations ran)
    if name not in _SIM_REGISTRY:
        raise KeyError(f"unknown sim arch {name!r}; have "
                       f"{sorted(_SIM_REGISTRY)}")
    return _SIM_REGISTRY[name]


def all_sim_archs() -> Dict[str, SimArch]:
    import repro.configs  # noqa: F401
    return dict(_SIM_REGISTRY)


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (ensure registrations ran)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)
