"""The 10 assigned architectures, exactly as specified in the task brief.

Sources are noted per config; where the one-line brief conflicts with the
published model card we follow the brief and note the deviation (see
DESIGN.md "Assigned architectures" for the reconciliation).
"""
from __future__ import annotations

from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig, SimArch,
                                SSMConfig, register, register_sim)

# --- deepseek-v2-lite-16b [arXiv:2405.04434; hf] ---------------------------
# 27L d=2048, 16 heads, MLA kv_lora=512, MoE: 64 routed top-6 + 2 shared,
# expert_ff=1408, first layer dense (dense_ff=10944).
register(ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    num_layers=27, d_model=2048, num_q_heads=16, num_kv_heads=16,
    d_ff=10944, vocab_size=102400,
    attention_kind="mla",
    mla=MLAConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=64, top_k=6, expert_ff=1408, num_shared=2,
                  first_k_dense=1, dense_ff=10944),
    activation="silu", norm="rms",
    notes="MLA + fine-grained MoE; brief lists '160 routed' which matches "
          "deepseek-v2 (236B), not -lite; we follow the hf card (64 routed).",
))

# --- kimi-k2-1t-a32b [arXiv: Kimi K2 tech report; paper-table] --------------
# 61L d=7168, 64 heads (GQA kv=8 per brief), MoE 384 experts top-8,
# expert_ff=2048, 1 shared expert, first layer dense.
register(ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    num_layers=61, d_model=7168, num_q_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=18432, vocab_size=163840,
    attention_kind="gqa", rope_base=50000.0,
    moe=MoEConfig(num_experts=384, top_k=8, expert_ff=2048, num_shared=1,
                  first_k_dense=1, dense_ff=18432, capacity_factor=1.25),
    activation="silu", norm="rms",
    notes="Brief specifies GQA kv=8 (the release uses MLA); we follow the "
          "brief. 1.03e12 params, ~32B active.",
))

# --- gemma2-27b [arXiv:2408.00118; hf] --------------------------------------
# 46L d=4608, 32 heads / 16 kv, head_dim 128, GeGLU d_ff=36864 (gate+up),
# alternating local(4096)/global attention, attn softcap 50, final softcap 30,
# query_pre_attn_scalar=144, RMSNorm(+1) pre+post, tied + scaled embeddings.
register(ModelConfig(
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_q_heads=32, num_kv_heads=16,
    head_dim=128, d_ff=36864, vocab_size=256000,
    window=4096, window_pattern="alternating",
    attn_softcap=50.0, final_softcap=30.0, query_scale=144.0,
    activation="gelu_tanh", norm="rms_offset",
    tie_embeddings=True, scale_embeddings=True,
))

# --- stablelm-3b [hf:stabilityai/stablelm-*] --------------------------------
# 32L d=2560, 32 heads MHA, d_ff=6912, vocab 50304, partial rotary 25%.
register(ModelConfig(
    name="stablelm-3b", family="dense",
    num_layers=32, d_model=2560, num_q_heads=32, num_kv_heads=32,
    d_ff=6912, vocab_size=50304,
    rope_fraction=0.25, norm="layer", attn_bias=False,
    activation="silu",
))

# --- phi4-mini-3.8b [arXiv:2412.08905; hf] ----------------------------------
# 32L d=3072, 24 heads / 8 kv, SwiGLU d_ff=8192, vocab 200064, tied embeds.
register(ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    num_layers=32, d_model=3072, num_q_heads=24, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=200064,
    activation="silu", norm="rms", tie_embeddings=True,
))

# --- granite-20b [arXiv:2405.04324; hf] -------------------------------------
# GPT-BigCode style: 52L d=6144, 48 heads MQA (kv=1), d_ff=24576, learned
# absolute positions, LayerNorm + gelu, biases.
register(ModelConfig(
    name="granite-20b", family="dense",
    num_layers=52, d_model=6144, num_q_heads=48, num_kv_heads=1,
    head_dim=128, d_ff=24576, vocab_size=49152,
    pos_enc="absolute", learned_positions=True, max_position=32768 + 8192,
    mlp_kind="plain", activation="gelu_tanh", norm="layer", attn_bias=True,
    notes="MQA; absolute learned positions exercise the paper's 'absolute' "
          "baseline row at LM scale.",
))

# --- internvl2-26b [arXiv:2404.16821; hf] -----------------------------------
# InternLM2-20B backbone: 48L d=6144, 48 heads / 8 kv, d_ff=16384, SwiGLU.
# InternViT frontend is a STUB: input_specs provides patch embeddings
# (vision_prefix tokens of width d_model).
register(ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_q_heads=48, num_kv_heads=8,
    head_dim=128, d_ff=16384, vocab_size=92553,
    activation="silu", norm="rms",
    vision_prefix=256,
    notes="Backbone only; InternViT-6B patch embeddings arrive precomputed "
          "as a 256-token prefix.",
))

# --- hymba-1.5b [arXiv:2411.13676; hf] --------------------------------------
# 32L d=1600, 25 q heads / 5 kv (head_dim 64), d_ff=5504, parallel
# attention+mamba heads, SWA except first/middle/last global layers.
register(ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_q_heads=25, num_kv_heads=5,
    head_dim=64, d_ff=5504, vocab_size=32001,
    window=1024, window_pattern="mostly_local", parallel_ssm=True,
    ssm=SSMConfig(kind="mamba", state_size=16, d_inner=3200, chunk=128),
    activation="silu", norm="rms",
    long_context_ok=True,
    notes="Parallel attn+SSM heads; meta-tokens omitted (see DESIGN.md). "
          "SWA + SSM make long_500k decode sub-quadratic.",
))

# --- whisper-base [arXiv:2212.04356] ----------------------------------------
# enc-dec, 6L each, d=512, 8 heads, d_ff=2048; conv frontend stubbed (inputs
# are 1500 precomputed frame embeddings).
register(ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, d_model=512, num_q_heads=8, num_kv_heads=8,
    head_dim=64, d_ff=2048, vocab_size=51865,
    enc_dec=True, encoder_layers=6, encoder_frames=1500,
    pos_enc="absolute", learned_positions=True, max_position=32768 + 256,
    mlp_kind="plain", activation="gelu", norm="layer", attn_bias=True,
    notes="Decoder max length far beyond the real 448-token budget so the "
          "assigned decode_32k/long shapes remain well-defined.",
))

# --- rwkv6-7b [arXiv:2404.05892; hf] ----------------------------------------
# Finch: 32L d=4096, attention-free (WKV6, head 64), channel-mix d_ff=14336.
register(ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_q_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    attention_kind="none", pos_enc="none", mlp_kind="rwkv",
    ssm=SSMConfig(kind="rwkv6", head_dim=64, chunk=16),
    norm="layer",
    long_context_ok=True,
    notes="Paper's attention technique inapplicable (attention-free); see "
          "DESIGN.md Arch-applicability.",
))

# --- agent-sim architectures (paper Table I rows) ---------------------------
# One arch per attention mechanism, identical everywhere else, so trained
# comparisons isolate the encoding (the paper's invariant-vs-absolute
# claim). ``.reduced()`` gives the CPU-sized variant the train_sim launcher,
# the train bench, and CI smoke jobs use; the full shapes are what
# ``launch.dryrun`` lowers on the production mesh alongside the LM arches.
_SIM_NOTES = {
    "absolute": "non-invariant baseline: learned Fourier pose embedding "
                "added to token features",
    "rope2d": "translation-invariant only (paper Sec. II-D)",
    "se2_repr": "exact SE(2) invariance via homogeneous-matrix "
                "representation (Sec. II-E)",
    "se2_fourier": "the paper's linear-memory SE(2) encoding (Sec. III)",
}
for _enc, _note in _SIM_NOTES.items():
    register_sim(SimArch(name=f"sim-{_enc.replace('_', '-')}",
                         encoding=_enc, notes=_note))
