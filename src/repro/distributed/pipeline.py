"""Pipeline parallelism: GPipe-style microbatched schedule on a "pipe" mesh
axis via ``shard_map`` + ``ppermute``.

For depth-dominated configs (granite-20b's 52 layers) pipeline stages are an
alternative to pure TP when the model axis is exhausted. The schedule here
is the classic fill-drain loop:

  * layers are split into ``P`` contiguous stages; stage parameters live on
    their pipe slice (leading "layers" dim sharded over "pipe");
  * the batch is split into ``M`` microbatches; each loop tick every stage
    processes one resident microbatch, then activations rotate one hop with
    ``lax.ppermute`` (neighbor-only traffic — the property that makes PP the
    cross-pod-friendly axis at 1000+ nodes);
  * total ticks = M + P - 1; bubble fraction = (P-1)/(M+P-1).

The implementation is deliberately layer-homogeneous (stage = equal slice of
a scanned block stack), matching how the uniform-depth architectures here
are built. Losses/logits are computed on the last stage and psum'd back.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    num_stages: int
    num_microbatches: int

    @property
    def bubble_fraction(self) -> float:
        p, m = self.num_stages, self.num_microbatches
        return (p - 1) / (m + p - 1)


def pipeline_apply(block_fn: Callable, stage_params, x, cfg: PipelineConfig,
                   axis_name: str = "pipe"):
    """Run inside shard_map: every pipe rank holds ``stage_params`` (its
    layers, stacked) and the full microbatched input ``x`` of shape
    ``(M, mb, ...)``; rank 0 feeds, rank P-1 collects.

    block_fn(stage_params, x_mb) -> x_mb applies this rank's layers.
    Returns (M, mb, ...) outputs (valid on the last stage; psum'd out).
    """
    p = cfg.num_stages
    m = cfg.num_microbatches
    rank = jax.lax.axis_index(axis_name)
    ticks = m + p - 1

    mb_shape = x.shape[1:]
    state = jnp.zeros(mb_shape, x.dtype)          # resident microbatch
    outputs = jnp.zeros((m,) + mb_shape, x.dtype)

    def tick(carry, t):
        state, outputs = carry
        # stage 0 ingests microbatch t (if still in range)
        feed = jnp.where(t < m, t, m - 1)
        state = jnp.where(rank == 0, x[feed], state)
        state = block_fn(stage_params, state)
        # last stage emits the microbatch that entered at t - (p - 1)
        out_idx = t - (p - 1)
        emit = jnp.logical_and(rank == p - 1, out_idx >= 0)
        outputs = jax.lax.cond(
            emit,
            lambda o: o.at[jnp.maximum(out_idx, 0)].set(state),
            lambda o: o,
            outputs)
        # rotate activations one hop down the pipe
        state = jax.lax.ppermute(
            state, axis_name, [(i, (i + 1) % p) for i in range(p)])
        return (state, outputs), None

    (state, outputs), _ = jax.lax.scan(tick, (state, outputs),
                                       jnp.arange(ticks))
    # broadcast the last stage's outputs to all ranks (for loss replication)
    # ppermute rotated one extra time; undo is unnecessary because outputs
    # were captured pre-rotation.
    mask = (rank == p - 1).astype(outputs.dtype)
    return jax.lax.psum(outputs * mask, axis_name)


def make_pipelined_fn(block_fn: Callable, mesh: Mesh, cfg: PipelineConfig,
                      axis_name: str = "pipe"):
    """Wrap a per-stage block fn into a full-model fn over the pipe axis.

    stage_params: any pytree whose leaves have a leading dim divisible by
    the pipe axis (layer-stacked); x: (batch, ...) with batch divisible by
    num_microbatches.
    """
    def full(params, x):
        m = cfg.num_microbatches
        xm = x.reshape((m, x.shape[0] // m) + x.shape[1:])
        inner = functools.partial(pipeline_apply, block_fn, cfg=cfg,
                                  axis_name=axis_name)
        out = shard_map(
            lambda sp, xi: inner(sp, xi),
            mesh=mesh,
            in_specs=(P(axis_name), P()),
            out_specs=P(),
            check_rep=False,
        )(params, xm)
        return out.reshape(x.shape[:1] + out.shape[2:])

    return full
