"""Data-parallel training step with compressed cross-pod gradient reduction.

At multi-pod scale the gradient all-reduce decomposes hierarchically:

    1. full-precision psum over the intra-pod "data" axis (fast ICI);
    2. int8-quantized psum over the cross-pod "pod" axis (slow DCI) with
       per-tensor scales, plus an error-feedback residual carried in the
       optimizer loop so quantization error never accumulates as bias.

Implemented with ``shard_map`` over the DP axes so the reduction really is
two separate collectives the compiler cannot re-fuse into one f32
all-reduce — this is the distributed-optimization trick, stated in code.

DCI byte savings: 4x vs f32 / 2x vs bf16 on the pod axis; see
EXPERIMENTS.md §Perf for the roofline impact on the multi-pod mesh.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.optim.transforms import apply_updates


def _int8_psum(g, axis_name: str):
    """Quantize -> integer psum -> dequantize (per-tensor scale).

    The scale is the max over the axis (one tiny f32 psum), so the shared
    grid is identical on every member and the integer sum is exact up to
    the quantization step.
    """
    g32 = g.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    # also return this member's dequantized transmission, for error feedback
    return total.astype(jnp.float32) * scale, q.astype(jnp.float32) * scale


def make_compressed_dp_step(loss_fn: Callable, optimizer, mesh: Mesh,
                            pod_axis: str = "pod", data_axis: str = "data",
                            compress: bool = True):
    """Returns step(params, opt_state, residual, batch) ->
    (params, opt_state, residual, loss).

    ``loss_fn(params, batch) -> scalar`` is written for a single shard;
    batch arrives sharded over (pod, data). Params/opt replicated across DP
    (TP axes can be composed by nesting — omitted here for clarity).
    ``residual`` carries the error-feedback state (same tree as params).
    """
    have_pod = pod_axis in mesh.shape

    def shard_step(params, opt_state, residual, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        # 1) full-precision intra-pod reduction (ICI)
        grads = jax.tree.map(
            lambda g: jax.lax.pmean(g, data_axis), grads)
        loss = jax.lax.pmean(loss, data_axis)
        if have_pod:
            # 2) compressed cross-pod reduction (DCI) with error feedback
            # mesh.shape is static; jax.lax.axis_size is not available
            # on all supported jax versions.
            npods = mesh.shape[pod_axis]
            if compress:
                def one(g, r):
                    target = g.astype(jnp.float32) + r
                    summed, sent = _int8_psum(target, pod_axis)
                    # classic error feedback: carry what *this* member failed
                    # to transmit (its own quantization error), not the
                    # cross-member averaging difference.
                    new_r = target - sent
                    return summed / npods, new_r
                flat_g, tdef = jax.tree.flatten(grads)
                flat_r = tdef.flatten_up_to(residual)
                pairs = [one(g, r) for g, r in zip(flat_g, flat_r)]
                grads = tdef.unflatten([p[0] for p in pairs])
                residual = tdef.unflatten([p[1] for p in pairs])
            else:
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, pod_axis), grads)
            loss = jax.lax.pmean(loss, pod_axis)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, residual, loss

    dp_axes = (pod_axis, data_axis) if have_pod else (data_axis,)
    return shard_map(
        shard_step, mesh=mesh,
        in_specs=(P(), P(), P(), P(dp_axes)),
        out_specs=(P(), P(), P(), P()),
        check_rep=False)
