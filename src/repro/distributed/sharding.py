"""Logical-axis sharding (MaxText-style) for params and activations.

Parameters carry *logical* axis names (``"embed"``, ``"heads"``, ``"mlp"``,
``"experts"``, ``"vocab"``, ...). A rule set maps logical names to mesh axes;
``sharding_for_specs`` resolves a whole parameter spec tree to
``NamedSharding``s, silently dropping any mesh axis that does not divide the
tensor dimension (GSPMD could pad, but replication is cheaper than uneven
layouts for the odd cases here — e.g. hymba's 25 query heads).

Activation constraints go through :func:`logical_constraint`, which is a
no-op unless a mesh + rule context is active (so model code is runnable on a
single CPU device without ceremony).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> tuple of mesh axes, in priority order. "fsdp" axes shard
# the big parameter matrices over the data-parallel axes (ZeRO-3 style);
# "model" is tensor parallelism.
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    # parameters
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "experts": ("model",),
    "embed": ("pod", "data"),        # FSDP storage sharding
    "embed_no_fsdp": (),
    "head_dim": (),
    "kv_lora": (),
    "layers": (),
    "state": (),
    "conv": (),
    "basis": (),
    # activations
    "act_batch": ("pod", "data"),
    # sequence parallelism: the residual stream (and any seq-major
    # activation) shards its sequence dim over the model axis wherever the
    # head/mlp dims aren't already using it. This is what keeps the
    # remat-saved per-layer carries at 1/16 size on the big configs.
    "act_seq": ("model",),
    "act_embed": (),
    "act_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_experts": ("model",),
    "act_kv": ("model",),
    # decode caches: prefer sharding KV heads over the model axis; when the
    # head count doesn't divide (MQA / kv=8 on a 16-wide axis), the spec
    # resolver falls through to sharding the cache length instead
    # (flash-decode style distributed softmax).
    "act_kvlen": ("model",),
    # flattened token dim in the MoE dispatch path (batch*seq collapsed)
    "act_tokens": ("pod", "data"),
    "act_cap": (),
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Dict[str, Tuple[str, ...]]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh_rules(mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    """Activate a mesh + logical rules for constraints inside model code."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(rules or DEFAULT_RULES)
    try:
        with mesh:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def dp_shard_count() -> int:
    """Number of data-parallel shards (pod x data) in the active mesh.

    The MoE layer uses this as its dispatch-group count so token sorting,
    capacity, and scatter/gather all stay local to a DP shard (the dispatch
    buffer then carries both a data-sharded group dim and a model-sharded
    expert dim — no global-token-count gathers in the lowered HLO)."""
    mesh = _CTX.mesh
    if mesh is None:
        return 1
    n = 1
    for a in ("pod", "data"):
        n *= mesh.shape.get(a, 1)
    return n


def _resolve_axis(dim: int, logical: Optional[str], mesh: Mesh,
                  rules: Dict[str, Tuple[str, ...]], used: set):
    """Mesh axes for one tensor dim, honoring divisibility and axis reuse."""
    if logical is None:
        return None
    axes = [a for a in rules.get(logical, ()) if a in mesh.shape]
    chosen = []
    size = 1
    for a in axes:
        if a in used:
            continue
        if dim % (size * mesh.shape[a]) == 0:
            chosen.append(a)
            size *= mesh.shape[a]
    for a in chosen:
        used.add(a)
    if not chosen:
        return None
    return tuple(chosen) if len(chosen) > 1 else chosen[0]


def spec_for(shape: Sequence[int], logical_axes: Sequence[Optional[str]],
             mesh: Mesh, rules: Optional[Dict[str, Tuple[str, ...]]] = None
             ) -> P:
    """PartitionSpec for one tensor given its logical axes."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    parts = [_resolve_axis(d, ax, mesh, rules, used)
             for d, ax in zip(shape, logical_axes)]
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def logical_constraint(x, *logical_axes: Optional[str]):
    """Sharding constraint by logical activation axis names (no-op w/o mesh)."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None:
        return x
    spec = spec_for(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def sharding_for_specs(spec_tree, mesh: Mesh,
                       rules: Optional[Dict[str, Tuple[str, ...]]] = None):
    """Map a ParamSpec tree to a NamedSharding tree."""
    from repro.nn.module import ParamSpec  # cycle-free: nn imports nothing here

    def one(spec):
        assert isinstance(spec, ParamSpec), spec
        return NamedSharding(mesh, spec_for(spec.shape, spec.axes, mesh, rules))

    return jax.tree.map(one, spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def batch_sharding(mesh: Mesh, shape: Sequence[int], rules=None) -> NamedSharding:
    """Sharding for a batch-leading array (tokens, labels, ...).

    Falls back to replication when the batch does not divide the DP axes
    (e.g. the batch=1 long-context shape).
    """
    logical = ["act_batch"] + [None] * (len(shape) - 1)
    return NamedSharding(mesh, spec_for(shape, logical, mesh, rules))


def derive_opt_shardings(spec_tree, opt_state, mesh, rules=None):
    """NamedShardings for an optimizer-state tree.

    Optimizer leaves mirror parameters (adamw mu/nu; adafactor unfactored v)
    or are factored reductions of them (adafactor vr/vc) — shardings are
    derived from the parameter ParamSpec logical axes so ZeRO-style state
    sharding follows the parameter layout exactly.
    """
    from repro.nn.module import ParamSpec, is_spec

    rules = rules or DEFAULT_RULES
    repl = NamedSharding(mesh, P())
    spec_leaves, spec_treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))

    def param_like(subtree):
        # shardings come from the ParamSpecs alone; the subtree only
        # proves the pytree structure matches (flatten_up_to would raise)
        spec_treedef.flatten_up_to(subtree)
        out = [NamedSharding(mesh, spec_for(s.shape, s.axes, mesh, rules))
               for s in spec_leaves]
        return spec_treedef.unflatten(out)

    def factored(subtree):
        leaves = spec_treedef.flatten_up_to(subtree)
        out = []
        for spec, leaf in zip(spec_leaves, leaves):
            if isinstance(leaf, dict) and "vr" in leaf:
                out.append({
                    "vr": NamedSharding(mesh, spec_for(
                        spec.shape[:-1], spec.axes[:-1], mesh, rules)),
                    "vc": NamedSharding(mesh, spec_for(
                        spec.shape[:-2] + spec.shape[-1:],
                        spec.axes[:-2] + spec.axes[-1:], mesh, rules)),
                })
            else:
                out.append({"v": NamedSharding(mesh, spec_for(
                    spec.shape, spec.axes, mesh, rules))})
        return spec_treedef.unflatten(out)

    def walk(node):
        if isinstance(node, tuple):
            return tuple(walk(x) for x in node)
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k == "step":
                    out[k] = repl
                elif k in ("mu", "nu"):
                    out[k] = param_like(v)
                elif k == "v":
                    out[k] = factored(v)
                else:
                    out[k] = walk(v)
            return out
        return repl

    return walk(opt_state)
