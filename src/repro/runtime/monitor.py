"""Run-health monitors: step timing / straggler detection / NaN guards.

On a real multi-pod deployment each host runs this monitor; step times are
periodically all-gathered (host-side, out of the jit path) and hosts whose
rolling median exceeds ``straggler_factor`` x the fleet median are flagged
for the cluster scheduler to drain-and-replace. Here the fleet is one
process, but the policy object, its thresholds, and its decision output are
the production ones and are unit-tested directly.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Deque, Dict, List, Optional


@dataclasses.dataclass
class StepTimer:
    window: int = 50

    def __post_init__(self):
        self.times: Deque[float] = collections.deque(maxlen=self.window)
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self.times.append(dt)
        return dt

    @property
    def median(self) -> float:
        if not self.times:
            return float("nan")
        s = sorted(self.times)
        return s[len(s) // 2]


@dataclasses.dataclass
class StragglerPolicy:
    """Flags ranks whose rolling median step time is anomalously slow."""

    straggler_factor: float = 1.5
    min_samples: int = 10

    def evaluate(self, medians: Dict[int, float]) -> List[int]:
        """medians: rank -> rolling median step seconds. Returns flagged
        ranks (candidates for preemptive replacement / checkpoint-evict)."""
        vals = [v for v in medians.values() if math.isfinite(v)]
        if len(vals) < 1:
            return []
        fleet = sorted(vals)[len(vals) // 2]
        return [r for r, v in medians.items()
                if math.isfinite(v) and v > self.straggler_factor * fleet]


@dataclasses.dataclass
class NaNGuard:
    """Skip-and-count policy for non-finite losses; halt after a run of them.

    Transient non-finite steps (a bad batch, a flaky host) are skipped —
    the params/opt-state update for that step is discarded. ``max_consecutive``
    non-finite steps in a row aborts the run (systematic divergence).
    """

    max_consecutive: int = 5

    def __post_init__(self):
        self.consecutive = 0
        self.total_skipped = 0

    def check(self, loss: float) -> str:
        """Returns 'ok' | 'skip' | 'halt'."""
        if math.isfinite(loss):
            self.consecutive = 0
            return "ok"
        self.consecutive += 1
        self.total_skipped += 1
        if self.consecutive >= self.max_consecutive:
            return "halt"
        return "skip"
