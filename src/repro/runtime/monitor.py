"""Run-health monitors: step timing / straggler detection / NaN guards.

On a real multi-pod deployment each host runs this monitor; step times are
periodically all-gathered (host-side, out of the jit path) and hosts whose
rolling median exceeds ``straggler_factor`` x the fleet median are flagged
for the cluster scheduler to drain-and-replace. Here the fleet is one
process, but the policy object, its thresholds, and its decision output are
the production ones and are unit-tested directly.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Deque, Dict, List, Optional

from repro import obs


@dataclasses.dataclass
class StepTimer:
    window: int = 50

    def __post_init__(self):
        self.times: Deque[float] = collections.deque(maxlen=self.window)
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        """NaN-safe: ``stop`` without a matching ``start`` (retry paths
        re-entering the loop after an exception, or a double-stop) returns
        NaN and records nothing, instead of raising ``TypeError`` on
        ``None - float`` or double-counting one interval as two samples.
        ``_t0`` is consumed by the stop, so each ``start`` yields at most
        one sample."""
        if self._t0 is None:
            return float("nan")
        dt = time.perf_counter() - self._t0
        self._t0 = None
        self.times.append(dt)
        return dt

    @property
    def count(self) -> int:
        """Samples currently in the rolling window (feeds
        :meth:`StragglerPolicy.evaluate`'s per-rank ``counts`` gate)."""
        return len(self.times)

    @property
    def median(self) -> float:
        """Rolling median over the window — the LOWER middle for even
        windows, matching the fleet baseline's :func:`_lower_median`: the
        upper-middle pick made an even-window rank report a systematically
        pessimistic median to the same :class:`StragglerPolicy` that
        compares it against lower-median fleet baselines."""
        if not self.times:
            return float("nan")
        return _lower_median(sorted(self.times))


def _lower_median(sorted_vals: List[float]) -> float:
    """Median that takes the LOWER middle for even-length inputs.

    The fleet baseline must not be dragged up by the straggler itself:
    with the upper-middle pick (``vals[n // 2]``) a 2-rank fleet's
    "median" IS the slow rank, so ``slow > factor * slow`` never holds
    and a 2-host straggler is structurally unflaggable. The lower middle
    keeps the baseline at the healthy rank (and is the exact median for
    odd fleets).
    """
    return sorted_vals[(len(sorted_vals) - 1) // 2]


@dataclasses.dataclass
class StragglerPolicy:
    """Flags ranks whose rolling median step time is anomalously slow.

    ``registry``: telemetry home (``None`` = the process default,
    ``obs.NULL`` = off). Every evaluation exports the per-rank medians /
    sample counts it saw as ``straggler.rank_median_s`` /
    ``straggler.rank_samples`` gauges, and a non-empty decision lands as
    a ``straggler.flagged`` instant event — so a drain-and-replace
    trigger is visible in the same Perfetto timeline as the step spans
    it acted on.
    """

    straggler_factor: float = 1.5
    min_samples: int = 10
    registry: Optional[obs.Registry] = None

    def _reg(self) -> obs.Registry:
        return self.registry if self.registry is not None \
            else obs.get_registry()

    def evaluate(self, medians: Dict[int, float],
                 counts: Optional[Dict[int, int]] = None) -> List[int]:
        """medians: rank -> rolling median step seconds; counts: rank ->
        number of step samples behind that median (e.g.
        ``StepTimer.count``). Returns flagged ranks (candidates for
        preemptive replacement / checkpoint-evict).

        A rank participates — on either side of the comparison — only
        once its median rests on at least ``min_samples`` steps:
        flagging a host off a single noisy step (or letting that step
        define the fleet baseline) churns replacements for free. When
        ``counts`` is omitted the fleet as a whole must carry
        ``min_samples`` finite medians before any flag is raised.
        """
        def warmed(r: int) -> bool:
            return counts is None or counts.get(r, 0) >= self.min_samples

        reg = self._reg()
        for r, v in medians.items():
            reg.gauge("straggler.rank_median_s", rank=r).set(v)
            if counts is not None:
                reg.gauge("straggler.rank_samples", rank=r) \
                   .set(counts.get(r, 0))
        eligible = {r: v for r, v in medians.items()
                    if math.isfinite(v) and warmed(r)}
        if not eligible or (counts is None
                            and len(eligible) < self.min_samples):
            return []
        fleet = _lower_median(sorted(eligible.values()))
        flagged = [r for r, v in eligible.items()
                   if v > self.straggler_factor * fleet]
        if flagged:
            reg.counter("straggler.flag_decisions").inc()
            reg.event("straggler.flagged",
                      ranks=",".join(str(r) for r in sorted(flagged)),
                      fleet_median_s=fleet,
                      factor=self.straggler_factor)
        return flagged

    def evaluate_timers(self, timers: Dict[int, "StepTimer"]) -> List[int]:
        """Convenience wrapper: derive (medians, counts) from per-rank
        :class:`StepTimer`\\ s — the host-side all-gather payload."""
        return self.evaluate({r: t.median for r, t in timers.items()},
                             {r: t.count for r, t in timers.items()})


@dataclasses.dataclass
class NaNGuard:
    """Skip-and-count policy for non-finite losses; halt after a run of them.

    Transient non-finite steps (a bad batch, a flaky host) are skipped —
    the params/opt-state update for that step is discarded. ``max_consecutive``
    non-finite steps in a row aborts the run (systematic divergence).
    """

    max_consecutive: int = 5

    def __post_init__(self):
        self.consecutive = 0
        self.total_skipped = 0

    def check(self, loss: float) -> str:
        """Returns 'ok' | 'skip' | 'halt'."""
        if math.isfinite(loss):
            self.consecutive = 0
            return "ok"
        self.consecutive += 1
        self.total_skipped += 1
        if self.consecutive >= self.max_consecutive:
            return "halt"
        return "skip"
