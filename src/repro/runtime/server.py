"""Batched serving loop: continuous batching over fixed decode slots.

Production shape (vLLM-style, adapted to TPU static shapes):

  * ``num_slots`` decode lanes share ONE jitted serve step — shapes never
    change, so there is exactly one compilation;
  * every scheduler tick advances *all* active slots by one token in a
    single device call, with **per-slot cache cursors** (a ``(B,)`` index
    vector; the attention layers scatter each row at its own position and
    mask per-row) — newly admitted requests prefill token-by-token while
    older requests keep decoding, with no head-of-line blocking;
  * retired slots are re-admitted immediately; their stale cache rows are
    unreachable because the new request's cursor restarts at 0 and the
    per-row causal mask hides everything beyond it.

Sampling happens host-side from the returned last-token logits (greedy or
temperature); fusing sampling into the device step is a listed perf
follow-up in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    generated: Optional[List[int]] = None

    @property
    def text_len(self) -> int:
        return len(self.prompt) + len(self.generated or ())


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    cursor: int = 0                 # tokens written into this slot's cache
    prefill_pos: int = 0            # next prompt token to feed


class Server:
    def __init__(self, model, params, *, num_slots: int, max_len: int,
                 eos_id: Optional[int] = None, seed: int = 0,
                 cache_dtype=jnp.float32):
        """``cache_dtype``: K/V cache storage dtype — a jnp dtype or
        "float32" / "bfloat16" / "int8" (int8 carries per-row scales and
        dequantizes inside the decode kernel, see ``Attention.init_cache``).
        """
        self.model = model
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.rng = np.random.default_rng(seed)
        self.cache = model.init_cache(num_slots, max_len, cache_dtype)
        self.slots = [_Slot() for _ in range(num_slots)]
        self.queue: List[Request] = []
        self.done: Dict[int, Request] = {}
        self.ticks = 0
        self._decode = jax.jit(self._decode_impl)

    def _decode_impl(self, params, cache, tokens, index_vec):
        logits, _, new_cache = self.model(params, tokens, cache=cache,
                                          cache_index=index_vec, remat=False)
        return logits[:, -1], new_cache

    # -- admission -----------------------------------------------------------
    def submit(self, request: Request):
        request.generated = []
        self.queue.append(request)

    def _admit(self):
        for slot in self.slots:
            if slot.request is None and self.queue:
                slot.request = self.queue.pop(0)
                slot.cursor = 0
                slot.prefill_pos = 0

    # -- main loop -----------------------------------------------------------
    def step(self):
        """One tick: admit, advance every active slot one token, retire."""
        self._admit()
        tokens = np.zeros((self.num_slots, 1), np.int32)
        index = np.zeros(self.num_slots, np.int32)
        active = []
        for i, slot in enumerate(self.slots):
            req = slot.request
            if req is None:
                continue
            active.append(i)
            index[i] = slot.cursor
            if slot.prefill_pos < len(req.prompt):
                tokens[i, 0] = req.prompt[slot.prefill_pos]
            else:
                tokens[i, 0] = req.generated[-1]
        if not active:
            return
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(index))
        logits = np.asarray(logits.astype(jnp.float32))
        self.ticks += 1
        for i in active:
            slot = self.slots[i]
            req = slot.request
            slot.cursor += 1
            if slot.prefill_pos < len(req.prompt):
                slot.prefill_pos += 1
                if slot.prefill_pos < len(req.prompt):
                    continue                      # still prefilling
            tok = self._sample(logits[i], req)
            req.generated.append(tok)
            finished = (len(req.generated) >= req.max_new_tokens
                        or (self.eos_id is not None and tok == self.eos_id)
                        or slot.cursor >= self.max_len - 1)
            if finished:
                self.done[req.uid] = req
                slot.request = None

    def _sample(self, logits: np.ndarray, req: Request) -> int:
        if req.temperature <= 0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / req.temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def run_until_drained(self, max_ticks: int = 10_000):
        while (self.queue or any(s.request for s in self.slots)) \
                and self.ticks < max_ticks:
            self.step()
        return self.done
