"""Fault-tolerant training loop.

The loop composes the substrates into the production shape:

  restore-or-init -> [data.next -> step -> monitors -> periodic ckpt] -> final ckpt

Fault-tolerance contract (exercised by tests/test_trainer_server.py and
tests/test_chaos.py, drilled end-to-end by ``repro.launch.chaos``):
  * **checkpoint/restart**: every ``ckpt_every`` steps the trainer saves
    (params, opt_state, data cursor, step). A killed-and-relaunched run
    resumes bit-exactly (same data order, same params trajectory).
  * **verified restore with fallback**: restore walks back past corrupt
    (truncated / bit-rotted / torn) checkpoints to the newest one whose
    CRC32 manifest verifies, instead of crashing on the latest; a
    NaN-halt checkpoint is tagged ``halt_reason`` and refuses a blind
    resume without ``force``.
  * **NaN guard**: non-finite losses skip the update (the step's params are
    discarded); a run of them halts with a clear error instead of training
    garbage for hours.
  * **straggler monitor**: rolling step-time medians feed a
    :class:`StragglerPolicy`; flagged ranks are reported via callback
    (the cluster integration point).
  * **preemption hook**: ``should_stop`` is polled each step; on SIGTERM
    (spot eviction) the harness sets it, the trainer checkpoints and exits
    cleanly.
  * **periodic eval**: every ``eval_every`` steps ``eval_cb(step, params)``
    runs (e.g. closed-loop rollout metrics through runtime.evaluation);
    it only reads params, so resume bit-exactness is unaffected.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, Optional

import jax

from repro import obs
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import ShardedIterator
from repro.runtime.monitor import NaNGuard, StepTimer

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    keep_checkpoints: int = 3
    max_consecutive_nans: int = 5
    eval_every: int = 0            # 0 disables the periodic eval callback


class Trainer:
    def __init__(self, step_fn: Callable, params, opt_state,
                 data: ShardedIterator, ckpt_dir: str,
                 config: TrainerConfig = TrainerConfig(),
                 metrics_cb: Optional[Callable[[int, Dict], None]] = None,
                 should_stop: Optional[Callable[[], bool]] = None,
                 param_shardings=None,
                 eval_cb: Optional[Callable[[int, Any], None]] = None,
                 registry: Optional[obs.Registry] = None,
                 flight: Optional[obs.FlightRecorder] = None):
        self.obs = registry if registry is not None else obs.get_registry()
        # postmortem flight recorder: dumped on NaN-halt / preemption
        self.flight = flight
        if flight is not None:
            flight.add_provider("trainer", self._flight_state)
        self.step_fn = step_fn
        self.params = params
        self.opt_state = opt_state
        self.data = data
        self.config = config
        self.ckpt = CheckpointManager(ckpt_dir, keep=config.keep_checkpoints)
        self.metrics_cb = metrics_cb or (lambda s, m: None)
        self.should_stop = should_stop or (lambda: False)
        self.eval_cb = eval_cb
        self.param_shardings = param_shardings
        self.step = 0
        self.timer = StepTimer()
        self.nan_guard = NaNGuard(config.max_consecutive_nans)
        self.history: list = []

    def _flight_state(self) -> Dict[str, Any]:
        """Host-side trainer state for the flight recorder: the loss tail
        and NaN accounting the postmortem view leads with."""
        return {"step": self.step,
                "nan_consecutive": self.nan_guard.consecutive,
                "nan_skipped_total": self.nan_guard.total_skipped,
                "step_time_median_s": self.timer.median,
                "loss_tail": [float(v) for v in self.history[-20:]]}

    # ------------------------------------------------------------------
    def restore_if_available(self, force: bool = False) -> bool:
        """Restore from the newest checkpoint that passes integrity
        verification (CRC32 + structure) — a corrupt/truncated latest
        checkpoint costs ``ckpt_every`` steps of replay, not the run;
        every step walked over is logged with its reason and counted in
        ``trainer.ckpt_fallback`` / surfaced as a ``trainer.ckpt_skipped``
        event.

        A checkpoint tagged ``halt_reason`` (saved by a NaN-halt) is
        refused without ``force=True``: blindly resuming from the exact
        params + data cursor that just diverged reproduces the same
        divergence — the operator must acknowledge (``--force`` on the
        launcher) after changing something."""
        tree, extra = self.ckpt.restore(fallback=True)
        if tree is None:
            return False
        report = self.ckpt.last_restore_report
        for s in report.get("skipped", ()):
            self.obs.counter("trainer.ckpt_fallback").inc()
            self.obs.event("trainer.ckpt_skipped", step=s["step"],
                           reason=s["reason"])
        halt_reason = (extra or {}).get("halt_reason")
        if halt_reason and not force:
            raise RuntimeError(
                f"checkpoint at step {int(extra['step'])} was saved by a "
                f"'{halt_reason}' halt; resuming it replays the same "
                f"divergence (same params, same data cursor). Pass "
                f"force=True (launcher: --force) to resume anyway.")
        self.params = tree["params"] if self.param_shardings is None else \
            jax.tree.map(jax.device_put, tree["params"], self.param_shardings)
        self.opt_state = tree["opt_state"]
        self.step = int(extra["step"])
        self.data.load_state_dict(extra["data"])
        log.info("restored from step %d%s", self.step,
                 f" (skipped {len(report['skipped'])} corrupt checkpoint(s))"
                 if report.get("skipped") else "")
        return True

    def _save(self, halt_reason: Optional[str] = None):
        extra = {"step": self.step, "data": self.data.state_dict()}
        if halt_reason is not None:
            # tag the checkpoint with why the run died so a relaunch can
            # refuse to blindly resume into the same divergence
            extra["halt_reason"] = halt_reason
        with self.obs.span("trainer.checkpoint"):
            self.ckpt.save(
                self.step,
                {"params": self.params, "opt_state": self.opt_state},
                extra=extra)

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        cfg = self.config
        while self.step < cfg.total_steps:
            if self.should_stop():
                log.warning("preemption requested; checkpointing at step %d",
                            self.step)
                if self.flight is not None:
                    log.warning("flight-recorder bundle: %s",
                                self.flight.dump(reason="preempted",
                                                 step=self.step))
                self._save()
                self.ckpt.wait()
                return {"status": "preempted", "step": self.step,
                        "nan_skipped": self.nan_guard.total_skipped}
            batch = next(self.data)
            self.timer.start()
            # the step span covers dispatch + the loss materialization the
            # loop already pays (float(metrics["loss"]) below) — telemetry
            # adds no sync of its own, it reads the same host float
            with self.obs.span("trainer.step"):
                new_params, new_opt, metrics = self.step_fn(
                    self.params, self.opt_state, batch)
                loss = float(metrics["loss"])
            self.timer.stop()
            verdict = self.nan_guard.check(loss)
            if verdict == "halt":
                self.obs.event("trainer.halt", step=self.step,
                               consecutive=self.nan_guard.consecutive)
                if self.flight is not None:
                    log.error("flight-recorder bundle: %s",
                              self.flight.dump(reason="nan_halt",
                                               step=self.step, loss=loss))
                self._save(halt_reason="nan")
                self.ckpt.wait()
                raise FloatingPointError(
                    f"{self.nan_guard.consecutive} consecutive non-finite "
                    f"losses at step {self.step}")
            if verdict == "skip":
                log.warning("non-finite loss at step %d; update skipped",
                            self.step)
                self.obs.counter("trainer.nan_skipped").inc()
                self.step += 1
                continue
            self.params, self.opt_state = new_params, new_opt
            self.step += 1
            self.history.append(loss)
            if self.step % cfg.log_every == 0:
                self.obs.gauge("trainer.step_time_median_s") \
                    .set(self.timer.median)
                self.metrics_cb(self.step, {
                    **{k: float(v) for k, v in metrics.items()},
                    "sec_per_step": self.timer.median,
                    # a run that silently discarded N steps must not look
                    # identical to a clean one (tests/test_obs.py pins it)
                    "nan_skipped_total": self.nan_guard.total_skipped,
                    "nan_consecutive": self.nan_guard.consecutive})
            if self.step % cfg.ckpt_every == 0:
                self._save()
            # periodic evaluation (e.g. closed-loop rollout metrics): reads
            # params only, so it cannot perturb the bit-exact resume contract
            if (cfg.eval_every and self.eval_cb is not None
                    and self.step % cfg.eval_every == 0):
                with self.obs.span("trainer.eval"):
                    self.eval_cb(self.step, self.params)
        self._save()
        self.ckpt.wait()
        return {"status": "done", "step": self.step,
                "final_loss": self.history[-1] if self.history else None,
                "nan_skipped": self.nan_guard.total_skipped}
