"""Step functions: train_step / prefill / serve_step for every architecture.

These are the units the launcher jits, the dry-run lowers at 512 devices,
and the smoke tests run on CPU. Inputs are declared via :func:`input_specs`
(ShapeDtypeStructs — the dry-run never allocates the trillion-parameter
configs) and sharded via the logical-axis rules.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import batch_sharding, spec_for
from repro.nn.module import cast_params
from repro.nn.transformer import build_model
from repro.optim.transforms import Optimizer, apply_updates


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(logits, labels, mask=None):
    """Token cross entropy, computed against vocab-sharded logits.

    The log-softmax reductions are over the (model-sharded) vocab axis;
    GSPMD turns them into cheap scalar all-reduces instead of gathering the
    full logits — the reason we keep the vocab axis sharded end to end.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is None:
        return jnp.mean(nll)
    w = mask.astype(jnp.float32)
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1.0)


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, optimizer: Optimizer,
                    impl: Optional[str] = None,
                    remat: bool = True, unroll: bool = False) -> Callable:
    model = build_model(cfg, impl=impl, unroll=unroll)

    def train_step(params, opt_state, batch):
        def loss_fn(p32):
            # Cast parameters to the compute dtype HERE, on the FSDP-sharded
            # storage: every downstream weight all-gather then moves bf16
            # (not f32), and the matmul-transpose gradient reductions across
            # the data axis reduce in bf16 too — halving the two largest
            # collective classes. Grads arrive f32 at the optimizer via the
            # cast transpose.
            p = cast_params(p32, cfg.compute_dtype)
            if cfg.enc_dec:
                logits, aux, _ = model(p, batch["frames"], batch["tokens"])
            elif cfg.vision_prefix:
                logits, aux, _ = model(p, batch["tokens"],
                                       prefix_embeds=batch["prefix"],
                                       remat=remat)
                logits = logits[:, cfg.vision_prefix:]
            else:
                logits, aux, _ = model(p, batch["tokens"], remat=remat)
            loss = lm_loss(logits, batch["labels"])
            return loss + aux, (loss, aux)

        (_, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        updates, new_opt = optimizer.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "aux": aux, "grad_norm": gnorm}
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, impl: Optional[str] = None,
                      unroll: bool = False) -> Callable:
    model = build_model(cfg, impl=impl, unroll=unroll)

    def prefill(params, batch):
        if cfg.enc_dec:
            logits, _, _ = model(params, batch["frames"], batch["tokens"])
        elif cfg.vision_prefix:
            logits, _, _ = model(params, batch["tokens"],
                                 prefix_embeds=batch["prefix"], remat=False)
        else:
            logits, _, _ = model(params, batch["tokens"], remat=False)
        return logits[:, -1]

    return prefill


def make_serve_step(cfg: ModelConfig, impl: Optional[str] = None,
                    unroll: bool = False) -> Callable:
    """One decode step: new token + preallocated cache at ``index``."""
    model = build_model(cfg, impl=impl, unroll=unroll)

    def serve_step(params, cache, tokens, index, enc_out=None):
        if cfg.enc_dec:
            logits, new_cache = model.decode(params, tokens, enc_out,
                                             cache=cache, cache_index=index)
        else:
            logits, _, new_cache = model(params, tokens, cache=cache,
                                         cache_index=index, remat=False)
        return logits[:, -1], new_cache

    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs (dry-run) and sharding resolution
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the non-parameter step inputs."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cdt = cfg.compute_dtype
    if shape.mode == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.enc_dec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), cdt)
        if cfg.vision_prefix:
            specs["prefix"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_prefix, cfg.d_model), cdt)
        return specs
    if shape.mode == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.enc_dec:
            specs["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), cdt)
        if cfg.vision_prefix:
            specs["prefix"] = jax.ShapeDtypeStruct(
                (b, cfg.vision_prefix, cfg.d_model), cdt)
        return specs
    if shape.mode == "decode":
        model = build_model(cfg)
        cache = jax.eval_shape(
            functools.partial(model.init_cache, b, s, cdt))
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32),
                 "index": jax.ShapeDtypeStruct((), i32),
                 "cache": cache}
        if cfg.enc_dec:
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_frames, cfg.d_model), cdt)
        return specs
    raise ValueError(shape.mode)


# Logical axes for cache entries, keyed by leaf name. Trailing dims are
# matched right-to-left so the leading "layers" stacking dim is covered.
_CACHE_AXES = {
    "k": (None, "act_batch", "act_kv", "act_kvlen", None),
    "v": (None, "act_batch", "act_kv", "act_kvlen", None),
    "ckv": (None, "act_batch", None, "act_kvlen", None),
    "kr": (None, "act_batch", None, "act_kvlen", None),
    "s": (None, "act_batch", "act_heads", None, None),
    "h": (None, "act_batch", "act_mlp", None),
    "conv": (None, "act_batch", None, "act_mlp"),
    "shift": (None, "act_batch", None),
    "cmix_shift": (None, "act_batch", None),
}


def cache_sharding(cache_tree, mesh, rules=None):
    """NamedSharding tree for a (possibly layer-stacked) decode cache."""
    from jax.sharding import NamedSharding

    def walk(tree, key=None):
        if isinstance(tree, dict):
            return {k: walk(v, k) for k, v in tree.items()}
        axes = _CACHE_AXES.get(key)
        if axes is None:
            logical = [None] * tree.ndim
        elif tree.ndim >= len(axes):
            logical = [None] * (tree.ndim - len(axes)) + list(axes)
        else:
            logical = list(axes[len(axes) - tree.ndim:])
        return NamedSharding(mesh, spec_for(tree.shape, logical, mesh, rules))

    return walk(cache_tree)


def batch_shardings(specs: Dict[str, Any], mesh, rules=None):
    """Shardings for the input-spec dict (batch-leading arrays + cache)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = cache_sharding(v, mesh, rules)
        elif k == "index":
            out[k] = NamedSharding(mesh, P())
        else:
            out[k] = batch_sharding(mesh, v.shape, rules)
    return out
