"""Runtime: step functions, fault-tolerant trainer, serving loop, monitors."""
from repro.runtime import steps
from repro.runtime.steps import (input_specs, lm_loss, make_prefill_step,
                                 make_serve_step, make_train_step)

__all__ = ["steps", "input_specs", "lm_loss", "make_prefill_step",
           "make_serve_step", "make_train_step"]
