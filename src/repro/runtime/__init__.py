"""Runtime: step functions, fault-tolerant trainer, serving/rollout loops,
closed-loop evaluation, monitors."""
from repro.runtime import steps
from repro.runtime.evaluation import (EvalConfig, evaluate_families,
                                      evaluate_scenes)
from repro.runtime.rollout import RolloutEngine, rollout_keys
from repro.runtime.sim_server import (SceneRequest, SimResult, SimServer,
                                      serve_scenes)
from repro.runtime.steps import (input_specs, lm_loss, make_prefill_step,
                                 make_serve_step, make_train_step)

__all__ = ["steps", "input_specs", "lm_loss", "make_prefill_step",
           "make_serve_step", "make_train_step", "RolloutEngine",
           "rollout_keys", "EvalConfig", "evaluate_families",
           "evaluate_scenes", "SceneRequest", "SimResult", "SimServer",
           "serve_scenes"]
