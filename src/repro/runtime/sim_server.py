"""Continuous-batching simulation service: mid-flight scene admission.

The closed-loop analogue of :class:`repro.runtime.server.Server`, built
on the same fixed-slot discipline the :class:`RolloutEngine` introduced —
but where the engine runs one batch of scenes start-to-finish in
lockstep, the server is **long-lived**: scenes are admitted into free
slots and evicted at their horizon *while every other slot keeps
ticking*, so heavy traffic streams through one resident jitted tick with
exactly one compilation. The moving parts:

* **Slab KV cache.** All concurrent scenes share ONE layer-stacked
  ``(L, B, H, S_slab, ·)`` cache (f32 / bf16 / int8 + scales — PR 5's
  in-place plumbing) instead of each scene paying its own ``max_len``
  allocation + compile. A retiring scene frees its slot immediately; the
  successor's rows simply overwrite the prefix. Rows the predecessor
  left beyond the reset cursor are **not scrubbed** — they are provably
  unreachable, because every decode masks key positions >=
  ``kv_length = cursor + n`` and the cursor only ever advances over
  freshly written rows (``docs/serving.md`` states the full argument;
  ``tests/test_sim_server.py`` pins it bit-for-bit, adversarially).

* **Incremental prefill through the shared tick.** Admission writes only
  the scene's M map tokens (``AgentSimModel.admit_map`` on a throwaway
  1-slot cache, installed via ``install_slot_rows``); the scene's
  history then streams through the SAME jitted tick as everyone else,
  one teacher-forced step per tick — the sim twin of the LM server's
  token-by-token prompt prefill. No head-of-line blocking: a slot
  mid-prefill coexists with slots mid-rollout, and eviction is legal at
  any tick (mid-prefill included).

* **Bit-reproducibility under churn.** Sampling is keyed per
  (scene_id, sample_id) exactly like ``rollout_keys`` and folded with
  the slot's own sim time, and the streamed prefill is bit-identical to
  the engine's one-shot prefill (fully masked key blocks contribute
  exact zeros to the online softmax), so a scene's actions and poses are
  bit-identical to the same scene run alone in a fresh
  ``RolloutEngine`` — regardless of arrival order, slot assignment,
  co-residents, or cache recycling.

* **Host<->device pipelining.** ``tick()`` only *dispatches* device
  work; per-tick outputs (poses, action ids) are kept as device handles
  on a drain queue and materialized ``drain_lag`` ticks later, so tick
  t+1 is enqueued while tick t's metrics drain.

* **Per-slot health / quarantine.** The drain already materializes
  every lane's poses and action ids on the host; a numerically poisoned
  lane (NaN state — a bad scene, a kernel bug, a flipped bit) is caught
  there by a cheap non-finite / action-range check and **quarantined**:
  the lane's ``SimResult`` is delivered immediately with
  ``status="failed"`` + a reason, its slot is scrubbed back to the
  fresh-cache invariant and freed, and ``sim_server.quarantined`` /
  a ``sim_server.quarantine`` event record it. Healthy co-resident
  slots keep serving BIT-identical outputs to a fault-free run — slots
  only ever read their own slab rows, and every kernel applies masks
  with ``jnp.where`` after the score computation, so even non-finite
  stale rows cannot leak (drilled by ``repro.launch.chaos`` and pinned
  in ``tests/test_chaos.py``).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.nn.agent_sim import install_slot_rows
from repro.runtime.rollout import step_kinematics
from repro.scenarios.core import ScenarioConfig

__all__ = ["SceneRequest", "SimResult", "SimServer", "serve_scenes",
           "poisson_drive"]


@dataclasses.dataclass
class SceneRequest:
    """One (scene, sample) rollout lane.

    ``tensors`` is a scene tensor dict (or a ``Scene`` — anything with a
    ``.tensors``). ``t_hist`` history steps are teacher-forced, then the
    lane rolls out closed-loop until step ``t_total`` (default: the
    scenario config's ``num_steps``). Neither affects tensor shapes, so
    requests with different lengths share the one compiled tick.

    The sampling key is ``fold_in(fold_in(key(seed), scene_id),
    sample_id)`` — the exact ``rollout_keys`` stream, so a lane with
    ``scene_id=i, sample_id=k`` reproduces lane (i, k) of a
    ``RolloutEngine.run(..., seed=seed)`` bit-for-bit. ``scene_id``
    defaults to ``uid``.
    """
    uid: int
    tensors: Any
    t_hist: int
    t_total: Optional[int] = None
    seed: int = 0
    scene_id: Optional[int] = None
    sample_id: int = 0

    def __post_init__(self):
        if hasattr(self.tensors, "tensors"):
            self.tensors = self.tensors.tensors
        if self.scene_id is None:
            self.scene_id = self.uid


@dataclasses.dataclass
class SimResult:
    uid: int
    t_hist: int
    t_total: int
    future: np.ndarray        # (t_total - t_hist, A, 3) sampled poses
    actions: np.ndarray       # (t_total - t_hist, A) sampled action ids
    # slot-health outcome: "ok", or "failed" when the lane was
    # quarantined (non-finite poses / out-of-range actions) — the
    # partial future/actions up to the failure are preserved for
    # debugging, zero-filled beyond it
    status: str = "ok"
    reason: str = ""


@dataclasses.dataclass
class _Slot:
    req: Optional[SceneRequest] = None
    t: int = 0                # next sim step this slot will process


class SimServer:
    """Long-lived continuous-batching closed-loop simulation service."""

    def __init__(self, model, params, scen_cfg: ScenarioConfig, *,
                 num_slots: int, max_len: Optional[int] = None,
                 cache_dtype=None, decode_impl: Optional[str] = None,
                 drain_lag: int = 1,
                 registry: Optional[obs.Registry] = None):
        """``max_len``: slab width per slot in cache rows (default: the
        config's worst case ``M + num_steps * A``; rounded up to the
        decode kernel's 128-row block like ``RolloutEngine``). A request
        needs ``M + t_total * A <= max_len``. ``drain_lag``: how many
        ticks a tick's outputs stay on device before the host
        materializes them (1 = classic double buffering; 0 = synchronous,
        for latency measurements). ``cache_dtype`` / ``decode_impl`` as
        in ``RolloutEngine``.

        ``registry``: telemetry home (``repro.obs``; ``None`` = process
        default, ``obs.NULL`` = off). Every tick records a
        ``sim_server.tick`` span plus occupancy / resident / queued
        gauges from host-side bookkeeping; admissions record
        ``sim_server.queue_wait.seconds`` (submit -> admit) and, once a
        lane's first closed-loop action drains,
        ``sim_server.first_action.seconds`` (admit -> first action on
        host, pipelined drain included). All samples are host wall-clock
        or host counters — telemetry never touches a device value, so
        obs-on/obs-off runs are bit-identical and compile-count-identical
        (tests/test_obs.py)."""
        self.obs = registry if registry is not None else obs.get_registry()
        self.model = model
        self.params = params
        self.scen = scen_cfg
        self.num_slots = num_slots
        self.cache_dtype = cache_dtype
        self.decode_impl = decode_impl
        self.drain_lag = drain_lag
        max_len = max_len or (scen_cfg.num_map
                              + scen_cfg.num_steps * scen_cfg.num_agents)
        self.max_len = -(-max_len // 128) * 128 if max_len > 128 else max_len
        m = scen_cfg.num_map
        # throwaway admission cache: just wide enough for the map block,
        # block-aligned the same way as the slab
        self._sub_len = -(-m // 128) * 128 if m > 128 else m
        self._accel = jnp.asarray(scen_cfg.accel_values(), jnp.float32)
        self._yaw = jnp.asarray(scen_cfg.yaw_values(), jnp.float32)

        self.cache = model.init_cache(num_slots, self.max_len, cache_dtype)
        a = scen_cfg.num_agents
        kd = jax.random.key_data(jax.random.key(0))
        cdt = model.cfg.compute_dtype
        self.state = {
            "logits": jnp.zeros((num_slots, a, model.cfg.num_actions), cdt),
            "pose": jnp.zeros((num_slots, a, 3), jnp.float32),
            "speed": jnp.zeros((num_slots, a), jnp.float32),
            "proto": jnp.zeros((num_slots, a, scen_cfg.agent_feat_dim),
                               jnp.float32),
            "valid": jnp.zeros((num_slots, a), bool),
            "keys": jnp.zeros((num_slots,) + kd.shape, kd.dtype),
        }
        self.slots = [_Slot() for _ in range(num_slots)]
        self.queue: Deque[SceneRequest] = collections.deque()
        self.done: Dict[int, SimResult] = {}
        self._buf: Dict[int, Dict[str, Any]] = {}       # uid -> fill state
        # drain queue: (routes, acts_dev, pose_dev); routes maps batch
        # row -> (uid, future index)
        self._pending: Deque[Tuple[List[Tuple[int, int, int]], Any, Any]] \
            = collections.deque()
        self.ticks = 0
        self.admitted = 0
        self.evicted = 0
        self.quarantined = 0
        self._num_actions = int(model.cfg.num_actions)
        # Tracing the impl body is what a (re)compilation costs; the
        # retrace-guard test pins these at exactly 1 under slot churn.
        # Mirrored into the registry (sim_server.tick_traces /
        # admit_traces counters) so obs_report shows compile counts.
        self.tick_traces = 0
        self.admit_traces = 0
        self._submit_ts: Dict[int, float] = {}      # uid -> submit wall-time
        self.obs.gauge("sim_server.slab_rows").set(num_slots * self.max_len)
        self.obs.gauge("sim_server.slab_bytes").set(
            sum(int(np.prod(v.shape)) * v.dtype.itemsize
                for v in jax.tree.leaves(self.cache)))
        # CostAccounted AOT-compiles on first call (one trace, one
        # compilation — the retrace guards still hold) and records the
        # compiled FLOPs/bytes as cost.* gauges; see repro/obs/cost.py.
        self._tick = obs.CostAccounted(
            jax.jit(self._tick_impl, donate_argnums=(1, 2)),
            "sim_server.tick", registry=self.obs)
        self._admit = obs.CostAccounted(
            jax.jit(self._admit_impl, donate_argnums=(1, 2)),
            "sim_server.admit", registry=self.obs)

    # -- admission / eviction -------------------------------------------------

    def submit(self, req: SceneRequest):
        req.t_total = req.t_total or self.scen.num_steps
        live = self.scen.num_map + req.t_total * self.scen.num_agents
        if live > self.max_len:
            raise ValueError(
                f"request {req.uid}: live length {live} rows exceeds the "
                f"slab width {self.max_len}; raise max_len or shorten "
                f"t_total")
        if not 0 < req.t_hist <= req.t_total:
            raise ValueError(
                f"request {req.uid}: need 0 < t_hist <= t_total, got "
                f"({req.t_hist}, {req.t_total})")
        if req.uid in self._buf or req.uid in self.done \
                or any(s.req is not None and s.req.uid == req.uid
                       for s in self.slots) \
                or any(r.uid == req.uid for r in self.queue):
            raise ValueError(f"duplicate request uid {req.uid}")
        self._submit_ts[req.uid] = time.perf_counter()
        self.obs.counter("sim_server.submitted").inc()
        self.queue.append(req)

    def evict(self, uid: int) -> bool:
        """Cancel a resident request (legal at any tick, mid-prefill
        included). Its slot is immediately reusable; whatever rows it
        wrote stay in the slab, unreachable to successors. Returns
        whether the uid was found (resident or queued)."""
        for slot in self.slots:
            if slot.req is not None and slot.req.uid == uid:
                slot.req = None
                self._buf.pop(uid, None)
                self.evicted += 1
                self.obs.counter("sim_server.evicted").inc()
                self.obs.event("sim_server.evict", uid=uid, phase="resident")
                return True
        for r in self.queue:
            if r.uid == uid:
                self.queue.remove(r)
                self._submit_ts.pop(uid, None)
                self.obs.event("sim_server.evict", uid=uid, phase="queued")
                return True
        return False

    def _admit_pending(self):
        for si, slot in enumerate(self.slots):
            if slot.req is not None or not self.queue:
                continue
            req = self.queue.popleft()
            now = time.perf_counter()
            submit_ts = self._submit_ts.pop(req.uid, now)
            self.obs.histogram("sim_server.queue_wait.seconds") \
                .record(now - submit_ts)
            key = jax.random.fold_in(
                jax.random.fold_in(jax.random.key(req.seed), req.scene_id),
                req.sample_id)
            tt = req.tensors
            with self.obs.span("sim_server.admit"):
                self.cache, self.state = self._admit(
                    self.params, self.cache, self.state,
                    jnp.asarray(tt["map_feats"])[None],
                    jnp.asarray(tt["map_pose"])[None],
                    jnp.asarray(tt["map_valid"])[None],
                    jnp.asarray(si, jnp.int32), jax.random.key_data(key))
            slot.req = req
            slot.t = 0
            t_fut = req.t_total - req.t_hist
            a = self.scen.num_agents
            self._buf[req.uid] = {
                "future": np.zeros((t_fut, a, 3), np.float32),
                "actions": np.zeros((t_fut, a), np.int32),
                "filled": 0, "req": req,
                "admit_ts": time.perf_counter(),
            }
            self.admitted += 1
            self.obs.counter("sim_server.admitted").inc()

    def _admit_impl(self, params, cache, state, map_feats, map_pose,
                    map_valid, si, key_data):
        """Jitted admission: cursor reset + re-arm + map-token install.

        ``si`` is traced, so every slot shares one compilation. The map
        rows are computed on a fresh throwaway 1-slot cache — admission
        is byte-equivalent to the first M rows of a fresh engine's
        prefill by construction — then installed over slot ``si``'s
        prefix. Slot state (pose/speed/logits/validity) is zeroed; the
        first teacher tick supplies the real values.
        """
        self.admit_traces += 1
        self.obs.counter("sim_server.admit_traces").inc()
        with jax.named_scope("sim_server.admit"):
            m = map_feats.shape[1]
            sub = self.model.init_cache(1, self._sub_len, self.cache_dtype)
            _, sub = self.model.admit_map(params, sub, map_feats, map_pose,
                                          map_valid, impl=self.decode_impl)
            cache = install_slot_rows(cache, sub, si, m)
            state = dict(state)
            for k in ("logits", "pose", "speed", "proto", "valid"):
                state[k] = state[k].at[si].set(
                    jnp.zeros(state[k].shape[1:], state[k].dtype))
            state["keys"] = state["keys"].at[si].set(key_data)
            return cache, state

    # -- the tick -------------------------------------------------------------

    def _tick_impl(self, params, cache, state, tfeats, tpose, tvalid,
                   t, active, teacher):
        """One service tick, fully on device, every slot in one call.

        Rollout slots run the exact ``RolloutEngine`` step: sample an
        action per agent from the previous step's logits (key folded
        with the slot's OWN sim time — slots at different progress draw
        from their own streams), integrate kinematics, decode the new
        agent tokens against the slab. Teacher (mid-prefill) slots feed
        their history step instead — same token path, same mask, so
        prefill is just ticks with overridden inputs. Inactive slots are
        carried along shape-stably: their sampled garbage is discarded,
        their state frozen, and their cursor un-advanced — the A rows
        the decode scattered into their slab prefix land beyond the
        authoritative cursor and are unreachable (deliberately so: churn
        actively scribbles retired slots, and the isolation tests prove
        it cannot matter).
        """
        self.tick_traces += 1
        self.obs.counter("sim_server.tick_traces").inc()
        with jax.named_scope("sim_server.tick"):
            return self._tick_body(params, cache, state, tfeats, tpose,
                                   tvalid, t, active, teacher)

    def _tick_body(self, params, cache, state, tfeats, tpose, tvalid,
                   t, active, teacher):
        logits, pose, speed = state["logits"], state["pose"], state["speed"]
        proto, valid = state["proto"], state["valid"]
        keys = jax.random.wrap_key_data(state["keys"])
        keys_t = jax.vmap(jax.random.fold_in)(keys, t)
        acts = jax.vmap(jax.random.categorical)(
            keys_t, logits.astype(jnp.float32))              # (B, A)
        ai, yi = jnp.divmod(acts, self.scen.yaw_bins)
        new_pose, new_speed = step_kinematics(pose, speed, self._accel[ai],
                                              self._yaw[yi])
        new_pose = jnp.where(valid[..., None], new_pose, pose)
        new_speed = jnp.where(valid, new_speed, speed)
        tm = teacher[:, None]
        pose_in = jnp.where(tm[..., None], tpose, new_pose)
        speed_in = jnp.where(tm, tfeats[..., 0] * 10.0, new_speed)
        valid_in = jnp.where(tm, tvalid, valid)
        proto_in = jnp.where(tm[..., None], tfeats, proto)
        feats_in = jnp.where(tm[..., None], tfeats,
                             proto.at[..., 0].set(new_speed / 10.0))
        cur0 = cache["cursor"]
        new_logits, cache = self.model.step(params, cache, feats_in, pose_in,
                                            valid_in, t,
                                            impl=self.decode_impl)
        am1, am2 = active[:, None], active[:, None, None]
        cache["cursor"] = jnp.where(active, cache["cursor"], cur0)
        state = {
            "logits": jnp.where(am2, new_logits, logits),
            "pose": jnp.where(am2, pose_in, pose),
            "speed": jnp.where(am1, speed_in, speed),
            "proto": jnp.where(am2, proto_in, proto),
            "valid": jnp.where(am1, valid_in, valid),
            "keys": state["keys"],
        }
        return cache, state, acts, pose_in

    def tick(self) -> bool:
        """Admit, advance every resident slot one sim step, retire.

        Returns False when there was nothing to do (no resident or
        queued work). The device call is dispatched asynchronously;
        outputs are materialized ``drain_lag`` ticks later.
        """
        t0 = time.perf_counter()
        ticked = self._tick_host()
        # idle polls are free and would swamp the latency histogram with
        # near-zero samples; only working ticks count as spans
        if ticked:
            self.obs.observe_span("sim_server.tick", t0, time.perf_counter())
        return ticked

    def _tick_host(self) -> bool:
        self._admit_pending()
        b, a = self.num_slots, self.scen.num_agents
        active = np.zeros(b, bool)
        teacher = np.zeros(b, bool)
        t_vec = np.zeros(b, np.int32)
        tfeats = np.zeros((b, a, self.scen.agent_feat_dim), np.float32)
        tpose = np.zeros((b, a, 3), np.float32)
        tvalid = np.zeros((b, a), bool)
        routes: List[Tuple[int, int, int]] = []
        for si, slot in enumerate(self.slots):
            req = slot.req
            if req is None:
                continue
            active[si] = True
            t_vec[si] = slot.t
            if slot.t < req.t_hist:
                teacher[si] = True
                tt = req.tensors
                tfeats[si] = tt["agent_feats"][slot.t]
                tpose[si] = tt["agent_pose"][slot.t]
                tvalid[si] = tt["agent_valid"][slot.t]
            else:
                routes.append((si, req.uid, slot.t - req.t_hist))
        if not active.any():
            return False
        self.cache, self.state, acts, pose = self._tick(
            self.params, self.cache, self.state, jnp.asarray(tfeats),
            jnp.asarray(tpose), jnp.asarray(tvalid), jnp.asarray(t_vec),
            jnp.asarray(active), jnp.asarray(teacher))
        self.ticks += 1
        if routes:
            self._pending.append((routes, acts, pose))
        for slot in self.slots:
            if slot.req is None:
                continue
            slot.t += 1
            if slot.t >= slot.req.t_total:      # horizon: retire, free slot
                slot.req = None
        self._drain(self.drain_lag)
        if self.obs.enabled:
            m = self.scen.num_map
            live = sum(min(m + s.t * a, self.max_len)
                       for s in self.slots if s.req is not None)
            self.obs.counter("sim_server.ticks").inc()
            self.obs.gauge("sim_server.live_rows").set(live)
            self.obs.gauge("sim_server.occupancy").set(
                live / float(self.num_slots * self.max_len))
            self.obs.gauge("sim_server.resident").set(
                sum(s.req is not None for s in self.slots))
            self.obs.gauge("sim_server.queued").set(len(self.queue))
        return True

    # -- slot health / quarantine ---------------------------------------------

    def _health_reason(self, acts_row: np.ndarray,
                       pose_row: np.ndarray) -> Optional[str]:
        """Cheap host-side check on outputs the drain already
        materialized (no extra device touch): a numerically poisoned
        lane shows up as non-finite poses (NaN state propagates through
        the kinematic integration) or action ids outside the model's
        action space (categorical over non-finite logits)."""
        if not np.isfinite(pose_row).all():
            return "nonfinite_pose"
        if acts_row.min() < 0 or acts_row.max() >= self._num_actions:
            return "action_out_of_range"
        return None

    def _scrub_slot(self, si: int):
        """Reset slot ``si``'s slab rows and carried state to the fresh-
        cache values. Stale rows are unreachable even when non-finite
        (every kernel applies its mask with ``jnp.where`` AFTER the
        score computation, so a NaN score at a masked position is
        replaced, never propagated) — the scrub is defense in depth: it
        restores the fresh-cache invariant for the next tenant and stops
        the quarantined slot's frozen NaN state from writing more
        non-finite rows on subsequent (inactive, discarded) ticks."""
        cache = dict(self.cache)
        for k in ("k", "v", "k_scale", "v_scale"):
            if k in cache:
                cache[k] = cache[k].at[:, si].set(0)
        cache["times"] = cache["times"].at[si].set(0)
        cache["seg"] = cache["seg"].at[si].set(-1)
        cache["cursor"] = cache["cursor"].at[si].set(0)
        self.cache = cache
        state = dict(self.state)
        for k in ("logits", "pose", "speed", "proto"):
            state[k] = state[k].at[si].set(0)
        state["valid"] = state["valid"].at[si].set(False)
        self.state = state

    def _quarantine(self, si: int, uid: int, reason: str):
        """Evict a poisoned lane: its result is delivered immediately as
        ``failed`` (partial outputs preserved), its slot is scrubbed and
        freed for the next admission, and the event is counted — healthy
        slots are untouched and stay bit-identical to a fault-free run
        (pinned by tests/test_chaos.py)."""
        buf = self._buf.pop(uid, None)
        if buf is not None:
            req = buf["req"]
            self.done[uid] = SimResult(
                uid=uid, t_hist=req.t_hist, t_total=req.t_total,
                future=buf["future"], actions=buf["actions"],
                status="failed", reason=reason)
        slot = self.slots[si]
        if slot.req is not None and slot.req.uid == uid:
            slot.req = None
            self._scrub_slot(si)
        self.quarantined += 1
        self.obs.counter("sim_server.quarantined").inc()
        self.obs.event("sim_server.quarantine", uid=uid, slot=si,
                       reason=reason)

    # -- draining -------------------------------------------------------------

    def _drain(self, keep: int):
        """Materialize all but the newest ``keep`` ticks' outputs,
        health-checking every routed lane on the way."""
        while len(self._pending) > keep:
            routes, acts_dev, pose_dev = self._pending.popleft()
            acts_np = np.asarray(acts_dev)
            pose_np = np.asarray(pose_dev)
            for si, uid, fi in routes:
                buf = self._buf.get(uid)
                if buf is None:                 # evicted mid-flight
                    continue
                reason = self._health_reason(acts_np[si], pose_np[si])
                if reason is not None:
                    self._quarantine(si, uid, reason)
                    continue
                if buf["filled"] == 0:          # lane's first action landed
                    self.obs.histogram("sim_server.first_action.seconds") \
                        .record(time.perf_counter() - buf["admit_ts"])
                buf["future"][fi] = pose_np[si]
                buf["actions"][fi] = acts_np[si]
                buf["filled"] += 1
                req = buf["req"]
                if buf["filled"] == req.t_total - req.t_hist:
                    self.done[uid] = SimResult(
                        uid=uid, t_hist=req.t_hist, t_total=req.t_total,
                        future=buf["future"], actions=buf["actions"])
                    del self._buf[uid]

    def flush(self):
        """Drain every outstanding tick output to the host."""
        self._drain(0)

    def run_until_drained(self, max_ticks: int = 100_000
                          ) -> Dict[int, SimResult]:
        while (self.queue or any(s.req for s in self.slots)) \
                and self.ticks < max_ticks:
            self.tick()
        self.flush()
        return self.done

    # -- accounting -----------------------------------------------------------

    def stats(self) -> Dict[str, float]:
        """Slab accounting + lifecycle counters (host-side; no sync)."""
        slab_bytes = sum(int(np.prod(v.shape)) * v.dtype.itemsize
                         for v in jax.tree.leaves(self.cache))
        m, a = self.scen.num_map, self.scen.num_agents
        live = sum(min(m + s.t * a, self.max_len)
                   for s in self.slots if s.req is not None)
        return {
            "slots": float(self.num_slots),
            "slab_rows": float(self.num_slots * self.max_len),
            "slab_mib": slab_bytes / 2 ** 20,
            "live_rows": float(live),
            "occupancy": live / float(self.num_slots * self.max_len),
            "resident": float(sum(s.req is not None for s in self.slots)),
            "queued": float(len(self.queue)),
            "ticks": float(self.ticks),
            "admitted": float(self.admitted),
            "evicted": float(self.evicted),
            "quarantined": float(self.quarantined),
            "tick_compilations": float(self.tick_traces),
            "admit_compilations": float(self.admit_traces),
        }

    def postmortem_state(self) -> Dict[str, Any]:
        """Per-slot phase/cursor/scene-id table plus queue/drain state —
        pure host bookkeeping (no device touch), packaged for the flight
        recorder (``repro.obs.FlightRecorder``)."""
        m, a = self.scen.num_map, self.scen.num_agents
        slots = []
        for si, slot in enumerate(self.slots):
            if slot.req is None:
                slots.append({"slot": si, "phase": "idle"})
                continue
            req = slot.req
            buf = self._buf.get(req.uid, {})
            slots.append({
                "slot": si, "uid": req.uid, "scene_id": req.scene_id,
                "sample_id": req.sample_id, "t": slot.t,
                "t_hist": req.t_hist, "t_total": req.t_total,
                "phase": "prefill" if slot.t < req.t_hist else "rollout",
                "cursor_rows": min(m + slot.t * a, self.max_len),
                "filled": int(buf.get("filled", 0)),
            })
        return {"slots": slots,
                "queued_uids": [r.uid for r in self.queue],
                "done_uids": sorted(self.done),
                "pending_drains": len(self._pending),
                "stats": self.stats()}

    def dump_postmortem(self, path: str, *, reason: str = "manual",
                        **context) -> str:
        """Write a flight-recorder bundle (registry tail + snapshot +
        the per-slot table above) to ``path``; returns the path. Works
        even with telemetry off — the slot table is always live."""
        fr = obs.FlightRecorder(self.obs)
        fr.add_provider("sim_server", self.postmortem_state)
        return fr.dump(reason=reason, path=path, **context)


def poisson_drive(server: SimServer, requests: Sequence[SceneRequest], *,
                  rate: float, seed: int = 0,
                  warmup_ticks: int = 0) -> Dict[str, Any]:
    """Drive ``server`` with ``requests`` arriving as a Poisson process.

    ``rate`` is the mean arrival rate in requests per *tick* (the
    service clock): inter-arrival gaps are drawn i.i.d. exponential with
    mean ``1/rate``, so admissions interleave arbitrarily with resident
    scenes mid-prefill and mid-rollout — the schedule the invariance
    tests randomize over. Ticks until every request has drained.

    Per-tick wall-clock (device dispatch + pipelined drain) lands in a
    standalone :class:`repro.obs.Histogram` — the same log-bucket
    sketch the telemetry registry uses, so every consumer reads
    percentiles off one implementation instead of keeping raw lists.
    The first ``warmup_ticks`` *working* ticks (compile + warmup) are
    skipped. Returns ``{"latency": Histogram, "ticks": total working
    ticks incl. warmup, "arrival_ticks": ...}``.
    """
    rng = np.random.default_rng(seed)
    t_arrive = np.cumsum(rng.exponential(1.0 / rate, len(requests)))
    pending = collections.deque(zip(t_arrive, requests))
    hist = obs.Histogram("poisson_drive.tick.seconds")
    ticked_n = 0
    clock = 0.0
    while pending or server.queue or any(s.req for s in server.slots):
        while pending and pending[0][0] <= clock:
            server.submit(pending.popleft()[1])
        t0 = time.perf_counter()
        ticked = server.tick()
        if ticked:
            if ticked_n >= warmup_ticks:
                hist.record(time.perf_counter() - t0)
            ticked_n += 1
        clock += 1.0
        if not ticked and pending:        # idle gap: jump to next arrival
            clock = max(clock, pending[0][0])
    server.flush()
    return {"latency": hist, "ticks": ticked_n,
            "arrival_ticks": t_arrive.tolist()}


def serve_scenes(server: SimServer, scenes: Sequence, *, t_hist: int,
                 n_samples: int, seed: int = 0,
                 t_total: Optional[int] = None) -> np.ndarray:
    """Engine-shaped convenience: push ``scenes x n_samples`` lanes
    through ``server`` and return futures shaped exactly like
    ``RolloutEngine.run`` — (n_scenes, n_samples, T_fut, A, 3) — keyed so
    lane (si, ki) reproduces the engine's lane (si, ki) bit-for-bit.
    ``server`` must be idle (no resident work) and is left idle."""
    assert not server.queue and not any(s.req for s in server.slots), \
        "serve_scenes needs an idle server"
    base = len(server.done)
    uid0 = (max(server.done) + 1) if server.done else 0
    lanes = []
    for si, scene in enumerate(scenes):
        for ki in range(n_samples):
            uid = uid0 + len(lanes)
            server.submit(SceneRequest(
                uid=uid, tensors=scene, t_hist=t_hist, t_total=t_total,
                seed=seed, scene_id=si, sample_id=ki))
            lanes.append(uid)
    done = server.run_until_drained()
    assert len(done) - base == len(lanes)
    failed = [uid for uid in lanes if done[uid].status != "ok"]
    if failed:
        raise RuntimeError(
            f"serve_scenes: lanes {failed} were quarantined "
            f"({', '.join(sorted({done[u].reason for u in failed}))}); "
            "the stacked futures would silently contain failed lanes")
    fut = np.stack([done[uid].future for uid in lanes])
    t_fut = fut.shape[1]
    return fut.reshape(len(scenes), n_samples, t_fut,
                       server.scen.num_agents, 3)
