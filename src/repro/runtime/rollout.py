"""Batched closed-loop rollout engine over the cached SE(2) decode path.

The agent-simulation analogue of :class:`repro.runtime.server.Server`:
fixed scene slots, ONE jitted step advancing every slot in lockstep, and
per-slot cache cursors. Each engine tick appends one simulation step (A
agent tokens per scene) to every slot's K/V cache and runs the model's
incremental ``step`` — O(T) attention per tick instead of the O(T^2)
full-scene recompute the naive rollout pays (see ``docs/rollout.md`` and
``benchmarks/rollout_bench.py``).

Sampling is device-side and keyed per (scene, sample): slot ``(si, ki)``
draws from ``fold_in(fold_in(key(seed), si), ki)`` folded again with the
step index, so rollout metrics are bit-reproducible regardless of slot
assignment, chunking, or parallel execution order.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core import kinematics
from repro.scenarios.core import ScenarioConfig

#: mesh axes a fleet engine partitions its scene slots over, in order
FLEET_AXES = ("pod", "data")


def step_kinematics(pose, speed, accel, yaw_rate,
                    dt: float = kinematics.DT):
    """jnp entry point of the shared unicycle integrator
    (:mod:`repro.core.kinematics`) so the whole engine tick (decode +
    sample + integrate) stays in one jitted device call. The host data
    pipeline calls the very same function on numpy arrays — one
    implementation, identical integration by construction."""
    return kinematics.step_kinematics(pose, speed, accel, yaw_rate, dt,
                                      xp=jnp)


def rollout_keys(seed: int, n_scenes: int, n_samples: int):
    """The per-(scene, sample) PRNG keys the engine samples with; exposed so
    baselines can consume the identical stream."""
    base = jax.random.key(seed)
    return jnp.stack([
        jax.random.fold_in(jax.random.fold_in(base, si), ki)
        for si in range(n_scenes) for ki in range(n_samples)])


class RolloutEngine:
    """Closed-loop simulation over fixed slots with cached incremental decode.

    One slot = one (scene, sample) rollout. ``run`` chunks an arbitrary
    workload over ``num_slots`` lanes; every chunk reuses the same jitted
    prefill/step (shapes are static), so there is exactly one compilation
    of each.
    """

    def __init__(self, model, params, scen_cfg: ScenarioConfig,
                 *, num_slots: int, max_len: Optional[int] = None,
                 cache_dtype=None, decode_impl: Optional[str] = None,
                 mesh=None, registry: Optional[obs.Registry] = None):
        """``cache_dtype``: storage dtype of the per-layer K/V cache — a
        jnp dtype or "float32" / "bfloat16" / "int8" (int8 caches carry
        per-row scales beside K/V and are dequantized inside the decode
        kernel; see ``AgentSimModel.init_cache``). ``decode_impl``
        overrides the model's decode attention backend for this engine
        ("auto" / "flash_decode" / "xla" / "ref" / "chunked" — see
        ``repro.kernels.ops.decode_attention``); None keeps the model
        config's choice.

        ``mesh``: optional scene-axis mesh (``launch.mesh.make_fleet_mesh``)
        carrying the DP axes in :data:`FLEET_AXES`. When set, the jitted
        prefill/tick are ``shard_map``-ed over the slot axis: ``num_slots``
        lanes partition over ``("pod", "data")`` (rounded UP to a multiple
        of the shard count — ``run`` already pads partial chunks), params
        replicate, and each device advances only its local lanes. Per-slot
        PRNG keys and validity masks are computed on the HOST exactly as in
        the single-device path, every lane's attention / sampling /
        integration is lane-local, and lanes never interact — so gathered
        per-scene outputs are bit-identical to the unsharded engine
        regardless of device count or slot placement
        (tests/test_distributed.py pins this on a forced CPU mesh).

        ``registry``: telemetry home (``repro.obs``) — ``None`` = the
        process default, ``obs.NULL`` = off. The engine records
        ``rollout.prefill`` / ``rollout.step`` / ``rollout.chunk`` spans
        (host wall-clock around the async dispatches — never a forced
        sync) and a ``rollout.cache_bytes`` gauge from shape metadata;
        obs-on vs obs-off runs are bit-identical (tests/test_obs.py).
        """
        self.obs = registry if registry is not None else obs.get_registry()
        self.model = model
        self.params = params
        self.scen = scen_cfg
        self.mesh = mesh
        self.num_slots = num_slots
        max_len = max_len or (scen_cfg.num_map
                              + scen_cfg.num_steps * scen_cfg.num_agents)
        # Round up to the decode kernel's key-block size: layer-stacked
        # caches are consumed in place (padding them per call would copy
        # the whole buffer every tick); unwritten rows stay cursor-masked.
        self.max_len = -(-max_len // 128) * 128 if max_len > 128 else max_len
        self.cache_dtype = cache_dtype
        self.decode_impl = decode_impl
        self._accel = jnp.asarray(scen_cfg.accel_values(), jnp.float32)
        self._yaw = jnp.asarray(scen_cfg.yaw_values(), jnp.float32)
        raw_prefill = functools.partial(model.prefill, impl=decode_impl)

        def prefill_fn(params, cache, batch):
            # named_scope is trace-time annotation only (shows up in XLA /
            # --profile-dir traces); it cannot change values or shapes
            with jax.named_scope("rollout.prefill"):
                return raw_prefill(params, cache, batch)

        step_fn = self._step_impl
        self._cache_shardings = None
        if mesh is not None:
            lane_axes = tuple(a for a in FLEET_AXES if a in mesh.shape)
            extra = [a for a in mesh.shape
                     if a not in lane_axes and mesh.shape[a] > 1]
            if not lane_axes or extra:
                raise ValueError(
                    f"fleet mesh must carry only the scene axes "
                    f"{FLEET_AXES}; got {dict(mesh.shape)}")
            shards = int(np.prod([mesh.shape[a] for a in lane_axes]))
            self.num_slots = -(-num_slots // shards) * shards
            lane = P(lane_axes if len(lane_axes) > 1 else lane_axes[0])
            # cache leaves: layer-stacked K/V rows carry the slot axis at
            # dim 1 (L, B, H, S, .); times/seg/cursor carry it at dim 0
            cache_struct = jax.eval_shape(self.init_cache)
            stacked = set(model._LAYER_CACHE_KEYS)
            cache_spec = {k: (P(None, *lane) if k in stacked else lane)
                          for k in cache_struct}
            self._cache_shardings = {
                k: NamedSharding(mesh, s) for k, s in cache_spec.items()}
            prefill_fn = shard_map(
                prefill_fn, mesh=mesh,
                in_specs=(P(), cache_spec, lane),
                out_specs=(lane, cache_spec), check_rep=False)
            step_fn = shard_map(
                step_fn, mesh=mesh,
                in_specs=(P(), cache_spec) + (lane,) * 6 + (P(),),
                out_specs=(cache_spec,) + (lane,) * 4, check_rep=False)
        # Donate the cache so XLA updates it in place: without donation
        # every tick round-trips the full preallocated K/V cache through
        # a copy, which dwarfs the attention work the decode kernel
        # saves (the cache is tens of MiB per slot batch).
        # CostAccounted AOT-compiles on first call (still exactly one
        # trace/compilation — the zero-extra-compilation guards read its
        # _cache_size) and records compiled FLOPs/bytes as cost.* gauges.
        self._prefill = obs.CostAccounted(
            jax.jit(prefill_fn, donate_argnums=(1,)),
            "rollout.prefill", registry=self.obs)
        self._step = obs.CostAccounted(
            jax.jit(step_fn, donate_argnums=(1,)),
            "rollout.step", registry=self.obs)
        self.ticks = 0
        self.last_actions = None      # (S, K, T_fut, A) after each run()

    def init_cache(self):
        cache = self.model.init_cache(self.num_slots, self.max_len,
                                      self.cache_dtype)
        if self._cache_shardings is not None:
            # place slot-sharded from the start, so the prefill donation
            # reuses the buffers instead of resharding a replicated copy
            cache = jax.device_put(cache, self._cache_shardings)
        # shape metadata only — no device read, no sync
        self.obs.gauge("rollout.cache_bytes").set(
            sum(int(np.prod(v.shape)) * v.dtype.itemsize
                for v in jax.tree.leaves(cache)))
        return cache

    def _step_impl(self, params, cache, logits, pose, speed, feats_proto,
                   valid, keys, t):
        """One engine tick, fully on device: sample an action per agent from
        the previous step's logits, integrate kinematics to produce sim-step
        ``t``'s poses, then decode the A new agent tokens against the cache
        to get the next sampling distribution.

        ``valid`` (B, A) marks each slot's real agents (families generate
        variable agent counts padded to A slots); invalid agents are frozen
        in place and their tokens enter the cache segment-masked, so they
        never influence attention or metrics.

        ``keys`` arrive as raw uint32 key DATA (B, 2), not typed key
        arrays: the fleet path shard_maps this function over the slot
        axis and plain arrays partition like any other per-lane input.
        ``wrap_key_data`` reconstructs the identical typed keys, so the
        sampled stream is unchanged."""
        with jax.named_scope("rollout.step"):
            return self._step_body(params, cache, logits, pose, speed,
                                   feats_proto, valid, keys, t)

    def _step_body(self, params, cache, logits, pose, speed, feats_proto,
                   valid, keys, t):
        b, a, _ = feats_proto.shape
        keys = jax.random.wrap_key_data(keys)
        keys_t = jax.vmap(jax.random.fold_in, in_axes=(0, None))(keys, t)
        acts = jax.vmap(jax.random.categorical)(
            keys_t, logits.astype(jnp.float32))           # (B, A)
        ai, yi = jnp.divmod(acts, self.scen.yaw_bins)
        new_pose, new_speed = step_kinematics(pose, speed, self._accel[ai],
                                              self._yaw[yi])
        pose = jnp.where(valid[..., None], new_pose, pose)
        speed = jnp.where(valid, new_speed, speed)
        feats = feats_proto.at[..., 0].set(speed / 10.0)
        t_vec = jnp.broadcast_to(t, (b,)).astype(jnp.int32)
        logits, cache = self.model.step(params, cache, feats, pose, valid,
                                        t_vec, impl=self.decode_impl)
        return cache, logits, pose, speed, acts

    def _run_chunk(self, hist_batch: Dict[str, jnp.ndarray], keys,
                   t_hist: int, t_total: int):
        """Roll ``num_slots`` independent (scene, sample) lanes forward from
        their history; returns sampled poses (B, t_total - t_hist, A, 3).

        Mirrors the full-recompute loop's structure exactly: the action for
        step t is sampled from the logits of the step t-1 agent tokens (the
        last history step's logits come from prefill), so the cached and
        recompute rollouts draw from the same distributions with the same
        per-(scene, sample) key stream.
        """
        cache = self.init_cache()
        with self.obs.span("rollout.prefill"):
            hist_logits, cache = self._prefill(self.params, cache,
                                               hist_batch)
        logits = hist_logits[:, -1]                        # (B, A, K)
        pose = hist_batch["agent_pose"][:, -1]
        speed = hist_batch["agent_feats"][:, -1, :, 0] * 10.0
        feats_proto = hist_batch["agent_feats"][:, -1]
        # agents valid at the last history step stay the slot's live set
        # for the whole future (families keep validity constant in time)
        valid = hist_batch["agent_valid"][:, -1]
        out, out_acts = [], []
        for t in range(t_hist, t_total):
            # span = host dispatch time of the async device step — the
            # number the pipelining argument cares about; no added sync
            with self.obs.span("rollout.step"):
                cache, logits, pose, speed, acts = self._step(
                    self.params, cache, logits, pose, speed, feats_proto,
                    valid, keys, jnp.asarray(t, jnp.int32))
            self.ticks += 1
            self.obs.counter("rollout.ticks").inc()
            out.append(pose)
            out_acts.append(acts)
        # (B, T_fut, A, 3), (B, T_fut, A)
        return jnp.stack(out, axis=1), jnp.stack(out_acts, axis=1)

    def run(self, scenes: Sequence[Dict[str, np.ndarray]], *, t_hist: int,
            n_samples: int, seed: int = 0, t_total: Optional[int] = None):
        """Closed-loop rollouts for every scene x sample.

        ``scenes``: scene tensor dicts (any registered family's layout) or
        ``repro.scenarios.Scene`` objects. Returns sampled future poses,
        shape (n_scenes, n_samples, t_total - t_hist, A, 3), as numpy;
        the matching sampled action ids land in ``self.last_actions``,
        shape (n_scenes, n_samples, t_total - t_hist, A) — the isolation
        suite compares them bit-for-bit against the sim server's.
        """
        scenes = [s.tensors if hasattr(s, "tensors") else s for s in scenes]
        t_total = t_total or self.scen.num_steps
        n_scenes = len(scenes)
        total = n_scenes * n_samples
        # host-side key plumbing: the per-(scene, sample) stream is fixed
        # before any slot/shard assignment, so placement can't change it
        keys_all = np.asarray(
            jax.random.key_data(rollout_keys(seed, n_scenes, n_samples)))

        def lane_hist(flat_idx):
            s = scenes[flat_idx // n_samples]
            return {
                "map_feats": s["map_feats"], "map_pose": s["map_pose"],
                "map_valid": s["map_valid"],
                "agent_feats": s["agent_feats"][:t_hist],
                "agent_pose": s["agent_pose"][:t_hist],
                "agent_valid": s["agent_valid"][:t_hist],
            }

        futures, actions = [], []
        for start in range(0, total, self.num_slots):
            lanes = [min(start + i, total - 1)
                     for i in range(self.num_slots)]  # pad tail by repeating
            hist = {k: jnp.asarray(np.stack([lane_hist(i)[k] for i in lanes]))
                    for k in lane_hist(0)}
            keys = jnp.asarray(keys_all[np.asarray(lanes)])
            with self.obs.span("rollout.chunk"):
                fut, acts = self._run_chunk(hist, keys, t_hist, t_total)
                futures.append(np.asarray(fut[:total - start]))
                actions.append(np.asarray(acts[:total - start]))
        flat = np.concatenate(futures, axis=0)[:total]
        t_fut = t_total - t_hist
        a = self.scen.num_agents
        self.last_actions = np.concatenate(actions, axis=0)[:total] \
            .reshape(n_scenes, n_samples, t_fut, a)
        return flat.reshape(n_scenes, n_samples, t_fut, a, 3)
