"""Closed-loop evaluation harness over the cached RolloutEngine.

Batches *mixed-family* scenes (every family pads to the same static
shapes, so one engine compilation serves all of them) through
:class:`repro.runtime.RolloutEngine`, then scores each sampled future on
the host against the scene's ground truth and lane graph:

* **minADE** — best-of-K average displacement error over valid agents
  (masked; padding slots never enter the mean);
* **miss rate** — fraction of valid agents whose best-of-K *final*
  displacement exceeds ``miss_threshold_m``;
* **collision rate** — fraction of valid agents that come within
  ``collision_radius_m`` of another valid agent at any future step,
  averaged over samples;
* **off-road rate** — fraction of valid *vehicle* agent-steps farther
  than ``offroad_threshold_m`` from the nearest lane centerline
  (pedestrians are exempt — their crosswalk is their lane);
* **kinematic-infeasibility rate** — fraction of valid agent-steps whose
  implied speed / yaw rate between consecutive rollout poses exceeds the
  unicycle limits. The engine integrates with clamped actions, so this
  is a self-check that should sit at 0; any other rollout source (a
  learned policy emitting raw poses, a buggy integrator) gets caught.

All metrics are reported per family and aggregated; every metric is a
plain float so the benchmark layer can print CSV rows directly.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.kinematics import DT, MAX_SPEED, wrap_angle
from repro.scenarios import registry
from repro.scenarios.core import AGENT_TYPE, Scene, ScenarioConfig

__all__ = ["EvalConfig", "scene_metrics", "evaluate_scenes",
           "evaluate_families"]


@dataclasses.dataclass(frozen=True)
class EvalConfig:
    t_hist: int = 8                   # history steps fed to prefill
    n_samples: int = 4                # rollouts per scene
    seed: int = 0
    miss_threshold_m: float = 2.0
    collision_radius_m: float = 1.5
    offroad_threshold_m: float = 3.5
    kin_tolerance: float = 1.05       # fraction of the hard limits


METRICS = ("min_ade", "miss_rate", "collision_rate", "offroad_rate",
           "kinematic_infeasibility_rate")


def scene_metrics(scen_cfg: ScenarioConfig, eval_cfg: EvalConfig,
                  scene: Scene, futures: np.ndarray) -> Dict[str, float]:
    """Score one scene's sampled futures (K, T_fut, A, 3) against its
    ground truth and lane graph. Returns the METRICS dict plus
    ``n_agents`` (the valid-agent count the means ran over)."""
    t_hist = eval_cfg.t_hist
    tensors = scene.tensors
    gt = np.asarray(tensors["agent_pose"][t_hist:], np.float32)  # (Tf, A, 3)
    valid = np.asarray(tensors["agent_valid"][t_hist:], bool)    # (Tf, A)
    fut = np.asarray(futures, np.float32)                        # (K,Tf,A,3)
    k, t_fut, a, _ = fut.shape
    assert gt.shape[0] == t_fut, (gt.shape, fut.shape)
    alive = valid.any(axis=0)                                    # (A,)
    n_alive = int(alive.sum())
    if n_alive == 0 or t_fut == 0:
        return {m: float("nan") for m in METRICS} | {"n_agents": 0.0}

    w = valid.astype(np.float64)                                 # (Tf, A)
    steps = np.maximum(w.sum(axis=0), 1.0)                       # (A,)

    # minADE / miss rate (masked best-of-K)
    d = np.linalg.norm(fut[..., :2] - gt[None, ..., :2], axis=-1)  # (K,Tf,A)
    ade = (d * w[None]).sum(axis=1) / steps[None]                # (K, A)
    min_ade = float(ade.min(axis=0)[alive].mean())
    t_last = np.asarray(w.cumsum(axis=0).argmax(axis=0), int)    # (A,)
    fde = d[:, t_last, np.arange(a)]                             # (K, A)
    miss = float((fde.min(axis=0)[alive]
                  > eval_cfg.miss_threshold_m).mean())

    # collision rate: any valid pair within radius at any valid step
    pair_d = np.linalg.norm(fut[..., None, :2] - fut[..., None, :, :2],
                            axis=-1)                             # (K,Tf,A,A)
    pair_ok = valid[None, :, :, None] & valid[None, :, None, :]
    pair_ok &= ~np.eye(a, dtype=bool)[None, None]
    hit = (pair_d < eval_cfg.collision_radius_m) & pair_ok
    collided = hit.any(axis=(1, 3))                              # (K, A)
    collision = float(collided[:, alive].mean())

    # off-road rate: valid *vehicle* agent-steps off the lane graph
    veh = (np.asarray(tensors.get("agent_type",
                                  np.zeros(a, np.int32)))
           == AGENT_TYPE["vehicle"])
    veh_w = w * veh[None, :]                                     # (Tf, A)
    if scene.lane_graph is not None and veh_w.sum() > 0:
        # driving lanes only: standing on a crosswalk is still off-road
        # for a vehicle
        dist = scene.lane_graph.distance(fut[..., :2],
                                         kinds=("lane",))       # (K,Tf,A)
        off = (dist > eval_cfg.offroad_threshold_m) * veh_w[None]
        offroad = float(off.sum() / (k * veh_w.sum()))
    else:
        offroad = float("nan")

    # kinematic feasibility between consecutive rollout poses
    if t_fut > 1:
        dxy = np.linalg.norm(np.diff(fut[..., :2], axis=1), axis=-1)
        dth = np.abs(wrap_angle(np.diff(fut[..., 2], axis=1), xp=np))
        ok_steps = (valid[:-1] & valid[1:]).astype(np.float64)   # (Tf-1, A)
        bad = ((dxy > MAX_SPEED * DT * eval_cfg.kin_tolerance)
               | (dth > scen_cfg.max_yaw_rate * DT
                  * eval_cfg.kin_tolerance + 1e-4)) * ok_steps[None]
        denom = k * max(ok_steps.sum(), 1.0)
        kin = float(bad.sum() / denom)
    else:
        kin = 0.0

    return {"min_ade": min_ade, "miss_rate": miss,
            "collision_rate": collision, "offroad_rate": offroad,
            "kinematic_infeasibility_rate": kin,
            "n_agents": float(n_alive)}


def evaluate_scenes(engine, scenes: Sequence[Scene],
                    eval_cfg: EvalConfig) -> Dict[str, Dict[str, float]]:
    """Closed-loop rollouts + metrics for a mixed-family scene list.

    ONE ``engine.run`` covers every scene regardless of family — all
    families share the config's static shapes (validity masks carry the
    per-scene variation), so slots mix freely and the jitted prefill/step
    compile once. Returns ``{family: {metric: mean, n_scenes, n_agents}}``
    plus an ``"overall"`` row; every aggregate row weights each scene by
    its valid-agent count (see :func:`_aggregate`).
    """
    futures = engine.run([s.tensors for s in scenes],
                         t_hist=eval_cfg.t_hist,
                         n_samples=eval_cfg.n_samples,
                         seed=eval_cfg.seed)       # (S, K, Tf, A, 3)
    per_family: Dict[str, List[Dict[str, float]]] = defaultdict(list)
    for si, scene in enumerate(scenes):
        per_family[scene.family].append(
            scene_metrics(engine.scen, eval_cfg, scene, futures[si]))
    out: Dict[str, Dict[str, float]] = {}
    all_rows: List[Dict[str, float]] = []
    for family, rows in sorted(per_family.items()):
        out[family] = _aggregate(rows)
        all_rows.extend(rows)
    out["overall"] = _aggregate(all_rows)
    return out


def _aggregate(rows: List[Dict[str, float]]) -> Dict[str, float]:
    """Agent-weighted mean of per-scene metric rows.

    Every metric in ``scene_metrics`` is a mean over a scene's VALID
    agents (or their agent-steps — each valid agent contributes the same
    fixed rollout horizon), so the family/overall aggregate weights each
    row by its ``n_agents``: the result equals the mean over all valid
    agents pooled across scenes. An unweighted mean of rows would let a
    1-agent scene move the table as much as a 30-agent scene, which at
    10k-scene fleet budgets materially skews the reported rows toward
    whichever families generate sparse scenes.
    """
    agg = {}
    for m in METRICS:
        pairs = [(r[m], r["n_agents"]) for r in rows
                 if np.isfinite(r[m]) and r["n_agents"] > 0]
        if pairs:
            v = np.asarray([p[0] for p in pairs], np.float64)
            w = np.asarray([p[1] for p in pairs], np.float64)
            agg[m] = float((v * w).sum() / w.sum())
        else:
            agg[m] = float("nan")
    agg["n_scenes"] = float(len(rows))
    agg["n_agents"] = float(np.sum([r["n_agents"] for r in rows]))
    return agg


def evaluate_families(model, params, scen_cfg: ScenarioConfig,
                      eval_cfg: EvalConfig, *,
                      families: Optional[Sequence[str]] = None,
                      n_scenes_per_family: int = 4, scene_seed: int = 777,
                      num_slots: Optional[int] = None, mesh=None
                      ) -> Dict[str, Dict[str, float]]:
    """Generate ``n_scenes_per_family`` scenes for every family and run
    the closed-loop evaluation in one mixed batch.

    ``mesh``: optional scene-axis mesh (``launch.mesh.make_fleet_mesh``)
    — the engine then ``shard_map``s its tick over the slot axis, with
    per-scene results bit-identical to the single-device path (see
    ``docs/distributed.md``).
    """
    from repro.runtime.rollout import RolloutEngine

    fams = list(families) if families is not None else registry.names()
    scenes = [registry.generate_scene(f, scene_seed, i, scen_cfg)
              for f in fams for i in range(n_scenes_per_family)]
    slots = num_slots or min(32, len(scenes) * eval_cfg.n_samples)
    engine = RolloutEngine(model, params, scen_cfg, num_slots=slots,
                           mesh=mesh)
    return evaluate_scenes(engine, scenes, eval_cfg)
