"""Pallas TPU kernels for the perf-critical compute hot spots.

The paper's central systems claim is that SE(2)-invariant attention can
reuse an unmodified flash-attention kernel (Alg. 2). Accordingly:

  * ``flash_attention``      — the Pallas TPU SDPA forward kernel the
    linear-memory algorithm routes through
    (causal/window/softcap/segments/GQA); also emits the LSE rows.
  * ``flash_attention_bwd``  — the FlashAttention-style backward kernels
    (dq and dk/dv), recomputing probabilities from the saved LSE so
    training is linear-memory on both sides of autodiff.
  * ``se2_project``          — fused SE(2) Fourier query/key projection
    (the Alg. 2 pre-processing, which otherwise materializes ~8x-expanded
    intermediates in HBM).
  * ``flash_decode``         — split-K ragged decode kernel for the
    rollout hot path (cursor-bounded scanning over preallocated caches,
    in-kernel dequantization of int8/bf16 KV), plus the cursor-bounded
    XLA twin and the KV quantization helpers.
  * ``ops``                  — padded, autodiff-capable public wrappers +
    implementation dispatcher used by the model stack.
  * ``ref``                  — pure-jnp oracles the kernels are validated
    against (and the linear-memory XLA fallback used on CPU/dry-run).

See ``docs/kernels.md`` for the tiling and memory model.
"""
from repro.kernels import (flash_attention, flash_attention_bwd, flash_decode,
                           ops, ref, se2_project)
from repro.kernels.flash_decode import dequantize_kv, quantize_kv
from repro.kernels.ops import (attention, decode_attention,
                               flash_attention as flash_attention_op)
from repro.kernels.se2_project import se2_fourier_project

__all__ = ["flash_attention", "flash_attention_bwd", "flash_decode", "ops",
           "ref", "se2_project", "attention", "decode_attention",
           "flash_attention_op", "se2_fourier_project", "quantize_kv",
           "dequantize_kv"]
