"""Pallas TPU flash-attention kernel (forward).

TPU-native design notes (vs. the CUDA FlashAttention the paper reuses):

  * Tiling targets VMEM: each grid step holds one ``(block_q, d)`` query
    tile plus one ``(block_k, d)`` key/value tile in VMEM; the online-softmax
    running state (m, l, acc) lives in VMEM scratch that persists across the
    innermost (key) grid dimension.
  * Block shapes default to 128 so the MXU (128x128 systolic array) runs at
    full tile occupancy; head dims are padded to a multiple of 128 by the
    ``ops.flash_attention`` wrapper.
  * The grid is (batch, q_heads, q_blocks, k_blocks) with
    ``dimension_semantics = (parallel, parallel, parallel, arbitrary)`` —
    the k dimension is sequential so the scratch accumulators carry.
  * Causal / sliding-window masking skips fully-masked key blocks with
    ``pl.when`` (block-level early out), and applies an element mask built
    from ``broadcasted_iota`` inside partially-masked blocks.
  * Grouped-query attention is folded into the index maps: the key/value
    BlockSpecs map q-head ``h`` to kv-head ``h // group``.

Supported features (superset of what the architectures need): causal masking,
sliding windows (gemma2 local layers, hymba), logit soft-capping (gemma2),
segment ids (agent-simulation scene packing + padding), GQA/MQA, distinct
qk/v head dims (SE(2) Fourier expanded features and MLA).

The pure-jnp oracle lives in ``repro.kernels.ref``; the public padded/
autodiff-capable wrapper lives in ``repro.kernels.ops``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_NEG_INF = -1e30


def _fwd_kernel(q_seg_ref, k_seg_ref, q_time_ref, k_time_ref,
                q_ref, k_ref, v_ref, o_ref, lse_ref,
                acc_ref, m_ref, l_ref, *,
                scale: float, causal: bool, window: Optional[int],
                softcap: Optional[float], block_q: int, block_k: int,
                num_k_blocks: int, use_segments: bool, use_times: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # Block-level early out: skip key blocks entirely masked by the causal /
    # sliding-window structure (saves both MXU work and VPU mask work).
    # With explicit per-token times the structure is data-dependent, so no
    # static skipping is possible.
    run = jnp.bool_(True)
    if not use_times:
        if causal:
            run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
        if window is not None:
            run = jnp.logical_and(run,
                                  k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (bk, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if softcap is not None and softcap > 0:
            s = jnp.tanh(s / softcap) * softcap

        if use_times:
            rows = q_time_ref[0][:, None]            # (bq, 1)
            cols = k_time_ref[0][None, :]            # (1, bk)
        else:
            rows = jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0) + q_start
            cols = jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1) + k_start
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, cols <= rows)
        if window is not None:
            mask = jnp.logical_and(mask, cols > rows - window)
        if use_segments:
            qs = q_seg_ref[0]                         # (bq,)
            ks = k_seg_ref[0]                         # (bk,)
            seg = jnp.logical_and(qs[:, None] == ks[None, :], ks[None, :] >= 0)
            mask = jnp.logical_and(mask, seg)
        s = jnp.where(mask, s, _NEG_INF)

        # m/l scratch are stored broadcast across the 128-lane minor dim so
        # the VMEM layout is native to the VPU (same trick as the reference
        # TPU flash kernel).
        m_prev = m_ref[:, 0]
        l_prev = l_ref[:, 0]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        # Fully-masked rows would otherwise contribute exp(-inf + inf) noise.
        p = jnp.where(mask, p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0, :] = m_ref[:, 0] + jnp.log(l)


def flash_attention_fwd(q, k, v, *,
                        causal: bool = False,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        q_segment_ids=None, k_segment_ids=None,
                        q_times=None, k_times=None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False,
                        return_lse: bool = False):
    """Raw kernel invocation. Requires block-aligned sequence lengths.

    q: (B, Hq, Sq, D); k: (B, Hkv, Sk, D); v: (B, Hkv, Sk, Dv);
    segment ids / times: (B, S) int32 or None. Sq % block_q == 0 etc.
    Returns (B, Hq, Sq, Dv) in v.dtype; with ``return_lse`` also the
    float32 (B, Hq, Sq) log-sum-exp rows consumed by the backward kernels.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    assert k.shape == (b, hkv, sk, d), (q.shape, k.shape, v.shape)
    assert hq % hkv == 0, (hq, hkv)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    nq, nk = sq // block_q, sk // block_k
    use_segments = q_segment_ids is not None
    if not use_segments:
        q_segment_ids = jnp.zeros((b, sq), jnp.int32)
        k_segment_ids = jnp.zeros((b, sk), jnp.int32)
    use_times = q_times is not None
    if not use_times:
        q_times = jnp.zeros((b, sq), jnp.int32)
        k_times = jnp.zeros((b, sk), jnp.int32)

    kernel = functools.partial(
        _fwd_kernel, scale=float(scale), causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, num_k_blocks=nk,
        use_segments=use_segments, use_times=use_times)

    out, lse = pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b_, h, iq, ik: (b_, iq)),
            pl.BlockSpec((1, block_k), lambda b_, h, iq, ik: (b_, ik)),
            pl.BlockSpec((1, block_q), lambda b_, h, iq, ik: (b_, iq)),
            pl.BlockSpec((1, block_k), lambda b_, h, iq, ik: (b_, ik)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, dv),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, h, iq, ik: (b_, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, sq, dv), v.dtype),
            jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, dv), jnp.float32),    # acc
            pltpu.VMEM((block_q, 128), jnp.float32),   # m (running max)
            pltpu.VMEM((block_q, 128), jnp.float32),   # l (running denom)
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_segment_ids, k_segment_ids, q_times, k_times, q, k, v)
    return (out, lse) if return_lse else out
