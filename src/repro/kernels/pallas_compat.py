"""Version compatibility shims for the Pallas TPU API.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` across
jax releases; every kernel in this package resolves the name through here so
the kernels import (and run in interpret mode) on either side of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
