"""Pallas TPU split-K flash-decode kernel for the rollout hot path.

The generic flash kernel (``flash_attention.py``) is shaped for training:
big query blocks, a sequential walk over *every* key block, and masking
folded into segment ids. A closed-loop rollout tick inverts all of those
assumptions — q_len is the handful of agent tokens appended this step,
the keys are a preallocated ``max_len`` cache that is mostly *unwritten*
(a per-slot ``kv_length`` cursor marks the live prefix), and there is no
backward pass. Routing that shape through the generic kernel wastes the
machine twice:

  1. **No parallelism.** One tiny query block means the whole (batch,
     head) program is a single sequential scan over key blocks; the MXU
     sits behind a serial dependency chain of online-softmax updates.
  2. **O(max_len) work per tick.** ``ops._fold_kv_length`` hides dead
     cache rows behind segment id -1, which masks them *after* their
     blocks are fetched from HBM and pushed through the MXU. Every tick
     pays for the whole preallocated cache, live or not.

This kernel is specialized for the decode shape:

* **Split-K parallelism** — the grid is ``(B, Hq, num_splits,
  blocks_per_split)`` with the split dimension parallel and only the
  within-split walk sequential. Each split reduces its key range to a
  partial ``(m, l, acc)`` triple (the associative online-softmax state);
  a cheap XLA combine rescales and sums the partials. Work that the
  single small-q program serialized now spreads across ``num_splits``
  programs per (batch, head).
* **Cursor-bounded ragged scanning** — ``kv_length`` rides in as a
  scalar-prefetch operand, so it is available to the BlockSpec index
  maps *before* the pipeline issues any copy: key blocks at or beyond a
  row's cursor are clamped back to the last live block (the pipeline
  elides the re-fetch of an already-resident block — no HBM traffic)
  and their compute is skipped entirely with ``pl.when`` (no MXU/VPU
  work). Each tick therefore costs O(live prefix), not O(max_len).
* **Quantized KV cache** — the cache may store the SE(2)-transformed
  K/V rows as int8 with per-(head, token) float32 scales (or as bf16);
  dequantization happens in VMEM on the tile just loaded, so the HBM
  working set of a tick shrinks 4x (2x for bf16) while all arithmetic
  stays float32.

Masking supports the decode feature set the model actually uses:
block-causal attention over explicit per-token times, segment ids, GQA
(via ``h // group`` index maps), and the ragged ``kv_length`` bound.
Softcap / sliding windows are deliberately out of scope — no decode
path uses them; fall back to the generic kernel if that changes.

``decode_ragged_xla`` is the same algorithm in pure XLA (a
``fori_loop`` whose trip count is the *batch-max* live block count — so
it is also O(live), unlike ``ref.mha_chunked`` which scans the padded
cache). It is the CPU/fallback production path and, together with
``ref.mha_reference`` over a dequantized cache, the parity oracle
(``tests/test_decode.py``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_NEG_INF = -1e30


# ---------------------------------------------------------------------------
# int8 KV-cache quantization helpers (shared by the cache writers, the
# kernels, and the oracle fallbacks).
# ---------------------------------------------------------------------------

#: cache storage dtypes accepted (as strings) by the model/engine
#: ``init_cache(dtype=...)`` / ``cache_dtype=`` options
CACHE_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
                "int8": jnp.int8}


def canonical_cache_dtype(dtype, default=None):
    """Resolve a cache-dtype option (string / jnp dtype / None)."""
    if dtype is None:
        return default
    if isinstance(dtype, str):
        return CACHE_DTYPES[dtype]
    return dtype


def quantize_kv(x, eps: float = 1e-8):
    """Symmetric int8 quantization over the feature axis.

    ``x`` (..., d) -> (int8 values (..., d), float32 scales (...,)). One
    scale per (batch, head, token) row: K/V rows are written to the cache
    once and never revised, so per-row absmax is exact, and a row's scale
    travels beside it in the cache.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.maximum(amax, eps) / 127.0
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127.0, 127.0)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype=jnp.float32):
    """Inverse of :func:`quantize_kv` (used by the XLA oracle paths; the
    Pallas kernel dequantizes per-tile in VMEM instead)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# ---------------------------------------------------------------------------
# The split-K kernel.
# ---------------------------------------------------------------------------

def _decode_kernel(kvl_ref, *refs, scale: float, block_k: int,
                   blocks_per_split: int, num_k_blocks: int,
                   use_segments: bool, use_times: bool,
                   quant_k: bool, quant_v: bool, layered: bool):
    """One grid step: fold one key block into this split's (m, l, acc).

    Grid: (B, Hq, num_splits, blocks_per_split); the last dimension is
    sequential so the online-softmax scratch carries across it; the split
    dimension is parallel. Outputs are per-split partials, combined by
    :func:`_combine_splits`.
    """
    (q_seg_ref, k_seg_ref, q_time_ref, k_time_ref,
     q_ref, k_ref, v_ref) = refs[:7]
    i = 7
    k_scale_ref = v_scale_ref = None
    if quant_k:
        k_scale_ref = refs[i]
        i += 1
    if quant_v:
        v_scale_ref = refs[i]
        i += 1
    o_ref, m_ref, l_ref = refs[i:i + 3]
    acc_s, m_s, l_s = refs[i + 3:]

    b = pl.program_id(0)
    split = pl.program_id(2)
    ik = pl.program_id(3)
    jk = split * blocks_per_split + ik          # global key-block index
    k_start = jk * block_k
    kvl = kvl_ref[b]

    @pl.when(ik == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, _NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    # Ragged early-out: a block entirely at/beyond the row's cursor (or
    # past the padded key range) does no loads (its index map clamped the
    # fetch to an already-resident block) and no compute.
    live = jnp.logical_and(jk < num_k_blocks, k_start < kvl)

    kv_idx = (0, 0, 0) if layered else (0, 0)    # layer-stacked cache tiles

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[kv_idx].astype(jnp.float32)        # (bk, d)
        v = v_ref[kv_idx].astype(jnp.float32)        # (bk, dv)
        if quant_k:
            k = k * k_scale_ref[kv_idx][:, None]
        if quant_v:
            v = v * v_scale_ref[kv_idx][:, None]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

        cols = jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1) + k_start
        mask = cols < kvl                            # ragged cursor bound
        if use_times:
            rows_t = q_time_ref[0][:, None]          # (bq, 1)
            cols_t = k_time_ref[0][None, :]          # (1, bk)
            mask = jnp.logical_and(mask, cols_t <= rows_t)
        if use_segments:
            qs = q_seg_ref[0]
            ks = k_seg_ref[0]
            seg = jnp.logical_and(qs[:, None] == ks[None, :],
                                  ks[None, :] >= 0)
            mask = jnp.logical_and(mask, seg)
        s = jnp.where(mask, s, _NEG_INF)
        # zero unreachable rows' VALUES too, not just their weights:
        # 0 * NaN is NaN, and rows beyond the cursor may carry any bit
        # pattern (a quarantined predecessor's NaN rows included). For
        # finite stale rows this is an exact no-op (0 * finite == 0).
        v = jnp.where(jnp.any(mask, axis=0)[:, None], v, 0.0)

        m_prev = m_s[:, 0]
        l_prev = l_s[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)                  # dead rows stay zero
        l_s[...] = jnp.broadcast_to(
            (l_prev * alpha + jnp.sum(p, axis=-1))[:, None], l_s.shape)
        m_s[...] = jnp.broadcast_to(m_new[:, None], m_s.shape)
        acc_s[...] = acc_s[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == blocks_per_split - 1)
    def _finalize():
        o_ref[0, 0, 0] = acc_s[...]
        m_ref[0, 0, 0] = m_s[:, 0]
        l_ref[0, 0, 0] = l_s[:, 0]


def _combine_splits(o_p, m_p, l_p, out_dtype):
    """Merge per-split partial softmax states (the standard split-K
    reduction): rescale every split to the global row max, sum the
    denominators and accumulators, normalize once.

    o_p (B, H, S, bq, dv); m_p / l_p (B, H, S, bq), all float32. A split
    that saw only dead blocks contributes m = -1e30 (finite sentinel, so
    exp stays NaN-free), l = 0, acc = 0 — an exact no-op in the sums.
    Rows with no live key anywhere end with l == 0 and are forced to
    zero, matching ``ref.mha_reference``'s fully-masked-row convention.
    """
    m_g = jnp.max(m_p, axis=2)                           # (B, H, bq)
    alpha = jnp.exp(m_p - m_g[:, :, None])               # (B, H, S, bq)
    l_g = jnp.sum(l_p * alpha, axis=2)
    o = jnp.sum(o_p * alpha[..., None], axis=2)
    out = o / jnp.maximum(l_g, 1e-30)[..., None]
    return out.astype(out_dtype)


def flash_decode_fwd(q, k, v, kv_length, *,
                     k_scale=None, v_scale=None,
                     q_segment_ids=None, k_segment_ids=None,
                     q_times=None, k_times=None,
                     scale: Optional[float] = None,
                     block_k: int = 128,
                     num_splits: Optional[int] = None,
                     interpret: bool = False,
                     layer: Optional[int] = None):
    """Raw kernel invocation. Requires aligned shapes.

    q (B, Hq, Sq, D) with Sq the (small, padded) decode query block;
    k (B, Hkv, Sk, D); v (B, Hkv, Sk, Dv); Sk % block_k == 0.
    ``kv_length`` (B,) int32 live-prefix cursors. ``k_scale``/``v_scale``
    (B, Hkv, Sk) float32 mark the cache as int8-quantized. Returns
    (B, Hq, Sq, Dv) in q.dtype.

    With ``layer=i`` (static int) the cache operands carry the model's
    leading layer axis — k (L, B, Hkv, Sk, D), v (L, B, Hkv, Sk, Dv),
    scales (L, B, Hkv, Sk) — and the BlockSpec index maps address layer
    ``i`` directly, so no per-layer (B, Hkv, Sk, .) slice of the stacked
    cache is ever materialized (see :func:`decode_ragged_xla`).
    """
    b, hq, sq, d = q.shape
    if layer is None:
        _, hkv, sk, dv = v.shape
        assert k.shape == (b, hkv, sk, d), (q.shape, k.shape, v.shape)
    else:
        nl, _, hkv, sk, dv = v.shape
        assert k.shape == (nl, b, hkv, sk, d), (q.shape, k.shape, v.shape)
        assert 0 <= layer < nl, (layer, nl)
    assert hq % hkv == 0, (hq, hkv)
    assert sk % block_k == 0, (sk, block_k)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    nk = sk // block_k
    if num_splits is None:
        num_splits = min(nk, 8)
    num_splits = max(1, min(num_splits, nk))
    bps = -(-nk // num_splits)                   # blocks per split
    kvl = jnp.asarray(kv_length, jnp.int32)
    if kvl.ndim == 0:
        kvl = jnp.broadcast_to(kvl[None], (b,))

    use_segments = q_segment_ids is not None
    if not use_segments:
        q_segment_ids = jnp.zeros((b, sq), jnp.int32)
        k_segment_ids = jnp.zeros((b, sk), jnp.int32)
    use_times = q_times is not None
    if not use_times:
        q_times = jnp.zeros((b, sq), jnp.int32)
        k_times = jnp.zeros((b, sk), jnp.int32)
    quant_k = k_scale is not None
    quant_v = v_scale is not None

    def _clamped(jk, kvl_b):
        # Last live block for this row; dead grid steps re-map to it so
        # the pipeline never fetches beyond the cursor (a repeated block
        # index is not re-copied), and in-kernel predication skips their
        # compute anyway.
        nlive = (kvl_b + block_k - 1) // block_k
        hi = jnp.maximum(jnp.minimum(nlive, nk) - 1, 0)
        return jnp.minimum(jk, hi)

    if layer is None:
        def kv_map(b_, h, s, ik, kvl_ref):
            return (b_, h // group, _clamped(s * bps + ik, kvl_ref[b_]), 0)

        def kvec_map(b_, h, s, ik, kvl_ref):
            return (b_, h // group, _clamped(s * bps + ik, kvl_ref[b_]))

        kv_block = (1, 1, block_k)
        kd_block = (1, 1, block_k, d)
        kdv_block = (1, 1, block_k, dv)
    else:
        def kv_map(b_, h, s, ik, kvl_ref):
            return (layer, b_, h // group,
                    _clamped(s * bps + ik, kvl_ref[b_]), 0)

        def kvec_map(b_, h, s, ik, kvl_ref):
            return (layer, b_, h // group,
                    _clamped(s * bps + ik, kvl_ref[b_]))

        kv_block = (1, 1, 1, block_k)
        kd_block = (1, 1, 1, block_k, d)
        kdv_block = (1, 1, 1, block_k, dv)

    def krow_map(b_, h, s, ik, kvl_ref):
        return (b_, _clamped(s * bps + ik, kvl_ref[b_]))

    in_specs = [
        pl.BlockSpec((1, sq), lambda b_, h, s, ik, kvl_ref: (b_, 0)),
        pl.BlockSpec((1, block_k), krow_map),
        pl.BlockSpec((1, sq), lambda b_, h, s, ik, kvl_ref: (b_, 0)),
        pl.BlockSpec((1, block_k), krow_map),
        pl.BlockSpec((1, 1, sq, d),
                     lambda b_, h, s, ik, kvl_ref: (b_, h, 0, 0)),
        pl.BlockSpec(kd_block, kv_map),
        pl.BlockSpec(kdv_block, kv_map),
    ]
    inputs = [q_segment_ids, k_segment_ids, q_times, k_times, q, k, v]
    if quant_k:
        in_specs.append(pl.BlockSpec(kv_block, kvec_map))
        inputs.append(k_scale.astype(jnp.float32))
    if quant_v:
        in_specs.append(pl.BlockSpec(kv_block, kvec_map))
        inputs.append(v_scale.astype(jnp.float32))

    kernel = functools.partial(
        _decode_kernel, scale=float(scale), block_k=block_k,
        blocks_per_split=bps, num_k_blocks=nk,
        use_segments=use_segments, use_times=use_times,
        quant_k=quant_k, quant_v=quant_v, layered=layer is not None)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hq, num_splits, bps),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, 1, sq, dv),
                         lambda b_, h, s, ik, kvl_ref: (b_, h, s, 0, 0)),
            pl.BlockSpec((1, 1, 1, sq),
                         lambda b_, h, s, ik, kvl_ref: (b_, h, s, 0)),
            pl.BlockSpec((1, 1, 1, sq),
                         lambda b_, h, s, ik, kvl_ref: (b_, h, s, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((sq, dv), jnp.float32),     # acc
            pltpu.VMEM((sq, 128), jnp.float32),    # m (running max)
            pltpu.VMEM((sq, 128), jnp.float32),    # l (running denom)
        ],
    )
    o_p, m_p, l_p = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, num_splits, sq, dv), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, num_splits, sq), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, num_splits, sq), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(kvl, *inputs)
    return _combine_splits(o_p, m_p, l_p, q.dtype)


# ---------------------------------------------------------------------------
# Padded public wrapper.
# ---------------------------------------------------------------------------

def pad_to_multiple(x, multiple, axis, value=0):
    """Pad ``axis`` up to a multiple; returns (padded, pad_amount).

    The single padding implementation for the kernels package —
    ``ops._pad_to`` aliases it (ops imports this module, not vice versa).
    """
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x, 0
    pad = multiple - rem
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


def _pad_axis(x, multiple, axis, value=0):
    return pad_to_multiple(x, multiple, axis, value)[0]


def flash_decode(q, k, v, kv_length, *,
                 k_scale=None, v_scale=None,
                 q_segment_ids=None, k_segment_ids=None,
                 q_times=None, k_times=None,
                 scale: Optional[float] = None,
                 block_k: int = 128,
                 num_splits: Optional[int] = None,
                 interpret: bool = False,
                 layer: Optional[int] = None):
    """Split-K ragged flash decode over arbitrary (unaligned) shapes.

    Pads head dims to 128 lanes, the query length to a 16-sublane tile,
    and the key length to ``block_k``; slices the padding back off. Key
    rows introduced by padding sit at positions >= ``kv_length`` and are
    already unreachable through the ragged bound — no extra masking
    operand is needed. Inference-only (no custom_vjp): the decode path
    never differentiates.

    With ``layer`` set (layer-stacked (L, B, H, S, .) cache operands),
    the cache is consumed **in place** and must already be token-aligned:
    ``S % block_k == 0`` (or ``S <= block_k``, which shrinks the block) —
    padding it here would copy the whole preallocated buffer every call.
    ``RolloutEngine`` rounds ``max_len`` up to a 128 multiple for exactly
    this reason.
    """
    b, hq, sq, d = q.shape
    sk, dv = v.shape[-2], v.shape[-1]
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    q = _pad_axis(q, 16, 2)
    if layer is None:
        q = _pad_axis(q, 128, 3)
        k = _pad_axis(_pad_axis(k, 128, 3), block_k, 2)
        v = _pad_axis(_pad_axis(v, 128, 3), block_k, 2)
        if k_scale is not None:
            k_scale = _pad_axis(k_scale, block_k, 2)   # (B, Hkv, Sk)
        if v_scale is not None:
            v_scale = _pad_axis(v_scale, block_k, 2)
    else:
        block_k = min(block_k, sk)
        if sk % block_k != 0:
            raise ValueError(
                f"layer-stacked decode caches must be block-aligned "
                f"(S={sk}, block_k={block_k}): padding in the hot path "
                f"would copy the whole cache every tick — allocate "
                f"max_len rounded up to a multiple of {block_k}")
        # Feature dims are consumed as allocated (padding would copy the
        # cache); on real TPU, allocate them 128-aligned for full MXU
        # tiles — interpret mode and the XLA twin don't care.
    if q_segment_ids is not None:
        q_segment_ids = _pad_axis(q_segment_ids, 16, 1, value=0)
        k_segment_ids = _pad_axis(k_segment_ids, block_k, 1, value=-1)
    if q_times is not None:
        q_times = _pad_axis(q_times, 16, 1, value=0)
        k_times = _pad_axis(k_times, block_k, 1, value=0)
    out = flash_decode_fwd(
        q, k, v, kv_length, k_scale=k_scale, v_scale=v_scale,
        q_segment_ids=q_segment_ids, k_segment_ids=k_segment_ids,
        q_times=q_times, k_times=k_times, scale=scale, block_k=block_k,
        num_splits=num_splits, interpret=interpret, layer=layer)
    return out[:, :, :sq, :dv]


# ---------------------------------------------------------------------------
# XLA ragged decode: the same O(live-prefix) algorithm without Pallas.
# ---------------------------------------------------------------------------

def decode_ragged_xla(q, k, v, kv_length, *,
                      k_scale=None, v_scale=None,
                      q_segment_ids=None, k_segment_ids=None,
                      q_times=None, k_times=None,
                      scale: Optional[float] = None,
                      block_k: int = 128,
                      layer: Optional[int] = None):
    """Cursor-bounded online-softmax decode in pure XLA.

    A ``fori_loop`` whose trip count is the **batch-max** live block
    count (``ceil(max(kv_length) / block_k)``) — a dynamic bound, lowered
    to a while loop, so each tick's work scales with the live cache
    prefix rather than the preallocated ``max_len``. This is the
    production decode path on CPU (where interpret-mode Pallas is slow)
    and the differentiation-free XLA twin of :func:`flash_decode`.

    Two details keep it truly O(live prefix) per call:

    * **No padding, ever.** Instead of padding the cache to a block
      multiple (which would copy the whole preallocated buffer every
      tick), the final partial block clamps its slice start to
      ``S - block_k`` and masks the re-read rows out (``cols >= start``)
      so every row is folded exactly once.
    * **Layer-stacked caches are sliced in place.** With ``layer=i``
      (a static int), ``k``/``v`` are the model's full stacked
      ``(L, B, Hkv, S, .)`` cache buffers and every block read is a
      single ``dynamic_slice`` at ``(i, 0, 0, start, 0)`` — the per-layer
      ``(B, Hkv, S, .)`` view is never materialized. (Slicing the layer
      out first — e.g. threading the cache through ``lax.scan`` xs/ys —
      copies O(max_len) per layer per tick and silently erases the
      ragged win; that is exactly the regression
      ``benchmarks/rollout_bench.py`` pins.)

    Quantized caches are dequantized one block at a time inside the
    loop, so the float32 working set stays O(block), mirroring the
    kernel's per-tile VMEM dequant.
    """
    b, hq, sq, d = q.shape
    if layer is None:
        _, hkv, sk, dv = v.shape
    else:
        _, _, hkv, sk, dv = v.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    block_k = min(block_k, sk)
    kvl = jnp.asarray(kv_length, jnp.int32)
    if kvl.ndim == 0:
        kvl = jnp.broadcast_to(kvl[None], (b,))
    qf = q.astype(jnp.float32)
    n_live = (jnp.minimum(jnp.max(kvl), sk) + block_k - 1) // block_k

    def block_slice(arr, start, width, token_axis_from_end):
        """dynamic_slice of one key block straight out of ``arr`` (which
        may carry the leading layer axis), never materializing more than
        the block."""
        nd = arr.ndim
        tok_ax = nd - token_axis_from_end
        starts = [0] * nd
        sizes = list(arr.shape)
        if layer is not None:
            starts[0] = layer
            sizes[0] = 1
        starts[tok_ax] = start
        sizes[tok_ax] = width
        out = jax.lax.dynamic_slice(arr, starts, sizes)
        return out[0] if layer is not None else out

    def body(i, carry):
        m, l, acc = carry
        start_u = i * block_k                       # nominal block start
        start = jnp.minimum(start_u, sk - block_k)  # clamped (last block)
        kc = block_slice(k, start, block_k, 2).astype(jnp.float32)
        vc = block_slice(v, start, block_k, 2).astype(jnp.float32)
        if k_scale is not None:
            kc = kc * block_slice(k_scale, start, block_k, 1)[..., None]
        if v_scale is not None:
            vc = vc * block_slice(v_scale, start, block_k, 1)[..., None]
        if group > 1:
            kc = jnp.repeat(kc, group, axis=1)
            vc = jnp.repeat(vc, group, axis=1)
        s = jnp.einsum("bhnd,bhmd->bhnm", qf, kc) * scale
        cols = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, block_k), 3) \
            + start
        # rows before the nominal start were folded by an earlier block
        # (clamping only moves the final partial block backwards)
        mask = (cols < kvl[:, None, None, None]) & (cols >= start_u)
        if q_times is not None:
            ct = jax.lax.dynamic_slice_in_dim(k_times, start, block_k, 1)
            mask = mask & (ct[:, None, None, :] <= q_times[:, None, :, None])
        if q_segment_ids is not None:
            cs = jax.lax.dynamic_slice_in_dim(k_segment_ids, start,
                                              block_k, 1)
            seg = (q_segment_ids[:, None, :, None] == cs[:, None, None, :]) \
                & (cs[:, None, None, :] >= 0)
            mask = mask & seg
        s = jnp.where(mask, s, _NEG_INF)
        # zero unreachable rows' values too, not just their weights:
        # 0 * NaN is NaN, and rows beyond the cursor may carry any bit
        # pattern (a quarantined predecessor's NaN rows included). For
        # finite stale rows this is an exact no-op (0 * finite == 0).
        vc = jnp.where(mask.any(axis=(1, 2))[:, None, :, None], vc, 0.0)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new[..., None]), 0.0)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhnm,bhmd->bhnd", p, vc)
        return m_new, l_new, acc_new

    m0 = jnp.full((b, hq, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, dv), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_live, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)
