"""Pallas TPU flash-attention kernels (backward).

FlashAttention-style backward pass: never materializes the (Sq, Sk)
probability matrix. Each kernel recomputes the block logits from the saved
per-row log-sum-exp (``lse``) emitted by the forward kernel, so the whole
train step stays linear-memory on both sides of the autodiff boundary.

Two kernels mirror the forward's tiling:

  * ``dq`` kernel — grid ``(batch, q_heads, q_blocks, k_blocks)``; the key
    dimension is sequential and a ``(block_q, d)`` float32 accumulator lives
    in VMEM scratch across it. Identical iteration structure to the forward,
    so the same causal/window block-skip predicate applies.
  * ``dk/dv`` kernel — grid ``(batch, kv_heads, k_blocks, group * q_blocks)``;
    the innermost dimension walks every (q-head-in-group, q-block) pair
    sequentially while ``(block_k, d)`` / ``(block_k, dv)`` accumulators sit
    in VMEM scratch. Folding the GQA group into the sequential dimension
    gives each kv head exactly one writer, so dk/dv accumulation needs no
    cross-core reduction.

Both kernels recompute P = exp(S - lse) from q/k rather than loading it:
at block sizes 128x128 the recompute is one extra MXU matmul, far cheaper
than streaming an (Sq, Sk) tensor through HBM (the quadratic-memory cost
the paper exists to avoid).

The preprocessing row term ``delta = sum(dO * O, axis=-1)`` is computed in
plain XLA by the caller (an elementwise multiply-reduce, O(Sq) memory),
matching FlashAttention-2's separate preprocess step.

Feature parity with the forward kernel: causal masking, sliding windows,
segment ids, explicit per-token times (block-causal agent scenes), logit
soft-capping, GQA/MQA, and distinct qk/v head dims.

The public autodiff wrapper (padding + ``jax.custom_vjp`` + backend
selection) lives in ``repro.kernels.ops``; the pure-XLA fallback backward is
``ops._bwd_chunked``, kept as the parity oracle and the non-TPU path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

_NEG_INF = -1e30


def _block_probs_and_ds(q, k, v, do, lse, delta, *, scale, softcap,
                        rows, cols, causal, window, use_segments,
                        q_seg, k_seg, block_q, block_k):
    """Shared recompute: P from saved LSE, then dS (pre-softmax grad).

    All operands are float32 tiles: q (bq, d), k (bk, d), v (bk, dv),
    do (bq, dv), lse/delta (bq,). Returns (p, ds) both (bq, bk), with dS
    already including the softcap chain rule and the score scale.
    """
    s_pre = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    if softcap is not None and softcap > 0:
        t = jnp.tanh(s_pre / softcap)
        s = t * softcap
        dcap = 1.0 - t * t
    else:
        s = s_pre
        dcap = None

    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask = jnp.logical_and(mask, cols <= rows)
    if window is not None:
        mask = jnp.logical_and(mask, cols > rows - window)
    if use_segments:
        seg = jnp.logical_and(q_seg[:, None] == k_seg[None, :],
                              k_seg[None, :] >= 0)
        mask = jnp.logical_and(mask, seg)

    # P = exp(S - lse) is exactly softmax(S) restricted to this block; rows
    # that were fully masked in the forward carry lse = log(1e-30) and are
    # masked to zero here anyway.
    p = jnp.where(mask, jnp.exp(s - lse[:, None]), 0.0)
    dp = jax.lax.dot_general(
        do, v, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    if dcap is not None:
        ds = ds * dcap
    ds = ds * scale
    return p, ds


def _mask_geometry(q_time_ref, k_time_ref, q_start, k_start, *,
                   block_q, block_k, use_times):
    if use_times:
        rows = q_time_ref[0][:, None]                    # (bq, 1)
        cols = k_time_ref[0][None, :]                    # (1, bk)
    else:
        rows = jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + q_start
        cols = jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1) + k_start
    return rows, cols


def _run_predicate(q_start, k_start, *, causal, window, block_q, block_k,
                   use_times):
    """Static block-skip: False iff the (q_block, k_block) tile is entirely
    masked by the causal / sliding-window structure. Identical condition for
    the forward, dq, and dk/dv kernels: the tile either contributes or not.
    With explicit per-token times the structure is data-dependent, so no
    static skipping is possible.
    """
    run = jnp.bool_(True)
    if not use_times:
        if causal:
            run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
        if window is not None:
            run = jnp.logical_and(run,
                                  k_start + block_k - 1 > q_start - window)
    return run


# ---------------------------------------------------------------------------
# dq kernel: grid (b, hq, q_blocks, k_blocks), sequential over k blocks.
# ---------------------------------------------------------------------------

def _dq_kernel(q_seg_ref, k_seg_ref, q_time_ref, k_time_ref,
               q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_acc_ref, *,
               scale: float, causal: bool, window: Optional[int],
               softcap: Optional[float], block_q: int, block_k: int,
               num_k_blocks: int, use_segments: bool, use_times: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc_ref[...] = jnp.zeros_like(dq_acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    run = _run_predicate(q_start, k_start, causal=causal, window=window,
                         block_q=block_q, block_k=block_k,
                         use_times=use_times)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        rows, cols = _mask_geometry(q_time_ref, k_time_ref, q_start, k_start,
                                    block_q=block_q, block_k=block_k,
                                    use_times=use_times)
        _, ds = _block_probs_and_ds(
            q, k, v, do, lse, delta, scale=scale, softcap=softcap,
            rows=rows, cols=cols, causal=causal, window=window,
            use_segments=use_segments, q_seg=q_seg_ref[0], k_seg=k_seg_ref[0],
            block_q=block_q, block_k=block_k)
        dq_acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        dq_ref[0, 0, :, :] = dq_acc_ref[...].astype(dq_ref.dtype)


# ---------------------------------------------------------------------------
# dk/dv kernel: grid (b, hkv, k_blocks, group * q_blocks), sequential over
# the fused (q-head-in-group, q_block) dimension.
# ---------------------------------------------------------------------------

def _dkv_kernel(q_seg_ref, k_seg_ref, q_time_ref, k_time_ref,
                q_ref, do_ref, lse_ref, delta_ref, k_ref, v_ref,
                dk_ref, dv_ref,
                dk_acc_ref, dv_acc_ref, *,
                scale: float, causal: bool, window: Optional[int],
                softcap: Optional[float], block_q: int, block_k: int,
                num_q_blocks: int, num_inner: int, use_segments: bool,
                use_times: bool):
    ik = pl.program_id(2)
    iqg = pl.program_id(3)
    iq = jax.lax.rem(iqg, num_q_blocks)

    @pl.when(iqg == 0)
    def _init():
        dk_acc_ref[...] = jnp.zeros_like(dk_acc_ref)
        dv_acc_ref[...] = jnp.zeros_like(dv_acc_ref)

    q_start = iq * block_q
    k_start = ik * block_k
    run = _run_predicate(q_start, k_start, causal=causal, window=window,
                         block_q=block_q, block_k=block_k,
                         use_times=use_times)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        rows, cols = _mask_geometry(q_time_ref, k_time_ref, q_start, k_start,
                                    block_q=block_q, block_k=block_k,
                                    use_times=use_times)
        p, ds = _block_probs_and_ds(
            q, k, v, do, lse, delta, scale=scale, softcap=softcap,
            rows=rows, cols=cols, causal=causal, window=window,
            use_segments=use_segments, q_seg=q_seg_ref[0], k_seg=k_seg_ref[0],
            block_q=block_q, block_k=block_k)
        dv_acc_ref[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_acc_ref[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(iqg == num_inner - 1)
    def _finalize():
        dk_ref[0, 0, :, :] = dk_acc_ref[...].astype(dk_ref.dtype)
        dv_ref[0, 0, :, :] = dv_acc_ref[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, o, lse, do, *,
                        causal: bool = False,
                        window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        q_segment_ids=None, k_segment_ids=None,
                        q_times=None, k_times=None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """Raw backward kernel invocation. Requires block-aligned inputs.

    q: (B, Hq, Sq, D); k: (B, Hkv, Sk, D); v: (B, Hkv, Sk, Dv);
    o/do: (B, Hq, Sq, Dv); lse: (B, Hq, Sq) float32 (from
    ``flash_attention_fwd(..., return_lse=True)``). Returns
    (dq, dk, dv) in the dtypes of (q, k, v).

    Padded query rows must carry ``do == 0`` (the ``ops`` wrapper pads the
    cotangent with zeros), which zeroes their dk/dv contributions without
    needing a row-validity mask; padded key columns are excluded via
    segment id -1, exactly as in the forward.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    assert k.shape == (b, hkv, sk, d), (q.shape, k.shape, v.shape)
    assert do.shape == o.shape == (b, hq, sq, dv), (do.shape, o.shape)
    assert lse.shape == (b, hq, sq), lse.shape
    assert hq % hkv == 0, (hq, hkv)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    group = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    nq, nk = sq // block_q, sk // block_k
    use_segments = q_segment_ids is not None
    if not use_segments:
        q_segment_ids = jnp.zeros((b, sq), jnp.int32)
        k_segment_ids = jnp.zeros((b, sk), jnp.int32)
    use_times = q_times is not None
    if not use_times:
        q_times = jnp.zeros((b, sq), jnp.int32)
        k_times = jnp.zeros((b, sk), jnp.int32)

    # FlashAttention-2 preprocess: delta_i = sum_j dO_ij O_ij, an O(Sq)
    # elementwise reduce that XLA fuses well; not worth a kernel.
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    lse = lse.astype(jnp.float32)

    common = dict(scale=float(scale), causal=causal, window=window,
                  softcap=softcap, block_q=block_q, block_k=block_k,
                  use_segments=use_segments, use_times=use_times)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, num_k_blocks=nk, **common),
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b_, h, iq, ik: (b_, iq)),
            pl.BlockSpec((1, block_k), lambda b_, h, iq, ik: (b_, ik)),
            pl.BlockSpec((1, block_q), lambda b_, h, iq, ik: (b_, iq)),
            pl.BlockSpec((1, block_k), lambda b_, h, iq, ik: (b_, ik)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda b_, h, iq, ik: (b_, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_q, dv),
                         lambda b_, h, iq, ik: (b_, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik: (b_, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h, iq, ik: (b_, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h, iq, ik: (b_, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_segment_ids, k_segment_ids, q_times, k_times,
      q, k, v, do, lse, delta)

    # The inner dimension fuses (head-in-group, q_block): head index
    # h*group + iqg // nq, q block iqg % nq.
    num_inner = group * nq

    def _qh(h, iqg):
        return h * group + iqg // nq

    dk, dv_out = pl.pallas_call(
        functools.partial(_dkv_kernel, num_q_blocks=nq, num_inner=num_inner,
                          **common),
        grid=(b, hkv, nk, num_inner),
        in_specs=[
            pl.BlockSpec((1, block_q),
                         lambda b_, h, ik, iqg: (b_, iqg % nq)),
            pl.BlockSpec((1, block_k), lambda b_, h, ik, iqg: (b_, ik)),
            pl.BlockSpec((1, block_q),
                         lambda b_, h, ik, iqg: (b_, iqg % nq)),
            pl.BlockSpec((1, block_k), lambda b_, h, ik, iqg: (b_, ik)),
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h, ik, iqg: (b_, _qh(h, iqg),
                                                 iqg % nq, 0)),
            pl.BlockSpec((1, 1, block_q, dv),
                         lambda b_, h, ik, iqg: (b_, _qh(h, iqg),
                                                 iqg % nq, 0)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, h, ik, iqg: (b_, _qh(h, iqg), iqg % nq)),
            pl.BlockSpec((1, 1, block_q),
                         lambda b_, h, ik, iqg: (b_, _qh(h, iqg), iqg % nq)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, ik, iqg: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda b_, h, ik, iqg: (b_, h, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h, ik, iqg: (b_, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda b_, h, ik, iqg: (b_, h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, sk, d), k.dtype),
            jax.ShapeDtypeStruct((b, hkv, sk, dv), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),     # dk accumulator
            pltpu.VMEM((block_k, dv), jnp.float32),    # dv accumulator
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q_segment_ids, k_segment_ids, q_times, k_times,
      q, do, lse, delta, k, v)

    return dq, dk, dv_out
