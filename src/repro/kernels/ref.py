"""Pure-jnp oracles for the attention kernels.

These are the ground truth every Pallas kernel is tested against
(``assert_allclose`` across shape/dtype sweeps). They are also usable
implementations in their own right: ``mha_reference`` is O(S^2) memory,
``mha_chunked`` is the linear-memory XLA fallback used on CPU and inside the
dry-run (where Pallas-on-TPU cannot lower).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def _length_mask(shape, kv_len):
    """(…, Sk) mask of valid key positions given per-batch kv lengths."""
    cols = jax.lax.broadcasted_iota(jnp.int32, shape, len(shape) - 1)
    return cols < kv_len


def _kv_length_mask(kv_length, sk):
    """(B, 1, 1, Sk) bool mask of live cache rows given per-row cursors.

    ``kv_length`` is a scalar or a ``(B,)`` vector of decode cursors: key
    positions ``>= kv_length[b]`` are unwritten cache slots and must never
    be attended. This is the cursor-based masking used by the incremental
    decode path (queries are new tokens, keys are a preallocated cache).
    """
    kvl = jnp.asarray(kv_length, jnp.int32)
    if kvl.ndim == 0:
        kvl = kvl[None]
    live = jnp.arange(sk, dtype=jnp.int32)[None, :] < kvl[:, None]  # (B, Sk)
    return live[:, None, None, :]


def build_mask(sq: int, sk: int, *, causal: bool = False,
               window: Optional[int] = None,
               q_segment_ids=None, k_segment_ids=None,
               q_times=None, k_times=None,
               q_offset: int = 0):
    """Boolean (…, sq, sk) attention mask; True = may attend.

    ``q_offset`` shifts query positions (used when queries are a suffix of
    the key sequence, e.g. chunked prefill / decode). ``q_times/k_times``
    (…, S) replace token indices for the causal/window comparison —
    block-causal attention over e.g. simulation timesteps (tokens with the
    same time attend to each other bidirectionally).
    """
    if q_times is not None:
        rows = q_times[..., :, None]
        cols = k_times[..., None, :]
        mask = jnp.ones(jnp.broadcast_shapes(rows.shape, cols.shape), bool)
    else:
        rows = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 0) + q_offset
        cols = jax.lax.broadcasted_iota(jnp.int32, (sq, sk), 1)
        mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask = mask & (cols <= rows)
    if window is not None:
        mask = mask & (cols > rows - window)
    if q_segment_ids is not None and k_segment_ids is not None:
        seg = (q_segment_ids[..., :, None] == k_segment_ids[..., None, :])
        seg &= k_segment_ids[..., None, :] >= 0
        mask = mask & seg
    return mask


def _maybe_softcap(s, softcap):
    if softcap is not None and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    return s


def _repeat_kv(k, num_q_heads):
    """Broadcast KV heads to Q heads for grouped-query attention."""
    b, hkv, s, d = k.shape
    if hkv == num_q_heads:
        return k
    group = num_q_heads // hkv
    return jnp.repeat(k, group, axis=1)


def mha_reference(q, k, v, *, causal: bool = False,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None,
                  q_segment_ids=None, k_segment_ids=None,
                  q_times=None, k_times=None,
                  q_offset: int = 0,
                  kv_length=None):
    """O(S^2)-memory multi-head attention oracle.

    Shapes: q ``(B, Hq, Sq, Dqk)``; k ``(B, Hkv, Sk, Dqk)``;
    v ``(B, Hkv, Sk, Dv)``. Hkv must divide Hq (GQA/MQA). Returns
    ``(B, Hq, Sq, Dv)``.

    ``kv_length`` (scalar or ``(B,)`` int) is the decode-cursor mask: key
    positions at or beyond it are treated as unwritten cache rows and
    masked out regardless of the other mask terms.
    """
    b, hq, sq, d = q.shape
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    s = jnp.einsum("bhnd,bhmd->bhnm", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = _maybe_softcap(s, softcap)
    if q_times is not None:
        mask = build_mask(sq, k.shape[2], causal=causal, window=window,
                          q_times=q_times, k_times=k_times)[:, None]
    elif hasattr(q_offset, "ndim") and getattr(q_offset, "ndim", 0) == 1:
        # per-row query offsets (continuous batching: each slot has its own
        # decode cursor)
        rows = (jax.lax.broadcasted_iota(jnp.int32, (sq, k.shape[2]), 0)[None]
                + q_offset[:, None, None])
        cols = jax.lax.broadcasted_iota(jnp.int32, (sq, k.shape[2]), 1)[None]
        mask = jnp.ones_like(rows, dtype=bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        mask = mask[:, None]
    else:
        mask = build_mask(sq, k.shape[2], causal=causal, window=window,
                          q_offset=q_offset)[None, None]
    if q_segment_ids is not None:
        seg = build_mask(sq, k.shape[2], q_segment_ids=q_segment_ids,
                         k_segment_ids=k_segment_ids)
        mask = mask & seg[:, None]
    if kv_length is not None:
        mask = mask & _kv_length_mask(kv_length, k.shape[2])
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fully-masked rows produce uniform p over -inf logits -> force zeros
    any_valid = mask.any(axis=-1, keepdims=True)
    p = jnp.where(any_valid, p, 0.0)
    # a key row no query can reach gets weight 0 — but 0 * NaN is NaN, so
    # an unreachable row's VALUE must be zeroed too, or its bit pattern
    # (e.g. a NaN-poisoned predecessor's stale cache rows) leaks through
    # the weighted sum. Reachable rows are written rows; for finite
    # values the zeroing is exact (0 * finite == 0) so outputs are
    # bit-identical. tests/test_chaos.py pins the NaN case.
    v = jnp.where(mask.any(axis=2)[..., None], v, jnp.zeros((), v.dtype))
    return jnp.einsum("bhnm,bhmd->bhnd", p, v.astype(jnp.float32)).astype(v.dtype)


def lse_reference(q, k, *, causal: bool = False,
                  window: Optional[int] = None,
                  softcap: Optional[float] = None,
                  scale: Optional[float] = None,
                  q_segment_ids=None, k_segment_ids=None,
                  q_times=None, k_times=None):
    """O(S^2) row log-sum-exp oracle for the forward kernel's lse output.

    Returns float32 ``(B, Hq, Sq)``. Fully-masked rows evaluate to
    ``log(1e-30)``-ish garbage in both implementations; compare only over
    rows with at least one valid key.
    """
    b, hq, sq, d = q.shape
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    k = _repeat_kv(k, hq)
    s = jnp.einsum("bhnd,bhmd->bhnm", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = _maybe_softcap(s, softcap)
    mask = build_mask(sq, k.shape[2], causal=causal, window=window,
                      q_times=q_times, k_times=k_times)
    mask = mask[:, None] if q_times is not None else mask[None, None]
    if q_segment_ids is not None:
        seg = build_mask(sq, k.shape[2], q_segment_ids=q_segment_ids,
                         k_segment_ids=k_segment_ids)
        mask = mask & seg[:, None]
    s = jnp.where(mask, s, _NEG_INF)
    return jax.scipy.special.logsumexp(s, axis=-1)


def mha_grads_reference(q, k, v, g, **kwargs):
    """Gradient oracle: (dq, dk, dv) via autodiff through ``mha_reference``.

    ``g`` is the output cotangent, shaped like the attention output. Every
    kwarg of :func:`mha_reference` is accepted. This is the ground truth the
    Pallas backward kernels (and the blocked-XLA backward) are tested
    against.
    """
    def loss(q, k, v):
        return jnp.sum(mha_reference(q, k, v, **kwargs)
                       * g.astype(jnp.float32))
    return jax.grad(loss, argnums=(0, 1, 2))(q, k, v)


def auto_chunk(sk: int, max_chunks: int = 64, base: int = 512) -> int:
    """Chunk size capping the scan trip count (dry-run accuracy: unrolled
    chunk loops must stay small enough to lower)."""
    c = base
    while sk > c * max_chunks:
        c *= 2
    return c


def mha_chunked(q, k, v, *, causal: bool = False,
                window: Optional[int] = None,
                softcap: Optional[float] = None,
                scale: Optional[float] = None,
                q_segment_ids=None, k_segment_ids=None,
                q_times=None, k_times=None,
                q_offset: int = 0,
                kv_length=None,
                chunk_size: Optional[int] = None,
                unroll: bool = False):
    """Linear-memory attention in pure XLA: online softmax over KV chunks.

    This mirrors the flash-attention recurrence with a ``lax.scan`` over key
    chunks, so peak memory is O(Sq * chunk) instead of O(Sq * Sk). It is the
    implementation used where the Pallas TPU kernel is unavailable (CPU
    runs, dry-run lowering) and is the oracle's memory-scaling counterpart.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    if chunk_size is None:
        chunk_size = auto_chunk(sk)
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    if sk % chunk_size != 0:
        pad = chunk_size - sk % chunk_size
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if k_segment_ids is None:
            k_segment_ids = jnp.zeros((b, sk), jnp.int32)
            if q_segment_ids is None:
                q_segment_ids = jnp.zeros((b, sq), jnp.int32)
        k_segment_ids = jnp.pad(k_segment_ids, ((0, 0), (0, pad)),
                                constant_values=-1)
        if k_times is not None:
            k_times = jnp.pad(k_times, ((0, 0), (0, pad)))
    sk_p = k.shape[2]
    n_chunks = sk_p // chunk_size
    group = hq // hkv
    qf = q.astype(jnp.float32)
    kvl = None
    if kv_length is not None:
        kvl = jnp.asarray(kv_length, jnp.int32)
        if kvl.ndim == 0:
            kvl = kvl[None]

    def body(carry, idx):
        m, l, acc = carry
        start = idx * chunk_size
        kc = jax.lax.dynamic_slice_in_dim(k, start, chunk_size, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(v, start, chunk_size, axis=2)
        kc = jnp.repeat(kc, group, axis=1).astype(jnp.float32)
        vc = jnp.repeat(vc, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhnd,bhmd->bhnm", qf, kc) * scale
        s = _maybe_softcap(s, softcap)
        if q_times is not None:
            rows = q_times[:, :, None]                       # (B, sq, 1)
            cols = jax.lax.dynamic_slice_in_dim(k_times, start, chunk_size,
                                                axis=1)[:, None, :]
            mask = jnp.ones((b, sq, chunk_size), dtype=bool)
        elif hasattr(q_offset, "ndim") and getattr(q_offset, "ndim", 0) == 1:
            rows = (jax.lax.broadcasted_iota(jnp.int32, (sq, chunk_size), 0)
                    [None] + q_offset[:, None, None])
            cols = (jax.lax.broadcasted_iota(jnp.int32, (sq, chunk_size), 1)
                    + start)[None]
            mask = jnp.ones((b, sq, chunk_size), dtype=bool)
        else:
            rows = (jax.lax.broadcasted_iota(jnp.int32, (sq, chunk_size), 0)
                    + q_offset)[None]
            cols = (jax.lax.broadcasted_iota(jnp.int32, (sq, chunk_size), 1)
                    + start)[None]
            mask = jnp.ones((1, sq, chunk_size), dtype=bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        mask = mask[:, None]
        if q_segment_ids is not None:
            ks = jax.lax.dynamic_slice_in_dim(k_segment_ids, start, chunk_size,
                                              axis=1)
            seg = (q_segment_ids[:, :, None] == ks[:, None, :]) & (
                ks[:, None, :] >= 0)
            mask = mask & seg[:, None]
        if kvl is not None:
            live = (jax.lax.broadcasted_iota(jnp.int32, (1, chunk_size), 1)
                    + start) < kvl[:, None]
            mask = mask & live[:, None, None, :]
        s = jnp.where(mask, s, _NEG_INF)
        # zero unreachable rows' values, not just their weights: 0 * NaN
        # is NaN, and stale cache rows may carry any bit pattern (see
        # mha_reference; exact no-op for finite stale rows)
        vc = jnp.where(mask.any(axis=2)[:, :, :, None], vc,
                       jnp.zeros((), vc.dtype))
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhnm,bhmd->bhnd", p, vc)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    acc0 = jnp.zeros((b, hq, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), jnp.arange(n_chunks),
                                  unroll=n_chunks if unroll else 1)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(v.dtype)
