"""Public attention ops: padded, autodiff-capable wrappers over the kernels.

``attention(...)`` is the single entry point the model stack uses; ``impl``
selects between:

  * ``"flash"``   — the Pallas TPU kernels, forward AND backward. On CPU the
    kernels run in interpret mode (used by tests).
  * ``"chunked"`` — pure-XLA linear-memory online-softmax attention
    (``ref.mha_chunked``); the implementation lowered in the multi-pod
    dry-run, and the default on CPU where interpret-mode Pallas is slow.
  * ``"ref"``     — O(S^2) reference (small inputs / oracle).

The flash path is wired with ``jax.custom_vjp``: the forward runs the Pallas
kernel and saves its log-sum-exp rows; the backward dispatches on
``bwd_impl``:

  * ``"pallas"`` (default) — the FlashAttention-style Pallas backward kernels
    (``repro.kernels.flash_attention_bwd``): a dq kernel and a dk/dv kernel,
    both recomputing block probabilities from the saved LSE in VMEM.
  * ``"xla"``    — the blocked-XLA recurrence (``_bwd_chunked``), kept as a
    selectable fallback and as the gradient parity oracle.

Either way training stays linear-memory end to end. The process-wide default
can be overridden with the ``REPRO_FLASH_BWD`` environment variable.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import flash_attention_bwd as fab
from repro.kernels import flash_decode as fd
from repro.kernels import ref

#: Default backend for the flash-attention backward pass. ``"pallas"`` runs
#: the Pallas kernels (interpret mode off-TPU); ``"xla"`` runs the blocked
#: recurrence. Overridable per call via ``flash_attention(bwd_impl=...)``.
DEFAULT_BWD_IMPL = os.environ.get("REPRO_FLASH_BWD", "pallas")


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


# the single padding implementation for the kernels package lives next to
# the decode kernel (this module imports it; the reverse would be a cycle)
_pad_to = fd.pad_to_multiple


def _decode_block_q(sq: int, block_q: int) -> int:
    """Shrink the query block for small-q (decode) calls.

    A decode step has q_len in the single digits; padding it to the default
    128-row block wastes ~99% of the MXU work. 16 sublanes is the minimum
    tile for every supported dtype (f32 needs 8, bf16 needs 16), so round
    the query length up to a multiple of 16 and never exceed the caller's
    block_q.
    """
    if sq >= block_q:
        return block_q
    return max(16, -(-sq // 16) * 16)


def _fold_kv_length(kv_length, q_seg, k_seg, b, sq, sk):
    """Fold decode-cursor masking into the segment-id machinery.

    Key positions at or beyond ``kv_length`` (scalar or per-row ``(B,)``
    cursors) get segment id -1, which the kernel's segment mask always
    rejects — the same mechanism that hides padded key rows. This reuses
    the existing kernel feature set instead of threading another operand
    through the Pallas call (and through the custom_vjp residuals).

    **Cost caveat**: the fold only changes the *mask*, not the iteration
    space. The generic kernel (and ``ref.mha_chunked``) still fetches and
    multiplies every KV block of the preallocated cache — dead rows are
    rejected after their HBM load and MXU work are already paid, so a
    decode tick costs O(max_len) regardless of the cursor. That is fine
    for training-shaped calls (the cache IS the sequence) but wrong for
    the rollout hot path; :func:`decode_attention` dispatches decode
    shapes to the split-K ragged kernel (``flash_decode.py``), which
    bounds both loads and FLOPs by the live prefix and keeps this path
    only as the parity oracle / fallback.
    """
    kvl = jnp.asarray(kv_length, jnp.int32)
    if kvl.ndim == 0:
        kvl = jnp.broadcast_to(kvl[None], (b,))
    live = jnp.arange(sk, dtype=jnp.int32)[None, :] < kvl[:, None]  # (B, Sk)
    # Materialize BOTH sides: the kernel enables its segment mask off
    # q_segment_ids alone, and a caller may legitimately pass either side
    # without the other (e.g. cache-side ids with all-valid queries).
    if q_seg is None:
        q_seg = jnp.zeros((b, sq), jnp.int32)
    if k_seg is None:
        k_seg = jnp.zeros((b, sk), jnp.int32)
    k_seg = jnp.where(live, k_seg, -1)
    return q_seg, k_seg


# ---------------------------------------------------------------------------
# Flash path: Pallas forward + Pallas (or blocked-XLA) backward, custom_vjp.
# ---------------------------------------------------------------------------

def _pad_all(q, k, v, q_seg, k_seg, q_times, k_times, *, block_q, block_k):
    """Pad sequences to block multiples and head dims to lane multiples.

    Zero-padding the qk contraction dim leaves scores unchanged; zero-padded
    dv columns are sliced off by the caller. Padded key rows get segment id
    -1 (always masked); padded query rows produce garbage rows that the
    caller slices off (forward) or that contribute zero because the padded
    cotangent is zero (backward).
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    q, _ = _pad_to(q, 128, 3)
    k, _ = _pad_to(k, 128, 3)
    v, dv_pad = _pad_to(v, 128, 3)
    need_seg = (sq % block_q != 0) or (sk % block_k != 0)
    if q_seg is None and need_seg:
        q_seg = jnp.zeros((b, sq), jnp.int32)
        k_seg = jnp.zeros((b, sk), jnp.int32)
    if q_seg is not None:
        q_seg, _ = _pad_to(q_seg, block_q, 1, value=0)
        k_seg, _ = _pad_to(k_seg, block_k, 1, value=-1)
    if q_times is not None:
        q_times, _ = _pad_to(q_times, block_q, 1, value=0)
        k_times, _ = _pad_to(k_times, block_k, 1, value=0)
    q, q_pad = _pad_to(q, block_q, 2)
    k, _ = _pad_to(k, block_k, 2)
    v, _ = _pad_to(v, block_k, 2)
    return q, k, v, q_seg, k_seg, q_times, k_times, q_pad, dv_pad


def _flash_fwd_padded(q, k, v, q_seg, k_seg, q_times, k_times, *, causal,
                      window, softcap, scale, block_q, block_k, interpret):
    """Run the forward kernel on padded operands; returns (out, lse)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    q, k, v, q_seg, k_seg, q_times, k_times, q_pad, dv_pad = _pad_all(
        q, k, v, q_seg, k_seg, q_times, k_times,
        block_q=block_q, block_k=block_k)
    out, lse = fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_segment_ids=q_seg, k_segment_ids=k_seg,
        q_times=q_times, k_times=k_times,
        block_q=block_q, block_k=block_k, interpret=interpret,
        return_lse=True)
    if q_pad:
        out = out[:, :, :sq, :]
        lse = lse[:, :, :sq]
    if dv_pad:
        out = out[..., :dv]
    return out, lse


def _bwd_pallas(saved, g, *, causal, window, softcap, scale, block_q,
                block_k, interpret):
    """Pallas backward: pad exactly like the forward, run the dq and dk/dv
    kernels, slice the padding back off.

    The cotangent (and hence ``delta``) is zero on padded query rows, which
    zeroes their dk/dv contributions; padded key rows carry segment id -1 and
    are masked out of dq.
    """
    q, k, v, o, lse, q_seg, k_seg, q_times, k_times = saved
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    qp, kp, vp, q_seg, k_seg, q_times, k_times, _, _ = _pad_all(
        q, k, v, q_seg, k_seg, q_times, k_times,
        block_q=block_q, block_k=block_k)
    gp, _ = _pad_to(g, 128, 3)
    gp, _ = _pad_to(gp, block_q, 2)
    op, _ = _pad_to(o, 128, 3)
    op, _ = _pad_to(op, block_q, 2)
    lsep, _ = _pad_to(lse, block_q, 2)
    dq, dk, dv_grad = fab.flash_attention_bwd(
        qp, kp, vp, op, lsep, gp, causal=causal, window=window,
        softcap=softcap, scale=scale,
        q_segment_ids=q_seg, k_segment_ids=k_seg,
        q_times=q_times, k_times=k_times,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return (dq[:, :, :sq, :d], dk[:, :, :sk, :d], dv_grad[:, :, :sk, :dv])


def _bwd_chunked(saved, g, *, causal, window, softcap, scale, chunk_size=512):
    """Linear-memory attention backward (FlashAttention recurrence in XLA).

    Recomputes block logits from (q, k) chunk by chunk; never materializes
    an (Sq, Sk) tensor. Handles GQA by accumulating dk/dv over head groups.
    """
    q, k, v, o, lse, q_seg, k_seg, q_times, k_times = saved
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    qf = q.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    of = o.astype(jnp.float32)
    delta = jnp.sum(gf * of, axis=-1)                    # (b, hq, sq)

    if sk % chunk_size != 0:
        pad = chunk_size - sk % chunk_size
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if k_seg is None:
            k_seg = jnp.zeros((b, sk), jnp.int32)
            q_seg = jnp.zeros((b, sq), jnp.int32)
        k_seg = jnp.pad(k_seg, ((0, 0), (0, pad)), constant_values=-1)
        if k_times is not None:
            k_times = jnp.pad(k_times, ((0, 0), (0, pad)))
    sk_p = k.shape[2]
    n_chunks = sk_p // chunk_size

    def body(dq, idx):
        start = idx * chunk_size
        kc = jax.lax.dynamic_slice_in_dim(k, start, chunk_size, 2)
        vc = jax.lax.dynamic_slice_in_dim(v, start, chunk_size, 2)
        kcr = jnp.repeat(kc, group, axis=1).astype(jnp.float32)
        vcr = jnp.repeat(vc, group, axis=1).astype(jnp.float32)
        s_pre = jnp.einsum("bhnd,bhmd->bhnm", qf, kcr) * scale
        if softcap is not None and softcap > 0:
            t = jnp.tanh(s_pre / softcap)
            s = t * softcap
            dcap = 1.0 - t * t
        else:
            s = s_pre
            dcap = None
        if q_times is not None:
            rows = q_times[:, :, None]
            cols = jax.lax.dynamic_slice_in_dim(
                k_times, start, chunk_size, 1)[:, None, :]
            mask = jnp.ones((b, sq, chunk_size), bool)
        else:
            rows = jax.lax.broadcasted_iota(
                jnp.int32, (sq, chunk_size), 0)[None]
            cols = (jax.lax.broadcasted_iota(
                jnp.int32, (sq, chunk_size), 1) + start)[None]
            mask = jnp.ones((1, sq, chunk_size), bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        mask = mask[:, None]
        if q_seg is not None:
            ks = jax.lax.dynamic_slice_in_dim(k_seg, start, chunk_size, 1)
            seg = (q_seg[:, :, None] == ks[:, None, :]) & (ks[:, None, :] >= 0)
            mask = mask & seg[:, None]
        p = jnp.where(mask, jnp.exp(s - lse[..., None]), 0.0)
        dp = jnp.einsum("bhnd,bhmd->bhnm", gf, vcr)
        ds = p * (dp - delta[..., None])
        if dcap is not None:
            ds = ds * dcap
        ds = ds * scale
        dq = dq + jnp.einsum("bhnm,bhmd->bhnd", ds, kcr)
        dkc = jnp.einsum("bhnm,bhnd->bhmd", ds, qf)
        dvc = jnp.einsum("bhnm,bhnd->bhmd", p, gf)
        if group > 1:
            dkc = dkc.reshape(b, hkv, group, chunk_size, d).sum(axis=2)
            dvc = dvc.reshape(b, hkv, group, chunk_size, dv).sum(axis=2)
        return dq, (dkc, dvc)

    dq0 = jnp.zeros_like(qf)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, jnp.arange(n_chunks))
    dk = jnp.moveaxis(dks, 0, 2).reshape(b, hkv, sk_p, d)[:, :, :sk]
    dv = jnp.moveaxis(dvs, 0, 2).reshape(b, hkv, sk_p, dv)[:, :, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(7, 8, 9, 10, 11, 12, 13, 14))
def _flash(q, k, v, q_seg, k_seg, q_times, k_times, causal, window, softcap,
           scale, block_q, block_k, interpret, bwd_impl):
    out, _ = _flash_fwd_padded(q, k, v, q_seg, k_seg, q_times, k_times,
                               causal=causal, window=window, softcap=softcap,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return out


def _flash_fwd_rule(q, k, v, q_seg, k_seg, q_times, k_times, causal, window,
                    softcap, scale, block_q, block_k, interpret, bwd_impl):
    # The forward kernel emits its log-sum-exp rows as a second output; the
    # backward recomputes block probabilities from them, so the residuals are
    # all O(S): no (Sq, Sk) tensor is ever saved.
    out, lse = _flash_fwd_padded(q, k, v, q_seg, k_seg, q_times, k_times,
                                 causal=causal, window=window,
                                 softcap=softcap, scale=scale,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    return out, (q, k, v, out, lse, q_seg, k_seg, q_times, k_times)


def _flash_bwd_rule(causal, window, softcap, scale, block_q, block_k,
                    interpret, bwd_impl, saved, g):
    if bwd_impl == "pallas":
        dq, dk, dv = _bwd_pallas(saved, g, causal=causal, window=window,
                                 softcap=softcap, scale=scale,
                                 block_q=block_q, block_k=block_k,
                                 interpret=interpret)
    elif bwd_impl == "xla":
        dq, dk, dv = _bwd_chunked(saved, g, causal=causal, window=window,
                                  softcap=softcap, scale=scale)
    else:
        raise ValueError(f"unknown bwd_impl {bwd_impl!r} "
                         "(expected 'pallas' or 'xla')")
    return dq, dk, dv, None, None, None, None


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, *, causal: bool = False,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    q_segment_ids=None, k_segment_ids=None,
                    q_times=None, k_times=None,
                    kv_length=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None,
                    bwd_impl: Optional[str] = None):
    """Differentiable flash attention (Pallas forward and backward).

    ``bwd_impl`` selects the backward backend: ``"pallas"`` (default; the
    FlashAttention-style dq and dk/dv kernels) or ``"xla"`` (the blocked
    recurrence — the fallback and parity oracle). The default comes from
    ``DEFAULT_BWD_IMPL`` / the ``REPRO_FLASH_BWD`` environment variable.

    ``kv_length`` (scalar or per-row ``(B,)`` decode cursors) masks key
    positions at or beyond it — the incremental-decode path where ``k``/``v``
    are preallocated caches only partially written. It is folded into the
    segment-id mask, so it composes with every other feature. Small-q calls
    (``q_len < block_q``, the decode shape) automatically shrink the query
    block to the minimum legal tile instead of padding to 128 rows.
    """
    if interpret is None:
        interpret = _default_interpret()
    if bwd_impl is None:
        bwd_impl = DEFAULT_BWD_IMPL
    block_q = _decode_block_q(q.shape[2], block_q)
    if kv_length is not None:
        q_segment_ids, k_segment_ids = _fold_kv_length(
            kv_length, q_segment_ids, k_segment_ids,
            q.shape[0], q.shape[2], k.shape[2])
    return _flash(q, k, v, q_segment_ids, k_segment_ids, q_times, k_times,
                  causal, window, softcap, scale, block_q, block_k, interpret,
                  bwd_impl)


# ---------------------------------------------------------------------------
# Decode dispatcher: small-q attention over a partially-written KV cache.
# ---------------------------------------------------------------------------

def decode_attention(q, k, v, *, kv_length, impl: str = "auto",
                     scale: Optional[float] = None,
                     q_segment_ids=None, k_segment_ids=None,
                     q_times=None, k_times=None,
                     k_scale=None, v_scale=None,
                     block_k: int = 128,
                     num_splits: Optional[int] = None,
                     interpret: Optional[bool] = None,
                     layer: Optional[int] = None):
    """Attention for the incremental-decode shape: a handful of query
    tokens against a preallocated (and possibly quantized) KV cache whose
    live prefix is bounded by per-row ``kv_length`` cursors.

    ``impl`` selects:

      * ``"auto"``         — ``"flash_decode"`` on TPU, ``"xla"`` elsewhere.
      * ``"flash_decode"`` — the Pallas split-K ragged kernel
        (``repro.kernels.flash_decode``): O(live-prefix) loads and FLOPs,
        in-kernel dequantization of int8 caches.
      * ``"xla"``          — the same cursor-bounded algorithm as a pure-XLA
        ``fori_loop`` over live key blocks (dynamic trip count); the
        production path on CPU.
      * ``"ref"`` / ``"chunked"`` / ``"flash"`` — the *generic* kernels with
        ``kv_length`` folded into the mask. These scan the whole
        preallocated cache every call (see :func:`_fold_kv_length`) and are
        kept as the parity oracle for every decode flag combination —
        quantized caches are dequantized up front with
        :func:`flash_decode.dequantize_kv` before the generic call.

    ``k_scale``/``v_scale`` (B, Hkv, Sk) float32 mark ``k``/``v`` as int8
    caches with per-(head, token) scales. ``layer`` (static int) marks
    ``k``/``v`` (and scales) as the model's layer-stacked
    ``(L, B, Hkv, Sk, .)`` cache buffers, which the ragged paths index in
    place — the per-layer slice is never materialized (the generic
    fallbacks *do* materialize it; they are O(max_len) oracles either
    way). Masking semantics (block-causal ``q_times``/``k_times``,
    segment ids, GQA) match :func:`attention` with ``causal=True``;
    decode is inference-only, so none of these paths define a VJP.
    """
    if impl == "auto":
        impl = "flash_decode" if jax.default_backend() == "tpu" else "xla"
    if impl == "flash_decode":
        if interpret is None:
            interpret = _default_interpret()
        return fd.flash_decode(
            q, k, v, kv_length, k_scale=k_scale, v_scale=v_scale,
            q_segment_ids=q_segment_ids, k_segment_ids=k_segment_ids,
            q_times=q_times, k_times=k_times, scale=scale,
            block_k=block_k, num_splits=num_splits, interpret=interpret,
            layer=layer)
    if impl == "xla":
        return fd.decode_ragged_xla(
            q, k, v, kv_length, k_scale=k_scale, v_scale=v_scale,
            q_segment_ids=q_segment_ids, k_segment_ids=k_segment_ids,
            q_times=q_times, k_times=k_times, scale=scale, block_k=block_k,
            layer=layer)
    if impl in ("ref", "chunked", "flash"):
        if layer is not None:
            k = k[layer]
            v = v[layer]
            k_scale = None if k_scale is None else k_scale[layer]
            v_scale = None if v_scale is None else v_scale[layer]
        if k_scale is not None:
            k = fd.dequantize_kv(k, k_scale, dtype=q.dtype)
        if v_scale is not None:
            v = fd.dequantize_kv(v, v_scale, dtype=q.dtype)
        # Causality in decode is expressed through explicit times (the
        # query rows are *appended* tokens — their positional indices
        # 0..Sq-1 say nothing about where they sit in the cache). With no
        # times, the structural mask is the cursor bound (+ segments).
        return attention(q, k, v, impl=impl, causal=q_times is not None,
                         scale=scale,
                         q_segment_ids=q_segment_ids,
                         k_segment_ids=k_segment_ids,
                         q_times=q_times, k_times=k_times,
                         kv_length=kv_length, block_k=block_k)
    raise ValueError(f"unknown decode_attention impl {impl!r}")


# ---------------------------------------------------------------------------
# Dispatcher used by the model stack.
# ---------------------------------------------------------------------------

def attention(q, k, v, *, impl: str = "auto", causal: bool = False,
              window: Optional[int] = None, softcap: Optional[float] = None,
              scale: Optional[float] = None,
              q_segment_ids=None, k_segment_ids=None,
              q_times=None, k_times=None,
              q_offset: int = 0,
              kv_length=None,
              block_q: int = 128, block_k: int = 128,
              chunk_size: Optional[int] = None,
              bwd_impl: Optional[str] = None):
    """Multi-head attention with selectable implementation.

    ``impl="auto"`` picks flash on TPU and the chunked XLA path elsewhere.
    ``q_offset`` (chunked/ref only) offsets query positions for decode.
    ``q_times/k_times``: block-causal over explicit per-token times
    (agent-simulation scenes). ``kv_length`` (all impls; scalar or per-row
    ``(B,)`` cursors) masks cache rows at or beyond the decode cursor —
    the incremental-decode path over preallocated K/V caches. ``bwd_impl``
    (flash only) selects the backward backend, see :func:`flash_attention`.
    """
    if impl == "auto":
        impl = "flash" if jax.default_backend() == "tpu" else "chunked"
    unroll = False
    if impl == "chunked_unrolled":   # dry-run mode: expand the chunk loop so
        impl, unroll = "chunked", True  # cost_analysis sees every chunk
    if impl == "flash":
        if q_offset:
            raise NotImplementedError("q_offset requires impl='chunked'/'ref'")
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               q_segment_ids=q_segment_ids,
                               k_segment_ids=k_segment_ids,
                               q_times=q_times, k_times=k_times,
                               kv_length=kv_length,
                               block_q=block_q, block_k=block_k,
                               bwd_impl=bwd_impl)
    if impl == "chunked":
        return ref.mha_chunked(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               q_segment_ids=q_segment_ids,
                               k_segment_ids=k_segment_ids,
                               q_times=q_times, k_times=k_times,
                               q_offset=q_offset, kv_length=kv_length,
                               chunk_size=chunk_size, unroll=unroll)
    if impl == "ref":
        return ref.mha_reference(q, k, v, causal=causal, window=window,
                                 softcap=softcap, scale=scale,
                                 q_segment_ids=q_segment_ids,
                                 k_segment_ids=k_segment_ids,
                                 q_times=q_times, k_times=k_times,
                                 q_offset=q_offset, kv_length=kv_length)
    raise ValueError(f"unknown attention impl {impl!r}")
