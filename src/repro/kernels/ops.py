"""Public attention ops: padded, autodiff-capable wrappers over the kernels.

``attention(...)`` is the single entry point the model stack uses; ``impl``
selects between:

  * ``"flash"``   — the Pallas TPU kernel (forward) + a linear-memory blocked
    backward. On CPU the kernel runs in interpret mode (used by tests).
  * ``"chunked"`` — pure-XLA linear-memory online-softmax attention
    (``ref.mha_chunked``); the implementation lowered in the multi-pod
    dry-run, and the default on CPU where interpret-mode Pallas is slow.
  * ``"ref"``     — O(S^2) reference (small inputs / oracle).

The flash path is wired with ``jax.custom_vjp``: the forward runs the Pallas
kernel and also emits the log-sum-exp rows; the backward recomputes block
logits chunk-by-chunk (classic FlashAttention recurrence) so training stays
linear-memory end to end.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import ref

_NEG_INF = -1e30


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x, multiple, axis, value=0.0):
    size = x.shape[axis]
    rem = size % multiple
    if rem == 0:
        return x, 0
    pad = multiple - rem
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), pad


# ---------------------------------------------------------------------------
# Flash path: Pallas forward + blocked-XLA backward via custom_vjp.
# ---------------------------------------------------------------------------

def _flash_fwd_padded(q, k, v, q_seg, k_seg, q_times, k_times, *, causal,
                      window, softcap, scale, block_q, block_k, interpret):
    """Pad sequences to block multiples and head dims to lane multiples."""
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    # Pad head dims to a multiple of 128 (MXU lane width); zero-padding the
    # contraction dim leaves scores unchanged, zero-padding dv is sliced off.
    q, _ = _pad_to(q, 128, 3)
    k, _ = _pad_to(k, 128, 3)
    v, dv_pad = _pad_to(v, 128, 3)
    # Pad sequence lengths to block multiples; padded keys get segment -1.
    need_seg = (sq % block_q != 0) or (sk % block_k != 0)
    if q_seg is None and need_seg:
        q_seg = jnp.zeros((b, sq), jnp.int32)
        k_seg = jnp.zeros((b, sk), jnp.int32)
    if q_seg is not None:
        q_seg, _ = _pad_to(q_seg, block_q, 1, value=0)
        k_seg, _ = _pad_to(k_seg, block_k, 1, value=-1)
    if q_times is not None:
        q_times, _ = _pad_to(q_times, block_q, 1, value=0)
        k_times, _ = _pad_to(k_times, block_k, 1, value=0)
    q, q_pad = _pad_to(q, block_q, 2)
    k, _ = _pad_to(k, block_k, 2)
    v, _ = _pad_to(v, block_k, 2)
    out = fa.flash_attention_fwd(
        q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
        q_segment_ids=q_seg, k_segment_ids=k_seg,
        q_times=q_times, k_times=k_times,
        block_q=block_q, block_k=block_k, interpret=interpret)
    if q_pad:
        out = out[:, :, :sq, :]
    if dv_pad:
        out = out[..., :dv]
    return out


def _bwd_chunked(saved, g, *, causal, window, softcap, scale, chunk_size=512):
    """Linear-memory attention backward (FlashAttention recurrence in XLA).

    Recomputes block logits from (q, k) chunk by chunk; never materializes
    an (Sq, Sk) tensor. Handles GQA by accumulating dk/dv over head groups.
    """
    q, k, v, o, lse, q_seg, k_seg, q_times, k_times = saved
    b, hq, sq, d = q.shape
    _, hkv, sk, dv = v.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    qf = q.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    of = o.astype(jnp.float32)
    delta = jnp.sum(gf * of, axis=-1)                    # (b, hq, sq)

    if sk % chunk_size != 0:
        pad = chunk_size - sk % chunk_size
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if k_seg is None:
            k_seg = jnp.zeros((b, sk), jnp.int32)
            q_seg = jnp.zeros((b, sq), jnp.int32)
        k_seg = jnp.pad(k_seg, ((0, 0), (0, pad)), constant_values=-1)
        if k_times is not None:
            k_times = jnp.pad(k_times, ((0, 0), (0, pad)))
    sk_p = k.shape[2]
    n_chunks = sk_p // chunk_size

    def body(dq, idx):
        start = idx * chunk_size
        kc = jax.lax.dynamic_slice_in_dim(k, start, chunk_size, 2)
        vc = jax.lax.dynamic_slice_in_dim(v, start, chunk_size, 2)
        kcr = jnp.repeat(kc, group, axis=1).astype(jnp.float32)
        vcr = jnp.repeat(vc, group, axis=1).astype(jnp.float32)
        s_pre = jnp.einsum("bhnd,bhmd->bhnm", qf, kcr) * scale
        if softcap is not None and softcap > 0:
            t = jnp.tanh(s_pre / softcap)
            s = t * softcap
            dcap = 1.0 - t * t
        else:
            s = s_pre
            dcap = None
        if q_times is not None:
            rows = q_times[:, :, None]
            cols = jax.lax.dynamic_slice_in_dim(
                k_times, start, chunk_size, 1)[:, None, :]
            mask = jnp.ones((b, sq, chunk_size), bool)
        else:
            rows = jax.lax.broadcasted_iota(
                jnp.int32, (sq, chunk_size), 0)[None]
            cols = (jax.lax.broadcasted_iota(
                jnp.int32, (sq, chunk_size), 1) + start)[None]
            mask = jnp.ones((1, sq, chunk_size), bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        mask = mask[:, None]
        if q_seg is not None:
            ks = jax.lax.dynamic_slice_in_dim(k_seg, start, chunk_size, 1)
            seg = (q_seg[:, :, None] == ks[:, None, :]) & (ks[:, None, :] >= 0)
            mask = mask & seg[:, None]
        p = jnp.where(mask, jnp.exp(s - lse[..., None]), 0.0)
        dp = jnp.einsum("bhnd,bhmd->bhnm", gf, vcr)
        ds = p * (dp - delta[..., None])
        if dcap is not None:
            ds = ds * dcap
        ds = ds * scale
        dq = dq + jnp.einsum("bhnm,bhmd->bhnd", ds, kcr)
        dkc = jnp.einsum("bhnm,bhnd->bhmd", ds, qf)
        dvc = jnp.einsum("bhnm,bhnd->bhmd", p, gf)
        if group > 1:
            dkc = dkc.reshape(b, hkv, group, chunk_size, d).sum(axis=2)
            dvc = dvc.reshape(b, hkv, group, chunk_size, dv).sum(axis=2)
        return dq, (dkc, dvc)

    dq0 = jnp.zeros_like(qf)
    dq, (dks, dvs) = jax.lax.scan(body, dq0, jnp.arange(n_chunks))
    dk = jnp.moveaxis(dks, 0, 2).reshape(b, hkv, sk_p, d)[:, :, :sk]
    dv = jnp.moveaxis(dvs, 0, 2).reshape(b, hkv, sk_p, dv)[:, :, :sk]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(7, 8, 9, 10, 11, 12, 13))
def _flash(q, k, v, q_seg, k_seg, q_times, k_times, causal, window, softcap,
           scale, block_q, block_k, interpret):
    return _flash_fwd_padded(q, k, v, q_seg, k_seg, q_times, k_times,
                             causal=causal, window=window, softcap=softcap,
                             scale=scale, block_q=block_q, block_k=block_k,
                             interpret=interpret)


def _flash_fwd_rule(q, k, v, q_seg, k_seg, q_times, k_times, causal, window,
                    softcap, scale, block_q, block_k, interpret):
    out = _flash_fwd_padded(q, k, v, q_seg, k_seg, q_times, k_times,
                            causal=causal, window=window, softcap=softcap,
                            scale=scale, block_q=block_q, block_k=block_k,
                            interpret=interpret)
    # LSE for the backward is recomputed cheaply from the chunked recurrence;
    # we recover it from the forward pieces instead of plumbing a second
    # kernel output: lse rows are re-derived in the backward's first pass.
    lse = _lse_chunked(q, k, q_seg, k_seg, q_times, k_times, causal=causal,
                       window=window, softcap=softcap, scale=scale)
    return out, (q, k, v, out, lse, q_seg, k_seg, q_times, k_times)


def _lse_chunked(q, k, q_seg, k_seg, q_times=None, k_times=None, *, causal,
                 window, softcap, scale, chunk_size=512):
    """Row log-sum-exp of the (masked, scaled, capped) logits, O(Sq) memory."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / float(d) ** 0.5
    if sk % chunk_size != 0:
        pad = chunk_size - sk % chunk_size
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        if k_seg is None:
            k_seg = jnp.zeros((b, sk), jnp.int32)
            q_seg = jnp.zeros((b, sq), jnp.int32)
        k_seg = jnp.pad(k_seg, ((0, 0), (0, pad)), constant_values=-1)
        if k_times is not None:
            k_times = jnp.pad(k_times, ((0, 0), (0, pad)))
    n_chunks = k.shape[2] // chunk_size
    qf = q.astype(jnp.float32)

    def body(carry, idx):
        m, l = carry
        start = idx * chunk_size
        kc = jax.lax.dynamic_slice_in_dim(k, start, chunk_size, 2)
        kc = jnp.repeat(kc, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhnd,bhmd->bhnm", qf, kc) * scale
        if softcap is not None and softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        if q_times is not None:
            rows = q_times[:, :, None]
            cols = jax.lax.dynamic_slice_in_dim(
                k_times, start, chunk_size, 1)[:, None, :]
            mask = jnp.ones((b, sq, chunk_size), bool)
        else:
            rows = jax.lax.broadcasted_iota(
                jnp.int32, (sq, chunk_size), 0)[None]
            cols = (jax.lax.broadcasted_iota(
                jnp.int32, (sq, chunk_size), 1) + start)[None]
            mask = jnp.ones((1, sq, chunk_size), bool)
        if causal:
            mask = mask & (cols <= rows)
        if window is not None:
            mask = mask & (cols > rows - window)
        mask = mask[:, None]
        if q_seg is not None:
            ks = jax.lax.dynamic_slice_in_dim(k_seg, start, chunk_size, 1)
            seg = (q_seg[:, :, None] == ks[:, None, :]) & (ks[:, None, :] >= 0)
            mask = mask & seg[:, None]
        s = jnp.where(mask, s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        l_new = l * jnp.exp(m - m_new) + jnp.where(
            mask, jnp.exp(s - m_new[..., None]), 0.0).sum(-1)
        return (m_new, l_new), None

    m0 = jnp.full((b, hq, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    (m, l), _ = jax.lax.scan(body, (m0, l0), jnp.arange(n_chunks))
    return m + jnp.log(jnp.maximum(l, 1e-30))


def _flash_bwd_rule(causal, window, softcap, scale, block_q, block_k,
                    interpret, saved, g):
    dq, dk, dv = _bwd_chunked(saved, g, causal=causal, window=window,
                              softcap=softcap, scale=scale)
    return dq, dk, dv, None, None, None, None


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q, k, v, *, causal: bool = False,
                    window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    q_segment_ids=None, k_segment_ids=None,
                    q_times=None, k_times=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """Differentiable flash attention (Pallas fwd, blocked-XLA bwd)."""
    if interpret is None:
        interpret = _default_interpret()
    return _flash(q, k, v, q_segment_ids, k_segment_ids, q_times, k_times,
                  causal, window, softcap, scale, block_q, block_k, interpret)


# ---------------------------------------------------------------------------
# Dispatcher used by the model stack.
# ---------------------------------------------------------------------------

def attention(q, k, v, *, impl: str = "auto", causal: bool = False,
              window: Optional[int] = None, softcap: Optional[float] = None,
              scale: Optional[float] = None,
              q_segment_ids=None, k_segment_ids=None,
              q_times=None, k_times=None,
              q_offset: int = 0,
              block_q: int = 128, block_k: int = 128,
              chunk_size: Optional[int] = None):
    """Multi-head attention with selectable implementation.

    ``impl="auto"`` picks flash on TPU and the chunked XLA path elsewhere.
    ``q_offset`` (chunked/ref only) offsets query positions for decode.
    ``q_times/k_times``: block-causal over explicit per-token times
    (agent-simulation scenes).
    """
    if impl == "auto":
        impl = "flash" if jax.default_backend() == "tpu" else "chunked"
    unroll = False
    if impl == "chunked_unrolled":   # dry-run mode: expand the chunk loop so
        impl, unroll = "chunked", True  # cost_analysis sees every chunk
    if impl == "flash":
        if q_offset:
            raise NotImplementedError("q_offset requires impl='chunked'/'ref'")
        return flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               q_segment_ids=q_segment_ids,
                               k_segment_ids=k_segment_ids,
                               q_times=q_times, k_times=k_times,
                               block_q=block_q, block_k=block_k)
    if impl == "chunked":
        return ref.mha_chunked(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               q_segment_ids=q_segment_ids,
                               k_segment_ids=k_segment_ids,
                               q_times=q_times, k_times=k_times,
                               q_offset=q_offset, chunk_size=chunk_size,
                               unroll=unroll)
    if impl == "ref":
        return ref.mha_reference(q, k, v, causal=causal, window=window,
                                 softcap=softcap, scale=scale,
                                 q_segment_ids=q_segment_ids,
                                 k_segment_ids=k_segment_ids,
                                 q_times=q_times, k_times=k_times,
                                 q_offset=q_offset)
    raise ValueError(f"unknown attention impl {impl!r}")
