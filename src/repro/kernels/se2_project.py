"""Pallas TPU kernel: fused SE(2) Fourier query/key projection.

The linear-memory algorithm (paper Alg. 2) pre-transforms every token:

  key/value side: quadrature-sample ``cos/sin(u_m(z_j))`` at 2F nodes,
    project onto the Fourier basis (two small matmuls per spatial axis), and
    assemble the expanded ``(4F + 2)``-wide feature block;
  query side: evaluate the basis ``b_n = [g_i(theta_n)]`` and rotate by
    ``v_n^{(x/y)}`` / ``theta_n``.

Unfused, XLA materializes several ``(tokens, nb, 2F)`` intermediates in HBM
(quadrature samples, their cos/sin, and four coefficient tensors) — an
~8x blow-up of the token stream before attention even starts. This kernel
keeps the whole pipeline for a tile of tokens resident in VMEM: one read of
``(x, pose)``, one write of the expanded features.

TPU adaptation: tokens ride the sublane dimension (tiles of ``block_t``
rows); the per-block loop over the ``nb`` feature blocks is unrolled
(nb is small, ~2-8); quadrature projection is a ``(block_t, 2F) @ (2F, F)``
MXU matmul. The quadrature constants are tiny and passed as replicated
inputs so Mosaic keeps them pinned in VMEM across the grid.

Validated against the pure-jnp oracle ``repro.core.encodings.SE2Fourier``
(which doubles as ``ref`` for this kernel) in interpret mode.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels.pallas_compat import CompilerParams

from repro.core import fourier
from repro.core.encodings import SE2Fourier, _log_spaced


def _k_kernel(pose_ref, x_ref, nodes_ref, proj_ref, out_ref, *,
              num_terms: int, num_blocks: int, scales: tuple):
    """Key/value-side projection for one tile of tokens."""
    F = num_terms
    xp = pose_ref[:, 0:1]                       # (bt, 1)
    yp = pose_ref[:, 1:2]
    theta = pose_ref[:, 2:3]
    cz = nodes_ref[0:1, :]                      # (1, 2F) cos(z_j)
    sz = nodes_ref[1:2, :]                      # (1, 2F) sin(z_j)
    proj = proj_ref[...]                        # (2F, F)
    ct, st = jnp.cos(theta), jnp.sin(theta)     # (bt, 1)
    width = 4 * F + 2
    for b in range(num_blocks):
        a = scales[b]
        ux = (a * xp) * cz + (a * yp) * sz      # (bt, 2F)
        uy = -(a * xp) * sz + (a * yp) * cz
        gx = jnp.dot(jnp.cos(ux), proj, preferred_element_type=jnp.float32)
        lx = jnp.dot(jnp.sin(ux), proj, preferred_element_type=jnp.float32)
        gy = jnp.dot(jnp.cos(uy), proj, preferred_element_type=jnp.float32)
        ly = jnp.dot(jnp.sin(uy), proj, preferred_element_type=jnp.float32)
        k0 = x_ref[:, 6 * b + 0:6 * b + 1].astype(jnp.float32)
        k1 = x_ref[:, 6 * b + 1:6 * b + 2].astype(jnp.float32)
        k2 = x_ref[:, 6 * b + 2:6 * b + 3].astype(jnp.float32)
        k3 = x_ref[:, 6 * b + 3:6 * b + 4].astype(jnp.float32)
        k4 = x_ref[:, 6 * b + 4:6 * b + 5].astype(jnp.float32)
        k5 = x_ref[:, 6 * b + 5:6 * b + 6].astype(jnp.float32)
        off = b * width
        seg = jnp.concatenate(
            [gx * k0 - lx * k1, lx * k0 + gx * k1,
             gy * k2 - ly * k3, ly * k2 + gy * k3,
             ct * k4 - st * k5, st * k4 + ct * k5], axis=1)
        out_ref[:, off:off + width] = seg.astype(out_ref.dtype)


def _q_kernel(pose_ref, x_ref, basis_ref, out_ref, *,
              num_terms: int, num_blocks: int, scales: tuple):
    """Query-side projection for one tile of tokens."""
    F = num_terms
    xp = pose_ref[:, 0:1]
    yp = pose_ref[:, 1:2]
    theta = pose_ref[:, 2:3]
    ct, st = jnp.cos(theta), jnp.sin(theta)
    freqs = basis_ref[0:1, :]                   # (1, F) integer frequencies
    odd = basis_ref[1:2, :]                     # (1, F) 1.0 where g_i = sin
    zf = theta * freqs
    bvec = odd * jnp.sin(zf) + (1.0 - odd) * jnp.cos(zf)   # (bt, F)
    width = 4 * F + 2
    for b in range(num_blocks):
        a = scales[b]
        vx = -(a * xp) * ct - (a * yp) * st     # (bt, 1)
        vy = (a * xp) * st - (a * yp) * ct
        q0 = x_ref[:, 6 * b + 0:6 * b + 1].astype(jnp.float32)
        q1 = x_ref[:, 6 * b + 1:6 * b + 2].astype(jnp.float32)
        q2 = x_ref[:, 6 * b + 2:6 * b + 3].astype(jnp.float32)
        q3 = x_ref[:, 6 * b + 3:6 * b + 4].astype(jnp.float32)
        q4 = x_ref[:, 6 * b + 4:6 * b + 5].astype(jnp.float32)
        q5 = x_ref[:, 6 * b + 5:6 * b + 6].astype(jnp.float32)
        cvx, svx = jnp.cos(vx), jnp.sin(vx)
        cvy, svy = jnp.cos(vy), jnp.sin(vy)
        rx0 = q0 * cvx + q1 * svx               # rho(-v) [q0; q1]
        rx1 = -q0 * svx + q1 * cvx
        ry0 = q2 * cvy + q3 * svy
        ry1 = -q2 * svy + q3 * cvy
        t0 = q4 * ct - q5 * st                  # rho(theta) [q4; q5]
        t1 = q4 * st + q5 * ct
        off = b * width
        seg = jnp.concatenate(
            [rx0 * bvec, rx1 * bvec, ry0 * bvec, ry1 * bvec, t0, t1], axis=1)
        out_ref[:, off:off + width] = seg.astype(out_ref.dtype)


def se2_fourier_project(x, pose, enc: SE2Fourier, mode: str, *,
                        block_t: int = 256,
                        interpret: Optional[bool] = None):
    """Fused SE(2) Fourier projection.

    Args:
      x: ``(tokens, head_dim)`` query or key/value features.
      pose: ``(tokens, 3)`` SE(2) poses.
      enc: the encoding config (num_terms, scales, head_dim).
      mode: "q" for the query-side transform, "k" for key/value-side.

    Returns ``(tokens, enc.expanded_dim)``; bit-compatible (to fp32 rounding)
    with ``enc.transform_q`` / ``enc.transform_k``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    t, d = x.shape
    assert d == enc.head_dim, (d, enc.head_dim)
    F, nb = enc.num_terms, enc.num_blocks
    scales = tuple(float(s) for s in
                   _log_spaced(nb, enc.min_scale, enc.max_scale))
    c = enc.expanded_dim

    pad = (-t) % block_t
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        pose = jnp.pad(pose, ((0, pad), (0, 0)))
    tp = x.shape[0]
    grid = (tp // block_t,)
    pose32 = pose.astype(jnp.float32)

    if mode == "k":
        nodes, _ = fourier._quadrature_constants(F)  # float64 numpy
        const_nodes = jnp.asarray(
            np.stack([np.cos(nodes), np.sin(nodes)]), dtype=jnp.float32)
        proj = fourier.quadrature_projection(F, jnp.float32)
        kernel = functools.partial(_k_kernel, num_terms=F, num_blocks=nb,
                                   scales=scales)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_t, 3), lambda i: (i, 0)),
                pl.BlockSpec((block_t, d), lambda i: (i, 0)),
                pl.BlockSpec((2, 2 * F), lambda i: (0, 0)),
                pl.BlockSpec((2 * F, F), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((block_t, c), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((tp, c), x.dtype),
            compiler_params=CompilerParams(
                dimension_semantics=("parallel",)),
            interpret=interpret,
        )(pose32, x, const_nodes, proj)
    elif mode == "q":
        freqs = fourier.basis_frequencies(F).astype(np.float32)
        odd = (np.arange(F) % 2 == 1).astype(np.float32)
        basis_const = jnp.asarray(np.stack([freqs, odd]), dtype=jnp.float32)
        kernel = functools.partial(_q_kernel, num_terms=F, num_blocks=nb,
                                   scales=scales)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((block_t, 3), lambda i: (i, 0)),
                pl.BlockSpec((block_t, d), lambda i: (i, 0)),
                pl.BlockSpec((2, F), lambda i: (0, 0)),
            ],
            out_specs=pl.BlockSpec((block_t, c), lambda i: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((tp, c), x.dtype),
            compiler_params=CompilerParams(
                dimension_semantics=("parallel",)),
            interpret=interpret,
        )(pose32, x, basis_const)
    else:
        raise ValueError(f"mode must be 'q' or 'k', got {mode!r}")
    return out[:t]
