"""Compiled-cost accounting for jitted hot paths, recorded once at compile.

``launch/dryrun.py`` proved the pattern: XLA's ``cost_analysis`` /
``memory_analysis`` on an AOT-compiled executable give the *device* cost
of a program — FLOPs, HBM bytes accessed, argument/output/temp buffer
sizes — without ever running it. This module generalizes that plumbing
into an always-on accounting layer: wrap any ``jax.jit`` callable in
:class:`CostAccounted` and its compiled cost lands in the owning
registry as ``cost.*`` gauges labeled by hot-path name, exported through
the existing snapshot / Prometheus / Chrome-trace paths and rendered as
a roofline-style table by ``obs_report``.

Zero-sync contract: the analysis runs exactly once, at compile time, on
the host-side executable object — never per tick, and never touching a
device value. After the first call the wrapper is one attribute check
away from the bare compiled executable, identical whether telemetry is
on or off (the obs-on/off bit-parity tests drive both).

No jax import here: the wrapper duck-types ``fn.lower(*args).compile()``
(the AOT API), so the obs package stays importable without jax.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

from repro.obs.registry import Registry, get_registry

__all__ = ["CostAccounted", "compiled_cost", "record_compiled_cost"]

#: ``cost_analysis`` keys -> our metric names (XLA uses spaces in keys)
_COST_KEYS = {"flops": "flops", "bytes accessed": "bytes_accessed"}

#: ``memory_analysis`` attributes -> our metric names
_MEM_ATTRS = {"argument_size_in_bytes": "argument_bytes",
              "output_size_in_bytes": "output_bytes",
              "temp_size_in_bytes": "temp_bytes",
              "alias_size_in_bytes": "alias_bytes",
              "generated_code_size_in_bytes": "generated_code_bytes",
              "peak_memory_in_bytes": "peak_bytes"}


def compiled_cost(compiled: Any) -> Dict[str, float]:
    """Extract a flat ``{metric: value}`` record from a compiled
    executable's cost/memory analyses. Defensive by design: backends
    disagree on the exact surface (CPU's ``cost_analysis`` returns a
    one-element list; ``peak_memory_in_bytes`` is TPU-only), so missing
    pieces are simply absent from the record rather than raising."""
    rec: Dict[str, float] = {}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        for key, out in _COST_KEYS.items():
            v = cost.get(key)
            if v is not None:
                rec[out] = float(v)
    except Exception:
        pass
    try:
        mem = compiled.memory_analysis()
        for attr, out in _MEM_ATTRS.items():
            v = getattr(mem, attr, None)
            if v is not None:
                rec[out] = float(v)
    except Exception:
        pass
    if "peak_bytes" not in rec:
        parts = [rec.get(k) for k in
                 ("argument_bytes", "output_bytes", "temp_bytes")]
        if any(p is not None for p in parts):
            rec["peak_bytes"] = float(sum(p for p in parts if p is not None))
    return rec


def record_compiled_cost(registry: Registry, path: str, compiled: Any, *,
                         lower_s: Optional[float] = None,
                         compile_s: Optional[float] = None,
                         **labels) -> Dict[str, float]:
    """Record one compiled executable's cost as ``cost.*{path=...}``
    gauges plus a ``cost.compiled`` instant event on the timeline."""
    rec = compiled_cost(compiled)
    if lower_s is not None:
        rec["lower_seconds"] = float(lower_s)
    if compile_s is not None:
        rec["compile_seconds"] = float(compile_s)
    if registry.enabled:
        for metric, v in rec.items():
            registry.gauge(f"cost.{metric}", path=path, **labels).set(v)
        registry.counter("cost.compilations", path=path, **labels).inc()
        registry.event("cost.compiled", path=path, **labels, **rec)
    return rec


class CostAccounted:
    """Wrap a ``jax.jit`` callable so its compiled cost is accounted.

    The first call AOT-lowers and compiles (``fn.lower(*args).compile()``)
    — the same single compilation the plain jit would have done — runs
    the cost/memory analyses on the resulting executable, records them
    into ``registry`` (the process default if ``None``, resolved at
    compile time), and then *every* call, including the first, executes
    through the compiled object. Exactly one trace, one compilation, one
    accounting; per-call overhead after that is one ``is None`` check.

    Shape/dtype-polymorphic call sites cannot use this wrapper (the AOT
    executable is specialized to the first call's avals); every hot path
    in this repo is intentionally single-signature — the retrace guards
    in ``tests/test_sim_server.py`` pin that — so this is a feature: a
    second signature now fails loudly instead of silently retracing.
    """

    def __init__(self, fn: Callable, name: str, *,
                 registry: Optional[Registry] = None,
                 labels: Optional[Dict[str, str]] = None):
        self._fn = fn
        self.name = name
        self._labels = dict(labels or {})
        self._registry = registry
        self._compiled: Any = None
        self.num_compilations = 0
        self.cost: Optional[Dict[str, float]] = None

    def _cache_size(self) -> int:
        """Resident compiled programs — mirrors jit's private
        ``_cache_size`` so the zero-extra-compilation guards keep reading
        the same invariant through the wrapper."""
        return self.num_compilations

    def __call__(self, *args):
        if self._compiled is None:
            reg = (self._registry if self._registry is not None
                   else get_registry())
            t0 = time.perf_counter()
            lowered = self._fn.lower(*args)
            t1 = time.perf_counter()
            self._compiled = lowered.compile()
            t2 = time.perf_counter()
            self.num_compilations += 1
            self.cost = record_compiled_cost(
                reg, self.name, self._compiled,
                lower_s=t1 - t0, compile_s=t2 - t1, **self._labels)
        return self._compiled(*args)
