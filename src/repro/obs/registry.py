"""Zero-sync telemetry registry: counters, gauges, log-bucket histograms,
and monotonic-clock spans.

Design rules (the "zero-sync" contract — see ``docs/observability.md``):

* **Instrumentation never forces a device sync.** Every sample a metric
  ingests is a plain host ``float``/``int`` that the instrumented code
  already had — wall-clock deltas from ``time.perf_counter``, queue
  lengths, slot occupancy computed from host-side bookkeeping, byte
  counts derived from array *shape metadata*. Calling
  ``block_until_ready`` / ``float(device_array)`` from inside an
  instrument is a bug; on-device scalars must ride the output pytrees the
  pipelined drain already materializes, and get recorded *then*.

* **Disabled means free.** ``Registry(enabled=False)`` (or the module
  :data:`NULL` singleton) hands out no-op instruments and a shared no-op
  span context, so a hot loop instrumented unconditionally costs a dict
  lookup and nothing else when telemetry is off. The obs-on/obs-off
  bit-parity tests and the serve-bench overhead gate keep the *enabled*
  cost honest too.

* **Aggregates in bounded memory.** Histograms are log-bucketed
  (:data:`Histogram.buckets_per_doubling` buckets per power of two), so
  a week of tick latencies costs the same few hundred ints as a minute;
  the raw per-event record lives in the bounded trace-event ring instead
  (see :meth:`Registry.span` / ``repro.obs.export``).

Spans measure **host wall-clock between enter and exit** — for code that
only *dispatches* async device work, that is dispatch + whatever the
caller awaited, by design: the host pipeline is the thing being watched.
Device-side truth comes from the optional ``jax.profiler`` integration
(``--profile-dir`` on the launchers).
"""
from __future__ import annotations

import math
import os
import threading
import time
from typing import Any, Dict, Iterator, List, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "NULL",
           "get_registry", "set_registry"]

LabelsKey = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Dict[str, Any]) -> LabelsKey:
    if not labels:
        return ()
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    """Monotonically increasing count (events, skips, compilations).

    Thread-safe: ``inc`` may race between the SimServer drain thread and
    the submitting thread, so the read-modify-write is held under a
    per-instrument lock (plain ``+=`` on a float is *not* atomic across
    the bytecode boundary).
    """

    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: LabelsKey = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "counter", "name": self.name,
                "labels": dict(self.labels), "value": self.value}


class Gauge:
    """Last-write-wins sampled value (occupancy, resident slots, bytes).

    Thread-safe; last writer wins by definition, the lock just keeps the
    float() conversion and store from interleaving with snapshots."""

    __slots__ = ("name", "labels", "value", "_lock")

    def __init__(self, name: str, labels: LabelsKey = ()):
        self.name = name
        self.labels = labels
        self.value = float("nan")
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "gauge", "name": self.name,
                "labels": dict(self.labels), "value": self.value}


class Histogram:
    """Log-bucketed histogram over positive floats, O(1) memory per decade.

    Bucket ``i`` covers ``[2**(i/B), 2**((i+1)/B))`` with
    ``B = buckets_per_doubling``; a recorded value lands in
    ``floor(log2(v) * B)``. Percentiles are reconstructed from the bucket
    holding the target rank, reported at its *geometric midpoint*, so the
    worst-case relative error of any quantile is
    ``2**(1/(2B)) - 1`` (:attr:`max_rel_error`, ~1.1% at the default
    B=32) — plus whatever rank-interpolation difference a tiny sample
    count carries vs ``np.percentile``. Zero / negative samples count in
    a dedicated underflow bucket and sort below every positive bucket.

    Also usable standalone (outside a :class:`Registry`) as the shared
    percentile helper — ``benchmarks/serve_bench.py`` and
    ``poisson_drive`` aggregate tick latencies through it instead of
    keeping raw lists.
    """

    __slots__ = ("name", "labels", "buckets_per_doubling", "count", "sum",
                 "min", "max", "zero_count", "buckets", "_lock")

    def __init__(self, name: str = "", labels: LabelsKey = (),
                 buckets_per_doubling: int = 32):
        self.name = name
        self.labels = labels
        self.buckets_per_doubling = buckets_per_doubling
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.zero_count = 0
        self.buckets: Dict[int, int] = {}
        self._lock = threading.Lock()

    @property
    def max_rel_error(self) -> float:
        """Worst-case relative error of a bucketed quantile estimate."""
        return 2.0 ** (1.0 / (2 * self.buckets_per_doubling)) - 1.0

    def record(self, v: float) -> None:
        v = float(v)
        if math.isnan(v):
            return
        with self._lock:
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v
            if v <= 0.0:
                self.zero_count += 1
                return
            i = math.floor(math.log2(v) * self.buckets_per_doubling)
            self.buckets[i] = self.buckets.get(i, 0) + 1

    def _bucket_mid(self, i: int) -> float:
        return 2.0 ** ((i + 0.5) / self.buckets_per_doubling)

    def percentile(self, q: float) -> float:
        """Quantile ``q`` in [0, 100] at the owning bucket's geometric
        midpoint (exact-sample extremes for q at/beyond the ends)."""
        if self.count == 0:
            return float("nan")
        # nearest-rank on the bucket CDF; rank is 1-based
        rank = max(1, math.ceil(q / 100.0 * self.count))
        if rank == 1 and self.zero_count == 0:
            return self.min                 # exact extreme samples
        if rank == self.count:
            return self.max
        if rank <= self.zero_count:
            return min(self.min, 0.0)
        seen = self.zero_count
        for i in sorted(self.buckets):
            seen += self.buckets[i]
            if seen >= rank:
                # clamp to the observed envelope so p0/p100 are exact
                return min(max(self._bucket_mid(i), self.min), self.max)
        return self.max

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else float("nan")

    def snapshot(self) -> Dict[str, Any]:
        return {"kind": "histogram", "name": self.name,
                "labels": dict(self.labels), "count": self.count,
                "sum": self.sum,
                "min": self.min if self.count else float("nan"),
                "max": self.max if self.count else float("nan"),
                "p50": self.percentile(50), "p90": self.percentile(90),
                "p99": self.percentile(99),
                "buckets_per_doubling": self.buckets_per_doubling,
                "zero_count": self.zero_count,
                "buckets": {str(i): n for i, n in sorted(self.buckets.items())}}


class _Span:
    """Reusable timed region: records duration into ``<name>.seconds`` and
    appends one complete ("ph": "X") trace event on exit."""

    __slots__ = ("_reg", "name", "labels", "_t0")

    def __init__(self, reg: "Registry", name: str, labels: Dict[str, Any]):
        self._reg = reg
        self.name = name
        self.labels = labels
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self._reg.observe_span(self.name, self._t0, time.perf_counter(),
                               **self.labels)


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return None


class _NullInstrument:
    """Shared no-op counter/gauge/histogram for disabled registries."""

    __slots__ = ()
    name = ""
    labels: LabelsKey = ()
    count = 0
    sum = 0.0
    value = 0.0
    max_rel_error = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def record(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return float("nan")


_NULL_SPAN = _NullSpan()
_NULL_INSTRUMENT = _NullInstrument()

#: default capacity of the bounded trace-event ring. At ~10 spans per
#: service tick this holds hours of serving; older events are dropped
#: (counted in ``dropped_events``) rather than growing without bound.
TRACE_CAPACITY = 200_000


class Registry:
    """Process-wide home for instruments plus a bounded trace-event ring.

    Handing out instruments is idempotent per ``(kind, name, labels)`` —
    hot loops may either cache the handle or re-look it up every tick
    (one dict hit). All instruments are host-side pure-python; nothing
    here ever touches a device value.

    Instrument creation and the trace ring are guarded by a registry
    lock, and each instrument locks its own mutation, so drain /
    pipelining threads may record concurrently without lost samples.

    ``identity`` carries fleet coordinates (rank / process_index / pod /
    data, see ``repro.obs.fleet``); ``epoch`` anchors the monotonic span
    clock (``t0``) to wall time so per-rank traces from different
    processes can be merged onto one timeline.
    """

    def __init__(self, enabled: bool = True,
                 trace_capacity: int = TRACE_CAPACITY):
        self.enabled = enabled
        self.t0 = time.perf_counter()
        self.epoch = time.time()
        self.pid = os.getpid()
        self.identity: Dict[str, Any] = {}
        self._instruments: Dict[Tuple[str, str, LabelsKey], Any] = {}
        self._events: List[Dict[str, Any]] = []
        self._cap = trace_capacity
        self.dropped_events = 0
        self._lock = threading.RLock()

    def set_identity(self, **coords) -> "Registry":
        """Stamp fleet coordinates (``rank=3, pod=1, data=1, ...``) into
        this registry; they ride every snapshot and exported trace."""
        with self._lock:
            self.identity.update(coords)
        return self

    @staticmethod
    def tid() -> int:
        return threading.get_ident() % 1_000_000

    # -- instruments --------------------------------------------------------

    def _get(self, kind: str, cls, name: str, labels: Dict[str, Any]):
        if not self.enabled:
            return _NULL_INSTRUMENT
        key = (kind, name, _labels_key(labels))
        inst = self._instruments.get(key)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(key)
                if inst is None:
                    inst = cls(name, key[2])
                    self._instruments[key] = inst
        return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels)

    # -- spans / events ------------------------------------------------------

    def span(self, name: str, **labels):
        """``with registry.span("sim_server.tick"): ...`` — a monotonic
        wall-clock region; duration lands in the ``<name>.seconds``
        histogram and as one Chrome trace event."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, labels)

    def observe_span(self, name: str, t0: float, t1: float,
                     **labels) -> None:
        """Record an already-measured ``perf_counter`` interval as if it
        had run under :meth:`span` — for callers that only know after the
        fact whether an interval should count (e.g. idle service ticks
        are measured but not recorded)."""
        if not self.enabled:
            return
        self.histogram(name + ".seconds", **labels).record(t1 - t0)
        self._push_event({
            "name": name, "ph": "X", "pid": self.pid, "tid": self.tid(),
            "ts": (t0 - self.t0) * 1e6, "dur": (t1 - t0) * 1e6,
            **({"args": labels} if labels else {})})

    def event(self, name: str, **labels) -> None:
        """Instant event (straggler flagged, slot evicted, run halted)."""
        if not self.enabled:
            return
        self._push_event({
            "name": name, "ph": "i", "s": "p", "pid": self.pid,
            "tid": self.tid(),
            "ts": (time.perf_counter() - self.t0) * 1e6,
            **({"args": labels} if labels else {})})

    def _push_event(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            if len(self._events) >= self._cap:
                # drop the oldest half in one slice instead of per-event pops
                drop = self._cap // 2
                del self._events[:drop]
                self.dropped_events += drop
            self._events.append(ev)

    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    # -- snapshots -----------------------------------------------------------

    def instruments(self) -> Iterator[Any]:
        return iter(self._instruments.values())

    def snapshot(self) -> Dict[str, Any]:
        """Host-side aggregate view: every instrument's current state.
        Safe to call anywhere — reads python state only, no device sync."""
        with self._lock:
            out: Dict[str, Any] = {
                "counters": [], "gauges": [], "histograms": [],
                "dropped_events": self.dropped_events,
                "identity": dict(self.identity), "epoch": self.epoch}
            insts = sorted(self._instruments.items())
        for (kind, _, _), inst in insts:
            out[kind + "s"].append(inst.snapshot())
        return out


#: disabled singleton: pass ``registry=obs.NULL`` to switch a component's
#: telemetry off entirely (the no-perturbation tests drive both paths).
NULL = Registry(enabled=False)

_default = Registry()
_default_lock = threading.Lock()


def get_registry() -> Registry:
    """The process-wide default registry every component falls back to."""
    return _default


def set_registry(reg: Registry) -> Registry:
    """Swap the process default (tests / embedders); returns the old one."""
    global _default
    with _default_lock:
        old, _default = _default, reg
    return old
